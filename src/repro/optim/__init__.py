from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import wsd_schedule, cosine_schedule
from repro.optim.compression import (CompressionState, compress_init,
                                     compressed_gradients)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "wsd_schedule", "cosine_schedule",
           "CompressionState", "compress_init", "compressed_gradients"]
