"""AdamW in pure JAX (pytree-native, no optax dependency).

Supports bf16 parameters with f32 master moments, global-norm clipping and
decoupled weight decay.  State layout mirrors the parameter pytree so the
sharding rules (incl. ZeRO-1 over the data axis) apply leaf-for-leaf.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # 'f32' | 'int8': 8-bit moments (Dettmers-style row-wise dynamic
    # quantization) cut optimizer state 4x — what makes trillion-parameter
    # training fit the 512-chip mesh (EXPERIMENTS.md §Dry-run).
    moment_dtype: str = "f32"


def _q8_init(p):
    """(values int8/uint8, row scales f16) for a moment tensor."""
    shape = p.shape if p.ndim else (1,)
    return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape[:-1], jnp.float16))


def _q8_encode_signed(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=-1), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _q8_decode_signed(q, scale):
    return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def _q8_encode_unsigned(x):
    """nu >= 0: use the int8 range as [0, 254] for extra resolution."""
    scale = jnp.maximum(jnp.max(x, axis=-1), 1e-20) / 254.0
    q = (jnp.clip(jnp.round(x / scale[..., None]), 0, 254) - 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _q8_decode_unsigned(q, scale):
    return (q.astype(jnp.float32) + 127.0) * scale.astype(jnp.float32)[..., None]


def adamw_init(params, moment_dtype: str = "f32") -> dict:
    if moment_dtype == "int8":
        qs = [(_q8_init(p)) for p in jax.tree.leaves(params)]
        treedef = jax.tree.structure(params)
        return {
            "mu_q": jax.tree.unflatten(treedef, [q for q, _ in qs]),
            "mu_s": jax.tree.unflatten(treedef, [s for _, s in qs]),
            "nu_q": jax.tree.unflatten(treedef, [q for q, _ in
                                                 [(_q8_init(p)) for p in
                                                  jax.tree.leaves(params)]]),
            "nu_s": jax.tree.unflatten(treedef, [s for _, s in
                                                 [(_q8_init(p)) for p in
                                                  jax.tree.leaves(params)]]),
            "count": jnp.zeros((), jnp.int32),
        }
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(grads, state, params, lr: jnp.ndarray,
                 cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, dict]:
    """Returns (new_params, new_state)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    int8 = cfg.moment_dtype == "int8"

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step + cfg.weight_decay * p32)
        return p32.astype(p.dtype), mu, nu

    # explicit flatten/unflatten: NamedTuple subtrees (MoEParams, SSMParams)
    # are tuples, so tuple-based unzipping via tree.map would corrupt them
    leaves_p, treedef = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)

    if int8:
        mus = [_q8_decode_signed(q, s).reshape(p.shape) for p, q, s in
               zip(leaves_p, jax.tree.leaves(state["mu_q"]),
                   jax.tree.leaves(state["mu_s"]))]
        nus = [_q8_decode_unsigned(q, s).reshape(p.shape) for p, q, s in
               zip(leaves_p, jax.tree.leaves(state["nu_q"]),
                   jax.tree.leaves(state["nu_s"]))]
    else:
        mus = jax.tree.leaves(state["mu"])
        nus = jax.tree.leaves(state["nu"])

    outs = [upd(p, g, m, n) for p, g, m, n in
            zip(leaves_p, leaves_g, mus, nus)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    if int8:
        mq, ms, nq, ns = [], [], [], []
        for _, mu, nu in outs:
            mu = mu if mu.ndim else mu[None]
            nu = nu if nu.ndim else nu[None]
            a, b = _q8_encode_signed(mu)
            c, d = _q8_encode_unsigned(nu)
            mq.append(a); ms.append(b); nq.append(c); ns.append(d)
        return new_params, {
            "mu_q": jax.tree.unflatten(treedef, mq),
            "mu_s": jax.tree.unflatten(treedef, ms),
            "nu_q": jax.tree.unflatten(treedef, nq),
            "nu_s": jax.tree.unflatten(treedef, ns),
            "count": count,
        }
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}
