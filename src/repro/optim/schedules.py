"""LR schedules.  WSD (Warmup-Stable-Decay) is MiniCPM's schedule
(arXiv:2404.06395) — the assigned minicpm-2b config trains with it."""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def wsd_schedule(peak_lr: float, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_frac: float = 0.1) -> Callable:
    """Warmup -> Stable plateau -> exponential Decay (MiniCPM WSD)."""

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        in_decay = jnp.maximum(step - warmup_steps - stable_steps, 0.0)
        decay_ratio = jnp.minimum(in_decay / jnp.maximum(decay_steps, 1), 1.0)
        decay_mult = final_frac ** decay_ratio
        return jnp.where(step < warmup_steps + stable_steps, warm,
                         peak_lr * decay_mult)

    return lr


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) /
                     jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return lr
