"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback (residual carried in f32 across steps).

At 512+ chips the DP gradient all-reduce is a first-order cost; int8 cuts
its bytes 4x (vs f32) at the price of quantization noise, which error
feedback re-injects the next step so the optimizer sees an unbiased
long-run gradient.  Applied per-leaf with per-tensor scale.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any            # pytree of f32 error-feedback residuals


def compress_init(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def _quantize(g):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_gradients(grads, state: CompressionState
                         ) -> Tuple[Any, CompressionState]:
    """Simulate the compress -> all-reduce -> decompress path.

    Under pjit the actual all-reduce is inserted by SPMD on the int8
    values; this function applies the quantize/dequantize transfer
    function and maintains the error-feedback residual, which is the
    numerics-relevant part on any topology.
    """
    def leaf(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize(g32)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(state.residual)
    outs = [leaf(g, r) for g, r in zip(leaves_g, leaves_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, CompressionState(residual=new_r)
