"""MiniCPM-2B — dense LM, WSD schedule (llama-like arch).
[arXiv:2404.06395; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,          # MHA (GQA with kv == heads)
    d_ff=5760,
    vocab=122_753,
    head_dim=64,
    rope_theta=10_000.0,
    source="arXiv:2404.06395; hf (WSD schedule: see repro.optim.schedules)",
)
