from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    all_archs,
    cell_applicable,
    get_arch,
)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MoEConfig", "SSMConfig",
           "ShapeConfig", "all_archs", "cell_applicable", "get_arch"]
