"""CodeQwen1.5-7B — dense LM, Qwen1.5 architecture.
[hf:Qwen/CodeQwen1.5-7B]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13_440,
    vocab=92_416,
    head_dim=128,
    rope_theta=1_000_000.0,   # qwen1.5 long-context base
    source="hf:Qwen/CodeQwen1.5-7B",
)
