"""MusicGen-large — decoder-only transformer over EnCodec audio tokens:
4 parallel codebooks (vocab 2048 each) summed at the embedding and
predicted by 4 parallel heads.  The EnCodec frontend is a STUB
(input_specs() provides the token grid). [arXiv:2306.05284; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    head_dim=64,
    rope_theta=10_000.0,
    n_codebooks=4,
    source="arXiv:2306.05284; hf",
)
