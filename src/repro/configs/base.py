"""Architecture + shape configuration schema.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeConfig`.  ``registry()`` maps
``--arch`` ids to configs (one module per arch under ``repro.configs``).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int          # per-expert FFN hidden dim
    # 'ep' shards the expert dim over the model axis (needs n_experts >=
    # axis size); 'tp' shards each expert's d_expert instead (few experts)
    sharding: str = "ep"
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128             # SSD chunk length (state-space duality)
    conv_kernel: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free families
    n_kv_heads: int
    d_ff: int                    # dense FFN hidden (0 for pure-SSM / pure-MoE)
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    swa_window: int = 0          # sliding-window size; 0 = full causal
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # vlm: every Nth layer is a cross-attention layer over image tokens
    cross_attn_every: int = 0
    n_frontend_tokens: int = 0   # precomputed patch/frame embeddings (stub)
    # audio: EnCodec-style parallel codebooks summed at the embedding
    n_codebooks: int = 0
    source: str = ""             # provenance note
    attn_block: int = 256        # flash-attention q/kv tile (probes set = S)
    attn_impl: str = "masked"    # 'masked' (full nq x nk grid, paper-faithful
                                 # baseline) | 'triangular' (§Perf hillclimb:
                                 # only reachable block pairs)
    kv_dtype: str = "model"      # decode KV cache dtype: 'model' (= activations)
                                 # | 'int8' (§Perf hillclimb: per-(slot,head)
                                 # scaled quantization, halves KV bytes)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the vocab axis shards over
        the model axis (e.g. minicpm's 122753 -> 122880).  Pad logits are
        never targeted by the loss and are masked at sampling time."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the 500k-context shape: SSM / hybrid / SWA archs."""
        return self.family in ("ssm", "hybrid") or self.swa_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, V = self.d_model, self.vocab
        total = V * d                       # embedding
        if not self.tie_embeddings:
            total += d * V                  # lm head
        total += d                          # final norm
        per_layer = self._per_layer_params()
        total += self.n_layers * per_layer
        if self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            total += n_cross * self._cross_layer_params()
        if self.n_codebooks:
            total += (self.n_codebooks - 1) * V * d  # extra codebook embeds
        return total

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        return q + kv + o

    def _ffn_params(self) -> int:
        if self.moe is not None:
            router = self.d_model * self.moe.n_experts
            expert = 3 * self.d_model * self.moe.d_expert  # gate/up/down
            return router + self.moe.n_experts * expert
        return 3 * self.d_model * self.d_ff

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        s = self.ssm
        d_in = s.expand * d
        n_heads = d_in // s.head_dim
        in_proj = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)
        conv = (d_in + 2 * s.n_groups * s.d_state) * s.conv_kernel
        out = d_in * d + d_in  # out_proj + gated norm
        return in_proj + conv + out + 2 * n_heads  # + A_log, D

    def _per_layer_params(self) -> int:
        d = self.d_model
        norms = 2 * d
        if self.family == "ssm":
            return d + self._ssm_params()
        if self.family == "hybrid":
            return norms + self._attn_params() + self._ssm_params() + self._ffn_params()
        return norms + self._attn_params() + self._ffn_params()

    def _cross_layer_params(self) -> int:
        return 2 * self.d_model + self._attn_params()

    def active_param_count(self) -> int:
        """Active parameters per token (= total for non-MoE)."""
        if self.moe is None:
            return self.param_count()
        full_ffn = self.moe.n_experts * 3 * self.d_model * self.moe.d_expert
        active_ffn = self.moe.top_k * 3 * self.d_model * self.moe.d_expert
        return self.param_count() - self.n_layers * (full_ffn - active_ffn)

    def reduced(self, **overrides) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: Dict = dict(
            n_layers=2,
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16 if self.n_heads else 0,
            swa_window=min(self.swa_window, 32) if self.swa_window else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_frontend_tokens=8 if self.n_frontend_tokens else 0,
            n_codebooks=self.n_codebooks and 2,
            name=self.name + "-smoke",
        )
        if self.moe is not None:
            small["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                     sharding=self.moe.sharding,
                                     capacity_factor=8.0)  # drop-free for parity
        if self.ssm is not None:
            small["ssm"] = SSMConfig(d_state=8, head_dim=16, expand=2, chunk=8)
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "minicpm-2b",
    "codeqwen1_5-7b",
    "glm4-9b",
    "h2o-danube-3-4b",
    "hymba-1_5b",
    "llama-3_2-vision-90b",
    "mamba2-2_7b",
    "kimi-k2-1t-a32b",
    "mixtral-8x7b",
    "musicgen-large",
]


def normalize_arch_id(arch_id: str) -> str:
    return arch_id.replace(".", "_").replace("_", "-").replace("-", "_")


def get_arch(arch_id: str) -> ArchConfig:
    """Load ``repro.configs.<arch>`` and return its CONFIG."""
    key = arch_id.replace(".", "_").replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def all_archs() -> Dict[str, ArchConfig]:
    return {aid: get_arch(aid) for aid in ARCH_IDS}


def cell_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is this (arch x shape) dry-run cell runnable?  (See DESIGN.md §5.)"""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k decode requires sub-quadratic attention (skip per assignment)"
    return True, ""
