"""Mamba2-2.7B — attention-free SSM using the SSD (state-space duality)
algorithm: chunked intra-chunk matmuls + inter-chunk state recurrence.
[arXiv:2405.21060]"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,                # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
    source="arXiv:2405.21060",
)
