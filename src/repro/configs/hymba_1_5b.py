"""Hymba-1.5B — hybrid head architecture: attention heads and Mamba(2)
heads run in PARALLEL inside every layer and their (normed) outputs fuse.
[arXiv:2411.13676; hf]"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32_001,
    head_dim=64,
    rope_theta=10_000.0,
    swa_window=1024,          # hymba uses SWA on most layers
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=128),
    source="arXiv:2411.13676; hf",
)
