"""GLM4-9B — dense LM, aggressive GQA (2 KV heads), RoPE.
[hf:THUDM/glm-4-9b]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13_696,
    vocab=151_552,
    head_dim=128,
    rope_theta=500_000.0,
    source="hf:THUDM/glm-4-9b",
)
