"""H2O-Danube-3-4B — dense LM, llama+mistral mix with sliding-window
attention.  SWA makes it eligible for the 500k-context decode shape.
[arXiv:2401.16818]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10_240,
    vocab=32_000,
    head_dim=120,
    rope_theta=100_000.0,
    swa_window=4096,          # mistral-style sliding window
    source="arXiv:2401.16818",
)
