"""Kimi K2 — trillion-parameter MoE: 384 experts, top-8 routing,
~32B active parameters.  The headline case for the paper's technique:
the expert store dwarfs HBM and lives in the capacity tier, with the
HBM expert cache run by the CXL-SSD-Sim replacement policies.
[arXiv:2501.kimi2 (paper-table)]"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,                   # FFN is fully MoE
    vocab=163_840,
    head_dim=128,
    rope_theta=500_000.0,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, sharding="ep"),
    source="arXiv:2501.kimi2 (paper-table); ~1.05T total / ~32B active",
)
