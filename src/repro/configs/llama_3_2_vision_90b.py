"""Llama-3.2-Vision-90B backbone — every 5th layer cross-attends to
precomputed image patch embeddings (the vision frontend is a STUB per the
assignment: input_specs() provides the patch embeddings).
[hf:meta-llama/Llama-3.2-90B-Vision]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,       # 100 layers -> 20 cross-attention layers
    n_frontend_tokens=1600,   # precomputed image patch embeddings (stub)
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B scale-up)",
)
