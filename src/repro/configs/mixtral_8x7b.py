"""Mixtral-8x7B — 8 experts, top-2 routing, sliding-window attention.
With only 8 experts the model axis (16) shards INSIDE each expert
(``sharding='tp'``). [arXiv:2401.04088; hf]"""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,
    vocab=32_000,
    head_dim=128,
    rope_theta=1_000_000.0,
    swa_window=4096,          # per assignment spec
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=14_336, sharding="tp"),
    source="arXiv:2401.04088; hf",
)
