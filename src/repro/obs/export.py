"""Render a :class:`~repro.core.replay.metrics.MetricsBundle` to
Chrome/Perfetto ``trace_events`` JSON.

Layout (one *process* per track group, named via ``process_name``
metadata so ui.perfetto.dev groups them):

* one process per **host**, carrying counter tracks (``ph: "C"``) sampled
  once per tick window — ``bandwidth_gbps`` (window bytes over the window
  wall time), ``occupancy`` (latency-ticks accumulated per window tick:
  average requests in flight, Little's law), and ``hit_rate``;
* one ``fabric`` process with a complete event (``ph: "X"``) per **port**
  spanning the observed run, its counters (bytes, packets, queued /
  occupied ticks, QoS throttle events, per-host attribution) as ``args``,
  plus one event per ECMP pair carrying the per-path selection counts;
* one ``devices`` process with a complete event per **device** (media
  counters + per-device p50/p95/p99 latency ticks as ``args``) and per
  **flash** instance (write amplification inputs);
* when the run carried an active fault plan, one ``faults`` process with
  an instant event (``ph: "i"``) per nonzero fault counter (link CRC
  retries, failovers, degraded accesses, NAND read retries, retired
  blocks, poisoned reads) plus one summary event carrying all counters;
  pass ``down_windows=`` (the span dicts from
  :func:`repro.core.replay.metrics.down_window_spans`) to additionally
  render each down-link window as a duration event (``ph: "X"``) on the
  tick axis, one track per (host, link) — the degraded intervals line up
  under the host bandwidth/occupancy tracks they explain.

Timestamps are microseconds (the trace_events unit); 1 tick = 1 ps, so
``ts = ticks / 1e6``.  The output is plain JSON — no Perfetto SDK, no
protobuf, no new dependencies.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.core.replay.metrics import MetricsBundle, percentile_from_hist

_TICKS_PER_US = 1_000_000   # 1 tick = 1 ps


def _bundle_of(obj) -> MetricsBundle:
    if isinstance(obj, MetricsBundle):
        return obj
    mb = getattr(obj, "metrics", None)
    if isinstance(mb, MetricsBundle):
        return mb
    raise TypeError(
        "to_perfetto needs a MetricsBundle or a result carrying one "
        "(run the driver/engine with metrics=MetricsSpec(...))")


def _observed_ticks(mb: MetricsBundle) -> int:
    """Upper edge of the last non-empty window — the run span the counter
    tracks cover (a lower bound on wall ticks, exact when the run ends
    inside the windowed range)."""
    last = 0
    for host_rows in mb.windows:
        for w, row in enumerate(host_rows):
            if any(int(x) for x in row):
                last = max(last, w + 1)
    return last * mb.spec.window_ticks


def _pcts_args(hist_row) -> Dict[str, int]:
    out = {}
    for q in (50, 95, 99):
        p = percentile_from_hist(hist_row, q)
        if p is not None:
            out[f"p{q}_ticks"] = int(p["hi"])
    return out


def to_perfetto(bundle_or_result, down_windows=None) -> Dict:
    """Build the ``trace_events`` JSON document (as a dict) for a metrics
    bundle, or for any replay/driver result carrying one.  ``down_windows``
    optionally adds the transport down-link spans
    (:func:`repro.core.replay.metrics.down_window_spans`) to the faults
    track group."""
    mb = _bundle_of(bundle_or_result)
    wt = mb.spec.window_ticks
    events: List[Dict] = []

    def proc(pid: int, name: str) -> None:
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": name}})

    def counter(pid: int, name: str, ts_us: float, value) -> None:
        events.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                       "ts": ts_us, "args": {"value": value}})

    # ------------------------------------------------- host counter tracks
    for h, host in enumerate(mb.hosts):
        pid = h + 1
        proc(pid, f"host {host}")
        for w, row in enumerate(mb.windows[h]):
            nbytes, lat, n, hits = (int(x) for x in row)
            if not (nbytes or lat or n or hits):
                continue
            ts = (w * wt) / _TICKS_PER_US
            # bytes per window-second: bytes/(wt ps) -> GB/s is *1e3/wt
            counter(pid, "bandwidth_gbps", ts,
                    round(nbytes * 1e3 / wt, 6))
            counter(pid, "occupancy", ts, round(lat / wt, 6))
            counter(pid, "hit_rate", ts,
                    round(hits / n, 6) if n else 0.0)
        # zero-terminate each track so the last window renders with width
        end = _observed_ticks(mb) / _TICKS_PER_US
        for name in ("bandwidth_gbps", "occupancy", "hit_rate"):
            counter(pid, name, end, 0)

    dur = max(_observed_ticks(mb), 1) / _TICKS_PER_US

    # -------------------------------------------------------- fabric ports
    if mb.ports or mb.ecmp:
        pid = len(mb.hosts) + 1
        proc(pid, "fabric")
        for tid, (key, row) in enumerate(sorted(mb.ports.items())):
            events.append({"name": f"port {key}", "ph": "X", "pid": pid,
                           "tid": tid, "ts": 0.0, "dur": dur,
                           "args": {k: v for k, v in row.items()}})
        for tid, (key, counts) in enumerate(sorted(mb.ecmp.items()),
                                            start=len(mb.ports)):
            events.append({"name": f"ecmp {key}", "ph": "X", "pid": pid,
                           "tid": tid, "ts": 0.0, "dur": dur,
                           "args": {f"path{i}": int(c)
                                    for i, c in enumerate(counts)}})

    # ------------------------------------------------------------- devices
    pid = len(mb.hosts) + 2
    proc(pid, "devices")
    for d, name in enumerate(mb.devices):
        args = dict(mb.media[d]) if d < len(mb.media) else {}
        if d < len(mb.dev_hist):
            args.update(_pcts_args(mb.dev_hist[d]))
        events.append({"name": name, "ph": "X", "pid": pid, "tid": d,
                       "ts": 0.0, "dur": dur, "args": args})
    for i, f in enumerate(mb.flash):
        hw, gw = f["host_writes"], f["gc_writes"]
        args = dict(f)
        args["write_amplification"] = round((hw + gw) / hw, 6) if hw else 1.0
        events.append({"name": f"flash{i}", "ph": "X", "pid": pid,
                       "tid": len(mb.devices) + i, "ts": 0.0, "dur": dur,
                       "args": args})

    # -------------------------------------------------------------- faults
    if mb.faults is not None or down_windows:
        pid = len(mb.hosts) + 3
        proc(pid, "faults")
        tid = 1
        if mb.faults is not None:
            events.append({"name": "fault_counters", "ph": "X", "pid": pid,
                           "tid": 0, "ts": 0.0, "dur": dur,
                           "args": {k: int(v)
                                    for k, v in mb.faults.items()}})
            for k, v in sorted(mb.faults.items()):
                if not int(v):
                    continue
                events.append({"name": f"{k}={int(v)}", "ph": "i",
                               "pid": pid, "tid": tid, "ts": dur, "s": "p",
                               "args": {k: int(v)}})
                tid += 1
        # one track per (host, link): the window a down link was declared
        # over, mapped from access ordinals to ticks by the issue column
        tracks: Dict[str, int] = {}
        for span in down_windows or ():
            label = f"down {span['link']} @{span['host']}"
            t = tracks.setdefault(label, tid + len(tracks))
            ts = span["start_tick"] / _TICKS_PER_US
            events.append({
                "name": label, "ph": "X", "pid": pid, "tid": t, "ts": ts,
                "dur": max(span["end_tick"] / _TICKS_PER_US - ts,
                           1.0 / _TICKS_PER_US),
                "args": {k: (int(v) if not isinstance(v, str) else v)
                         for k, v in span.items()}})

    return {"traceEvents": events, "displayTimeUnit": "ns",
            "otherData": {
                "generator": "repro.obs",
                "hosts": list(mb.hosts),
                "devices": list(mb.devices),
                "window_ticks": wt,
            }}


def write_perfetto(bundle_or_result, path: str,
                   indent: Optional[int] = None,
                   down_windows=None) -> str:
    """Serialize :func:`to_perfetto` output to ``path``; returns ``path``."""
    doc = to_perfetto(bundle_or_result, down_windows=down_windows)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=indent)
    return path
