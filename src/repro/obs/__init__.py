"""Observability exports for replay telemetry.

Thin, dependency-free façade over :mod:`repro.core.replay.metrics`:
configure a run with :class:`MetricsSpec`, get a :class:`MetricsBundle`
back on the result (``result.metrics``), and render it to a Chrome/Perfetto
``trace_events`` JSON with :func:`to_perfetto` / :func:`write_perfetto`
(open in https://ui.perfetto.dev or ``chrome://tracing``).
"""

from repro.core.replay.metrics import (
    MetricsBundle,
    MetricsSpec,
    bucket_bounds,
    percentile_from_hist,
)
from repro.obs.export import to_perfetto, write_perfetto

__all__ = [
    "MetricsBundle",
    "MetricsSpec",
    "bucket_bounds",
    "percentile_from_hist",
    "to_perfetto",
    "write_perfetto",
]
