"""Pallas TPU kernel: prefill flash attention (tiled online softmax).

Grid ``(BH, nq, nk)`` with the kv axis innermost — TPU executes the grid
sequentially, so the running (m, l, acc) for one query tile lives in VMEM
scratch across the kv steps and the output tile is written on the last one.
Block shapes default to ``(128, head_dim)`` — MXU-aligned when head_dim is a
multiple of 128 (the wrapper pads).  Causal tiles that are fully masked
skip their matmuls via ``pl.when``.

Wrapper handles GQA by folding the group into the query tile index map, so
KV tiles are never materialized per-head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  bq: int, bk: int, seq_q: int, seq_kv: int,
                  causal: bool, window: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc[...], NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc[...])
        acc_sc[...] = jnp.zeros_like(acc_sc[...])

    q_start = qi * bq
    k_start = ki * bk

    def _tile():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= q_pos >= k_pos
            if window > 0:
                mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot(p, v)
        m_sc[...] = m_new

    if causal:
        # skip tiles the causal/window mask kills entirely
        live = k_start <= q_start + bq - 1
        if window > 0:
            live = jnp.logical_and(live, k_start + bk - 1 >= q_start - window + 1)
        pl.when(live)(_tile)
    else:
        _tile()

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-37)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, Skv, KV, hd). Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5

    bq = min(bq, S)
    bk = min(bk, Skv)
    pad_q = (-S) % bq
    pad_k = (-Skv) % bk
    pad_d = (-hd) % 128 if not interpret else 0   # MXU lane alignment on TPU
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, pad_d)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    Sq, Sk, d = S + pad_q, Skv + pad_k, hd + pad_d

    # (B*KV*G, Sq, d) query-major; KV stays (B*KV, Sk, d)
    qf = qp.transpose(0, 2, 1, 3).reshape(B * KV * G, Sq, d)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)

    nq, nk = Sq // bq, Sk // bk
    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_q=S, seq_kv=Skv,
                             causal=causal, window=window, scale=scale)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kern,
        grid=(B * KV * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, KV * G, Sq, d).transpose(0, 2, 1, 3)
    return out[:, :S, :, :hd]
