"""Pallas TPU kernel: page gather/scatter for the tiered KV/expert store.

The TPU-side half of the paper's DRAM-cache fill path: given a page table
(produced by the CXL-SSD-Sim replacement policies in ``repro.tiered``),
gather the referenced pages from the resident pool into a dense output —
one page per grid step, with the page index delivered by scalar prefetch so
the DMA source address is known before the body runs (Pallas pipelines the
copies).  ``page_scatter`` is the eviction path (dense -> pool).

A "page" here is one KV page: (page_tokens, kv_heads * head_dim * 2) — the
4 KB-flash-page analogue at the model level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(table_ref, pool_ref, out_ref):
    out_ref[...] = pool_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather(pool: jnp.ndarray, table: jnp.ndarray, *,
                interpret: bool = True) -> jnp.ndarray:
    """pool: (P, R, C) resident pages; table: (n,) int32 page indices.
    Returns (n, R, C) gathered pages."""
    P, R, C = pool.shape
    n = table.shape[0]
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, R, C), lambda i, table: (table[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, R, C), lambda i, table: (i, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, R, C), pool.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pool)


def _scatter_kernel(table_ref, pages_ref, pool_in_ref, pool_out_ref):
    pool_out_ref[...] = pages_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_scatter(pool: jnp.ndarray, table: jnp.ndarray, pages: jnp.ndarray, *,
                 interpret: bool = True) -> jnp.ndarray:
    """Write pages (n, R, C) into pool slots table (n,); returns new pool.
    (Eviction/fill path of the HBM page cache.)  The pool is aliased
    input->output so untouched slots carry over without a copy."""
    P, R, C = pool.shape
    n = table.shape[0]
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(n,),
            in_specs=[
                pl.BlockSpec((1, R, C), lambda i, table: (i, 0, 0)),
                pl.BlockSpec((1, R, C), lambda i, table: (table[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, R, C), lambda i, table: (table[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((P, R, C), pool.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(table.astype(jnp.int32), pages, pool)
