"""Pallas TPU kernel: set-associative cache-replay (the simulator hot-spot).

The paper's DRAM-cache layer decides hit/miss/evict for every 64 B access;
replaying long address traces against that state machine is the dominant
compute of trace-driven evaluation.  This kernel keeps the full cache state
— tags, timestamps, dirty bits, laid out ``(ways, sets)`` so the set axis
rides the 128-wide lanes — in VMEM scratch that persists across a
sequential grid, streaming the trace through in ``(1, T)`` chunks.

The update rule is bit-identical to :func:`repro.core.cache.trace_sim._run_trace`
(the lax.scan oracle), which in turn matches the pure-Python policy objects.
Cache replay is inherently sequential (every access depends on the state
left by the previous one), so the kernel is latency-bound scalar work per
access; TPU leverage comes from running independent sweeps (policies,
capacities, workloads) in parallel via vmap over ``pallas_call`` — see
``benchmarks/kernel_bench.py``.

VMEM budget: ``3 * ways * sets * 4`` bytes for state (default 8x4096 ->
384 KB) + two ``(1, T)`` int32 trace blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -(2**31) + 1


def _cache_sim_kernel(pages_ref, writes_ref, hits_ref, evicts_ref,
                      tags_ref, meta_ref, dirty_ref, *,
                      num_sets: int, ways: int, chunk: int, is_lru: bool):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        tags_ref[...] = jnp.full((ways, num_sets), -1, jnp.int32)
        meta_ref[...] = jnp.zeros((ways, num_sets), jnp.int32)
        dirty_ref[...] = jnp.zeros((ways, num_sets), jnp.int32)

    base_t = step * chunk

    def body(i, _):
        page = pages_ref[0, i]
        wr = writes_ref[0, i]
        t = base_t + i + 1
        s = jax.lax.rem(page, num_sets)

        line_tags = tags_ref[:, pl.ds(s, 1)][:, 0]    # (W,)
        line_meta = meta_ref[:, pl.ds(s, 1)][:, 0]
        line_dirty = dirty_ref[:, pl.ds(s, 1)][:, 0]

        match = line_tags == page
        hit = jnp.any(match)
        hit_way = jnp.argmax(match)

        valid = line_tags >= 0
        victim_key = jnp.where(valid, line_meta, NEG)
        victim_way = jnp.argmin(victim_key)
        way = jnp.where(hit, hit_way, victim_way).astype(jnp.int32)

        dirty_evict = jnp.logical_and(
            jnp.logical_and(~hit, valid[victim_way]),
            line_dirty[victim_way] > 0)

        new_tag = jnp.where(hit, line_tags[way], page)
        stamp = jnp.where(hit,
                          jnp.where(is_lru, t, line_meta[way]),
                          t).astype(jnp.int32)
        new_dirty = jnp.where(hit, line_dirty[way] | wr, wr).astype(jnp.int32)

        line_tags = line_tags.at[way].set(new_tag)
        line_meta = line_meta.at[way].set(stamp)
        line_dirty = line_dirty.at[way].set(new_dirty)
        tags_ref[:, pl.ds(s, 1)] = line_tags[:, None]
        meta_ref[:, pl.ds(s, 1)] = line_meta[:, None]
        dirty_ref[:, pl.ds(s, 1)] = line_dirty[:, None]

        hits_ref[0, i] = hit.astype(jnp.int32)
        evicts_ref[0, i] = dirty_evict.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit, static_argnames=("num_sets", "ways", "policy",
                                             "chunk", "interpret"))
def cache_sim(pages: jnp.ndarray, writes: jnp.ndarray, *, num_sets: int,
              ways: int, policy: str = "lru", chunk: int = 512,
              interpret: bool = True):
    """Replay a trace. pages: (N,) int32; writes: (N,) bool.
    Returns (hits (N,) bool, dirty_evicts (N,) bool)."""
    if policy not in ("lru", "fifo", "direct"):
        raise ValueError(f"kernel supports lru/fifo/direct, got {policy!r}")
    if policy == "direct" and ways != 1:
        raise ValueError("direct-mapped requires ways == 1")
    n = pages.shape[0]
    pad = (-n) % chunk
    pages = jnp.pad(pages.astype(jnp.int32), (0, pad))
    writes = jnp.pad(writes.astype(jnp.int32), (0, pad))
    c = (n + pad) // chunk
    pages2 = pages.reshape(c, chunk)
    writes2 = writes.reshape(c, chunk)

    kern = functools.partial(_cache_sim_kernel, num_sets=num_sets, ways=ways,
                             chunk=chunk, is_lru=(policy == "lru"))
    hits, evicts = pl.pallas_call(
        kern,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, chunk), jnp.int32),
            jax.ShapeDtypeStruct((c, chunk), jnp.int32),
        ],
        scratch_shapes=[_vmem((ways, num_sets)) for _ in range(3)],
        interpret=interpret,
    )(pages2, writes2)
    return (hits.reshape(-1)[:n].astype(bool),
            evicts.reshape(-1)[:n].astype(bool))


def _vmem(shape):
    """VMEM scratch allocation (int32)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.int32)


# ------------------------------------------------------------------- fused
def _cache_sim_fused_kernel(pages_ref, writes_ref, hits_ref, evicts_ref,
                            lat_ref, arr_ref, tags_ref, meta_ref, dirty_ref,
                            busy_ref, ring_ref, *, num_sets: int, ways: int,
                            chunk: int,
                            is_lru: bool, outstanding: int, issue_ns: int,
                            hit_ns: int, miss_ns: int, miss_occ_ns: int,
                            wb_ns: int):
    """Fused variant: the cache update rule of :func:`_cache_sim_kernel`
    plus per-access latency, emitted in the same sequential pass.

    Latency model (analytic, all in **nanoseconds**; int32 cursors hold
    ~2.1 s of simulated time — callers bound the trace accordingly, see
    :func:`repro.core.replay.pallas_engine.run_pallas`): closed-loop issue
    with ``outstanding`` slots — access
    *i* arrives ``issue_ns`` after its predecessor, but no earlier than
    completion *i - outstanding* (a ring buffer of the last K completion
    times, i.e. the driver's line-fill-buffer rule under in-order
    completion).  A hit costs ``hit_ns``; a miss queues on the fill path's
    busy-until scalar (``miss_occ_ns`` occupancy per fill — the 4 KB
    cache-DRAM transfer), then costs ``miss_ns`` service, plus ``wb_ns``
    when it also evicts a dirty page.  All latency state lives in VMEM
    scratch next to the cache state, so trace -> hit/evict/latency is one
    kernel."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        tags_ref[...] = jnp.full((ways, num_sets), -1, jnp.int32)
        meta_ref[...] = jnp.zeros((ways, num_sets), jnp.int32)
        dirty_ref[...] = jnp.zeros((ways, num_sets), jnp.int32)
        busy_ref[...] = jnp.zeros((1, 2), jnp.int32)     # [fill busy, prev arr]
        ring_ref[...] = jnp.zeros((1, outstanding), jnp.int32)

    base_t = step * chunk

    def body(i, _):
        page = pages_ref[0, i]
        wr = writes_ref[0, i]
        t = base_t + i + 1
        s = jax.lax.rem(page, num_sets)

        line_tags = tags_ref[:, pl.ds(s, 1)][:, 0]    # (W,)
        line_meta = meta_ref[:, pl.ds(s, 1)][:, 0]
        line_dirty = dirty_ref[:, pl.ds(s, 1)][:, 0]

        match = line_tags == page
        hit = jnp.any(match)
        hit_way = jnp.argmax(match)

        valid = line_tags >= 0
        victim_key = jnp.where(valid, line_meta, NEG)
        victim_way = jnp.argmin(victim_key)
        way = jnp.where(hit, hit_way, victim_way).astype(jnp.int32)

        dirty_evict = jnp.logical_and(
            jnp.logical_and(~hit, valid[victim_way]),
            line_dirty[victim_way] > 0)

        new_tag = jnp.where(hit, line_tags[way], page)
        stamp = jnp.where(hit,
                          jnp.where(is_lru, t, line_meta[way]),
                          t).astype(jnp.int32)
        new_dirty = jnp.where(hit, line_dirty[way] | wr, wr).astype(jnp.int32)

        line_tags = line_tags.at[way].set(new_tag)
        line_meta = line_meta.at[way].set(stamp)
        line_dirty = line_dirty.at[way].set(new_dirty)
        tags_ref[:, pl.ds(s, 1)] = line_tags[:, None]
        meta_ref[:, pl.ds(s, 1)] = line_meta[:, None]
        dirty_ref[:, pl.ds(s, 1)] = line_dirty[:, None]

        # latency: closed-loop arrival (LFB ring), then busy-until queueing
        # on the miss fill path
        slot = jax.lax.rem(base_t + i, outstanding)
        t_arr = jnp.maximum(busy_ref[0, 1] + issue_ns, ring_ref[0, slot])
        busy = busy_ref[0, 0]
        start = jnp.maximum(t_arr, busy)
        done = jnp.where(hit, t_arr + hit_ns,
                         start + miss_ns
                         + jnp.where(dirty_evict, wb_ns, 0)).astype(jnp.int32)
        busy_ref[0, 0] = jnp.where(hit, busy, start + miss_occ_ns)
        busy_ref[0, 1] = t_arr.astype(jnp.int32)
        ring_ref[0, slot] = done

        hits_ref[0, i] = hit.astype(jnp.int32)
        evicts_ref[0, i] = dirty_evict.astype(jnp.int32)
        lat_ref[0, i] = done - t_arr
        arr_ref[0, i] = t_arr.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


def fill_latency_assoc(hits, evicts, arr_ns, *, hit_ns: int, miss_ns: int,
                       miss_occ_ns: int, wb_ns: int):
    """Recompute the fused kernel's latency stream from its decisions and
    arrivals with the associative busy-until formulation shared with the
    replay engines (:func:`repro.core.replay.assoc.busy_until`).

    The kernel's fill path is a gated max-plus chain — misses occupy the
    cache-DRAM fill stage for ``miss_occ_ns`` each, hits bypass it — so
    given the arrival stream the whole latency recurrence is one
    associative scan, **bit-identical** to the sequential in-kernel chain
    (tested against both the kernel and the ref twin).  Used by
    ``run_pallas(validate=True)`` to cross-check every kernel run in the
    golden-trace suite.
    """
    from repro.core.replay.assoc import busy_until

    hits = jnp.asarray(hits, bool)
    evicts = jnp.asarray(evicts, bool)
    arr = jnp.asarray(arr_ns)
    miss = ~hits
    free = busy_until(arr, jnp.full(arr.shape, miss_occ_ns, arr.dtype),
                      active=miss, init=0)
    start = free - miss_occ_ns                  # fill-stage grant per miss
    lat = jnp.where(hits, hit_ns,
                    start - arr + miss_ns + jnp.where(evicts, wb_ns, 0))
    return lat.astype(arr.dtype)


@functools.partial(jax.jit, static_argnames=(
    "num_sets", "ways", "policy", "chunk", "interpret", "outstanding",
    "issue_ns", "hit_ns", "miss_ns", "miss_occ_ns", "wb_ns"))
def cache_sim_fused(pages: jnp.ndarray, writes: jnp.ndarray, *, num_sets: int,
                    ways: int, policy: str = "lru", outstanding: int = 32,
                    issue_ns: int = 1, hit_ns: int = 50, miss_ns: int = 5000,
                    miss_occ_ns: int = 213, wb_ns: int = 0, chunk: int = 512,
                    interpret: bool = True):
    """Fused trace replay: one kernel emits (hits, dirty_evicts, latency_ns,
    arrival_ns).

    Hit/evict decisions are bit-identical to :func:`cache_sim` (and so to
    the lax.scan oracle and the Python policy objects); the latency stream
    follows the analytic closed-loop model documented on the kernel,
    validated against :func:`repro.kernels.ref.cache_sim_fused_ref`."""
    if policy not in ("lru", "fifo", "direct"):
        raise ValueError(f"kernel supports lru/fifo/direct, got {policy!r}")
    if policy == "direct" and ways != 1:
        raise ValueError("direct-mapped requires ways == 1")
    n = pages.shape[0]
    pad = (-n) % chunk
    pages = jnp.pad(pages.astype(jnp.int32), (0, pad))
    writes = jnp.pad(writes.astype(jnp.int32), (0, pad))
    c = (n + pad) // chunk
    pages2 = pages.reshape(c, chunk)
    writes2 = writes.reshape(c, chunk)

    kern = functools.partial(
        _cache_sim_fused_kernel, num_sets=num_sets, ways=ways, chunk=chunk,
        is_lru=(policy == "lru"), outstanding=max(1, outstanding),
        issue_ns=issue_ns, hit_ns=hit_ns, miss_ns=miss_ns,
        miss_occ_ns=miss_occ_ns, wb_ns=wb_ns)
    hits, evicts, lat, arr = pl.pallas_call(
        kern,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((1, chunk), lambda i: (i, 0))
                   for _ in range(4)],
        out_shape=[jax.ShapeDtypeStruct((c, chunk), jnp.int32)
                   for _ in range(4)],
        scratch_shapes=[_vmem((ways, num_sets)) for _ in range(3)]
        + [_vmem((1, 2)), _vmem((1, max(1, outstanding)))],
        interpret=interpret,
    )(pages2, writes2)
    return (hits.reshape(-1)[:n].astype(bool),
            evicts.reshape(-1)[:n].astype(bool),
            lat.reshape(-1)[:n],
            arr.reshape(-1)[:n])
