"""Pallas TPU kernel: set-associative cache-replay (the simulator hot-spot).

The paper's DRAM-cache layer decides hit/miss/evict for every 64 B access;
replaying long address traces against that state machine is the dominant
compute of trace-driven evaluation.  This kernel keeps the full cache state
— tags, timestamps, dirty bits, laid out ``(ways, sets)`` so the set axis
rides the 128-wide lanes — in VMEM scratch that persists across a
sequential grid, streaming the trace through in ``(1, T)`` chunks.

The update rule is bit-identical to :func:`repro.core.cache.trace_sim._run_trace`
(the lax.scan oracle), which in turn matches the pure-Python policy objects.
Cache replay is inherently sequential (every access depends on the state
left by the previous one), so the kernel is latency-bound scalar work per
access; TPU leverage comes from running independent sweeps (policies,
capacities, workloads) in parallel via vmap over ``pallas_call`` — see
``benchmarks/kernel_bench.py``.

VMEM budget: ``3 * ways * sets * 4`` bytes for state (default 8x4096 ->
384 KB) + two ``(1, T)`` int32 trace blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -(2**31) + 1


def _cache_sim_kernel(pages_ref, writes_ref, hits_ref, evicts_ref,
                      tags_ref, meta_ref, dirty_ref, *,
                      num_sets: int, ways: int, chunk: int, is_lru: bool):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        tags_ref[...] = jnp.full((ways, num_sets), -1, jnp.int32)
        meta_ref[...] = jnp.zeros((ways, num_sets), jnp.int32)
        dirty_ref[...] = jnp.zeros((ways, num_sets), jnp.int32)

    base_t = step * chunk

    def body(i, _):
        page = pages_ref[0, i]
        wr = writes_ref[0, i]
        t = base_t + i + 1
        s = jax.lax.rem(page, num_sets)

        line_tags = tags_ref[:, pl.ds(s, 1)][:, 0]    # (W,)
        line_meta = meta_ref[:, pl.ds(s, 1)][:, 0]
        line_dirty = dirty_ref[:, pl.ds(s, 1)][:, 0]

        match = line_tags == page
        hit = jnp.any(match)
        hit_way = jnp.argmax(match)

        valid = line_tags >= 0
        victim_key = jnp.where(valid, line_meta, NEG)
        victim_way = jnp.argmin(victim_key)
        way = jnp.where(hit, hit_way, victim_way).astype(jnp.int32)

        dirty_evict = jnp.logical_and(
            jnp.logical_and(~hit, valid[victim_way]),
            line_dirty[victim_way] > 0)

        new_tag = jnp.where(hit, line_tags[way], page)
        stamp = jnp.where(hit,
                          jnp.where(is_lru, t, line_meta[way]),
                          t).astype(jnp.int32)
        new_dirty = jnp.where(hit, line_dirty[way] | wr, wr).astype(jnp.int32)

        line_tags = line_tags.at[way].set(new_tag)
        line_meta = line_meta.at[way].set(stamp)
        line_dirty = line_dirty.at[way].set(new_dirty)
        tags_ref[:, pl.ds(s, 1)] = line_tags[:, None]
        meta_ref[:, pl.ds(s, 1)] = line_meta[:, None]
        dirty_ref[:, pl.ds(s, 1)] = line_dirty[:, None]

        hits_ref[0, i] = hit.astype(jnp.int32)
        evicts_ref[0, i] = dirty_evict.astype(jnp.int32)
        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit, static_argnames=("num_sets", "ways", "policy",
                                             "chunk", "interpret"))
def cache_sim(pages: jnp.ndarray, writes: jnp.ndarray, *, num_sets: int,
              ways: int, policy: str = "lru", chunk: int = 512,
              interpret: bool = True):
    """Replay a trace. pages: (N,) int32; writes: (N,) bool.
    Returns (hits (N,) bool, dirty_evicts (N,) bool)."""
    if policy not in ("lru", "fifo", "direct"):
        raise ValueError(f"kernel supports lru/fifo/direct, got {policy!r}")
    if policy == "direct" and ways != 1:
        raise ValueError("direct-mapped requires ways == 1")
    n = pages.shape[0]
    pad = (-n) % chunk
    pages = jnp.pad(pages.astype(jnp.int32), (0, pad))
    writes = jnp.pad(writes.astype(jnp.int32), (0, pad))
    c = (n + pad) // chunk
    pages2 = pages.reshape(c, chunk)
    writes2 = writes.reshape(c, chunk)

    kern = functools.partial(_cache_sim_kernel, num_sets=num_sets, ways=ways,
                             chunk=chunk, is_lru=(policy == "lru"))
    hits, evicts = pl.pallas_call(
        kern,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c, chunk), jnp.int32),
            jax.ShapeDtypeStruct((c, chunk), jnp.int32),
        ],
        scratch_shapes=[_vmem((ways, num_sets)) for _ in range(3)],
        interpret=interpret,
    )(pages2, writes2)
    return (hits.reshape(-1)[:n].astype(bool),
            evicts.reshape(-1)[:n].astype(bool))


def _vmem(shape):
    """VMEM scratch allocation (int32)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.int32)
