"""Pallas TPU kernel: single-token decode attention over a KV-cache shard.

Designed for the flash-decoding scheme of ``repro.distributed``: the KV
cache's sequence axis is sharded across the ``model`` mesh axis, every
device runs this kernel over its local shard, and the partial results are
combined with a max/sum softmax merge across devices — so the kernel also
RETURNS its local ``(m, l)`` statistics.

Grid ``(BH, nk)``; kv tiles stream through VMEM while the running
(m, l, acc) sits in scratch.  ``n_valid`` arrives via scalar prefetch so the
same compiled kernel serves any cache fill level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(n_valid_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, m_sc, l_sc, acc_sc, *,
                   bk: int, scale: float, gqa: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc[...], NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc[...])
        acc_sc[...] = jnp.zeros_like(acc_sc[...])

    n_valid = n_valid_ref[0]
    k_start = ki * bk

    @pl.when(k_start < n_valid)
    def _tile():
        q = q_ref[0].astype(jnp.float32)                 # (G, d)
        k = k_ref[0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < n_valid, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1, keepdims=True)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot(p, v)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-37)).astype(o_ref.dtype)
        m_ref[0] = m_sc[...]
        l_ref[0] = l_sc[...]


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode_tpu(q, k_cache, v_cache, n_valid, *, bk: int = 512,
                     interpret: bool = True):
    """q: (B, H, hd); k/v_cache: (B, Skv, KV, hd); n_valid: () int32.

    Returns (out (B, H, hd), m (B, H), l (B, H)) — partial-softmax stats for
    cross-shard combining; ``out`` is already the locally-normalized result.
    """
    B, H, hd = q.shape
    Skv, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    bk = min(bk, Skv)
    pad_k = (-Skv) % bk
    pad_d = (-hd) % 128 if not interpret else 0
    kp = jnp.pad(k_cache, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    vp = jnp.pad(v_cache, ((0, 0), (0, pad_k), (0, 0), (0, pad_d)))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_d)))
    Sk, d = Skv + pad_k, hd + pad_d

    qf = qp.reshape(B * KV, G, d)
    kf = kp.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    vf = vp.transpose(0, 2, 1, 3).reshape(B * KV, Sk, d)
    nk = Sk // bk
    nv = jnp.asarray(n_valid, jnp.int32).reshape(1)

    kern = functools.partial(_decode_kernel, bk=bk, scale=scale, gqa=G)
    out, m, l = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * KV, nk),
            in_specs=[
                pl.BlockSpec((1, G, d), lambda bh, ki, nv_ref: (bh, 0, 0)),
                pl.BlockSpec((1, bk, d), lambda bh, ki, nv_ref: (bh, ki, 0)),
                pl.BlockSpec((1, bk, d), lambda bh, ki, nv_ref: (bh, ki, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, G, d), lambda bh, ki, nv_ref: (bh, 0, 0)),
                pl.BlockSpec((1, G, 1), lambda bh, ki, nv_ref: (bh, 0, 0)),
                pl.BlockSpec((1, G, 1), lambda bh, ki, nv_ref: (bh, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, d), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B * KV, G, d), q.dtype),
            jax.ShapeDtypeStruct((B * KV, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B * KV, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(nv, qf, kf, vf)
    out = out.reshape(B, H, d)[:, :, :hd]
    return out, m.reshape(B, H), l.reshape(B, H)


def combine_partials(outs, ms, ls):
    """Merge per-shard decode partials along a leading shard axis.

    outs: (n, B, H, hd) locally-normalized outputs; ms/ls: (n, B, H).
    Returns the exact global attention output (B, H, hd)."""
    m_glob = ms.max(axis=0)                              # (B, H)
    w = jnp.exp(ms - m_glob[None]) * ls                  # un-normalize
    denom = w.sum(axis=0)
    num = (outs * w[..., None]).sum(axis=0)
    return num / jnp.maximum(denom, 1e-37)[..., None]
