"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.cache.trace_sim import _run_trace
from repro.models.layers import attention_ref, decode_attention


def cache_sim_ref(pages, writes, *, num_sets: int, ways: int,
                  policy: str = "lru"):
    """lax.scan cache replay (validated against the Python policy objects)."""
    hits, evicts, _ = _run_trace(jnp.asarray(pages, jnp.int32),
                                 jnp.asarray(writes, bool),
                                 num_sets, ways, policy == "lru")
    return hits, evicts


def cache_sim_fused_ref(pages, writes, *, num_sets: int, ways: int,
                        policy: str = "lru", outstanding: int = 32,
                        issue_ns: int = 1, hit_ns: int = 50,
                        miss_ns: int = 5000, miss_occ_ns: int = 213,
                        wb_ns: int = 0):
    """Oracle for :func:`repro.kernels.cache_sim.cache_sim_fused`: the scan
    cache replay plus the same closed-loop (LFB-ring) busy-until latency
    recurrence (all in int32 nanoseconds)."""
    hits, evicts, _ = _run_trace(jnp.asarray(pages, jnp.int32),
                                 jnp.asarray(writes, bool),
                                 num_sets, ways, policy == "lru")
    K = max(1, outstanding)

    def step(carry, x):
        busy, prev, ring = carry
        i, hit, ev = x
        slot = jax.lax.rem(i, K)
        t = jnp.maximum(prev + issue_ns, ring[slot])
        start = jnp.maximum(t, busy)
        done = jnp.where(hit, t + hit_ns,
                         start + miss_ns + jnp.where(ev, wb_ns, 0))
        busy = jnp.where(hit, busy, start + miss_occ_ns)
        return (busy, t, ring.at[slot].set(done)), (done - t).astype(jnp.int32)

    n = hits.shape[0]
    # prev-arrival starts at 0, like the kernel's scratch init: the first
    # access arrives at issue_ns.
    _, lat = jax.lax.scan(
        step, (jnp.int32(0), jnp.int32(0), jnp.zeros(K, jnp.int32)),
        (jnp.arange(n, dtype=jnp.int32), hits, evicts))
    return hits, evicts, lat


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """O(S^2) full-softmax attention (supports GQA + SWA + cross lengths)."""
    if q.shape[1] == k.shape[1] or causal:
        return attention_ref(q, k, v, causal=causal, window=window)
    B, S, H, hd = q.shape
    KV = k.shape[2]
    qg = q.reshape(B, S, KV, H // KV, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, n_valid):
    """Masked full-length decode attention + its (m, l) statistics."""
    out = decode_attention(q, k_cache, v_cache, n_valid)
    B, Smax, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bckd->bkgc", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(Smax)[None, :] < jnp.asarray(n_valid).reshape(-1, 1)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = s.max(axis=-1).reshape(B, H)
    l = jnp.exp(s - s.max(axis=-1, keepdims=True)).sum(-1).reshape(B, H)
    return out, m, l


def page_gather_ref(pool, table):
    return jnp.take(pool, table, axis=0)


def page_scatter_ref(pool, table, pages):
    return pool.at[table].set(pages)
