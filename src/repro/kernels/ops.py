"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (kernel bodies execute in Python for
validation) and False on TPU, where the kernels compile to Mosaic.
"""

from __future__ import annotations

import jax

from repro.kernels.cache_sim import cache_sim
from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.flash_decode import combine_partials, flash_decode_tpu
from repro.kernels.page_gather import page_gather, page_scatter


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def cache_sim_op(pages, writes, *, num_sets, ways, policy="lru", chunk=512):
    return cache_sim(pages, writes, num_sets=num_sets, ways=ways,
                     policy=policy, chunk=chunk,
                     interpret=_interpret_default())


def flash_attention_op(q, k, v, *, causal=True, window=0, bq=128, bk=128):
    return flash_attention_tpu(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=_interpret_default())


def flash_decode_op(q, k_cache, v_cache, n_valid, *, bk=512):
    return flash_decode_tpu(q, k_cache, v_cache, n_valid, bk=bk,
                            interpret=_interpret_default())


def _as3d(x):
    """Kernels address pages as (P, R, C); flatten any trailing page shape."""
    if x.ndim == 3:
        return x, None
    shape = x.shape
    r = shape[1] if x.ndim > 1 else 1
    c = 1
    for d in shape[2:]:
        c *= d
    return x.reshape(shape[0], r, max(c, 1)), shape


def page_gather_op(pool, table):
    pool3, orig = _as3d(pool)
    out = page_gather(pool3, table, interpret=_interpret_default())
    if orig is not None:
        out = out.reshape((out.shape[0],) + orig[1:])
    return out


def page_scatter_op(pool, table, pages):
    pool3, orig = _as3d(pool)
    pages3, _ = _as3d(pages)
    out = page_scatter(pool3, table, pages3, interpret=_interpret_default())
    return out.reshape(orig) if orig is not None else out


__all__ = ["cache_sim_op", "flash_attention_op", "flash_decode_op",
           "combine_partials", "page_gather_op", "page_scatter_op"]
