"""Fault-tolerant checkpointing: atomic writes, checksums, async save,
elastic re-mesh restore.

* **Atomic**: a checkpoint is written to ``step_N.tmp/`` and ``os.replace``d
  into ``step_N/`` only after every leaf + the manifest land — a crash
  mid-save never corrupts the latest good checkpoint.
* **Verified**: the manifest records per-leaf SHA-256; restore checks them.
* **Elastic**: ``restore(..., mesh=, specs=)`` places leaves with
  ``NamedSharding`` on whatever mesh the *restarted* job has — a checkpoint
  saved on 2x16x16 restores onto 16x16 (or a debug 2x2) unchanged, which is
  the elastic-scaling path for node failures.
* **Async**: ``save_async`` snapshots to host then writes on a thread so
  training continues; ``wait()`` joins before the next save.
* Iterator/RNG state rides along (preemption-safe data order).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _fsync_write(path: Path, data) -> None:
    """Write ``data`` (bytes or str) and fsync before returning — the
    durability half of the tmp-dir + ``os.replace`` publish protocol: a
    power cut after the rename can never expose a published checkpoint
    whose contents still sit in the page cache."""
    mode = "wb" if isinstance(data, bytes) else "w"
    with open(path, mode) as fh:
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())


def _fsync_dir(path: Path) -> None:
    """fsync a directory so a just-published rename survives power loss
    (no-op on platforms whose dirfd fsync is unsupported)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # pragma: no cover - exotic filesystems
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover
        pass
    finally:
        os.close(fd)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                       for k in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> Path:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host_tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: Dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, _ = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, leaf in flat.items():
            # raw bytes + dtype string: np.save corrupts ml_dtypes (bfloat16)
            fname = key.replace("/", "__") + ".bin"
            raw = np.ascontiguousarray(leaf).tobytes()
            _fsync_write(tmp / fname, raw)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                "sha256": hashlib.sha256(raw).hexdigest(),
            }
        _fsync_write(tmp / "manifest.json", json.dumps(manifest, indent=1))
        _fsync_dir(tmp)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        _fsync_dir(self.dir)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                mesh=None, specs: Any = None, verify: bool = True):
        """Restore into the structure of ``template``.  With ``mesh`` and
        ``specs``, leaves are placed as NamedSharding(mesh, spec) — the
        elastic re-mesh path."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        flat_t, treedef = _flatten(template)
        spec_flat = None
        if specs is not None:
            spec_flat, _ = _flatten(specs)

        restored = {}
        for key, tmpl in flat_t.items():
            ent = manifest["leaves"].get(key)
            if ent is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            raw = (cdir / ent["file"]).read_bytes()
            if verify:
                digest = hashlib.sha256(raw).hexdigest()
                if digest != ent["sha256"]:
                    raise IOError(f"checksum mismatch for {key!r}")
            dtype = np.dtype(ent["dtype"]) if ent["dtype"] != "bfloat16" \
                else np.dtype("bfloat16")
            arr = np.frombuffer(raw, dtype=dtype).reshape(ent["shape"]).copy()
            if list(arr.shape) != list(tmpl.shape):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {tmpl.shape}")
            if mesh is not None:
                spec = spec_flat.get(key, P()) if spec_flat else P()
                arr = jax.device_put(arr, NamedSharding(mesh, spec))
            restored[key] = arr

        leaves = [restored[k] for k in flat_t]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["extra"], step

    def restore_flat(self, step: Optional[int] = None, verify: bool = True):
        """Restore a checkpoint as the flat ``{key: ndarray}`` mapping it
        was saved from, shapes/dtypes taken from the manifest — no
        structural template needed.  The streaming-replay resume path uses
        this: its snapshot leaves (per-chunk output parts, fault-builder
        accumulators) have shapes only the checkpoint itself knows."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        flat = {}
        for key, ent in manifest["leaves"].items():
            raw = (cdir / ent["file"]).read_bytes()
            if verify:
                digest = hashlib.sha256(raw).hexdigest()
                if digest != ent["sha256"]:
                    raise IOError(f"checksum mismatch for {key!r}")
            flat[key] = np.frombuffer(raw, dtype=np.dtype(ent["dtype"])) \
                .reshape(ent["shape"]).copy()
        return flat, manifest["extra"], step

    def restore_latest_good(self, template: Any = None, mesh=None,
                            specs: Any = None, verify: bool = True):
        """Restore the newest checkpoint that passes verification, walking
        backwards over older steps when the latest is torn or corrupt
        (truncated leaf, checksum mismatch, unparseable manifest, missing
        file).  ``.tmp`` directories — crashes mid-save — are invisible by
        construction (:meth:`all_steps` excludes them).  With
        ``template=None`` restores the flat mapping (:meth:`restore_flat`).
        Raises ``FileNotFoundError`` when no checkpoint restores cleanly."""
        errors = []
        for step in sorted(self.all_steps(), reverse=True):
            try:
                if template is None:
                    return self.restore_flat(step, verify=verify)
                return self.restore(template, step, mesh=mesh, specs=specs,
                                    verify=verify)
            except (OSError, KeyError, ValueError,
                    json.JSONDecodeError) as exc:
                errors.append(f"step {step}: {type(exc).__name__}: {exc}")
        detail = ("; ".join(errors) if errors
                  else f"no checkpoints under {self.dir}")
        raise FileNotFoundError(
            f"no restorable checkpoint under {self.dir} ({detail})")
