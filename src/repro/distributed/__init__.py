from repro.distributed.sharding import (
    MeshAxes,
    batch_spec,
    decode_state_specs,
    param_specs,
    opt_state_specs,
)
from repro.distributed.step import make_train_step, make_prefill_step, make_decode_step

__all__ = ["MeshAxes", "batch_spec", "decode_state_specs", "param_specs",
           "opt_state_specs", "make_train_step", "make_prefill_step",
           "make_decode_step"]
