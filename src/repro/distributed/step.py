"""Train / prefill / decode step builders with production shardings.

These are the functions the launcher jits and the dry-run lowers.  Loss uses
the one-hot formulation (``logsumexp - sum(logits*onehot)``) so the vocab
axis stays sharded over ``model`` end-to-end — materializing a full
``(B, S, V)`` log-softmax gather would un-shard 160k-vocab logits.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import (MeshAxes, batch_spec,
                                        decode_state_specs, opt_state_specs,
                                        param_specs)
from repro.models.transformer import (MeshCtx, decode_step, forward,
                                      init_decode_state, init_params)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compressed_gradients


def make_mesh_ctx(mesh: Mesh, batch_replicated: bool = False,
                  resident_experts: bool = False) -> MeshCtx:
    ax = MeshAxes.for_mesh(mesh)
    return MeshCtx(mesh=mesh, dp_axes=ax.dp, tp_axis=ax.tp,
                   batch_replicated=batch_replicated,
                   resident_experts=resident_experts)


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray) -> jnp.ndarray:
    """Sharded-vocab-safe mean NLL.  logits: (..., V); targets: (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    true_logit = jnp.sum(logits * jax.nn.one_hot(targets, V, dtype=logits.dtype),
                         axis=-1)
    return jnp.mean(lse - true_logit)


def make_loss_fn(cfg: ArchConfig, ctx: Optional[MeshCtx], remat: bool = True,
                 aux_coef: float = 0.01, unroll: bool = False,
                 remat_policy: Optional[str] = None) -> Callable:
    def loss_fn(params, batch):
        logits, aux = forward(params, cfg, batch, ctx=ctx, remat=remat,
                              unroll=unroll, remat_policy=remat_policy)
        tokens = batch["tokens"]
        if cfg.n_codebooks:
            nll = cross_entropy(logits[:, :-1], tokens[:, 1:])
        else:
            nll = cross_entropy(logits[:, :-1], tokens[:, 1:])
        return nll + aux_coef * aux
    return loss_fn


def make_train_step(cfg: ArchConfig, mesh: Optional[Mesh], *,
                    lr_fn: Callable, adamw_cfg: AdamWConfig = AdamWConfig(),
                    remat: bool = True, compress_grads: bool = False,
                    unroll: bool = False, accum_steps: int = 1,
                    remat_policy: Optional[str] = None):
    """Returns ``train_step(params, opt_state, batch, step[, comp_state])``.

    ``accum_steps > 1`` splits the per-device batch into microbatches and
    accumulates gradients over a ``lax.scan`` — the activation working set
    (layer checkpoints, logits) shrinks by the accumulation factor while
    compute and the DP all-reduce are unchanged (§Perf memory lever).
    """
    ctx = make_mesh_ctx(mesh) if mesh is not None else None
    loss_fn = make_loss_fn(cfg, ctx, remat=remat, unroll=unroll,
                           remat_policy=remat_policy)

    def grad_fn(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        B = batch["tokens"].shape[0]
        assert B % accum_steps == 0, (B, accum_steps)
        micro = {k: v.reshape((accum_steps, B // accum_steps) + v.shape[1:])
                 for k, v in batch.items()}

        def body(acc, mb):
            loss_acc, g_acc = acc
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), g0),
                                        micro)
        inv = 1.0 / accum_steps
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def train_step(params, opt_state, batch, step, comp_state=None):
        loss, grads = grad_fn(params, batch)
        if compress_grads and comp_state is not None:
            grads, comp_state = compressed_gradients(grads, comp_state)
        lr = lr_fn(step)
        params, opt_state = adamw_update(grads, opt_state, params, lr, adamw_cfg)
        out = (params, opt_state, loss)
        return out + ((comp_state,) if compress_grads else ())

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh: Optional[Mesh],
                      unroll: bool = False):
    """Inference prefill: full-sequence forward -> logits (no loss)."""
    ctx = make_mesh_ctx(mesh) if mesh is not None else None

    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch, ctx=ctx, remat=False,
                            unroll=unroll)
        return logits

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh: Optional[Mesh],
                    batch_replicated: bool = False, unroll: bool = False,
                    resident_experts: bool = False):
    """One-token decode: (params, state, tokens) -> (logits, state)."""
    ctx = (make_mesh_ctx(mesh, batch_replicated, resident_experts)
           if mesh is not None else None)

    def serve_step(params, state, tokens):
        return decode_step(params, cfg, state, tokens, ctx=ctx, unroll=unroll)

    return serve_step


# convenience aliases used by launch/
make_decode_step = make_serve_step


@dataclass
class ShardingPlan:
    """Everything the launcher/dry-run needs to jit a step."""
    params: Any
    opt_state: Any
    batch: Dict[str, P]
    decode_state: Any

    def named(self, mesh: Mesh, tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree)


def plan_shardings(cfg: ArchConfig, mesh: Mesh, params_shape, opt_shape=None,
                   decode_state_shape=None, kind: str = "train",
                   batch_replicated: bool = False) -> ShardingPlan:
    ax = MeshAxes.for_mesh(mesh)
    pspecs = param_specs(params_shape, cfg, mesh, ax)
    ospecs = (opt_state_specs(opt_shape, pspecs, mesh, ax)
              if opt_shape is not None else None)
    dspecs = (decode_state_specs(decode_state_shape, cfg, mesh, ax,
                                 batch_replicated)
              if decode_state_shape is not None else None)
    return ShardingPlan(params=pspecs, opt_state=ospecs,
                        batch=batch_spec(cfg, ax, kind, batch_replicated),
                        decode_state=dspecs)
