"""Pipeline parallelism over the ``pod`` axis (GPipe schedule).

On the 2x16x16 multi-pod mesh the default plan is DP over pods (gradients
all-reduce over the slow inter-pod links once per step).  When activations
are smaller than gradients — deep-narrow models or large accumulation — the
better plan is to split LAYERS across pods and stream microbatches
(activations cross pods instead of gradients).  This module implements that
alternative: stages = pods, ``collective_permute`` moves activations
stage->stage, and microbatches keep all stages busy (GPipe; bubble fraction
= (P-1)/(P-1+M)).

Implemented with ``shard_map`` over the ``pod`` axis: every pod runs the
same program on its layer slice; non-stage-0 inputs are ignored, partial
outputs stream forward.  Works for any per-layer ``block_fn(x, blk) -> x``.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_forward(block_fn: Callable, mesh, *, stage_axis: str = "pod",
                     microbatches: int = 4):
    """Returns ``fn(x, stacked_blocks) -> y`` running layers split across
    ``stage_axis`` with GPipe microbatching.

    x: (B, ...) activations (B % microbatches == 0);
    stacked_blocks: pytree stacked on a leading n_layers axis with
    n_layers % n_stages == 0 (each stage takes a contiguous slice).
    """
    n_stages = mesh.shape[stage_axis]

    def staged(x, blocks):
        stage = jax.lax.axis_index(stage_axis)
        B = x.shape[0]
        mb = B // microbatches
        xs = x.reshape(microbatches, mb, *x.shape[1:])

        def run_stage(xmb):
            def body(h, blk):
                return block_fn(h, blk), None
            h, _ = jax.lax.scan(body, xmb, blocks)
            return h

        n_ticks = microbatches + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range); others take the
            # previous stage's output that arrived last tick
            mb_idx = jnp.clip(t, 0, microbatches - 1)
            inject = jnp.where(stage == 0,
                               jnp.ones((), jnp.bool_), jnp.zeros((), jnp.bool_))
            x_in = jnp.where(inject & (t < microbatches), xs[mb_idx], buf)
            y = run_stage(x_in)
            # pass forward: stage i -> stage i+1 (last stage wraps to 0,
            # but its payload is only consumed as output)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf_next = jax.lax.ppermute(y, stage_axis, perm)
            # last stage records finished microbatch (t - (n_stages-1))
            done_idx = t - (n_stages - 1)
            is_done = (done_idx >= 0) & (done_idx < microbatches) & \
                      (stage == n_stages - 1)
            outs = jax.lax.cond(
                is_done,
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y[None], jnp.clip(done_idx, 0, microbatches - 1), 0),
                lambda o: o, outs)
            return (buf_next, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # broadcast final outputs from the last stage to all pods (masked
        # psum — ppermute cannot fan out one source to many destinations)
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs.reshape(B, *x.shape[1:])

    def fn(x, stacked_blocks):
        return shard_map(
            staged, mesh=mesh,
            in_specs=(P(), jax.tree.map(lambda _: P(stage_axis),
                                        stacked_blocks)),
            out_specs=P(),
            check_rep=False,
        )(x, stacked_blocks)

    return fn
