"""Straggler mitigation: a step-time watchdog.

At thousand-node scale, slow hosts (thermal throttling, failing NICs,
background daemons) stretch synchronous steps.  The watchdog keeps an EWMA
of step time, flags steps slower than ``threshold x EWMA``, attributes them
(in multi-process runs, via per-host timing exchange — here, per logical
shard), and drives two mitigations:

  * advisory: report offending hosts so the orchestrator can drain/replace
    them (the action at real scale);
  * in-run: after ``evict_after`` consecutive flags the launcher re-meshes
    without the slow host — exercised in tests through the elastic
    checkpoint-restore path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class StragglerConfig:
    ewma_alpha: float = 0.1
    threshold: float = 2.0          # flag steps slower than 2x EWMA
    warmup_steps: int = 5           # ignore compile/first steps
    evict_after: int = 3            # consecutive flags before eviction advice


@dataclass
class StragglerReport:
    step: int
    duration_s: float
    ewma_s: float
    flagged: bool
    evict_advised: bool
    host: Optional[int] = None


class StragglerWatchdog:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()) -> None:
        self.cfg = cfg
        self.ewma: Optional[float] = None
        self.step = 0
        self._consecutive = 0
        self._t0: Optional[float] = None
        self.reports: List[StragglerReport] = []
        self.flagged_hosts: Dict[int, int] = {}

    def start_step(self) -> None:
        self._t0 = time.perf_counter()

    def end_step(self, host: Optional[int] = None,
                 duration_s: Optional[float] = None) -> StragglerReport:
        if duration_s is None:
            assert self._t0 is not None, "start_step() not called"
            duration_s = time.perf_counter() - self._t0
        self.step += 1
        flagged = False
        evict = False
        if self.step <= self.cfg.warmup_steps or self.ewma is None:
            self.ewma = duration_s if self.ewma is None else (
                self.cfg.ewma_alpha * duration_s
                + (1 - self.cfg.ewma_alpha) * self.ewma)
        else:
            flagged = duration_s > self.cfg.threshold * self.ewma
            if flagged:
                self._consecutive += 1
                if host is not None:
                    self.flagged_hosts[host] = self.flagged_hosts.get(host, 0) + 1
                evict = self._consecutive >= self.cfg.evict_after
            else:
                self._consecutive = 0
                # only healthy steps update the EWMA (a straggler must not
                # drag the baseline up and mask itself)
                self.ewma = (self.cfg.ewma_alpha * duration_s
                             + (1 - self.cfg.ewma_alpha) * self.ewma)
        rep = StragglerReport(self.step, duration_s, float(self.ewma),
                              flagged, evict, host)
        self.reports.append(rep)
        return rep

    def worst_hosts(self, k: int = 3) -> List[int]:
        return sorted(self.flagged_hosts, key=self.flagged_hosts.get,
                      reverse=True)[:k]
