"""Flash-decoding with the KV-cache SEQUENCE axis sharded over the model
axis (SP-for-decode).

Why: at decode_32k (batch 128, 32 k context) the KV cache of a GQA model
like glm4 is ~170 GB — it only fits if *both* batch (data axis) and
sequence (model axis) shard.  Head-sharding cannot help (kv_heads=2 < 16).
Each device holds a contiguous slot-range of the ring buffer, computes a
partial softmax over its shard, and the exact result is reconstructed with
a max/sum merge (pmax + psum) — the same math as
:func:`repro.kernels.flash_decode.combine_partials`, validated against it.

Per layer the collectives are tiny (q/k/v all-gathers of a single token's
projections + two psums of (B, H, hd)), while the big KV tensor never
moves — that is the point.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope

NEG_INF = -1e30


def _partial_attn(q, kc, vc, n_valid_local):
    """q: (B, H, hd); kc/vc: (B, S_loc, KV, hd); n_valid_local: () int32.
    Returns locally-normalized (out, m, l) partial-softmax stats."""
    B, S_loc, KV, hd = kc.shape
    H = q.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bckd->bkgc", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(S_loc)[None, :] < n_valid_local
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, vc.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-37)[..., None]
    return (out.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H))


def decode_attn_sharded(x, blk, cfg: ArchConfig, k_cache, v_cache, cur, ctx,
                        k_scale=None, v_scale=None):
    """One decode-attention layer under shard_map.

    x: (B, D) [batch over dp unless ctx.batch_replicated];
    k_cache/v_cache: (B, Sc, KV, hd) with Sc sharded over tp;
    cur: () int32 global token position.
    Returns (y (B, D), new_k_cache, new_v_cache).
    """
    mesh, tp, dp = ctx.mesh, ctx.tp_axis, ctx.dp_axes
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    b = None if ctx.batch_replicated else dp
    Sc = k_cache.shape[1]
    n_tp = mesh.shape[tp]
    quant = k_scale is not None

    def body(xl, wq, wk, wv, wo, kc, vc, cur, ks=None, vs=None):
        Bl, D = xl.shape
        S_loc = kc.shape[1]
        tpi = jax.lax.axis_index(tp)

        # --- projections (column-sharded) -> assemble full heads
        q = jax.lax.all_gather(xl @ wq, tp, axis=1, tiled=True).reshape(Bl, H, hd)
        kn = jax.lax.all_gather(xl @ wk, tp, axis=1, tiled=True).reshape(Bl, KV, hd)
        vn = jax.lax.all_gather(xl @ wv, tp, axis=1, tiled=True).reshape(Bl, KV, hd)
        pos = jnp.full((Bl, 1), cur)
        q = apply_rope(q[:, None], pos, cfg.rope_theta)[:, 0]
        kn = apply_rope(kn[:, None], pos, cfg.rope_theta)[:, 0]

        # --- ring-buffer write: only the owning shard stores the new KV
        slot = jax.lax.rem(cur, Sc)
        local_slot = slot - tpi * S_loc
        sel = (jnp.arange(S_loc)[None, :, None, None] == local_slot)
        if quant:
            from repro.models.transformer import _quantize_kv
            kq, ksn = _quantize_kv(kn.astype(jnp.float32))
            vq, vsn = _quantize_kv(vn.astype(jnp.float32))
            kc = jnp.where(sel, kq[:, None], kc)
            vc = jnp.where(sel, vq[:, None], vc)
            ks = jnp.where(sel[..., 0], ksn[:, None], ks)
            vs = jnp.where(sel[..., 0], vsn[:, None], vs)
            k_eff = kc.astype(jnp.float32) * ks.astype(jnp.float32)[..., None]
            v_eff = vc.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]
        else:
            kc = jnp.where(sel, kn[:, None].astype(kc.dtype), kc)
            vc = jnp.where(sel, vn[:, None].astype(vc.dtype), vc)
            k_eff, v_eff = kc, vc

        # --- local partial attention over my slot range
        n_valid = jnp.minimum(cur + 1, Sc)
        n_local = jnp.clip(n_valid - tpi * S_loc, 0, S_loc)
        out, m, l = _partial_attn(q, k_eff, v_eff, n_local)

        # --- exact softmax merge across the tp axis
        m_g = jax.lax.pmax(m, tp)
        w = jnp.exp(m - m_g) * l
        denom = jax.lax.psum(w, tp)
        num = jax.lax.psum(out * w[..., None], tp)
        out = num / jnp.maximum(denom, 1e-37)[..., None]

        # --- output projection: my head slice x row-sharded wo, psum
        h_loc = (H * hd) // n_tp
        mine = jax.lax.dynamic_slice_in_dim(out.reshape(Bl, H * hd),
                                            tpi * h_loc, h_loc, 1)
        y = jax.lax.psum(mine.astype(wo.dtype) @ wo, tp)
        if quant:
            return y.astype(xl.dtype), kc, vc, ks, vs
        return y.astype(xl.dtype), kc, vc

    base_in = (P(b, None), P(None, tp), P(None, tp), P(None, tp),
               P(tp, None), P(b, tp, None, None), P(b, tp, None, None), P())
    base_out = (P(b, None), P(b, tp, None, None), P(b, tp, None, None))
    if quant:
        return shard_map(
            body, mesh=mesh,
            in_specs=base_in + (P(b, tp, None), P(b, tp, None)),
            out_specs=base_out + (P(b, tp, None), P(b, tp, None)),
            check_rep=False,
        )(x, blk["wq"], blk["wk"], blk["wv"], blk["wo"], k_cache, v_cache,
          cur, k_scale, v_scale)
    return shard_map(
        body, mesh=mesh,
        in_specs=base_in,
        out_specs=base_out,
        check_rep=False,
    )(x, blk["wq"], blk["wk"], blk["wv"], blk["wo"], k_cache, v_cache, cur)
