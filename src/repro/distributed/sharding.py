"""Sharding rules: parameter/optimizer/activation PartitionSpecs.

Axis roles:
  * ``dp`` axes (``('data',)`` single-pod, ``('pod','data')`` multi-pod):
    batch / ZeRO-1 optimizer-state sharding.
  * ``tp`` axis (``'model'``): tensor parallelism — attention heads, FFN
    hidden, vocab, MoE experts (EP) or expert-hidden (TP-in-expert), SSM
    inner channels.

Rules are name-based over the stacked parameter pytree (leaves carry a
leading ``n_layers`` axis).  Anything not matched is replicated.  Divisibility
is checked per-leaf: a rule that does not divide falls back to replication
(logged), so every assigned architecture shards cleanly on the 16x16 and
2x16x16 production meshes.
"""

from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

log = logging.getLogger(__name__)


@dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...]
    tp: str

    @staticmethod
    def for_mesh(mesh: Mesh) -> "MeshAxes":
        names = tuple(mesh.axis_names)
        if names == ("data", "model"):
            return MeshAxes(dp=("data",), tp="model")
        if names == ("pod", "data", "model"):
            return MeshAxes(dp=("pod", "data"), tp="model")
        # generic: last axis is tp, all leading axes dp
        return MeshAxes(dp=names[:-1], tp=names[-1])


def _dim(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _spec_fits(mesh: Mesh, shape, spec: P) -> bool:
    for size, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            continue
        if size % _dim(mesh, axes) != 0:
            return False
    return True


def _rule(path: str, cfg: ArchConfig, ax: MeshAxes,
          kind: str = "train") -> P:
    """PartitionSpec for a stacked-leaf path (without the layer axis)."""
    tp = ax.tp
    # ---- top-level
    if path.endswith("embed"):
        if cfg.n_codebooks:
            return P(None, tp, None)
        return P(tp, None)
    if path.endswith("lm_head"):
        if cfg.n_codebooks:
            return P(None, None, tp)
        return P(None, tp)
    if path.endswith("final_norm"):
        return P(None)

    layered = ".blocks." in path or ".cross." in path
    lead = (None, None) if ".blocks." in path and cfg.cross_attn_every else \
           ((None,) if layered else ())

    name = path.split(".")[-1]
    # ---- moe (checked before dense mlp: names overlap)
    if ".moe." in path:
        if name == "router":
            return P(*lead, None, None)
        ep = cfg.moe is not None and cfg.moe.sharding == "ep"
        if ep:
            if kind == "decode":
                # resident-expert decode layout: experts over tp, expert-
                # hidden over dp — weights never move; tokens do (§Perf)
                if name == "w_down":   # (E, F, D)
                    return P(*lead, tp, ax.dp, None)
                return P(*lead, tp, None, ax.dp)
            # EP + FSDP (training): experts over tp AND the within-expert
            # dim over the dp axes (a trillion-param expert store exceeds
            # HBM under EP alone; pjit all-gathers each layer's local
            # experts just in time, which is the FSDP pattern).
            return P(*lead, tp, ax.dp, None)
        # tp-in-expert
        if kind == "decode":
            # keep weights resident at decode (F over tp only)
            if name == "w_down":
                return P(*lead, None, tp, None)
            return P(*lead, None, None, tp)
        # training: + FSDP over the other hidden dim
        if name == "w_down":  # (E, F, D): shard F over tp, D over dp
            return P(*lead, None, tp, ax.dp)
        return P(*lead, None, ax.dp, tp)  # (E, D, F): D over dp, F over tp
    # ---- ssm (shard inner channels for pure-SSM; replicate for hybrid,
    # whose head count does not divide the model axis — see DESIGN.md §6)
    if ".ssm." in path:
        if cfg.family != "ssm":
            return P()
        if name in ("in_z", "in_x", "conv_x"):
            return P(*lead, None, tp)
        if name == "out_proj":
            return P(*lead, tp, None)
        if name in ("A_log", "D_skip", "dt_bias", "norm_w", "conv_bx"):
            return P(*lead, tp)
        if name == "in_dt":
            return P(*lead, None, tp)
        return P()  # in_B, in_C, conv_B/C + their biases: replicated
    # ---- attention
    if name in ("wq", "wk", "wv"):
        return P(*lead, None, tp)
    if name == "wo":
        return P(*lead, tp, None)
    # ---- dense mlp
    if name in ("w_gate", "w_up"):
        return P(*lead, None, tp)
    if name == "w_down":
        return P(*lead, tp, None)
    # ---- norms, gates, everything else
    return P()


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "." + ".".join(parts)


def param_specs(params, cfg: ArchConfig, mesh: Mesh,
                ax: Optional[MeshAxes] = None, kind: str = "train"):
    """PartitionSpec pytree for the parameter pytree.  ``kind='decode'``
    switches MoE experts to the resident layout (see _rule)."""
    ax = ax or MeshAxes.for_mesh(mesh)

    def leaf_spec(path, leaf):
        # NamedTuple fields (SSMParams/MoEParams) appear as tuple indices;
        # rebuild a name using the field list when possible.
        spec = _rule(_path_str(path), cfg, ax, kind)
        if len(spec) > leaf.ndim:
            spec = P(*tuple(spec)[:leaf.ndim])
        if not _spec_fits(mesh, leaf.shape, spec):
            log.info("sharding fallback to replicate: %s %s %s",
                     _path_str(path), leaf.shape, spec)
            return P()
        return spec

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def fsdp_param_specs(params, cfg: ArchConfig, mesh: Mesh,
                     ax: Optional[MeshAxes] = None,
                     axes: Optional[Tuple[str, ...]] = None):
    """Fully-sharded (ZeRO-3 / MaxText-style) parameter specs: every leaf's
    largest divisible dim shards over ALL mesh axes; weights are all-gathered
    just-in-time per layer.  The §Perf alternative to Megatron TP when the
    per-layer activation all-reduces dominate (weak-ICI pods, small models):
    wire drops from O(L x activations) to O(3 x params)."""
    ax = ax or MeshAxes.for_mesh(mesh)
    all_axes = axes if axes is not None else tuple(ax.dp) + (ax.tp,)
    n_all = _dim(mesh, all_axes)

    def leaf_spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in order:
            if leaf.shape[i] % n_all == 0:
                return P(*(None,) * i, all_axes, *(None,) * (leaf.ndim - i - 1))
        for i in order:  # fall back to a single-axis shard
            if leaf.shape[i] % _dim(mesh, ax.tp) == 0:
                return P(*(None,) * i, ax.tp, *(None,) * (leaf.ndim - i - 1))
        return P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def opt_state_specs(opt_state, pspecs, mesh: Mesh,
                    ax: Optional[MeshAxes] = None, zero1: bool = True):
    """Moment specs = param specs, plus ZeRO-1: additionally shard the first
    dimension whose spec is free over the dp axes (when divisible)."""
    ax = ax or MeshAxes.for_mesh(mesh)
    dp_size = _dim(mesh, ax.dp)

    def _uses_dp(spec_t) -> bool:
        for axes in spec_t:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                if a in ax.dp:
                    return True
        return False

    def zero_spec(spec: P, leaf):
        if not zero1:
            return spec
        spec_t = tuple(spec) + (None,) * (leaf.ndim - len(spec))
        if _uses_dp(spec_t):
            return spec  # already FSDP-sharded over dp (e.g. MoE experts)
        for i, (s, size) in enumerate(zip(spec_t, leaf.shape)):
            if s is None and size % dp_size == 0 and size >= dp_size:
                return P(*spec_t[:i], ax.dp, *spec_t[i + 1:])
        return spec

    if "mu_q" in opt_state:  # int8 moments: values like params, scales
        # like params minus the (row-quantized) last axis
        q_mu = jax.tree.map(zero_spec, pspecs, opt_state["mu_q"])
        q_nu = jax.tree.map(zero_spec, pspecs, opt_state["nu_q"])

        def scale_spec(spec: P, leaf):
            t = tuple(spec)[:-1] if len(spec) else ()
            cand = P(*t)
            return cand if _spec_fits(mesh, leaf.shape, cand) else P()

        s_mu = jax.tree.map(scale_spec, q_mu, opt_state["mu_s"])
        s_nu = jax.tree.map(scale_spec, q_nu, opt_state["nu_s"])
        return {"mu_q": q_mu, "mu_s": s_mu, "nu_q": q_nu, "nu_s": s_nu,
                "count": P()}
    mu_specs = jax.tree.map(zero_spec, pspecs, opt_state["mu"])
    nu_specs = jax.tree.map(zero_spec, pspecs, opt_state["nu"])
    return {"mu": mu_specs, "nu": nu_specs, "count": P()}


def batch_spec(cfg: ArchConfig, ax: MeshAxes, kind: str,
               batch_replicated: bool = False) -> Dict[str, P]:
    """Input shardings for a batch dict."""
    b = None if batch_replicated else ax.dp
    spec = {"tokens": P(b, None, None) if cfg.n_codebooks else P(b, None)}
    if cfg.cross_attn_every:
        spec["frontend"] = P(b, None, None)
    if kind == "train":
        spec["targets"] = dict(spec)["tokens"]
    return spec


def decode_state_specs(state, cfg: ArchConfig, mesh: Mesh,
                       ax: Optional[MeshAxes] = None,
                       batch_replicated: bool = False):
    """Decode-state shardings: KV cache sequence axis over tp (the
    flash-decoding layout), batch over dp, SSM heads over tp for pure SSM."""
    ax = ax or MeshAxes.for_mesh(mesh)
    b = None if batch_replicated else ax.dp
    specs: Dict[str, Any] = {"cur": P()}
    if "k" in state:
        seq_ok = state["k"].shape[2] % _dim(mesh, ax.tp) == 0
        s = ax.tp if seq_ok else None
        specs["k"] = P(None, b, s, None, None)
        specs["v"] = P(None, b, s, None, None)
        if "k_scale" in state:
            specs["k_scale"] = P(None, b, s, None)
            specs["v_scale"] = P(None, b, s, None)
    if "ssm" in state:
        h_shard = ax.tp if (cfg.family == "ssm" and
                            state["ssm"].h.shape[2] % _dim(mesh, ax.tp) == 0) else None
        from repro.models.ssm import SSMState
        specs["ssm"] = SSMState(h=P(None, b, h_shard, None, None),
                                conv_buf=P(None, b, None, None))
    if "cross_k" in state:
        specs["cross_k"] = P(None, b, None, None, None)
        specs["cross_v"] = P(None, b, None, None, None)
    return specs
