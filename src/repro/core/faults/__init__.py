"""Deterministic fault injection and graceful degradation.

:class:`FaultPlan` is a pure function of ``(seed, FaultConfig)`` that
schedules four fault classes — link flit CRC-retry bursts, port/link down
windows, NAND read-retry + grown bad blocks, and poison propagation —
injected tick-identically into the interpreted drivers and the fused
replay lanes.  See :mod:`repro.core.faults.plan`.
"""

from repro.core.faults.plan import (
    DeviceUnreachable,
    FaultConfig,
    FaultPlan,
    erase_fails_jnp,
    fault_hash,
    fault_hash_np,
    install,
    nand_read_retries_jnp,
    str_salt,
)

__all__ = [
    "DeviceUnreachable",
    "FaultConfig",
    "FaultPlan",
    "erase_fails_jnp",
    "fault_hash",
    "fault_hash_np",
    "install",
    "nand_read_retries_jnp",
    "str_salt",
]
