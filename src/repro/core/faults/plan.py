"""Deterministic fault injection: a seeded :class:`FaultPlan` shared by the
interpreted and fused replay paths.

Every fault decision is a pure function of ``(seed, config, stable key)`` —
no wall-clock, no RNG state — so the interpreted drivers and the fused
``lax.scan`` lanes inject *identical* faults and stay tick-exact.  The keys
are chosen to be computable on both sides:

* **link flit CRC retries** — keyed on ``(port, per-host access ordinal)``:
  the interpreted :class:`~repro.core.fabric.fabric.FabricAttachedDevice`
  counts its own accesses, the fused lane uses the trace index, so the
  per-access retry columns precompute exactly.
* **port/link down windows** — declared directly as ordinal intervals
  ``(u, v, first_ordinal, last_ordinal_exclusive)`` per undirected link, so
  both sides see the same degraded route set for the same access.
* **NAND read retries / erase failures** — keyed on a per-flash *operation
  sequence number* (reads and erases counted separately), which advances in
  the same order in the python FTL/PAL and in the in-scan flash state.
* **poison** — keyed on ``(host index, per-host access ordinal)``; reads
  only, surfaced as per-access status, never as fabricated latency.

The decision hash is splitmix64 over the mixed key.  Three twins —
scalar python int, vectorized numpy ``uint64``, and traced ``jnp.uint64``
(for in-scan NAND decisions) — are property-tested bit-equal.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import FrozenSet, List, Tuple

import numpy as np

_M64 = (1 << 64) - 1
_M32 = (1 << 32) - 1
_GOLDEN = 0x9E3779B97F4A7C15
_MULT1 = 0xBF58476D1CE4E5B9
_MULT2 = 0x94D049BB133111EB
_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3

# per-class salts keep the four fault streams independent under one seed
SALT_LINK = 0xA1A1
SALT_DOWN = 0xB2B2          # reserved (windows are explicit, not hashed)
SALT_NAND_READ = 0xC3C3
SALT_NAND_ERASE = 0xD4D4
SALT_POISON = 0xE5E5


class DeviceUnreachable(ValueError):
    """Raised when routing finds zero surviving paths to a device — every
    equal-cost path (and every recomputed fallback route) crosses a down
    port.  Subclasses ``ValueError`` so pre-fault unreachability handling
    keeps working."""


def str_salt(s: str) -> int:
    """FNV-1a over a node/port name — the stable string-keyed salt."""
    h = _FNV_OFFSET
    for b in s.encode():
        h = ((h ^ b) * _FNV_PRIME) & _M64
    return h


def _mix(x: int) -> int:
    """splitmix64 finalizer (scalar python int)."""
    x = (x + _GOLDEN) & _M64
    x = ((x ^ (x >> 30)) * _MULT1) & _M64
    x = ((x ^ (x >> 27)) * _MULT2) & _M64
    return x ^ (x >> 31)


def _mix_np(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (numpy uint64, wraps mod 2^64 like the scalar)."""
    x = x.astype(np.uint64) + np.uint64(_GOLDEN)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(_MULT1)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(_MULT2)
    return x ^ (x >> np.uint64(31))


def fault_hash(seed: int, salt: int, a: int, b: int) -> int:
    """64-bit decision hash over ``(seed, class salt, key a, key b)``."""
    h = _mix((seed + salt) & _M64)
    h = _mix(h ^ (a & _M64))
    return _mix(h ^ (b & _M64))


def fault_hash_np(seed: int, salt: int, a: int, b: np.ndarray) -> np.ndarray:
    """Vectorized :func:`fault_hash` over an array of ``b`` keys."""
    h0 = _mix((seed + salt) & _M64)
    h1 = _mix(h0 ^ (a & _M64))
    return _mix_np(np.uint64(h1) ^ np.asarray(b).astype(np.uint64))


def _rate_threshold(rate: float) -> int:
    """``rate`` in [0, 1] as a 32-bit comparison threshold."""
    if not 0.0 <= rate <= 1.0:
        raise ValueError(f"fault rate must be in [0, 1], got {rate}")
    return min(1 << 32, int(rate * (1 << 32)))


def _count_from(h: int, thresh: int, kmax: int) -> int:
    """Low 32 bits gate the event, high bits pick the burst size 1..kmax."""
    if (h & _M32) < thresh:
        return 1 + (h >> 32) % kmax
    return 0


@dataclass(frozen=True)
class FaultConfig:
    """Static fault schedule parameters.  All-zero rates and no down
    windows mean an inert plan (``FaultPlan.active`` is False)."""

    # class 1: link flit CRC-retry bursts — probability per (port, access)
    # that the flit needs 1..link_retry_max extra full serializations
    link_retry_rate: float = 0.0
    link_retry_max: int = 3
    # class 2: down windows, one per undirected link:
    # (u, v, first_ordinal, last_ordinal_exclusive) over per-host access
    # ordinals — both port directions (u, v) and (v, u) are down
    down_links: Tuple[Tuple[str, str, int, int], ...] = ()
    # class 3: NAND read retries (per physical page read) and grown bad
    # blocks (per erase — a failed erase retires the block from the pool)
    nand_read_retry_rate: float = 0.0
    nand_read_retry_max: int = 2
    erase_fail_rate: float = 0.0
    # class 4: poison — probability per (host, read access) that the
    # returned line carries the CXL poison flag
    poison_rate: float = 0.0


class FaultPlan:
    """Seeded, fully deterministic fault schedule (see module docstring)."""

    def __init__(self, config: FaultConfig, seed: int = 0) -> None:
        self.config = config
        self.seed = int(seed) & _M64
        self._link_thresh = _rate_threshold(config.link_retry_rate)
        self._nand_thresh = _rate_threshold(config.nand_read_retry_rate)
        self._erase_thresh = _rate_threshold(config.erase_fail_rate)
        self._poison_thresh = _rate_threshold(config.poison_rate)
        for name, kmax in (("link_retry_max", config.link_retry_max),
                           ("nand_read_retry_max",
                            config.nand_read_retry_max)):
            if kmax < 1:
                raise ValueError(f"{name} must be >= 1, got {kmax}")
        for u, v, a0, a1 in config.down_links:
            if a0 < 0 or a1 < a0:
                raise ValueError(
                    f"down window for {u}->{v} must satisfy 0 <= first <= "
                    f"last, got [{a0}, {a1})")

    # ------------------------------------------------------------ activity
    @property
    def has_link(self) -> bool:
        return self._link_thresh > 0

    @property
    def has_down(self) -> bool:
        return bool(self.config.down_links)

    @property
    def has_nand(self) -> bool:
        return self._nand_thresh > 0 or self._erase_thresh > 0

    @property
    def has_poison(self) -> bool:
        return self._poison_thresh > 0

    @property
    def active(self) -> bool:
        return (self.has_link or self.has_down or self.has_nand
                or self.has_poison)

    @property
    def has_transport_faults(self) -> bool:
        """Fault classes that ride the fabric transport (link retries,
        down windows) or the per-access status path (poison)."""
        return self.has_link or self.has_down or self.has_poison

    def class_names(self) -> Tuple[str, ...]:
        """The active fault classes, by human name, in schedule order —
        refusal messages use this to say exactly *which* class a lane
        cannot mirror (empty for an inert plan)."""
        out = []
        if self.has_link:
            out.append("link-retry")
        if self.has_down:
            out.append("port-down")
        if self.has_nand:
            out.append("NAND")
        if self.has_poison:
            out.append("poison")
        return tuple(out)

    # ------------------------------------------- class 1: link CRC retries
    def link_retries(self, port: Tuple[str, str], ordinal: int) -> int:
        """Extra full serializations (0 = clean) for one flit on one
        directed port, keyed on the issuing host's access ordinal."""
        if not self.has_link:
            return 0
        h = fault_hash(self.seed, SALT_LINK, str_salt(f"{port[0]}->{port[1]}"),
                       ordinal)
        return _count_from(h, self._link_thresh, self.config.link_retry_max)

    def link_retries_np(self, port: Tuple[str, str],
                        ordinals: np.ndarray) -> np.ndarray:
        """Vector twin of :meth:`link_retries` (int64)."""
        n = np.asarray(ordinals).shape[0]
        if not self.has_link:
            return np.zeros(n, np.int64)
        h = fault_hash_np(self.seed, SALT_LINK,
                          str_salt(f"{port[0]}->{port[1]}"), ordinals)
        hit = (h & np.uint64(_M32)) < np.uint64(self._link_thresh)
        k = np.uint64(1) + (h >> np.uint64(32)) \
            % np.uint64(self.config.link_retry_max)
        return np.where(hit, k, np.uint64(0)).astype(np.int64)

    # ------------------------------------------- class 2: down windows
    def down_links_at(self, ordinal: int) -> FrozenSet[Tuple[str, str]]:
        """The set of *directed* port keys down for this access ordinal
        (both orientations of every down undirected link)."""
        out = set()
        for u, v, a0, a1 in self.config.down_links:
            if a0 <= ordinal < a1:
                out.add((u, v))
                out.add((v, u))
        return frozenset(out)

    def down_segments(self, n: int) -> List[Tuple[int, int,
                                                  FrozenSet[Tuple[str, str]]]]:
        """Partition ordinals ``[0, n)`` into maximal runs of constant
        down-set: ``[(lo, hi_exclusive, down_set), ...]`` — the fused lane
        builds one route table entry per distinct segment."""
        cuts = {0, n}
        for _, _, a0, a1 in self.config.down_links:
            cuts.add(min(max(a0, 0), n))
            cuts.add(min(max(a1, 0), n))
        edges = sorted(cuts)
        return [(lo, hi, self.down_links_at(lo))
                for lo, hi in zip(edges, edges[1:]) if hi > lo]

    # ------------------------------------------- class 3: NAND faults
    def nand_read_retries(self, seq: int) -> int:
        """Extra sense+transfer rounds (0 = clean) for the ``seq``-th
        physical page read on a flash instance."""
        if self._nand_thresh == 0:
            return 0
        h = fault_hash(self.seed, SALT_NAND_READ, 0, seq)
        return _count_from(h, self._nand_thresh,
                           self.config.nand_read_retry_max)

    def erase_fails(self, seq: int) -> bool:
        """Whether the ``seq``-th block erase on a flash instance fails
        (the block grows bad and is retired from the free pool)."""
        if self._erase_thresh == 0:
            return False
        h = fault_hash(self.seed, SALT_NAND_ERASE, 0, seq)
        return (h & _M32) < self._erase_thresh

    def nand_statics(self) -> Tuple[int, ...]:
        """Hashable static tuple for the fused stack config:
        ``(seed, read_thresh, read_max, erase_thresh)``; empty when the
        plan schedules no NAND faults."""
        if not self.has_nand:
            return ()
        return (self.seed, self._nand_thresh,
                self.config.nand_read_retry_max, self._erase_thresh)

    # ------------------------------------------- class 4: poison
    def poisoned(self, host_idx: int, ordinal: int, write: bool) -> bool:
        """Whether this (read) access returns a poisoned line."""
        if write or not self.has_poison:
            return False
        h = fault_hash(self.seed, SALT_POISON, host_idx, ordinal)
        return (h & _M32) < self._poison_thresh

    def poisoned_np(self, host_idx: int, ordinals: np.ndarray,
                    writes: np.ndarray) -> np.ndarray:
        """Vector twin of :meth:`poisoned` (bool)."""
        n = np.asarray(ordinals).shape[0]
        if not self.has_poison:
            return np.zeros(n, bool)
        h = fault_hash_np(self.seed, SALT_POISON, host_idx, ordinals)
        return ((h & np.uint64(_M32)) < np.uint64(self._poison_thresh)) \
            & ~np.asarray(writes, bool)


# ------------------------------------------------------------ jnp twins
# Used only inside the fused scan, where the NAND sequence counters are
# data-dependent (GC migration reads advance them).  Runs under the scoped
# jax x64 mode every replay engine already enables.
def nand_read_retries_jnp(statics: Tuple[int, ...], seq):
    """Traced twin of :meth:`FaultPlan.nand_read_retries` over the in-scan
    read-sequence counter ``seq`` (int64 -> int64)."""
    import jax.numpy as jnp

    seed, read_thresh, read_max, _ = statics
    h = _mix_jnp_scalar(seed, SALT_NAND_READ, seq)
    hit = (h & jnp.uint64(_M32)) < jnp.uint64(read_thresh)
    k = jnp.uint64(1) + (h >> jnp.uint64(32)) % jnp.uint64(read_max)
    return jnp.where(hit, k, jnp.uint64(0)).astype(jnp.int64)


def erase_fails_jnp(statics: Tuple[int, ...], seq):
    """Traced twin of :meth:`FaultPlan.erase_fails` (int64 -> bool)."""
    import jax.numpy as jnp

    seed, _, _, erase_thresh = statics
    h = _mix_jnp_scalar(seed, SALT_NAND_ERASE, seq)
    return (h & jnp.uint64(_M32)) < jnp.uint64(erase_thresh)


def _mix_jnp_scalar(seed: int, salt: int, b):
    """``fault_hash(seed, salt, 0, b)`` with the two seed-side mixes folded
    at trace time (python ints) and only the key-side mix traced."""
    import jax.numpy as jnp

    h0 = _mix((seed + salt) & _M64)
    h1 = _mix(h0 ^ 0)
    x = jnp.uint64(h1) ^ b.astype(jnp.uint64)
    x = x + jnp.uint64(_GOLDEN)
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(_MULT1)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(_MULT2)
    return x ^ (x >> jnp.uint64(31))


# ------------------------------------------------------------ installation
def install(plan: FaultPlan, targets) -> FaultPlan:
    """Wire ``plan`` onto replay targets (fabric mounts or direct devices).

    Sets ``fault_plan`` on every target, on the shared fabric of mounted
    targets (link/down faults ride the transport), and on the FTL/PAL of
    any flash stack reachable through the target (NAND faults).  Pool
    views are not supported — fault ordinals are per-host, which pool
    address interleaving would scramble."""
    for t in targets:
        fabric = getattr(t, "fabric", None)
        if fabric is None and hasattr(t, "pool"):
            raise TypeError(
                "fault injection supports fabric mounts and direct "
                "devices, not pool views")
        t.fault_plan = plan
        inner = getattr(t, "inner", t)
        if fabric is not None:
            fabric.fault_plan = plan
        hil = getattr(inner, "hil", None)
        if hil is not None:
            hil.ftl.fault_plan = plan
            hil.ftl.pal.fault_plan = plan
    return plan
