"""CXL fabric topology: a static graph of hosts, switches, and devices.

A :class:`Topology` is pure structure — node names, node kinds, and links
with per-link bandwidth/propagation parameters.  Timing state (per-port
busy-until occupancy) lives in :class:`repro.core.fabric.switch.SwitchPort`,
instantiated by :class:`repro.core.fabric.fabric.Fabric` from this graph.

Builders cover the shapes evaluated in multi-host CXL studies
(CXL-ClusterSim, OpenCXD):

``direct``         host_i — dev_i point-to-point (degenerate fabric; must
                   reproduce bare :class:`~repro.core.devices.CXLLink`
                   timing exactly)
``single_switch``  all hosts and devices on one switch (star)
``two_level``      leaf switches holding hosts, root switch holding devices
``spine_leaf``     two-tier Clos (every leaf uplinks to every spine) — the
                   canonical ECMP shape: ``num_spines`` equal-cost paths
                   between endpoints on different leaves
``mesh``           2-D grid of switches, hosts/devices attached round-robin
``multi_pod``      datacenter fabric: ``num_pods`` spine_leaf pods joined by
                   a core switch tier (every pod spine uplinks to every core
                   switch).  Hosts are block-assigned to pods; each host's
                   private device lives one pod over, so ``h_i -> d_i``
                   traffic always crosses the core tier and ECMP fans out
                   over ``spines x cores x spines`` pod-egress paths.

Node names are ``h<i>`` (hosts), ``s<i>`` / ``s<r>_<c>`` / ``p<k>s<j>`` /
``c<j>`` (switches), and ``d<i>`` (devices).  Topologies are immutable once
handed to a ``Fabric``; routing results are cached under that assumption.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

HOST = "host"
SWITCH = "switch"
DEVICE = "device"

DEFAULT_LINK_BW_GBPS = 16.0   # PCIe 4.0 x8-class CXL link, per direction


@dataclass(frozen=True)
class LinkSpec:
    """One *directed* link (an egress port): serialization bandwidth plus a
    fixed propagation delay."""
    bw_gbps: float = DEFAULT_LINK_BW_GBPS
    prop_ns: float = 0.0


@dataclass
class Topology:
    name: str = "custom"
    kinds: Dict[str, str] = field(default_factory=dict)           # node -> kind
    links: Dict[Tuple[str, str], LinkSpec] = field(default_factory=dict)
    _adj: Dict[str, List[str]] = field(default_factory=dict)

    # ------------------------------------------------------------- building
    def _add_node(self, node: str, kind: str) -> str:
        if node in self.kinds:
            raise ValueError(f"duplicate node {node!r}")
        self.kinds[node] = kind
        self._adj[node] = []
        return node

    def add_host(self, node: str) -> str:
        return self._add_node(node, HOST)

    def add_switch(self, node: str) -> str:
        return self._add_node(node, SWITCH)

    def add_device(self, node: str) -> str:
        return self._add_node(node, DEVICE)

    def connect(self, u: str, v: str, bw_gbps: float = DEFAULT_LINK_BW_GBPS,
                prop_ns: float = 0.0) -> None:
        """Add a full-duplex link ``u <-> v`` (two directed LinkSpecs)."""
        for node in (u, v):
            if node not in self.kinds:
                raise ValueError(f"unknown node {node!r}")
        if (u, v) in self.links:
            raise ValueError(f"duplicate link {u!r} <-> {v!r}")
        if bw_gbps <= 0:
            raise ValueError(f"link {u!r} <-> {v!r}: bandwidth must be > 0")
        spec = LinkSpec(bw_gbps=bw_gbps, prop_ns=prop_ns)
        self.links[(u, v)] = spec
        self.links[(v, u)] = spec
        self._adj[u].append(v)
        self._adj[v].append(u)
        self._adj[u].sort()
        self._adj[v].sort()

    # -------------------------------------------------------------- queries
    def neighbors(self, node: str) -> List[str]:
        return self._adj[node]

    def kind(self, node: str) -> str:
        return self.kinds[node]

    def nodes_of_kind(self, kind: str) -> List[str]:
        return sorted(n for n, k in self.kinds.items() if k == kind)

    @property
    def hosts(self) -> List[str]:
        return self.nodes_of_kind(HOST)

    @property
    def switches(self) -> List[str]:
        return self.nodes_of_kind(SWITCH)

    @property
    def devices(self) -> List[str]:
        return self.nodes_of_kind(DEVICE)

    def validate(self) -> None:
        for node, kind in self.kinds.items():
            if not self._adj[node]:
                raise ValueError(f"{kind} {node!r} is disconnected")
            if kind != SWITCH and len(self._adj[node]) > 1:
                # Endpoints own exactly one port; fan-out belongs to switches.
                raise ValueError(
                    f"{kind} {node!r} has {len(self._adj[node])} links; "
                    "endpoints attach to exactly one fabric port")


# ------------------------------------------------------------------ builders
def _check_counts(num_hosts: int, num_devices: int) -> None:
    if num_hosts < 1 or num_devices < 1:
        raise ValueError("topology needs at least one host and one device")


def direct(num_pairs: int = 1, bw_gbps: float = DEFAULT_LINK_BW_GBPS) -> Topology:
    """``h_i — d_i`` point-to-point links, no switches.  With one pair this is
    exactly the paper's single-host CXLLink configuration."""
    _check_counts(num_pairs, num_pairs)
    topo = Topology(name="direct")
    for i in range(num_pairs):
        h = topo.add_host(f"h{i}")
        d = topo.add_device(f"d{i}")
        topo.connect(h, d, bw_gbps=bw_gbps)
    topo.validate()
    return topo


def single_switch(num_hosts: int, num_devices: int,
                  bw_gbps: float = DEFAULT_LINK_BW_GBPS) -> Topology:
    """Star: every host and device hangs off one switch ``s0``."""
    _check_counts(num_hosts, num_devices)
    topo = Topology(name="single_switch")
    sw = topo.add_switch("s0")
    for i in range(num_hosts):
        topo.connect(topo.add_host(f"h{i}"), sw, bw_gbps=bw_gbps)
    for i in range(num_devices):
        topo.connect(topo.add_device(f"d{i}"), sw, bw_gbps=bw_gbps)
    topo.validate()
    return topo


def two_level(num_hosts: int, num_devices: int, num_leaves: int = 2,
              bw_gbps: float = DEFAULT_LINK_BW_GBPS,
              uplink_bw_gbps: float | None = None) -> Topology:
    """Two-level tree: hosts round-robin onto leaf switches, leaves uplink to
    a root switch, devices on the root.  The leaf->root uplink is the shared
    bottleneck (defaults to the same bandwidth as edge links)."""
    _check_counts(num_hosts, num_devices)
    if num_leaves < 1:
        raise ValueError("need at least one leaf switch")
    topo = Topology(name="two_level")
    root = topo.add_switch("s_root")
    leaves = [topo.add_switch(f"s{i}") for i in range(num_leaves)]
    for leaf in leaves:
        topo.connect(leaf, root, bw_gbps=(uplink_bw_gbps if uplink_bw_gbps
                                          is not None else bw_gbps))
    for i in range(num_hosts):
        topo.connect(topo.add_host(f"h{i}"), leaves[i % num_leaves],
                     bw_gbps=bw_gbps)
    for i in range(num_devices):
        topo.connect(topo.add_device(f"d{i}"), root, bw_gbps=bw_gbps)
    topo.validate()
    return topo


def spine_leaf(num_hosts: int, num_devices: int, num_leaves: int = 2,
               num_spines: int = 2, bw_gbps: float = DEFAULT_LINK_BW_GBPS,
               uplink_bw_gbps: float | None = None) -> Topology:
    """Two-tier Clos: every leaf uplinks to every spine, hosts round-robin
    onto the first leaves, devices round-robin onto the last ones.  Any
    host->device pair on different leaves has ``num_spines`` equal-cost
    paths — the canonical ECMP shape (with ECMP off, deterministic
    single-path routing leaves all but one spine idle)."""
    _check_counts(num_hosts, num_devices)
    if num_leaves < 1 or num_spines < 1:
        raise ValueError("spine_leaf needs at least one leaf and one spine")
    topo = Topology(name="spine_leaf")
    spines = [topo.add_switch(f"sp{i}") for i in range(num_spines)]
    leaves = [topo.add_switch(f"s{i}") for i in range(num_leaves)]
    up = uplink_bw_gbps if uplink_bw_gbps is not None else bw_gbps
    for leaf in leaves:
        for spine in spines:
            topo.connect(leaf, spine, bw_gbps=up)
    for i in range(num_hosts):
        topo.connect(topo.add_host(f"h{i}"), leaves[i % num_leaves],
                     bw_gbps=bw_gbps)
    for i in range(num_devices):
        topo.connect(topo.add_device(f"d{i}"),
                     leaves[(num_leaves - 1 - i) % num_leaves],
                     bw_gbps=bw_gbps)
    topo.validate()
    return topo


def mesh(num_hosts: int, num_devices: int, rows: int = 2, cols: int = 2,
         bw_gbps: float = DEFAULT_LINK_BW_GBPS) -> Topology:
    """``rows x cols`` switch grid (4-neighbor).  Hosts attach round-robin
    from the top-left corner, devices round-robin from the bottom-right, so
    traffic crosses the grid."""
    _check_counts(num_hosts, num_devices)
    if rows < 1 or cols < 1:
        raise ValueError("mesh needs at least one switch row and column")
    topo = Topology(name="mesh")
    grid = [[topo.add_switch(f"s{r}_{c}") for c in range(cols)]
            for r in range(rows)]
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                topo.connect(grid[r][c], grid[r][c + 1], bw_gbps=bw_gbps)
            if r + 1 < rows:
                topo.connect(grid[r][c], grid[r + 1][c], bw_gbps=bw_gbps)
    flat = [grid[r][c] for r in range(rows) for c in range(cols)]
    for i in range(num_hosts):
        topo.connect(topo.add_host(f"h{i}"), flat[i % len(flat)],
                     bw_gbps=bw_gbps)
    rflat = list(reversed(flat))
    for i in range(num_devices):
        topo.connect(topo.add_device(f"d{i}"), rflat[i % len(rflat)],
                     bw_gbps=bw_gbps)
    topo.validate()
    return topo


def multi_pod(num_pods: int = 2, hosts_per_pod: int = 4,
              devices_per_pod: int | None = None, num_leaves: int = 2,
              num_spines: int = 2, num_core: int = 2,
              bw_gbps: float = DEFAULT_LINK_BW_GBPS,
              uplink_bw_gbps: float | None = None,
              core_bw_gbps: float | None = None) -> Topology:
    """Multi-pod datacenter fabric: ``num_pods`` spine_leaf pods joined by a
    core tier.  Pod ``k`` owns leaves ``p<k>s<j>`` and spines ``p<k>sp<j>``
    (full leaf-spine bipartite, like :func:`spine_leaf`); every pod spine
    uplinks to every core switch ``c<j>``.

    Hosts are **block-assigned**: pod ``k`` holds hosts
    ``h[k*hosts_per_pod : (k+1)*hosts_per_pod]``, round-robin over the pod's
    leaves — the contiguous host blocks are exactly what the sharded replay
    partitions across JAX devices.  Device ``d<i>`` sits in the pod *after*
    its host's pod (``(pod(i) + 1) % num_pods``), so every ``h_i -> d_i``
    mount crosses the core tier: leaf -> spine (``num_spines`` choices) ->
    core (``num_core`` choices) -> spine -> leaf, i.e.
    ``num_spines * num_core * num_spines`` equal-cost ECMP paths (capped by
    routing's :data:`~repro.core.fabric.routing.MAX_ECMP_PATHS`).  With a
    single pod the core tier still carries no host->device traffic shortcut
    — require ``num_pods >= 2`` so the shape is honest."""
    if num_pods < 2:
        raise ValueError("multi_pod needs at least two pods "
                         "(use spine_leaf for a single pod)")
    if hosts_per_pod < 1:
        raise ValueError("multi_pod needs at least one host per pod")
    if num_leaves < 1 or num_spines < 1 or num_core < 1:
        raise ValueError("multi_pod needs >= 1 leaf, spine and core switch")
    dpp = hosts_per_pod if devices_per_pod is None else devices_per_pod
    if dpp < 1:
        raise ValueError("multi_pod needs at least one device per pod")
    up = uplink_bw_gbps if uplink_bw_gbps is not None else bw_gbps
    core_bw = core_bw_gbps if core_bw_gbps is not None else up
    topo = Topology(name="multi_pod")
    cores = [topo.add_switch(f"c{j}") for j in range(num_core)]
    leaves: List[List[str]] = []
    for k in range(num_pods):
        pod_spines = [topo.add_switch(f"p{k}sp{j}")
                      for j in range(num_spines)]
        pod_leaves = [topo.add_switch(f"p{k}s{j}") for j in range(num_leaves)]
        leaves.append(pod_leaves)
        for leaf in pod_leaves:
            for spine in pod_spines:
                topo.connect(leaf, spine, bw_gbps=up)
        for spine in pod_spines:
            for core in cores:
                topo.connect(spine, core, bw_gbps=core_bw)
    for i in range(num_pods * hosts_per_pod):
        k = i // hosts_per_pod
        topo.connect(topo.add_host(f"h{i}"),
                     leaves[k][(i % hosts_per_pod) % num_leaves],
                     bw_gbps=bw_gbps)
    for i in range(num_pods * dpp):
        k = (i // dpp + 1) % num_pods
        topo.connect(topo.add_device(f"d{i}"),
                     leaves[k][(i % dpp) % num_leaves], bw_gbps=bw_gbps)
    topo.validate()
    return topo


TOPOLOGY_BUILDERS = {
    "direct": direct,
    "single_switch": single_switch,
    "two_level": two_level,
    "spine_leaf": spine_leaf,
    "mesh": mesh,
    "multi_pod": multi_pod,
}


def build_topology(kind: str, **kwargs) -> Topology:
    try:
        builder = TOPOLOGY_BUILDERS[kind]
    except KeyError:
        raise ValueError(f"unknown topology {kind!r}; choose from "
                         f"{sorted(TOPOLOGY_BUILDERS)}") from None
    return builder(**kwargs)
