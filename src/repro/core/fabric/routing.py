"""Shortest-path routing over a fabric topology.

Paths are computed by Dijkstra over hop count with *deterministic
tie-breaking*: among equal-length paths the lexicographically smallest node
sequence wins (the heap orders candidates by ``(hops, path_tuple)``).  Two
runs of the same scenario therefore route identically — a property the
equivalence tests and the vectorized congestion estimator both rely on.

Only switches relay traffic; hosts and devices are endpoints.  Routes are
cached per ``(src, dst)`` under the assumption that the topology is static
once a :class:`~repro.core.fabric.fabric.Fabric` is built.
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Tuple

from repro.core.fabric.topology import SWITCH, Topology


class RoutingTable:
    def __init__(self, topology: Topology) -> None:
        self.topology = topology
        self._cache: Dict[Tuple[str, str], List[str]] = {}

    def path(self, src: str, dst: str) -> List[str]:
        """Node sequence ``[src, ..., dst]``; raises if unreachable."""
        key = (src, dst)
        cached = self._cache.get(key)
        if cached is None:
            cached = self._cache[key] = _shortest_path(self.topology, src, dst)
        return cached

    def hops(self, src: str, dst: str) -> int:
        return len(self.path(src, dst)) - 1


def _shortest_path(topo: Topology, src: str, dst: str) -> List[str]:
    if src == dst:
        raise ValueError(f"src == dst ({src!r})")
    for node in (src, dst):
        if node not in topo.kinds:
            raise ValueError(f"unknown node {node!r}")
    # (hops, path) heap: equal hop counts resolve to the lexicographically
    # smallest path, making routing deterministic across runs.
    heap: List[Tuple[int, Tuple[str, ...]]] = [(0, (src,))]
    settled = set()
    while heap:
        hops, path = heapq.heappop(heap)
        node = path[-1]
        if node == dst:
            return list(path)
        if node in settled:
            continue
        settled.add(node)
        for nxt in topo.neighbors(node):
            if nxt in settled:
                continue
            # Endpoints never relay: expand through switches, or stop at dst.
            if nxt != dst and topo.kind(nxt) != SWITCH:
                continue
            heapq.heappush(heap, (hops + 1, path + (nxt,)))
    raise ValueError(f"no path from {src!r} to {dst!r}")
