"""Shortest-path routing over a fabric topology, with optional ECMP.

Paths are computed over hop count with *deterministic tie-breaking*: among
equal-length paths the lexicographically smallest node sequence wins.  Two
runs of the same scenario therefore route identically — a property the
equivalence tests and the vectorized congestion estimator both rely on.

:meth:`RoutingTable.paths` enumerates *all* equal-cost shortest paths
(lexicographically ordered, so ``paths(...)[0] == path(...)``), which is the
ECMP path set.  :func:`flow_hash` / :func:`flow_choices` map a flow key
``(src, dst, line_addr)`` onto that set deterministically: pure mod-2^64
integer arithmetic (FNV-1a pair salt + splitmix64 finalizer), so the scalar
per-access Python path and the vectorized numpy export used by the fused
replay agree bit-for-bit.

Only switches relay traffic; hosts and devices are endpoints.  Routes are
cached per ``(src, dst)`` under the assumption that the topology is static
once a :class:`~repro.core.fabric.fabric.Fabric` is built.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, FrozenSet, List, Tuple

import numpy as np

from repro.core.fabric.topology import SWITCH, Topology
from repro.core.faults import DeviceUnreachable

# Keep the ECMP fan-out bounded on dense graphs (a large mesh has a
# combinatorial number of equal-cost paths).  The lexicographically smallest
# MAX_ECMP_PATHS are retained — deterministic, and a superset is never
# needed because selection hashes into the retained list.
MAX_ECMP_PATHS = 16

_M64 = (1 << 64) - 1
_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3


def pair_salt(src: str, dst: str) -> int:
    """FNV-1a over ``"src->dst"`` — the per-flow-pair hash salt."""
    h = _FNV_OFFSET
    for b in f"{src}->{dst}".encode():
        h = ((h ^ b) * _FNV_PRIME) & _M64
    return h


def flow_hash(src: str, dst: str, line_addr: int) -> int:
    """Deterministic 64-bit flow hash over ``(src, dst, line_addr)``.

    splitmix64 finalizer over the line address xor'd with the pair salt.
    Stable across runs and processes (never Python's randomized ``hash``).
    """
    x = (int(line_addr) ^ pair_salt(src, dst)) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


def flow_choices(src: str, dst: str, line_addrs: np.ndarray,
                 num_paths: int) -> np.ndarray:
    """Vectorized ``flow_hash(...) % num_paths`` for a line-address array.

    numpy uint64 arithmetic wraps mod 2^64, matching the scalar
    :func:`flow_hash` exactly — the fused replay precomputes its per-access
    route-choice column with this, so it cannot drift from the interpreted
    per-access path.
    """
    if num_paths <= 1:
        return np.zeros(np.asarray(line_addrs).shape, np.int32)
    x = np.asarray(line_addrs).astype(np.uint64)
    x = x ^ np.uint64(pair_salt(src, dst))
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x % np.uint64(num_paths)).astype(np.int32)


def flow_choices_jnp(src: str, dst: str, line_addrs, num_paths: int):
    """Traced twin of :func:`flow_choices` (``jnp.uint64`` arithmetic wraps
    mod 2^64 exactly like numpy), so route-choice columns for traces that
    are *synthesized on-device* (``repro.data.workloads``) never leave the
    accelerator.  Requires x64 (run under the ``enable_x64()`` scope every
    replay engine already opens); bit-equal to the scalar and numpy twins
    (property-tested)."""
    import jax.numpy as jnp

    if num_paths <= 1:
        return jnp.zeros(jnp.shape(line_addrs), jnp.int32)
    x = jnp.asarray(line_addrs).astype(jnp.uint64)
    x = x ^ jnp.uint64(pair_salt(src, dst))
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    x = x ^ (x >> jnp.uint64(31))
    return (x % jnp.uint64(num_paths)).astype(jnp.int32)


_EMPTY_DOWN: FrozenSet[Tuple[str, str]] = frozenset()


class RoutingTable:
    def __init__(self, topology: Topology) -> None:
        self.topology = topology
        self._cache: Dict[Tuple[str, str], List[List[str]]] = {}
        # masked-route cache: (src, dst, down-set) -> recomputed paths,
        # populated only when a whole equal-cost set is down (failover)
        self._down_cache: Dict[Tuple[str, str, FrozenSet[Tuple[str, str]]],
                               List[List[str]]] = {}

    def paths(self, src: str, dst: str,
              down: FrozenSet[Tuple[str, str]] = _EMPTY_DOWN
              ) -> List[List[str]]:
        """All equal-cost shortest node sequences ``[src, ..., dst]``,
        lexicographically ordered (capped at :data:`MAX_ECMP_PATHS`);
        raises if unreachable.

        ``down`` masks directed port keys: surviving base paths are
        returned if any remain; otherwise routes are *recomputed* over the
        masked topology (failover onto longer paths).  Zero surviving
        paths raises :class:`~repro.core.faults.DeviceUnreachable` naming
        the down-port set."""
        key = (src, dst)
        cached = self._cache.get(key)
        if cached is None:
            cached = self._cache[key] = _all_shortest_paths(
                self.topology, src, dst)
        if not down:
            return cached
        surviving = [p for p in cached if not _path_blocked(p, down)]
        if surviving:
            return surviving
        dkey = (src, dst, down)
        rerouted = self._down_cache.get(dkey)
        if rerouted is None:
            try:
                rerouted = _all_shortest_paths(self.topology, src, dst,
                                               blocked=down)
            except ValueError:
                rerouted = []
            self._down_cache[dkey] = rerouted
        if not rerouted:
            raise DeviceUnreachable(
                f"no surviving route from {src!r} to {dst!r}: every path "
                f"crosses a down port (down={sorted(down)})")
        return rerouted

    def path(self, src: str, dst: str) -> List[str]:
        """The primary (lexicographically smallest shortest) path."""
        return self.paths(src, dst)[0]

    def num_paths(self, src: str, dst: str) -> int:
        return len(self.paths(src, dst))

    def select(self, src: str, dst: str, line_addr: int,
               down: FrozenSet[Tuple[str, str]] = _EMPTY_DOWN
               ) -> List[str]:
        """ECMP selection: hash ``(src, dst, line_addr)`` onto the
        (surviving) equal-cost path set.  With a single shortest path this
        is exactly :meth:`path`; with every path down it raises
        :class:`~repro.core.faults.DeviceUnreachable`."""
        paths = self.paths(src, dst, down=down)
        if len(paths) == 1:
            return paths[0]
        return paths[flow_hash(src, dst, line_addr) % len(paths)]

    def hops(self, src: str, dst: str) -> int:
        return len(self.path(src, dst)) - 1


def _path_blocked(path: List[str],
                  down: FrozenSet[Tuple[str, str]]) -> bool:
    """Whether any hop of ``path`` crosses a down directed port."""
    return any((u, v) in down for u, v in zip(path, path[1:]))


def _all_shortest_paths(topo: Topology, src: str, dst: str,
                        blocked: FrozenSet[Tuple[str, str]] = frozenset()
                        ) -> List[List[str]]:
    """Lazily enumerate equal-cost shortest paths in lexicographic order.

    A reverse BFS from ``dst`` over the relay-constrained graph labels
    every node with its shortest remaining distance; a forward DFS from
    ``src`` then walks only distance-decreasing edges, visiting candidates
    in sorted order — so paths stream out lexicographically (the first one
    reproduces the seed Dijkstra tie-break exactly) and generation stops at
    :data:`MAX_ECMP_PATHS` without materializing the combinatorial path
    set a dense mesh would otherwise produce."""
    if src == dst:
        raise ValueError(f"src == dst ({src!r})")
    for node in (src, dst):
        if node not in topo.kinds:
            raise ValueError(f"unknown node {node!r}")
    # dist_d[v]: hops from v to dst relaying only through switches.
    dist_d = {dst: 0}
    queue = deque([dst])
    while queue:
        node = queue.popleft()
        # Endpoints never relay: expand through switches (or dst itself).
        if node != dst and topo.kind(node) != SWITCH:
            continue
        for nxt in topo.neighbors(node):
            # expanding node -> nxt labels the *forward* edge (nxt, node)
            if blocked and (nxt, node) in blocked:
                continue
            if nxt not in dist_d:
                dist_d[nxt] = dist_d[node] + 1
                queue.append(nxt)
    if src not in dist_d:
        raise ValueError(f"no path from {src!r} to {dst!r}")

    paths: List[List[str]] = []
    prefix = [src]

    def walk(node: str) -> None:
        if len(paths) >= MAX_ECMP_PATHS:
            return
        if node == dst:
            paths.append(list(prefix))
            return
        for nxt in topo.neighbors(node):        # adjacency is kept sorted
            if nxt != dst and topo.kind(nxt) != SWITCH:
                continue
            if blocked and (node, nxt) in blocked:
                continue
            if dist_d.get(nxt, -1) == dist_d[node] - 1:
                prefix.append(nxt)
                walk(nxt)
                prefix.pop()

    walk(src)
    return paths
