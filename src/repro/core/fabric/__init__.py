"""repro.core.fabric — CXL switch-fabric subsystem.

Multi-host switch topologies (direct / single-switch / two-level tree /
mesh), deterministic shortest-path routing, per-port bandwidth occupancy,
and pooled-memory scenarios.  ``Fabric.traverse`` mirrors
``CXLLink.traverse`` so every existing ``MemDevice`` mounts behind the
fabric unchanged via ``FabricAttachedDevice`` / ``MemoryPool``.

The vectorized congestion estimator lives in
:mod:`repro.core.fabric.link_sim` (imported lazily — it pulls in JAX).
"""

from repro.core.fabric.fabric import Fabric, FabricAttachedDevice
from repro.core.fabric.pool import HostPortView, MemoryPool, PoolAddressMapper
from repro.core.fabric.routing import RoutingTable, flow_choices, flow_hash
from repro.core.fabric.switch import SwitchPort
from repro.core.fabric.topology import (
    TOPOLOGY_BUILDERS,
    Topology,
    build_topology,
    direct,
    mesh,
    single_switch,
    spine_leaf,
    two_level,
)

__all__ = [
    "Fabric", "FabricAttachedDevice",
    "MemoryPool", "HostPortView", "PoolAddressMapper",
    "RoutingTable", "SwitchPort", "flow_hash", "flow_choices",
    "Topology", "build_topology", "TOPOLOGY_BUILDERS",
    "direct", "single_switch", "two_level", "spine_leaf", "mesh",
]
