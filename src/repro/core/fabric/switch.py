"""Switch-port timing: per-port serialization occupancy (busy-until).

A :class:`SwitchPort` is one *directed* egress port of the fabric — the unit
of bandwidth contention.  It uses the same analytic busy-until discipline as
:class:`repro.core.devices.CXLLink.traverse`: a transfer occupies the port
for ``nbytes / bw`` and later arrivals queue behind it.  Store-and-forward
means a packet is fully serialized onto a link before the next hop begins,
so multi-hop paths pay serialization once per hop.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import ns, to_s


@dataclass
class SwitchPort:
    """Directed egress port ``src -> dst`` with busy-until occupancy."""

    src: str
    dst: str
    bw_gbps: float
    prop_ns: float = 0.0

    busy_until: int = 0
    packets: int = 0
    bytes: int = 0
    queued_ticks: int = 0     # total ticks transfers waited for the port
    occupied_ticks: int = 0   # total ticks the port was serializing

    def transmit(self, now: int, nbytes: int) -> int:
        """Serialize ``nbytes`` onto this port starting no earlier than
        ``now``; returns the tick the last byte arrives at ``dst``."""
        occ = ns(nbytes / self.bw_gbps)   # bytes / (GB/s) == ns
        start = max(now, self.busy_until)
        self.queued_ticks += start - now
        self.busy_until = start + occ
        self.packets += 1
        self.bytes += nbytes
        self.occupied_ticks += occ
        return start + occ + ns(self.prop_ns)

    def utilization(self, elapsed_ticks: int) -> float:
        """Fraction of ``elapsed_ticks`` the port spent serializing."""
        return self.occupied_ticks / elapsed_ticks if elapsed_ticks else 0.0

    def achieved_gbps(self, elapsed_ticks: int) -> float:
        sec = to_s(elapsed_ticks)
        return self.bytes / sec / 1e9 if sec else 0.0

    def reset(self) -> None:
        self.busy_until = 0
        self.packets = 0
        self.bytes = 0
        self.queued_ticks = 0
        self.occupied_ticks = 0
