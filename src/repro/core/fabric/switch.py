"""Switch-port timing: per-port serialization occupancy (busy-until).

A :class:`SwitchPort` is one *directed* egress port of the fabric — the unit
of bandwidth contention.  It uses the same analytic busy-until discipline as
:class:`repro.core.devices.CXLLink.traverse`: a transfer occupies the port
for ``nbytes / bw`` and later arrivals queue behind it.  Store-and-forward
means a packet is fully serialized onto a link before the next hop begins,
so multi-hop paths pay serialization once per hop.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.engine import ns, to_s


@dataclass
class SwitchPort:
    """Directed egress port ``src -> dst`` with busy-until occupancy."""

    src: str
    dst: str
    bw_gbps: float
    prop_ns: float = 0.0

    busy_until: int = 0
    packets: int = 0
    bytes: int = 0
    queued_ticks: int = 0     # total ticks transfers waited for the port
    occupied_ticks: int = 0   # total ticks the port was serializing
    # traffic attribution: originating endpoint -> bytes carried for it
    # (QoS groundwork: scheduling stays FCFS, this is accounting only)
    bytes_by_origin: Dict[str, int] = field(default_factory=dict)

    def occ_ticks(self, nbytes: int) -> int:
        """Serialization occupancy for ``nbytes`` — THE definition of this
        port's busy-until increment.  Both the interpreted path
        (:meth:`transmit`) and the fused replay's route-tensor export
        (:meth:`Fabric.route_occupancy`) call this, so the rule cannot
        drift between them."""
        return ns(nbytes / self.bw_gbps)   # bytes / (GB/s) == ns

    def transmit(self, now: int, nbytes: int,
                 origin: Optional[str] = None) -> int:
        """Serialize ``nbytes`` onto this port starting no earlier than
        ``now``; returns the tick the last byte arrives at ``dst``.
        ``origin`` attributes the traffic to its source endpoint."""
        occ = self.occ_ticks(nbytes)
        start = max(now, self.busy_until)
        self.queued_ticks += start - now
        self.busy_until = start + occ
        self.packets += 1
        self.bytes += nbytes
        self.occupied_ticks += occ
        if origin is not None:
            self.bytes_by_origin[origin] = \
                self.bytes_by_origin.get(origin, 0) + nbytes
        return start + occ + ns(self.prop_ns)

    def utilization(self, elapsed_ticks: int) -> float:
        """Fraction of ``elapsed_ticks`` the port spent serializing."""
        return self.occupied_ticks / elapsed_ticks if elapsed_ticks else 0.0

    def achieved_gbps(self, elapsed_ticks: int) -> float:
        sec = to_s(elapsed_ticks)
        return self.bytes / sec / 1e9 if sec else 0.0

    def reset(self) -> None:
        self.busy_until = 0
        self.packets = 0
        self.bytes = 0
        self.queued_ticks = 0
        self.occupied_ticks = 0
        self.bytes_by_origin = {}
