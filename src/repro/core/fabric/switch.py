"""Switch-port timing: per-port serialization occupancy (busy-until), with
optional weighted QoS arbitration.

A :class:`SwitchPort` is one *directed* egress port of the fabric — the unit
of bandwidth contention.  It uses the same analytic busy-until discipline as
:class:`repro.core.devices.CXLLink.traverse`: a transfer occupies the port
for ``nbytes / bw`` and later arrivals queue behind it.  Store-and-forward
means a packet is fully serialized onto a link before the next hop begins,
so multi-hop paths pay serialization once per hop.

QoS discipline (``weight_by_origin``): weighted virtual-finish-time
arbitration in requester-throttling form, the way CXL.mem QoS actually
operates (the switch signals load back to the host, which slows its
injection — in-flight data is never reordered).  Packets always serialize
at their FCFS position — ``busy_until``, and every downstream busy-until
they touch, advances exactly as without QoS, so the port never idles and
the one-pass analytic model keeps processing order aligned with simulated
time.  Separately, each origin *o* carries a virtual finish time
``vft[o]`` advancing by ``occ * W_active / w_o`` per transfer — *o*'s
service interval on a GPS (generalized processor sharing) port shared with
the currently-contending origins.  When *o* is virtually backlogged
(``vft[o] > now``: it has been injecting faster than its weighted share),
:meth:`qos_update` returns that virtual finish as a *completion floor*;
the fabric applies the floor to the final acknowledgment the issuing host
sees (never to the data path), so the host's line-fill-buffer slots recycle
no faster than its share while other origins' packets flow untouched.
Under contention the bandwidth split converges to the weight ratio — the
allocation a smallest-virtual-finish-time pick over queued transfers would
produce; a lone (or under-share, or sparse) origin is never floored, so
the discipline is work-conserving and degenerates to FCFS exactly.

When every configured weight is equal the port runs the legacy FCFS path
bit-for-bit (the arbitration is skipped entirely, not just neutral).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.engine import ns, to_s

# An origin counts toward the contending (active) weight sum if it arrived
# at the port within this many serialization quanta — generous enough that a
# closed-loop host throttled below its fair share still registers, short
# enough that a finished trace releases its share promptly.
ACTIVE_WINDOW_OCC = 16


@dataclass
class SwitchPort:
    """Directed egress port ``src -> dst`` with busy-until occupancy."""

    src: str
    dst: str
    bw_gbps: float
    prop_ns: float = 0.0

    busy_until: int = 0
    packets: int = 0
    bytes: int = 0
    queued_ticks: int = 0     # total ticks transfers waited for the port
    occupied_ticks: int = 0   # total ticks the port was serializing
    # QoS observability: transfers whose origin was virtually backlogged
    # here (qos_update returned a nonzero completion floor)
    qos_throttle_events: int = 0
    # fault observability: extra full serializations charged by CRC-retry
    # bursts (see repro.core.faults) — each retry re-serializes the flit
    crc_retries: int = 0
    # traffic attribution: originating endpoint -> bytes carried for it
    bytes_by_origin: Dict[str, int] = field(default_factory=dict)
    # QoS weights: originating endpoint -> relative share of this port under
    # contention.  An empty or all-equal map keeps the exact FCFS
    # discipline (the gate looks at configured values only).  Missing
    # origins default to 1.0 when arbitration is active — but
    # Fabric.set_qos_weights requires every host be configured explicitly,
    # so the default only matters for hand-built ports.
    weight_by_origin: Dict[str, float] = field(default_factory=dict)
    # weighted-arbitration state (only touched when QoS is enabled):
    # per-origin virtual finish times and last arrival ticks
    _vft: Dict[str, int] = field(default_factory=dict)
    _last_arr: Dict[str, int] = field(default_factory=dict)

    @property
    def qos_enabled(self) -> bool:
        """Weighted arbitration runs only when configured weights differ;
        all-equal weights mean FCFS, taken on the exact legacy path."""
        w = self.weight_by_origin
        return bool(w) and min(w.values()) != max(w.values())

    def weight_of(self, origin: str) -> float:
        return float(self.weight_by_origin.get(origin, 1.0))

    def set_weights(self, weights: Dict[str, float]) -> None:
        for origin, w in weights.items():
            if not w > 0:
                raise ValueError(
                    f"QoS weight for {origin!r} must be > 0, got {w}")
        self.weight_by_origin = dict(weights)

    def occ_ticks(self, nbytes: int) -> int:
        """Serialization occupancy for ``nbytes`` — THE definition of this
        port's busy-until increment.  Both the interpreted path
        (:meth:`transmit`) and the fused replay's route-tensor export
        (:meth:`Fabric.route_occupancy`) call this, so the rule cannot
        drift between them."""
        return ns(nbytes / self.bw_gbps)   # bytes / (GB/s) == ns

    def qos_update(self, now: int, nbytes: int, origin: str) -> int:
        """Advance ``origin``'s virtual finish time for one transfer
        arriving at ``now`` and return the completion *floor* it imposes
        (0 when the origin is within its share).  The virtual clock
        advances by ``occ * W_active / w_o`` per transfer — origin *o*'s
        service interval on a GPS port shared with the currently-contending
        origins, where a peer contends if it arrived within the last
        :data:`ACTIVE_WINDOW_OCC` serialization quanta.  An idle spell
        resyncs the clock to the arrival tick, so sparse traffic is never
        penalized and no credit is banked; only a virtually backlogged
        origin (``vft > now``) is floored.  The float expressions here are
        mirrored operation-for-operation (same summation order, same
        truncation) by the fused multi-host scan in
        :mod:`repro.core.replay.multihost`; do not reorder them."""
        occ = self.occ_ticks(nbytes)
        w_self = self.weight_of(origin)
        prev = self._vft.get(origin, 0)
        win = occ * ACTIVE_WINDOW_OCC
        w_active = 0.0
        for o in sorted(set(self._last_arr) | {origin}):
            if o == origin or self._last_arr[o] + win > now:
                w_active = w_active + self.weight_of(o)
        pace = int(occ * (w_active / w_self))
        self._vft[origin] = max(prev, now) + pace
        self._last_arr[origin] = now
        if prev > now:
            self.qos_throttle_events += 1
            return prev + pace
        return 0

    def transmit(self, now: int, nbytes: int,
                 origin: Optional[str] = None, retries: int = 0) -> int:
        """Serialize ``nbytes`` onto this port starting no earlier than
        ``now``; returns the tick the last byte arrives at ``dst``.
        ``origin`` attributes the traffic to its source endpoint.  QoS
        never bends this data path — weighted arbitration floors the final
        host acknowledgment via :meth:`qos_update` instead.  ``retries``
        charges that many extra full serializations (CXL link-level
        CRC-retry: the whole flit goes back on the wire), occupying the
        port for ``occ * (1 + retries)``; ``bytes`` stays goodput."""
        occ = self.occ_ticks(nbytes) * (1 + retries)
        start = max(now, self.busy_until)
        self.queued_ticks += start - now
        self.busy_until = start + occ
        self.packets += 1
        self.bytes += nbytes
        self.occupied_ticks += occ
        self.crc_retries += retries
        if origin is not None:
            self.bytes_by_origin[origin] = \
                self.bytes_by_origin.get(origin, 0) + nbytes
        return start + occ + ns(self.prop_ns)

    def utilization(self, elapsed_ticks: int) -> float:
        """Fraction of ``elapsed_ticks`` the port spent serializing."""
        return self.occupied_ticks / elapsed_ticks if elapsed_ticks else 0.0

    def achieved_gbps(self, elapsed_ticks: int) -> float:
        sec = to_s(elapsed_ticks)
        return self.bytes / sec / 1e9 if sec else 0.0

    def reset(self) -> None:
        self.busy_until = 0
        self.packets = 0
        self.bytes = 0
        self.queued_ticks = 0
        self.occupied_ticks = 0
        self.qos_throttle_events = 0
        self.crc_retries = 0
        self.bytes_by_origin = {}
        self._vft = {}
        self._last_arr = {}
