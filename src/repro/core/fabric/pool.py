"""Pooled memory: many hosts sharing many devices through the fabric.

The pooling story CXL 2.0+ sells: a rack of memory devices behind a switch,
carved up or interleaved across hosts.  :class:`PoolAddressMapper` turns a
host-physical address into ``(device_index, device_local_address)``;
:class:`MemoryPool` binds the mapper + fabric + devices and hands out
per-host :class:`HostPortView`\\ s — each a plain ``MemDevice``, so existing
drivers (``TraceDriver``, ``MultiHostDriver``) run against pooled memory
unchanged while per-host stats accumulate on the view.

Mapping modes:

``interleave``  frames of ``granularity`` bytes round-robin across devices
                (spreads one host's bandwidth over all devices)
``segment``     contiguous ``segment_bytes`` slabs, one device per slab
                (capacity pooling: each slab is a private region)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.devices import MemDevice
from repro.core.fabric.fabric import Fabric, LINE_BYTES

DEFAULT_GRANULARITY = 4096   # one flash/DRAM-cache page


@dataclass(frozen=True)
class PoolAddressMapper:
    num_devices: int
    mode: str = "interleave"              # 'interleave' | 'segment'
    granularity: int = DEFAULT_GRANULARITY
    segment_bytes: int = 1 << 30          # per-device slab in 'segment' mode

    def __post_init__(self) -> None:
        if self.num_devices < 1:
            raise ValueError("pool needs at least one device")
        if self.mode not in ("interleave", "segment"):
            raise ValueError(f"unknown pool mode {self.mode!r}")
        if self.granularity < 1 or self.segment_bytes < 1:
            raise ValueError("granularity/segment_bytes must be positive")

    def map(self, addr: int) -> Tuple[int, int]:
        """Global pool address -> ``(device_index, device_local_addr)``."""
        if self.mode == "interleave":
            frame, off = divmod(addr, self.granularity)
            dev, local_frame = frame % self.num_devices, frame // self.num_devices
            return dev, local_frame * self.granularity + off
        dev, local = divmod(addr, self.segment_bytes)
        if dev >= self.num_devices:
            raise ValueError(
                f"address {addr:#x} beyond pool capacity "
                f"({self.num_devices} x {self.segment_bytes:#x})")
        return dev, local


class MemoryPool:
    """Devices mounted at fabric nodes + an address mapper across them."""

    def __init__(self, fabric: Fabric, devices: Dict[str, MemDevice],
                 mapper: Optional[PoolAddressMapper] = None,
                 detach_links: bool = True) -> None:
        if not devices:
            raise ValueError("pool needs at least one device")
        for node in devices:
            if node not in fabric.topology.kinds:
                raise ValueError(f"unknown fabric node {node!r}")
        self.mapper = mapper or PoolAddressMapper(num_devices=len(devices))
        if self.mapper.num_devices != len(devices):
            raise ValueError("mapper.num_devices != number of pool devices")
        self.fabric = fabric
        self.device_nodes: List[str] = sorted(devices)
        # Detach only after all validation: a failed construction must not
        # leave the caller's devices silently mutated (NullLink'd).
        self.devices: List[MemDevice] = [
            devices[n].detach_link() if detach_links else devices[n]
            for n in self.device_nodes]

    def view(self, host: str) -> "HostPortView":
        """This host's window onto the pool (a normal ``MemDevice``)."""
        return HostPortView(self, host)

    def views(self, hosts: Sequence[str]) -> List["HostPortView"]:
        return [self.view(h) for h in hosts]


class HostPortView(MemDevice):
    """One host's port into a :class:`MemoryPool`.

    ``service`` routes each access through the fabric from this host to the
    device the mapper selects; contention with other hosts emerges from the
    shared port and device busy-until state.  Stats on this object are
    per-host; stats on the pooled devices are aggregate.
    """

    def __init__(self, pool: MemoryPool, host: str) -> None:
        # Inherit an engine so the event-driven path (access/access_flit)
        # works; pooled devices share one engine in full-system mode.
        super().__init__(pool.devices[0].engine)
        if host not in pool.fabric.topology.kinds:
            raise ValueError(f"unknown host node {host!r}")
        self.pool = pool
        self.host = host
        self.name = f"pool-view:{host}"
        for node in pool.device_nodes:          # fail fast if unroutable
            pool.fabric.routing.path(host, node)

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        dev_idx, local = self.pool.mapper.map(addr)
        node = self.pool.device_nodes[dev_idx]
        # ECMP flow key: the device-local line address — the same value the
        # fused replay hashes host-side after applying the pool mapper.
        t, floor = self.pool.fabric.traverse_qos(now, self.host, node, size,
                                                 line_addr=local // LINE_BYTES)
        done = self.pool.devices[dev_idx].service(t, local, size, write,
                                                  posted)
        return max(done, floor)
