"""The fabric proper: routed, contended transport between endpoints.

:meth:`Fabric.traverse` mirrors :meth:`repro.core.devices.CXLLink.traverse`
— same analytic busy-until fast path, same return convention (arrival tick
including the CXL.mem round-trip extra) — but walks a routed multi-hop path
with per-port occupancy and per-switch store-and-forward latency.  On a
``direct`` topology with matching parameters it reproduces ``CXLLink``
timing *exactly* (tested), so mounting a device behind the fabric is a
strict generalization of the paper's point-to-point configuration.

Two scheduling/routing refinements are opt-in:

* ``qos_weights`` — per-host weighted virtual-finish-time arbitration on
  every port (see :class:`~repro.core.fabric.switch.SwitchPort`); all-equal
  weights keep the exact FCFS discipline.
* ``ecmp=True`` — per-access load balancing over *all* equal-cost shortest
  paths, selected by a deterministic flow hash over
  ``(src, dst, line_addr)`` (see :mod:`repro.core.fabric.routing`).

:class:`FabricAttachedDevice` composes the fabric with any existing
:class:`~repro.core.devices.MemDevice` unchanged: fabric transport first,
then the device's own media timing.  Devices that embed a private
``CXLLink`` (cxl-dram, cxl-ssd, cxl-ssd-cache) are neutralized via
:meth:`~repro.core.devices.MemDevice.detach_link` so link latency is not
double-counted.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.devices import MemDevice
from repro.core.engine import ns
from repro.core.fabric.routing import RoutingTable, flow_hash
from repro.core.fabric.switch import SwitchPort
from repro.core.fabric.topology import SWITCH, Topology, build_topology

DEFAULT_FORWARD_NS = 35.0    # per-switch store-and-forward latency
DEFAULT_RT_EXTRA_NS = 50.0   # Table I: total CXL.mem network round-trip extra
LINE_BYTES = 64              # flow-hash granularity: one cache line


class Fabric:
    """A switch fabric instantiated from a static :class:`Topology`."""

    def __init__(self, topology: Topology,
                 forward_ns: float = DEFAULT_FORWARD_NS,
                 rt_extra_ns: float = DEFAULT_RT_EXTRA_NS,
                 ecmp: bool = False,
                 qos_weights: Optional[Dict[str, float]] = None) -> None:
        topology.validate()
        self.topology = topology
        self.routing = RoutingTable(topology)
        self.forward_ns = forward_ns
        self.rt_extra_ns = rt_extra_ns
        self.ecmp = ecmp
        self.ports: Dict[Tuple[str, str], SwitchPort] = {
            (u, v): SwitchPort(u, v, spec.bw_gbps, spec.prop_ns)
            for (u, v), spec in topology.links.items()
        }
        if qos_weights:
            self.set_qos_weights(qos_weights)
        self.stats = {"transfers": 0, "bytes": 0}
        # ECMP observability: "src->dst" -> per-path selection counts, for
        # pairs that actually have alternatives (len(paths) > 1)
        self.ecmp_counts: Dict[str, List[int]] = {}
        # deterministic fault injection (repro.core.faults.install wires
        # this); counters mirror the fused lanes' fault telemetry
        self.fault_plan = None
        self.fault_stats = {"link_retries": 0, "failovers": 0,
                            "degraded_accesses": 0}

    @classmethod
    def build(cls, kind: str, *, forward_ns: float = DEFAULT_FORWARD_NS,
              rt_extra_ns: float = DEFAULT_RT_EXTRA_NS, ecmp: bool = False,
              qos_weights: Optional[Dict[str, float]] = None,
              **topo_kwargs) -> "Fabric":
        return cls(build_topology(kind, **topo_kwargs),
                   forward_ns=forward_ns, rt_extra_ns=rt_extra_ns,
                   ecmp=ecmp, qos_weights=qos_weights)

    # ---------------------------------------------------------------- QoS
    def set_qos_weights(self, weights: Dict[str, float]) -> None:
        """Install per-origin weights on every port.  Every host of the
        topology must be weighted explicitly — the all-equal-weights FCFS
        shortcut looks only at configured values, so a partially-configured
        map like ``{"h0": 2, "h1": 2}`` on a three-host fabric would
        silently drop the implied 2:2:1 split.  Configure before any
        traffic: the fused replay snapshots a fresh fabric, and mid-run
        weight changes are not part of the modeled discipline."""
        if getattr(self, "stats", {}).get("transfers", 0):
            raise ValueError("set QoS weights before the fabric carries "
                             "traffic (or Fabric.reset() first)")
        hosts = set(self.topology.hosts)
        missing = sorted(hosts - set(weights))
        unknown = sorted(set(weights) - hosts)
        if missing or unknown:
            raise ValueError(
                f"QoS weights must name every host exactly once "
                f"(missing: {missing or 'none'}, not a host: "
                f"{unknown or 'none'})")
        for port in self.ports.values():
            port.set_weights(weights)

    @property
    def qos_enabled(self) -> bool:
        return any(p.qos_enabled for p in self.ports.values())

    # ------------------------------------------------------------ transport
    def path(self, src: str, dst: str) -> List[str]:
        return self.routing.path(src, dst)

    def paths(self, src: str, dst: str) -> List[List[str]]:
        """The ECMP path set actually used for ``src -> dst``: all
        equal-cost shortest paths when ECMP is on, else the primary path."""
        if self.ecmp:
            return self.routing.paths(src, dst)
        return [self.routing.path(src, dst)]

    def select_path(self, src: str, dst: str,
                    line_addr: Optional[int]) -> List[str]:
        if self.ecmp and line_addr is not None:
            return self.routing.select(src, dst, line_addr)
        return self.routing.path(src, dst)

    def route_occupancy(self, src: str, dst: str, nbytes: int,
                        choice: Optional[int] = None
                        ) -> List[Tuple[Tuple[str, str], int, int]]:
        """Tensor export of :meth:`traverse`'s per-hop timing for ``nbytes``:
        one ``(port_key, occ_ticks, after_ticks)`` triple per hop, where
        ``after`` folds propagation plus the per-switch store-and-forward
        latency, each rounded separately with ``ns()`` exactly as
        :meth:`traverse` does.  ``choice`` picks a route from the ECMP path
        set (default: the primary path).  The fused replay engines build
        their route tensors from this single definition so the busy-until
        rule cannot drift between the interpreted and vectorized paths."""
        if choice is None:
            path = self.routing.path(src, dst)
        else:
            path = self.paths(src, dst)[choice]
        return self.path_occupancy(path, nbytes)

    def path_occupancy(self, path: List[str], nbytes: int
                       ) -> List[Tuple[Tuple[str, str], int, int]]:
        """:meth:`route_occupancy` for an *explicit* node sequence — the
        fused fault lanes build union route tables (failover routes have
        different hop counts) from this same single definition."""
        hops = []
        for u, v in zip(path, path[1:]):
            port = self.ports[(u, v)]
            after = ns(port.prop_ns)
            if self.topology.kind(v) == SWITCH:
                after += ns(self.forward_ns)
            hops.append(((u, v), port.occ_ticks(nbytes), after))
        return hops

    def select_faulted(self, src: str, dst: str,
                       line_addr: Optional[int], ordinal: Optional[int]
                       ) -> Tuple[List[str], bool, bool]:
        """Route selection under the installed fault plan: returns
        ``(path, degraded, failover)``.  ``degraded`` — the access routed
        over a pair whose (ECMP) path set was reduced by down ports;
        ``failover`` — the chosen path differs from the fault-free choice.
        Pure function of the routing tables and the plan, so the fused
        lanes precompute their per-access route columns with exactly this.
        Raises :class:`~repro.core.faults.DeviceUnreachable` when every
        route is down."""
        plan = self.fault_plan
        down = (plan.down_links_at(ordinal)
                if plan is not None and ordinal is not None and plan.has_down
                else frozenset())
        if self.ecmp and line_addr is not None:
            base = self.routing.paths(src, dst)
            paths = self.routing.paths(src, dst, down=down) if down else base
            degraded = bool(down) and paths != base
            if len(paths) > 1:
                path = paths[flow_hash(src, dst, line_addr) % len(paths)]
            else:
                path = paths[0]
            if not degraded:
                return path, False, False
            nominal = (base[flow_hash(src, dst, line_addr) % len(base)]
                       if len(base) > 1 else base[0])
            return path, True, path != nominal
        nominal = self.routing.path(src, dst)
        if not down:
            return nominal, False, False
        path = self.routing.paths(src, dst, down=down)[0]
        return path, path != nominal, path != nominal

    def traverse_qos(self, now: int, src: str, dst: str, nbytes: int,
                     line_addr: Optional[int] = None,
                     ordinal: Optional[int] = None) -> Tuple[int, int]:
        """Carry ``nbytes`` from ``src`` to ``dst``.  Returns ``(arrival,
        ack_floor)``: the physical completion tick (arrival + round-trip
        extra, queueing on every port's busy-until along the route — the
        data path is pure FCFS, identical with or without QoS) and the
        weighted-arbitration floor on the *final host acknowledgment*
        (0 when no port regulates this origin).  Callers must apply the
        floor after media service, never to the data path — a floored
        timestamp fed into shared busy-until state would block other
        hosts' earlier traffic.  ``line_addr`` keys the ECMP flow hash
        (ignored unless the fabric was built with ``ecmp=True``).
        ``ordinal`` is the issuing host's access ordinal, keying the
        installed fault plan (down windows exclude dead paths — rerouting
        onto longer paths when a whole equal-cost set is down — and
        CRC-retry bursts charge extra serializations per port); ``None``
        leaves the plan unconsulted.  QoS pacing stays keyed on the clean
        occupancy — retries stretch serialization, not the host's
        entitlement."""
        plan = self.fault_plan
        if plan is not None and ordinal is not None and plan.active:
            path, degraded, failover = self.select_faulted(
                src, dst, line_addr, ordinal)
            if degraded:
                self.fault_stats["degraded_accesses"] += 1
                if failover:
                    self.fault_stats["failovers"] += 1
            elif (self.ecmp and line_addr is not None
                    and self.routing.num_paths(src, dst) > 1):
                paths = self.routing.paths(src, dst)
                k = flow_hash(src, dst, line_addr) % len(paths)
                counts = self.ecmp_counts.setdefault(
                    f"{src}->{dst}", [0] * len(paths))
                counts[k] += 1
            retry_on = plan.has_link
        elif self.ecmp and line_addr is not None:
            paths = self.routing.paths(src, dst)
            if len(paths) > 1:
                k = flow_hash(src, dst, line_addr) % len(paths)
                counts = self.ecmp_counts.setdefault(
                    f"{src}->{dst}", [0] * len(paths))
                counts[k] += 1
                path = paths[k]
            else:
                path = paths[0]
            retry_on = False
        else:
            path = self.routing.path(src, dst)
            retry_on = False
        t = now
        floor = 0
        for u, v in zip(path, path[1:]):
            port = self.ports[(u, v)]
            r = plan.link_retries((u, v), ordinal) if retry_on else 0
            if r:
                self.fault_stats["link_retries"] += r
            if port.qos_enabled:
                floor = max(floor, port.qos_update(t, nbytes, src))
            t = port.transmit(t, nbytes, origin=src, retries=r)
            if self.topology.kind(v) == SWITCH:
                t += ns(self.forward_ns)
        self.stats["transfers"] += 1
        self.stats["bytes"] += nbytes
        return t + ns(self.rt_extra_ns), floor

    def traverse(self, now: int, src: str, dst: str, nbytes: int,
                 line_addr: Optional[int] = None,
                 ordinal: Optional[int] = None) -> int:
        """The :meth:`traverse_qos` physical arrival tick alone — the exact
        :meth:`CXLLink.traverse` contract.  QoS-floored mounts go through
        :meth:`traverse_qos` (the floor binds the host ack, not the data
        arrival this returns)."""
        return self.traverse_qos(now, src, dst, nbytes, line_addr,
                                 ordinal=ordinal)[0]

    # ------------------------------------------------------------ mounting
    def mount(self, host: str, device_node: str, device: MemDevice,
              detach_link: bool = True) -> "FabricAttachedDevice":
        """Attach ``device`` at ``device_node`` as seen from ``host``."""
        return FabricAttachedDevice(self, host, device_node, device,
                                    detach_link=detach_link)

    # -------------------------------------------------------------- reports
    def port_report(self, elapsed_ticks: int) -> List[dict]:
        """Per-port traffic/occupancy summary, sorted by bytes desc then name
        (deterministic).  ``utilization`` is the fraction of the elapsed
        window the port spent serializing; ``bytes_by_host`` attributes the
        port's traffic to the originating endpoints; ``qos_weights`` echoes
        the arbitration weights when weighted scheduling is active."""
        rows = []
        for p in self.ports.values():
            if not p.packets:
                continue
            row = {
                "port": f"{p.src}->{p.dst}",
                "bytes": p.bytes,
                "packets": p.packets,
                "utilization": p.utilization(elapsed_ticks),
                "achieved_gbps": p.achieved_gbps(elapsed_ticks),
                "queued_ticks": p.queued_ticks,
                "qos_throttle_events": p.qos_throttle_events,
                "bytes_by_host": dict(sorted(p.bytes_by_origin.items())),
            }
            if p.qos_enabled:
                row["qos_weights"] = dict(sorted(p.weight_by_origin.items()))
            rows.append(row)
        rows.sort(key=lambda r: (-r["bytes"], r["port"]))
        return rows

    def bottleneck_port(self, src: str, dst: str) -> SwitchPort:
        """The minimum-bandwidth port along the primary route (first on
        ties)."""
        path = self.routing.path(src, dst)
        hops = [self.ports[(u, v)] for u, v in zip(path, path[1:])]
        return min(hops, key=lambda p: p.bw_gbps)

    def reset(self) -> None:
        for p in self.ports.values():
            p.reset()
        self.stats = {"transfers": 0, "bytes": 0}
        self.ecmp_counts = {}
        self.fault_stats = {"link_retries": 0, "failovers": 0,
                            "degraded_accesses": 0}


class FabricAttachedDevice(MemDevice):
    """Any :class:`MemDevice` mounted behind the fabric, unchanged.

    ``service`` = fabric transport (routed, contended) + the inner device's
    own media timing.  Presents the standard ``MemDevice`` interface so
    :class:`~repro.core.workloads.driver.TraceDriver` and the event-driven
    path both work against fabric-attached memory.
    """

    is_cxl = True

    def __init__(self, fabric: Fabric, host: str, device_node: str,
                 inner: MemDevice, detach_link: bool = True) -> None:
        super().__init__(inner.engine)
        for node, kind in ((host, "host"), (device_node, "device")):
            if node not in fabric.topology.kinds:
                raise ValueError(f"unknown {kind} node {node!r}")
        fabric.routing.path(host, device_node)  # fail fast if unroutable
        self.fabric = fabric
        self.host = host
        self.device_node = device_node
        # Detach only after validation: a failed mount must not leave the
        # caller's device silently mutated (NullLink'd).
        self.inner = inner.detach_link() if detach_link else inner
        self.name = f"fabric:{inner.name}@{device_node}"
        # per-mount access ordinal: the fault-plan key for this host's
        # traffic (the fused lanes key their precomputed columns on the
        # trace index, which is exactly this counter)
        self._fault_ord = 0

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        ordinal = None
        if self.fabric.fault_plan is not None:
            ordinal = self._fault_ord
            self._fault_ord += 1
        t, floor = self.fabric.traverse_qos(now, self.host, self.device_node,
                                            size,
                                            line_addr=addr // LINE_BYTES,
                                            ordinal=ordinal)
        return max(self.inner.service(t, addr, size, write, posted), floor)
