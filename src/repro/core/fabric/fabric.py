"""The fabric proper: routed, contended transport between endpoints.

:meth:`Fabric.traverse` mirrors :meth:`repro.core.devices.CXLLink.traverse`
— same analytic busy-until fast path, same return convention (arrival tick
including the CXL.mem round-trip extra) — but walks a routed multi-hop path
with per-port occupancy and per-switch store-and-forward latency.  On a
``direct`` topology with matching parameters it reproduces ``CXLLink``
timing *exactly* (tested), so mounting a device behind the fabric is a
strict generalization of the paper's point-to-point configuration.

:class:`FabricAttachedDevice` composes the fabric with any existing
:class:`~repro.core.devices.MemDevice` unchanged: fabric transport first,
then the device's own media timing.  Devices that embed a private
``CXLLink`` (cxl-dram, cxl-ssd, cxl-ssd-cache) are neutralized via
:meth:`~repro.core.devices.MemDevice.detach_link` so link latency is not
double-counted.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.devices import MemDevice
from repro.core.engine import ns
from repro.core.fabric.routing import RoutingTable
from repro.core.fabric.switch import SwitchPort
from repro.core.fabric.topology import SWITCH, Topology, build_topology

DEFAULT_FORWARD_NS = 35.0    # per-switch store-and-forward latency
DEFAULT_RT_EXTRA_NS = 50.0   # Table I: total CXL.mem network round-trip extra


class Fabric:
    """A switch fabric instantiated from a static :class:`Topology`."""

    def __init__(self, topology: Topology,
                 forward_ns: float = DEFAULT_FORWARD_NS,
                 rt_extra_ns: float = DEFAULT_RT_EXTRA_NS) -> None:
        topology.validate()
        self.topology = topology
        self.routing = RoutingTable(topology)
        self.forward_ns = forward_ns
        self.rt_extra_ns = rt_extra_ns
        self.ports: Dict[Tuple[str, str], SwitchPort] = {
            (u, v): SwitchPort(u, v, spec.bw_gbps, spec.prop_ns)
            for (u, v), spec in topology.links.items()
        }
        self.stats = {"transfers": 0, "bytes": 0}

    @classmethod
    def build(cls, kind: str, *, forward_ns: float = DEFAULT_FORWARD_NS,
              rt_extra_ns: float = DEFAULT_RT_EXTRA_NS, **topo_kwargs) -> "Fabric":
        return cls(build_topology(kind, **topo_kwargs),
                   forward_ns=forward_ns, rt_extra_ns=rt_extra_ns)

    # ------------------------------------------------------------ transport
    def path(self, src: str, dst: str) -> List[str]:
        return self.routing.path(src, dst)

    def route_occupancy(self, src: str, dst: str,
                        nbytes: int) -> List[Tuple[Tuple[str, str], int, int]]:
        """Tensor export of :meth:`traverse`'s per-hop timing for ``nbytes``:
        one ``(port_key, occ_ticks, after_ticks)`` triple per hop, where
        ``after`` folds propagation plus the per-switch store-and-forward
        latency, each rounded separately with ``ns()`` exactly as
        :meth:`traverse` does.  The fused replay engines build their route
        tensors from this single definition so the busy-until rule cannot
        drift between the interpreted and vectorized paths."""
        path = self.routing.path(src, dst)
        hops = []
        for u, v in zip(path, path[1:]):
            port = self.ports[(u, v)]
            after = ns(port.prop_ns)
            if self.topology.kind(v) == SWITCH:
                after += ns(self.forward_ns)
            hops.append(((u, v), port.occ_ticks(nbytes), after))
        return hops

    def traverse(self, now: int, src: str, dst: str, nbytes: int) -> int:
        """Carry ``nbytes`` from ``src`` to ``dst``; returns the completion
        tick (arrival + round-trip extra), queueing on every port's
        busy-until along the route."""
        path = self.routing.path(src, dst)
        t = now
        for u, v in zip(path, path[1:]):
            t = self.ports[(u, v)].transmit(t, nbytes, origin=src)
            if self.topology.kind(v) == SWITCH:
                t += ns(self.forward_ns)
        self.stats["transfers"] += 1
        self.stats["bytes"] += nbytes
        return t + ns(self.rt_extra_ns)

    # ------------------------------------------------------------ mounting
    def mount(self, host: str, device_node: str, device: MemDevice,
              detach_link: bool = True) -> "FabricAttachedDevice":
        """Attach ``device`` at ``device_node`` as seen from ``host``."""
        return FabricAttachedDevice(self, host, device_node, device,
                                    detach_link=detach_link)

    # -------------------------------------------------------------- reports
    def port_report(self, elapsed_ticks: int) -> List[dict]:
        """Per-port traffic/occupancy summary, sorted by bytes desc then name
        (deterministic).  ``utilization`` is the fraction of the elapsed
        window the port spent serializing; ``bytes_by_host`` attributes the
        port's traffic to the originating endpoints (QoS groundwork — the
        scheduling itself stays FCFS)."""
        rows = [{
            "port": f"{p.src}->{p.dst}",
            "bytes": p.bytes,
            "packets": p.packets,
            "utilization": p.utilization(elapsed_ticks),
            "achieved_gbps": p.achieved_gbps(elapsed_ticks),
            "queued_ticks": p.queued_ticks,
            "bytes_by_host": dict(sorted(p.bytes_by_origin.items())),
        } for p in self.ports.values() if p.packets]
        rows.sort(key=lambda r: (-r["bytes"], r["port"]))
        return rows

    def bottleneck_port(self, src: str, dst: str) -> SwitchPort:
        """The minimum-bandwidth port along the route (first on ties)."""
        path = self.routing.path(src, dst)
        hops = [self.ports[(u, v)] for u, v in zip(path, path[1:])]
        return min(hops, key=lambda p: p.bw_gbps)

    def reset(self) -> None:
        for p in self.ports.values():
            p.reset()
        self.stats = {"transfers": 0, "bytes": 0}


class FabricAttachedDevice(MemDevice):
    """Any :class:`MemDevice` mounted behind the fabric, unchanged.

    ``service`` = fabric transport (routed, contended) + the inner device's
    own media timing.  Presents the standard ``MemDevice`` interface so
    :class:`~repro.core.workloads.driver.TraceDriver` and the event-driven
    path both work against fabric-attached memory.
    """

    is_cxl = True

    def __init__(self, fabric: Fabric, host: str, device_node: str,
                 inner: MemDevice, detach_link: bool = True) -> None:
        super().__init__(inner.engine)
        for node, kind in ((host, "host"), (device_node, "device")):
            if node not in fabric.topology.kinds:
                raise ValueError(f"unknown {kind} node {node!r}")
        fabric.routing.path(host, device_node)  # fail fast if unroutable
        self.fabric = fabric
        self.host = host
        self.device_node = device_node
        # Detach only after validation: a failed mount must not leave the
        # caller's device silently mutated (NullLink'd).
        self.inner = inner.detach_link() if detach_link else inner
        self.name = f"fabric:{inner.name}@{device_node}"

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        t = self.fabric.traverse(now, self.host, self.device_node, size)
        return self.inner.service(t, addr, size, write, posted)
