"""Vectorized fabric congestion estimation (JAX hot path).

Same philosophy as :mod:`repro.core.cache.trace_sim`: the per-access
busy-until replay in :class:`~repro.core.fabric.fabric.Fabric` is exact but
Python-speed; for *what-if sweeps* over large traces we want an analytic
estimate that JIT-compiles and vmaps.  The model here is fluid-flow:

1. every access is attributed to its (host, device) pair;
2. per-pair bytes are reduced with ``jax.ops.segment_sum`` (one segment per
   pair — the trace can be millions of accesses);
3. per-*link* bytes come from a static route-weight matrix ``R`` (pairs x
   links), computed once from the routing table: ``link_bytes = R.T @
   pair_bytes``.  On an ECMP fabric each of a pair's equal-cost paths
   carries weight ``1/K`` (the flow hash spreads uniformly in
   expectation), so shared first/last hops accumulate back to 1 and the
   spine tier splits — matching the exact replay's spreading;
4. link utilization = link_bytes / (bw x window); a pair's congestion
   factor is the max utilization along its route, and its predicted
   throughput scales by ``1 / max(1, congestion)``.

This ignores queueing order (it is a load-balance estimate, not a replay),
but it identifies bottleneck links and relative per-host slowdowns in one
matmul — and ``what_if_bandwidth`` vmaps the whole pipeline over candidate
link-speed scalings for instant capacity-planning sweeps.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fabric.fabric import Fabric


class LinkCongestionSim:
    """Static route matrix + jitted trace reduction for one fabric."""

    def __init__(self, fabric: Fabric, hosts: Sequence[str],
                 device_nodes: Sequence[str]) -> None:
        self.hosts = list(hosts)
        self.device_nodes = list(device_nodes)
        self.link_names: List[str] = [f"{u}->{v}"
                                      for (u, v) in sorted(fabric.ports)]
        link_index = {name: i for i, name in enumerate(self.link_names)}
        n_pairs = len(self.hosts) * len(self.device_nodes)
        routes = np.zeros((n_pairs, len(self.link_names)), dtype=np.float32)
        for hi, h in enumerate(self.hosts):
            for di, d in enumerate(self.device_nodes):
                # ECMP-aware: fabric.paths is the path set actually routed
                # ([primary] when ecmp is off); each path carries 1/K.
                paths = fabric.paths(h, d)
                for path in paths:
                    for u, v in zip(path, path[1:]):
                        routes[hi * len(self.device_nodes) + di,
                               link_index[f"{u}->{v}"]] += 1.0 / len(paths)
        self.routes = jnp.asarray(routes)                       # (P, L)
        self.link_bw_bytes_per_s = jnp.asarray(
            [fabric.ports[tuple(name.split("->"))].bw_gbps * 1e9
             for name in self.link_names], dtype=jnp.float32)   # (L,)

    # ------------------------------------------------------------------ API
    def pair_ids(self, host_idx, dev_idx) -> jnp.ndarray:
        """Fuse per-access host/device indices into segment ids."""
        return jnp.asarray(host_idx, jnp.int32) * len(self.device_nodes) \
            + jnp.asarray(dev_idx, jnp.int32)

    def estimate(self, host_idx, dev_idx, nbytes, window_s: float) -> Dict[str, np.ndarray]:
        """Per-link utilization and per-pair slowdown for a trace assumed to
        span ``window_s`` seconds.  Returns plain-numpy arrays."""
        util, slowdown, pair_bytes = _estimate(
            self.pair_ids(host_idx, dev_idx),
            jnp.asarray(nbytes, jnp.float32),
            self.routes, self.link_bw_bytes_per_s,
            jnp.float32(window_s))
        return {
            "link_names": self.link_names,
            "link_utilization": np.asarray(util),
            "pair_slowdown": np.asarray(slowdown),
            "pair_bytes": np.asarray(pair_bytes),
            "bottleneck_link": self.link_names[int(np.argmax(np.asarray(util)))],
        }

    def what_if_bandwidth(self, host_idx, dev_idx, nbytes, window_s: float,
                          bw_scales: Sequence[float]) -> Dict[str, np.ndarray]:
        """vmap the estimate over uniform link-speed scalings — 'what if the
        fabric were k x faster?' — one compiled sweep, no Python loop."""
        pair = self.pair_ids(host_idx, dev_idx)
        b = jnp.asarray(nbytes, jnp.float32)
        scales = jnp.asarray(bw_scales, jnp.float32)
        util, slowdown, _ = jax.vmap(
            lambda s: _estimate(pair, b, self.routes,
                                self.link_bw_bytes_per_s * s,
                                jnp.float32(window_s)))(scales)
        return {
            "bw_scales": np.asarray(scales),
            "max_link_utilization": np.asarray(util.max(axis=1)),
            "mean_pair_slowdown": np.asarray(slowdown.mean(axis=1)),
        }


@functools.partial(jax.jit, static_argnames=())
def _estimate(pair_ids: jnp.ndarray, nbytes: jnp.ndarray, routes: jnp.ndarray,
              link_bw_bytes_per_s: jnp.ndarray, window_s: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n_pairs = routes.shape[0]
    pair_bytes = jax.ops.segment_sum(nbytes, pair_ids, num_segments=n_pairs)
    link_bytes = routes.T @ pair_bytes                          # (L,)
    util = link_bytes / (link_bw_bytes_per_s * window_s)
    # A pair is slowed by its most-congested link; utilization <= 1 is
    # free.  Membership (routes > 0), not the fractional ECMP weight,
    # selects which links can slow a pair.
    pair_congestion = jnp.max(
        jnp.where(routes > 0, util[None, :], 0.0), axis=1)
    slowdown = jnp.maximum(1.0, pair_congestion)
    return util, slowdown, pair_bytes
