"""The five replacement policies of CXL-SSD-Sim (paper §II-C).

``Direct`` (direct-mapped), ``LRU``, ``FIFO``, ``2Q`` and ``LFRU``.

These classes are the *shared* policy engine: the DRAM-cache model of the
simulator (:mod:`repro.core.cache.dram_cache`) and the TPU tiered-memory
runtime (:mod:`repro.tiered`) both instantiate them, which is the point of
the reproduction — the replacement policy that manages 4 KB DRAM pages in
front of an SSD is the same object that manages KV/expert pages in HBM in
front of a capacity tier.

The interface is fully associative at the policy level and keyed by page id;
set-associativity (for ``Direct`` and the vectorized simulators) is layered
on top by the caller.  All operations are O(1) (ordered-dict / heap-free
designs) so multi-million-access traces stay cheap in pure Python, and the
vectorized `lax.scan`/Pallas paths are validated against these as oracles.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional


@dataclass
class EvictionResult:
    page: int
    dirty: bool


class CachePolicy:
    """Abstract policy over a fixed number of page frames."""

    name = "abstract"

    def __init__(self, capacity_pages: int) -> None:
        if capacity_pages <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity_pages
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.dirty_evictions = 0

    # -- interface ---------------------------------------------------------
    def lookup(self, page: int) -> bool:
        raise NotImplementedError

    def touch(self, page: int, dirty: bool = False) -> None:
        """Record an access to a resident page."""
        raise NotImplementedError

    def insert(self, page: int, dirty: bool = False) -> Optional[EvictionResult]:
        """Insert a page, evicting if full; returns the eviction, if any."""
        raise NotImplementedError

    def invalidate(self, page: int) -> bool:
        """Drop a page without writeback; True if it was resident."""
        raise NotImplementedError

    def is_dirty(self, page: int) -> bool:
        raise NotImplementedError

    def resident_pages(self) -> set[int]:
        raise NotImplementedError

    def __len__(self) -> int:
        return len(self.resident_pages())

    # -- convenience -------------------------------------------------------
    def access(self, page: int, write: bool = False) -> tuple[bool, Optional[EvictionResult]]:
        """Full access path: returns (hit, eviction)."""
        if self.lookup(page):
            self.hits += 1
            self.touch(page, dirty=write)
            return True, None
        self.misses += 1
        ev = self.insert(page, dirty=write)
        if ev is not None:
            self.evictions += 1
            if ev.dirty:
                self.dirty_evictions += 1
        return False, ev

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = self.dirty_evictions = 0


class LRUPolicy(CachePolicy):
    """Least Recently Used — an ordered dict with move-to-end on touch."""

    name = "lru"

    def __init__(self, capacity_pages: int) -> None:
        super().__init__(capacity_pages)
        self._map: OrderedDict[int, bool] = OrderedDict()  # page -> dirty

    def lookup(self, page: int) -> bool:
        return page in self._map

    def touch(self, page: int, dirty: bool = False) -> None:
        self._map[page] |= dirty
        self._map.move_to_end(page)

    def insert(self, page: int, dirty: bool = False) -> Optional[EvictionResult]:
        ev = None
        if len(self._map) >= self.capacity:
            victim, vdirty = self._map.popitem(last=False)
            ev = EvictionResult(victim, vdirty)
        self._map[page] = dirty
        return ev

    def invalidate(self, page: int) -> bool:
        return self._map.pop(page, None) is not None

    def is_dirty(self, page: int) -> bool:
        return self._map.get(page, False)

    def resident_pages(self) -> set[int]:
        return set(self._map)


class FIFOPolicy(CachePolicy):
    """First-In First-Out — insertion order only; touch does not promote."""

    name = "fifo"

    def __init__(self, capacity_pages: int) -> None:
        super().__init__(capacity_pages)
        self._map: OrderedDict[int, bool] = OrderedDict()

    def lookup(self, page: int) -> bool:
        return page in self._map

    def touch(self, page: int, dirty: bool = False) -> None:
        self._map[page] |= dirty  # no reordering: FIFO ignores recency

    def insert(self, page: int, dirty: bool = False) -> Optional[EvictionResult]:
        ev = None
        if len(self._map) >= self.capacity:
            victim, vdirty = self._map.popitem(last=False)
            ev = EvictionResult(victim, vdirty)
        self._map[page] = dirty
        return ev

    def invalidate(self, page: int) -> bool:
        return self._map.pop(page, None) is not None

    def is_dirty(self, page: int) -> bool:
        return self._map.get(page, False)

    def resident_pages(self) -> set[int]:
        return set(self._map)


class DirectPolicy(CachePolicy):
    """Direct-mapped: page p lives only in frame ``p % capacity``."""

    name = "direct"

    def __init__(self, capacity_pages: int) -> None:
        super().__init__(capacity_pages)
        self._frames: Dict[int, tuple[int, bool]] = {}  # frame -> (page, dirty)

    def _frame(self, page: int) -> int:
        return page % self.capacity

    def lookup(self, page: int) -> bool:
        entry = self._frames.get(self._frame(page))
        return entry is not None and entry[0] == page

    def touch(self, page: int, dirty: bool = False) -> None:
        f = self._frame(page)
        p, d = self._frames[f]
        assert p == page
        self._frames[f] = (p, d or dirty)

    def insert(self, page: int, dirty: bool = False) -> Optional[EvictionResult]:
        f = self._frame(page)
        ev = None
        if f in self._frames:
            vp, vd = self._frames[f]
            if vp != page:
                ev = EvictionResult(vp, vd)
        self._frames[f] = (page, dirty)
        return ev

    def invalidate(self, page: int) -> bool:
        f = self._frame(page)
        entry = self._frames.get(f)
        if entry is not None and entry[0] == page:
            del self._frames[f]
            return True
        return False

    def is_dirty(self, page: int) -> bool:
        entry = self._frames.get(self._frame(page))
        return bool(entry and entry[0] == page and entry[1])

    def resident_pages(self) -> set[int]:
        return {p for p, _ in self._frames.values()}


class TwoQPolicy(CachePolicy):
    """2Q (Johnson & Shasha '94, simplified full version).

    A1in: FIFO probation queue for first-touch pages (Kin = 25 % of frames).
    Am:   LRU queue for re-referenced pages.
    A1out: ghost FIFO of recently evicted probation pages (Kout = 50 % of
    frames, tags only).  A hit in A1out promotes straight into Am.
    """

    name = "2q"

    def __init__(self, capacity_pages: int, kin_frac: float = 0.25,
                 kout_frac: float = 0.5) -> None:
        super().__init__(capacity_pages)
        self.kin = max(1, int(capacity_pages * kin_frac))
        self.kout = max(1, int(capacity_pages * kout_frac))
        self._a1in: OrderedDict[int, bool] = OrderedDict()
        self._am: OrderedDict[int, bool] = OrderedDict()
        self._a1out: OrderedDict[int, None] = OrderedDict()  # ghosts

    def lookup(self, page: int) -> bool:
        return page in self._a1in or page in self._am

    def touch(self, page: int, dirty: bool = False) -> None:
        if page in self._am:
            self._am[page] |= dirty
            self._am.move_to_end(page)
        else:
            # A1in hit: stays in FIFO order (that's the 2Q rule — only an
            # A1out ghost hit promotes to Am).
            self._a1in[page] |= dirty

    def _evict_one(self) -> EvictionResult:
        if len(self._a1in) >= self.kin and self._a1in:
            victim, vd = self._a1in.popitem(last=False)
            self._a1out[victim] = None
            while len(self._a1out) > self.kout:
                self._a1out.popitem(last=False)
            return EvictionResult(victim, vd)
        if self._am:
            victim, vd = self._am.popitem(last=False)
            return EvictionResult(victim, vd)
        victim, vd = self._a1in.popitem(last=False)
        return EvictionResult(victim, vd)

    def insert(self, page: int, dirty: bool = False) -> Optional[EvictionResult]:
        ev = None
        if len(self._a1in) + len(self._am) >= self.capacity:
            ev = self._evict_one()
        if page in self._a1out:
            del self._a1out[page]
            self._am[page] = dirty
        else:
            self._a1in[page] = dirty
        return ev

    def invalidate(self, page: int) -> bool:
        if self._a1in.pop(page, None) is not None:
            return True
        return self._am.pop(page, None) is not None

    def is_dirty(self, page: int) -> bool:
        if page in self._a1in:
            return self._a1in[page]
        return self._am.get(page, False)

    def resident_pages(self) -> set[int]:
        return set(self._a1in) | set(self._am)


class LFRUPolicy(CachePolicy):
    """LFRU — Least Frequently Recently Used.

    Combines frequency and recency: victim = min over resident pages of
    ``(freq, last_use)``; frequency saturates and is halved on a sweep
    (aging) whenever an eviction happens with all-frequencies-high, so stale
    hot pages decay.  This matches the paper's description of LFRU as the
    frequency+recency hybrid among the five policies.
    """

    name = "lfru"

    def __init__(self, capacity_pages: int, freq_cap: int = 255) -> None:
        super().__init__(capacity_pages)
        self.freq_cap = freq_cap
        self._pages: Dict[int, list] = {}  # page -> [freq, last_use, dirty]
        self._clock = 0

    def lookup(self, page: int) -> bool:
        return page in self._pages

    def touch(self, page: int, dirty: bool = False) -> None:
        self._clock += 1
        ent = self._pages[page]
        ent[0] = min(ent[0] + 1, self.freq_cap)
        ent[1] = self._clock
        ent[2] = ent[2] or dirty

    def insert(self, page: int, dirty: bool = False) -> Optional[EvictionResult]:
        self._clock += 1
        ev = None
        if len(self._pages) >= self.capacity:
            victim = min(self._pages, key=lambda p: (self._pages[p][0], self._pages[p][1]))
            vf, _, vd = self._pages.pop(victim)
            ev = EvictionResult(victim, vd)
            if vf >= self.freq_cap // 2:  # aging sweep
                for ent in self._pages.values():
                    ent[0] >>= 1
        self._pages[page] = [1, self._clock, dirty]
        return ev

    def invalidate(self, page: int) -> bool:
        return self._pages.pop(page, None) is not None

    def is_dirty(self, page: int) -> bool:
        ent = self._pages.get(page)
        return bool(ent and ent[2])

    def resident_pages(self) -> set[int]:
        return set(self._pages)


POLICIES = {
    "direct": DirectPolicy,
    "lru": LRUPolicy,
    "fifo": FIFOPolicy,
    "2q": TwoQPolicy,
    "lfru": LFRUPolicy,
}


def make_policy(name: str, capacity_pages: int) -> CachePolicy:
    try:
        return POLICIES[name.lower()](capacity_pages)
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; choose from {sorted(POLICIES)}") from None
