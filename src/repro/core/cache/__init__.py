from repro.core.cache.policies import (
    POLICIES,
    CachePolicy,
    DirectPolicy,
    FIFOPolicy,
    LFRUPolicy,
    LRUPolicy,
    TwoQPolicy,
    make_policy,
)
from repro.core.cache.dram_cache import DRAMCache, DRAMCacheConfig
from repro.core.cache.trace_sim import TraceCacheSim, simulate_trace

__all__ = [
    "POLICIES",
    "CachePolicy",
    "DirectPolicy",
    "FIFOPolicy",
    "LFRUPolicy",
    "LRUPolicy",
    "TwoQPolicy",
    "make_policy",
    "DRAMCache",
    "DRAMCacheConfig",
    "TraceCacheSim",
    "simulate_trace",
]
