"""DRAM cache layer in front of the CXL-SSD (paper §II-C).

* 4 KB pages with valid + dirty bits, write-back / write-allocate;
* an MSHR table that coalesces overlapping 64 B requests targeting the same
  in-flight 4 KB page ("avoiding redundant SSD reads and reducing data
  traffic");
* pluggable replacement policy (the five of :mod:`repro.core.cache.policies`);
* a bounded writeback buffer so dirty evictions drain to flash in the
  background instead of serializing with demand fills.

Latency/occupancy accounting is analytic (busy-until), identical in style to
the PAL: a DRAM-cache hit costs the paper's 50 ns; a fill occupies the cache
DRAM for a 4 KB transfer at DDR4 bandwidth.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.cache.policies import CachePolicy, make_policy
from repro.core.engine import ns
from repro.core.ssd.hil import HIL

PAGE_BYTES = 4096
LINE_BYTES = 64


@dataclass
class DRAMCacheConfig:
    capacity_bytes: int = 16 << 20      # Table I: 16 MB
    policy: str = "lru"
    hit_latency_ns: float = 50.0        # Table I: DRAM cache access 50 ns
    dram_bw_gbps: float = 19.2          # DDR4-2400 single channel
    mshr_entries: int = 16
    writeback_buffer: int = 8

    @property
    def capacity_pages(self) -> int:
        return self.capacity_bytes // PAGE_BYTES


@dataclass
class _MSHREntry:
    page: int
    ready_tick: int
    coalesced: int = 0


class DRAMCache:
    """Write-back, write-allocate page cache backed by a SimpleSSD HIL."""

    def __init__(self, cfg: DRAMCacheConfig, ssd: HIL) -> None:
        self.cfg = cfg
        self.ssd = ssd
        self.policy: CachePolicy = make_policy(cfg.policy, cfg.capacity_pages)
        self._mshr: Dict[int, _MSHREntry] = {}
        self._wb_drain_tick = 0          # when the writeback buffer has room
        self._wb_inflight: list[int] = []  # completion ticks of queued writebacks
        self._dram_busy_until = 0
        self.stats = {
            "accesses": 0, "reads": 0, "writes": 0,
            "mshr_coalesced": 0, "mshr_stalls": 0,
            "fills": 0, "writebacks": 0,
        }

    # ------------------------------------------------------------- internals
    def _page_of(self, addr: int) -> int:
        return addr // PAGE_BYTES

    def _dram_xfer(self, now: int, nbytes: int) -> int:
        """Occupy cache-DRAM bandwidth; returns completion tick."""
        per_byte_ns = 1.0 / self.cfg.dram_bw_gbps  # ns per byte at GB/s
        start = max(now, self._dram_busy_until)
        done = start + ns(nbytes * per_byte_ns)
        self._dram_busy_until = done
        return done

    def _reap_writebacks(self, now: int) -> None:
        self._wb_inflight = [t for t in self._wb_inflight if t > now]

    def _queue_writeback(self, now: int, page: int) -> int:
        """Dirty eviction → background write to flash. Returns the tick at
        which the *demand path* may proceed (stall only if buffer full)."""
        self._reap_writebacks(now)
        stall_until = now
        if len(self._wb_inflight) >= self.cfg.writeback_buffer:
            stall_until = min(self._wb_inflight)
            self._reap_writebacks(stall_until)
        done = self.ssd.write(stall_until, page * PAGE_BYTES, PAGE_BYTES)
        self._wb_inflight.append(done)
        self.stats["writebacks"] += 1
        return stall_until

    # ------------------------------------------------------------------ api
    def access(self, now: int, addr: int, write: bool,
               posted: bool = False) -> int:
        """A 64 B access; returns completion tick (write-back semantics: a
        write completes when it lands in the DRAM cache).  ``posted`` writes
        return at queue-accept time; internal state (fills, writebacks,
        busy-until) advances identically either way."""
        self.stats["accesses"] += 1
        self.stats["writes" if write else "reads"] += 1
        page = self._page_of(addr)

        # In-flight fill → MSHR coalescing: ride the existing SSD read.  This
        # must be checked *before* residency — write-allocate inserts the
        # frame at miss time, but its data isn't in the cache DRAM until the
        # fill lands.
        ent = self._mshr.get(page)
        if ent is not None and ent.ready_tick > now:
            ent.coalesced += 1
            self.stats["mshr_coalesced"] += 1
            if write:
                # the store's line merges into the MSHR — ack now.  (Under a
                # direct-mapped policy a conflicting insert may have evicted
                # the frame while this fill was in flight; only mark dirty if
                # still resident.)
                if self.policy.lookup(page):
                    self.policy.touch(page, dirty=True)
                return now + ns(self.cfg.hit_latency_ns)
            return max(ent.ready_tick, now) + ns(self.cfg.hit_latency_ns)

        # Resident → hit at DRAM-cache latency.
        if self.policy.lookup(page):
            self.policy.hits += 1
            self.policy.touch(page, dirty=write)
            done = self._dram_xfer(now, LINE_BYTES)
            if write and posted:
                return now + ns(10.0)
            return max(done, now + ns(self.cfg.hit_latency_ns)) if not write \
                else now + ns(self.cfg.hit_latency_ns)

        # Miss → allocate MSHR (stall if the table is full).
        self.policy.misses += 1
        start = now
        if len(self._mshr) >= self.cfg.mshr_entries:
            self.stats["mshr_stalls"] += 1
            victim_ready = min(e.ready_tick for e in self._mshr.values())
            self._expire_mshrs(victim_ready)
            start = max(start, victim_ready)

        # Write-allocate: evict (write back if dirty), then fill from flash.
        ev = self.policy.insert(page, dirty=write)
        if ev is not None:
            self.policy.evictions += 1
            if ev.dirty:
                self.policy.dirty_evictions += 1
                start = max(start, self._queue_writeback(start, ev.page))

        self.stats["fills"] += 1
        if self.ssd.is_written(page * PAGE_BYTES):
            flash_done = self.ssd.read(start, page * PAGE_BYTES, PAGE_BYTES)
        else:
            flash_done = start  # virgin page: no flash read needed
        fill_done = self._dram_xfer(flash_done, PAGE_BYTES)
        self._mshr[page] = _MSHREntry(page=page, ready_tick=fill_done)
        self._expire_mshrs(now)
        if write:
            # write-allocate: the line lands in the fill buffer; ack at
            # cache latency (persistence domain = powered DRAM cache).
            return max(start, now) + ns(self.cfg.hit_latency_ns)
        return fill_done + ns(self.cfg.hit_latency_ns)

    def _expire_mshrs(self, now: int) -> None:
        for p in [p for p, e in self._mshr.items() if e.ready_tick <= now]:
            del self._mshr[p]

    def flush(self, now: int) -> int:
        """Write back all dirty pages (shutdown/persist); returns tick."""
        t = now
        for page in sorted(self.policy.resident_pages()):
            if self.policy.is_dirty(page):
                t = max(t, self.ssd.write(t, page * PAGE_BYTES, PAGE_BYTES))
        return t

    @property
    def hit_rate(self) -> float:
        return self.policy.hit_rate
