"""Vectorized set-associative cache simulation with ``jax.lax.scan``.

This is the simulator's compute hot-spot expressed as a JAX program: given an
address trace (page ids + write flags), replay a set-associative cache with
LRU / FIFO / Direct replacement and produce per-access hit flags plus
eviction traffic.  One scan step = one access; cache state (tags, timestamps,
dirty bits) is the carry.  The Pallas TPU kernel in
:mod:`repro.kernels.cache_sim` implements the same update rule with state
held in VMEM scratch across a sequential grid, and is validated against this
module, which in turn is validated against the pure-Python policy objects
(:mod:`repro.core.cache.policies`).

Note 2Q / LFRU keep variable-length queue metadata and are simulated via the
object model only; Direct/LRU/FIFO (the set-friendly policies) get the
vectorized fast path.  This mirrors hardware reality: tag+timestamp updates
are what a cache controller does per access.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

NEG = jnp.int32(-(2**31) + 1)


@dataclass
class TraceCacheSim:
    num_sets: int
    ways: int
    policy: str = "lru"  # 'lru' | 'fifo' | 'direct'

    def __post_init__(self) -> None:
        if self.policy not in ("lru", "fifo", "direct"):
            raise ValueError(f"vectorized sim supports lru/fifo/direct, got {self.policy}")
        if self.policy == "direct" and self.ways != 1:
            raise ValueError("direct-mapped requires ways == 1")

    def init_state(self):
        shape = (self.num_sets, self.ways)
        return (
            jnp.full(shape, -1, dtype=jnp.int32),   # tags (-1 = invalid)
            jnp.zeros(shape, dtype=jnp.int32),      # meta: LRU ts / FIFO insert ts
            jnp.zeros(shape, dtype=jnp.bool_),      # dirty
        )

    def run(self, pages, is_write):
        """Replay a trace. Returns (hits[N] bool, dirty_evicts[N] bool, state)."""
        pages = jnp.asarray(pages, dtype=jnp.int32)
        is_write = jnp.asarray(is_write, dtype=jnp.bool_)
        return _run_trace(pages, is_write, self.num_sets, self.ways,
                          self.policy == "lru")


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def _run_trace(pages, is_write, num_sets: int, ways: int, is_lru: bool):
    init = (
        jnp.full((num_sets, ways), -1, dtype=jnp.int32),
        jnp.zeros((num_sets, ways), dtype=jnp.int32),
        jnp.zeros((num_sets, ways), dtype=jnp.bool_),
    )

    def step(carry, inp):
        tags, meta, dirty = carry
        t, (page, wr) = inp
        s = jax.lax.rem(page, num_sets)
        line_tags = jax.lax.dynamic_slice_in_dim(tags, s, 1, 0)[0]     # (W,)
        line_meta = jax.lax.dynamic_slice_in_dim(meta, s, 1, 0)[0]
        line_dirty = jax.lax.dynamic_slice_in_dim(dirty, s, 1, 0)[0]

        match = line_tags == page
        hit = jnp.any(match)
        hit_way = jnp.argmax(match)

        valid = line_tags >= 0
        # victim: invalid way first (key=NEG), else smallest meta (LRU ts or
        # FIFO insertion ts — same rule, different update discipline).
        victim_key = jnp.where(valid, line_meta, NEG)
        victim_way = jnp.argmin(victim_key)
        way = jnp.where(hit, hit_way, victim_way)

        dirty_evict = (~hit) & valid[victim_way] & line_dirty[victim_way]

        new_tag = jnp.where(hit, line_tags[way], page)
        # LRU: bump timestamp on every touch. FIFO: stamp only on insert.
        stamp = jnp.where(hit, jnp.where(is_lru, t, line_meta[way]), t)
        new_dirty = jnp.where(hit, line_dirty[way] | wr, wr)

        line_tags = line_tags.at[way].set(new_tag)
        line_meta = line_meta.at[way].set(stamp)
        line_dirty = line_dirty.at[way].set(new_dirty)

        tags = jax.lax.dynamic_update_slice_in_dim(tags, line_tags[None], s, 0)
        meta = jax.lax.dynamic_update_slice_in_dim(meta, line_meta[None], s, 0)
        dirty = jax.lax.dynamic_update_slice_in_dim(dirty, line_dirty[None], s, 0)
        return (tags, meta, dirty), (hit, dirty_evict)

    n = pages.shape[0]
    ts = jnp.arange(1, n + 1, dtype=jnp.int32)
    (tags, meta, dirty), (hits, evicts) = jax.lax.scan(
        step, init, (ts, (pages, is_write)))
    return hits, evicts, (tags, meta, dirty)


def simulate_trace(pages: np.ndarray, is_write: np.ndarray, *, num_sets: int,
                   ways: int, policy: str = "lru") -> dict:
    """Convenience wrapper returning plain-numpy summary statistics."""
    sim = TraceCacheSim(num_sets=num_sets, ways=ways, policy=policy)
    hits, evicts, _ = sim.run(pages, is_write)
    hits = np.asarray(hits)
    evicts = np.asarray(evicts)
    return {
        "accesses": int(hits.size),
        "hits": int(hits.sum()),
        "hit_rate": float(hits.mean()) if hits.size else 0.0,
        "dirty_evictions": int(evicts.sum()),
        "hit_flags": hits,
        "dirty_evict_flags": evicts,
    }
