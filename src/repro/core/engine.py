"""Discrete-event simulation engine (gem5-style tick loop).

The engine is deliberately tiny: a monotonic tick counter (1 tick == 1 ps,
matching gem5's default resolution) and a priority queue of events.  Devices
schedule completion callbacks; the engine drains them in (tick, seq) order so
simultaneous events retain FIFO semantics.

The engine is the *slow path* of the simulator — it sequences device-level
latencies (SSD channel occupancy, MSHR wakeups, CXL round trips).  The *hot
path* — per-access cache-state updates over long address traces — is
vectorized separately in :mod:`repro.core.cache.trace_sim` and in the Pallas
kernel :mod:`repro.kernels.cache_sim`.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Optional

# 1 tick = 1 picosecond, like gem5.
TICKS_PER_NS = 1_000
TICKS_PER_US = 1_000_000
TICKS_PER_MS = 1_000_000_000
TICKS_PER_S = 1_000_000_000_000


def ns(x: float) -> int:
    """Convert nanoseconds to ticks."""
    return int(round(x * TICKS_PER_NS))


def us(x: float) -> int:
    """Convert microseconds to ticks."""
    return int(round(x * TICKS_PER_US))


def to_ns(ticks: int) -> float:
    return ticks / TICKS_PER_NS


def to_us(ticks: int) -> float:
    return ticks / TICKS_PER_US


def to_s(ticks: int) -> float:
    return ticks / TICKS_PER_S


@dataclass(order=True)
class _Event:
    tick: int
    seq: int
    callback: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)


class EventEngine:
    """A minimal deterministic discrete-event engine."""

    def __init__(self) -> None:
        self._queue: list[_Event] = []
        self._seq = itertools.count()
        self.now: int = 0
        self.events_executed: int = 0

    # ------------------------------------------------------------------ API
    def schedule(self, delay_ticks: int, callback: Callable[[], None]) -> _Event:
        """Schedule ``callback`` to run ``delay_ticks`` from now."""
        if delay_ticks < 0:
            raise ValueError(f"negative delay: {delay_ticks}")
        ev = _Event(self.now + int(delay_ticks), next(self._seq), callback)
        heapq.heappush(self._queue, ev)
        return ev

    def schedule_at(self, tick: int, callback: Callable[[], None]) -> _Event:
        if tick < self.now:
            raise ValueError(f"cannot schedule in the past: {tick} < {self.now}")
        ev = _Event(int(tick), next(self._seq), callback)
        heapq.heappush(self._queue, ev)
        return ev

    @staticmethod
    def cancel(ev: _Event) -> None:
        ev.cancelled = True

    def run(self, until: Optional[int] = None, max_events: Optional[int] = None) -> int:
        """Drain the queue; returns the final tick."""
        n = 0
        while self._queue:
            if until is not None and self._queue[0].tick > until:
                self.now = until
                break
            if max_events is not None and n >= max_events:
                break
            ev = heapq.heappop(self._queue)
            if ev.cancelled:
                continue
            assert ev.tick >= self.now, "event queue went backwards"
            self.now = ev.tick
            ev.callback()
            self.events_executed += 1
            n += 1
        return self.now

    def pending(self) -> int:
        return sum(1 for e in self._queue if not e.cancelled)

    def reset(self) -> None:
        self._queue.clear()
        self.now = 0
        self.events_executed = 0
