"""repro.core — the CXL-SSD-Sim reproduction (paper pillar 1).

Full-system memory simulator: CXL.mem protocol layer, SimpleSSD-style SSD
backend, DRAM cache layer with five replacement policies, five device
models, and the paper's workloads (STREAM, membench, Viper).
"""

from repro.core.engine import EventEngine, ns, us, to_ns, to_us, to_s
from repro.core.devices import (
    DEVICE_NAMES,
    CachedCXLSSDDevice,
    CXLDRAMDevice,
    CXLSSDDevice,
    DRAMDevice,
    PMEMDevice,
    make_device,
)

__all__ = [
    "EventEngine", "ns", "us", "to_ns", "to_us", "to_s",
    "DEVICE_NAMES", "make_device",
    "DRAMDevice", "CXLDRAMDevice", "PMEMDevice", "CXLSSDDevice",
    "CachedCXLSSDDevice",
]
