"""STREAM (McCalpin) bandwidth kernels over the simulated devices (Fig. 3).

Copy:  a[i] = b[i]            2 arrays touched / iteration
Scale: a[i] = q*b[i]          2
Add:   a[i] = b[i] + c[i]     3
Triad: a[i] = b[i] + q*c[i]   3

The paper uses an 8 MB dataset; accesses are sequential 64 B lines with the
full LFB depth outstanding, so the result is the device's sustainable
bandwidth.
"""

from __future__ import annotations

from typing import Dict, Iterator

from repro.core.devices import MemDevice
from repro.core.workloads.driver import Access, TraceDriver, TraceResult

LINE = 64


def _kernel_trace(base: int, array_bytes: int, reads: int, writes: int) -> Iterator[Access]:
    """Interleave per-iteration reads then writes, line by line."""
    nlines = array_bytes // LINE
    # array layout: [w0][r0][r1] each array_bytes long
    for i in range(nlines):
        off = i * LINE
        for r in range(reads):
            yield (base + (1 + r) * array_bytes + off, LINE, False)
        for w in range(writes):
            yield (base + w * array_bytes + off, LINE, True)


def run_stream(device: MemDevice, dataset_bytes: int = 8 << 20,
               outstanding: int = 32, iterations: int = 2,
               base_addr: int = 0) -> Dict[str, TraceResult]:
    """Run the four STREAM kernels; returns per-kernel TraceResult.

    Like the real STREAM, each kernel runs ``iterations`` times and the last
    pass is reported — the first pass warms any cache layer (the paper's
    cached CXL-SSD point is precisely its warm steady state).
    """
    kernels = {
        "copy": (1, 1),
        "scale": (1, 1),
        "add": (2, 1),
        "triad": (2, 1),
    }
    results: Dict[str, TraceResult] = {}
    t = 0
    for name, (reads, writes) in kernels.items():
        arrays = reads + writes
        array_bytes = (dataset_bytes // arrays) // LINE * LINE
        driver = TraceDriver(device, outstanding=outstanding)
        for _ in range(max(1, iterations)):
            res = driver.run(_kernel_trace(base_addr, array_bytes, reads, writes),
                             start_tick=t)
            t = res.end_tick
        results[name] = res
    return results
