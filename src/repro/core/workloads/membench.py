"""membench-style load-latency measurement (Fig. 4).

Random dependent 64 B loads (pointer chasing): each load's address depends on
the previous load's value, so exactly one access is in flight — the measured
quantity is pure access latency, not bandwidth.
"""

from __future__ import annotations

import numpy as np

from repro.core.devices import MemDevice
from repro.core.workloads.driver import TraceDriver, TraceResult

LINE = 64


def run_membench(device: MemDevice, working_set_bytes: int = 8 << 20,
                 accesses: int = 20_000, seed: int = 7, iterations: int = 2,
                 base_addr: int = 0) -> TraceResult:
    """Pointer-chase latency.  ``iterations=2`` reports the warm pass (hot
    data), matching the paper's random-read latency comparison where the
    cached CXL-SSD serves hits from its DRAM layer."""
    rng = np.random.default_rng(seed)
    nlines = working_set_bytes // LINE
    # A random permutation cycle == pointer-chase order.
    order = rng.permutation(nlines)
    addrs = base_addr + order[:accesses] * LINE

    # Untimed init: membench writes the pointer array before chasing it, so
    # the working set exists on the backing medium.
    init = TraceDriver(device, outstanding=32)
    res = init.run((base_addr + i * LINE, LINE, True) for i in range(nlines))
    t = res.end_tick

    driver = TraceDriver(device, outstanding=1)  # dependent chain
    for _ in range(max(1, iterations)):
        res = driver.run(((int(a), LINE, False) for a in addrs), start_tick=t)
        t = res.end_tick
    return res
