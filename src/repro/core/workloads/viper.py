"""Viper KV-store workload model (paper §III-C, Figs. 5-6).

Viper is a hybrid KV store: the offset index lives in (local) DRAM, the
value log lives on the device under test.  Each operation therefore issues:

* index probe/update accesses against local DRAM,
* value-log accesses (``ceil(kv_size/64)`` sequential 64 B lines) against
  the target device — appends go to the moving log tail, reads to the key's
  stored offset,
* hot metadata accesses (allocator/block headers) against the target device
  — a tiny set of pages touched by *every* operation.  This is the high
  temporal locality the paper calls out ("repeated metadata access" during
  update/delete), and it is what separates the replacement policies.

Five timed phases of ``ops_per_phase`` operations each: insert, write (put
to an existing key), query, update, delete — matching the paper's list.
QPS per phase = ops / simulated elapsed time.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np

from repro.core.devices import DRAMDevice, MemDevice
from repro.core.engine import ns, to_s

LINE = 64
PAGE = 4096


@dataclass
class ViperConfig:
    kv_bytes: int = 216               # paper: 216 B and 532 B experiments
    ops_per_phase: int = 10_000
    keyspace: int = 28_000
    seed_keys: int = 18_000           # untimed pre-population
    compute_ns: float = 500.0         # per-op CPU work (hashing, memcpy, ...)
    metadata_pages: int = 8           # hot allocator/block headers
    value_base: int = 1 << 30         # value log base address on device
    meta_base: int = 0                # metadata region base on device
    zipf_s: float = 0.9               # key-popularity skew (YCSB-style)
    seed: int = 11

    @property
    def value_lines(self) -> int:
        return (self.kv_bytes + LINE - 1) // LINE


@dataclass
class _State:
    tail: int = 0                                  # log tail offset (bytes)
    offsets: Dict[int, int] = field(default_factory=dict)  # key -> log offset
    op_count: int = 0


class _Viper:
    def __init__(self, cfg: ViperConfig, device: MemDevice, index: DRAMDevice) -> None:
        self.cfg = cfg
        self.dev = device
        self.idx = index
        self.st = _State()
        # Zipf-weighted header choice: the allocator head page is touched far
        # more often than per-block headers (rank-skewed, like real metadata)
        rng = np.random.default_rng(cfg.seed + 1)
        w = 1.0 / np.arange(1, cfg.metadata_pages + 1) ** 1.6
        self._meta_seq = rng.choice(cfg.metadata_pages, size=1 << 16,
                                    p=w / w.sum())

    # --------------------------------------------------------------- pieces
    def _index_probe(self, t: int) -> int:
        t = self.idx.service(t, 0x1000 + (self.st.op_count * 128) % (1 << 20), LINE, False)
        return self.idx.service(t, 0x2000 + (self.st.op_count * 64) % (1 << 20), LINE, False)

    def _index_update(self, t: int) -> int:
        return self.idx.service(t, 0x3000 + (self.st.op_count * 64) % (1 << 20), LINE, True)

    def _metadata(self, t: int, write: bool) -> int:
        page = int(self._meta_seq[self.st.op_count & 0xFFFF])
        addr = self.cfg.meta_base + page * PAGE + (self.st.op_count % 8) * LINE
        t = self.dev.service(t, addr, LINE, False)
        if write:
            t = self.dev.service(t, addr, LINE, True)
        return t

    def _value_lines(self, t0: int, offset: int, write: bool) -> int:
        """Value lines issue back-to-back (multiple LFBs): latencies overlap,
        occupancy/queueing serializes inside the device model."""
        done = t0
        for i in range(self.cfg.value_lines):
            addr = self.cfg.value_base + offset + i * LINE
            done = max(done, self.dev.service(t0 + ns(i), addr, LINE, write))
        return done

    def _append(self, t: int, key: int) -> int:
        off = self.st.tail
        self.st.tail += self.cfg.value_lines * LINE
        done = self._value_lines(t, off, write=True)
        self.st.offsets[key] = off
        return done

    # ------------------------------------------------------------------ ops
    def insert(self, t: int, key: int) -> int:
        self.st.op_count += 1
        t = self._index_probe(t)
        t = self._append(t, key)
        t = self._index_update(t)
        t = self._metadata(t, write=True)
        return t + ns(self.cfg.compute_ns)

    put = insert  # Viper put-to-existing-key is also an append + remap

    def query(self, t: int, key: int) -> int:
        self.st.op_count += 1
        t = self._index_probe(t)
        off = self.st.offsets.get(key, 0)
        t = self._value_lines(t, off, write=False)
        t = self._metadata(t, write=False)
        return t + ns(self.cfg.compute_ns)

    def update(self, t: int, key: int) -> int:
        self.st.op_count += 1
        t = self._index_probe(t)
        off = self.st.offsets.get(key, 0)
        t = self._value_lines(t, off, write=False)   # read old version
        t = self._append(t, key)                     # append new version
        t = self._index_update(t)
        t = self._metadata(t, write=True)
        return t + ns(self.cfg.compute_ns)

    def delete(self, t: int, key: int) -> int:
        self.st.op_count += 1
        t = self._index_probe(t)
        off = self.st.offsets.pop(key, 0)
        t = self.dev.service(t, self.cfg.value_base + off, LINE, True)  # tombstone
        t = self._index_update(t)
        t = self._metadata(t, write=True)
        return t + ns(self.cfg.compute_ns)


def run_viper(device: MemDevice, cfg: ViperConfig | None = None) -> Dict[str, float]:
    """Run the five phases; returns {phase: QPS} plus 'avg'."""
    cfg = cfg or ViperConfig()
    rng = np.random.default_rng(cfg.seed)
    idx = DRAMDevice()
    kv = _Viper(cfg, device, idx)

    t = 0
    # untimed pre-population (builds the log + warms nothing: the device
    # under test still sees the writes, matching a freshly-loaded store)
    for key in range(cfg.seed_keys):
        t = kv.insert(t, key)

    phases: Dict[str, float] = {}
    new_keys = list(range(cfg.seed_keys, cfg.keyspace))
    rng.shuffle(new_keys)
    n = cfg.ops_per_phase

    def timed(name: str, keys, fn) -> None:
        nonlocal t
        t0 = t
        for k in keys:
            t = fn(t, int(k))
        phases[name] = n / max(to_s(t - t0), 1e-12)

    # YCSB-style Zipfian key popularity (hot keys dominate), shuffled over
    # the keyspace so popularity is uncorrelated with insertion order.
    ranks = np.arange(1, cfg.keyspace + 1, dtype=np.float64)
    pk = ranks ** -cfg.zipf_s
    pk /= pk.sum()
    keymap = rng.permutation(cfg.keyspace)

    def live():
        return keymap[rng.choice(cfg.keyspace, size=n, p=pk)]

    timed("insert", (new_keys * (n // len(new_keys) + 1))[:n], kv.insert)
    timed("write", live(), kv.put)
    timed("query", live(), kv.query)
    timed("update", live(), kv.update)
    # delete unique keys (re-inserting is not modeled; sample w/o replacement)
    timed("delete", keymap[rng.choice(cfg.keyspace, size=n, replace=False)], kv.delete)

    phases["avg"] = float(np.mean([phases[p] for p in
                                   ("insert", "write", "query", "update", "delete")]))
    return phases
