from repro.core.workloads.driver import (
    MultiHostDriver,
    MultiHostResult,
    TraceDriver,
    TraceResult,
)
from repro.core.workloads.stream import run_stream
from repro.core.workloads.membench import run_membench
from repro.core.workloads.viper import ViperConfig, run_viper

__all__ = ["TraceDriver", "TraceResult", "MultiHostDriver", "MultiHostResult",
           "run_stream", "run_membench", "ViperConfig", "run_viper"]
