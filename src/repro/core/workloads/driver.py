"""Trace driver: a CPU issue model with bounded outstanding requests.

Models the core's load/store unit: ``outstanding`` line-fill-buffer slots.
Dependent chains (membench pointer chasing) use ``outstanding=1``; streaming
kernels use the full LFB depth so bandwidth saturates by Little's law.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Iterable, Tuple

from repro.core.devices import MemDevice
from repro.core.engine import to_ns, to_s

Access = Tuple[int, int, bool]  # (addr, size, write)


@dataclass
class TraceResult:
    accesses: int
    bytes_moved: int
    elapsed_ticks: int
    sum_latency_ticks: int
    end_tick: int = 0      # absolute completion tick (chain multi-pass runs)

    @property
    def elapsed_s(self) -> float:
        return to_s(self.elapsed_ticks)

    @property
    def avg_latency_ns(self) -> float:
        return to_ns(self.sum_latency_ticks) / self.accesses if self.accesses else 0.0

    @property
    def bandwidth_gbps(self) -> float:
        return self.bytes_moved / self.elapsed_s / 1e9 if self.elapsed_ticks else 0.0


class TraceDriver:
    """``outstanding≈32`` models LFBs + hardware prefetch streams; real cores
    need ~latency/occupancy (~24 for DDR4) in flight to reach media bandwidth."""

    def __init__(self, device: MemDevice, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5, posted_writes: bool = True) -> None:
        self.device = device
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes

    def run(self, trace: Iterable[Access], start_tick: int = 0) -> TraceResult:
        from repro.core.engine import ns

        slots: list[int] = [start_tick] * self.outstanding  # min-heap of free times
        heapq.heapify(slots)
        now = start_tick
        n = 0
        total_bytes = 0
        sum_lat = 0
        first_issue = None
        last_done = start_tick
        issue_ov = ns(self.issue_overhead_ns)

        for addr, size, write in trace:
            slot_free = heapq.heappop(slots)
            issue = max(now, slot_free)
            if first_issue is None:
                first_issue = issue
            done = self.device.service(issue, addr, size, write,
                                       posted=write and self.posted_writes)
            heapq.heappush(slots, done)
            sum_lat += done - issue
            last_done = max(last_done, done)
            now = issue + issue_ov  # next access can issue after decode/AGU
            n += 1
            total_bytes += size

        if first_issue is None:
            first_issue = start_tick
        return TraceResult(accesses=n, bytes_moved=total_bytes,
                           elapsed_ticks=last_done - first_issue,
                           sum_latency_ticks=sum_lat,
                           end_tick=last_done)
