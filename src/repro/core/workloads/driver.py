"""Trace drivers: CPU issue models with bounded outstanding requests.

:class:`TraceDriver` models one core's load/store unit: ``outstanding``
line-fill-buffer slots.  Dependent chains (membench pointer chasing) use
``outstanding=1``; streaming kernels use the full LFB depth so bandwidth
saturates by Little's law.

:class:`MultiHostDriver` interleaves N such hosts onto *shared* targets
(fabric-attached devices or pool views): accesses are issued in global
issue-time order with deterministic host-index tie-breaking, so contention
on shared switch ports and device media emerges from the targets' busy-until
state rather than from run ordering.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple

from repro.core.devices import MemDevice
from repro.core.engine import to_ns, to_s

Access = Tuple[int, int, bool]  # (addr, size, write)


@dataclass
class TraceResult:
    accesses: int
    bytes_moved: int
    elapsed_ticks: int
    sum_latency_ticks: int
    end_tick: int = 0      # absolute completion tick (chain multi-pass runs)
    # telemetry bundle (repro.core.replay.metrics.MetricsBundle) when the
    # run collected metrics; None otherwise.  Typed loosely: the metrics
    # layer imports this module, not vice versa.
    metrics: object = None

    @property
    def elapsed_s(self) -> float:
        return to_s(self.elapsed_ticks)

    @property
    def avg_latency_ns(self) -> float:
        return to_ns(self.sum_latency_ticks) / self.accesses if self.accesses else 0.0

    @property
    def bandwidth_gbps(self) -> float:
        return self.bytes_moved / self.elapsed_s / 1e9 if self.elapsed_ticks else 0.0

    @property
    def p99_ns(self):
        """99th-percentile latency (ns, bucket upper edge) from the metrics
        bundle; None without metrics or on an empty trace."""
        return (self.metrics.percentile_ns(99)
                if self.metrics is not None else None)

    @property
    def hit_rate(self):
        """Device hit rate (cache/buffer/row hits over accesses) from the
        metrics bundle; None without metrics."""
        return self.metrics.hit_rate if self.metrics is not None else None

    @property
    def write_amplification(self):
        """Flash write amplification from the metrics bundle; None without
        metrics."""
        return (self.metrics.write_amplification
                if self.metrics is not None else None)


ENGINES = ("python", "scan", "assoc", "pallas")


class TraceDriver:
    """``outstanding≈32`` models LFBs + hardware prefetch streams; real cores
    need ~latency/occupancy (~24 for DDR4) in flight to reach media bandwidth.

    ``engine`` selects the replay backend:

    ``python``   interpret every access through the device objects (the
                 reference semantics; always available);
    ``scan``     the fused :mod:`repro.core.replay` lax.scan — one compiled
                 program for the whole stack (FTL greedy GC included: a
                 GC-pressure trace selects the GC-capable stack lane
                 instead of falling back), tick-identical to ``python``
                 for supported shapes (raises
                 :class:`~repro.core.replay.ReplayUnsupported` otherwise).
                 ``block_size=B`` replays B accesses per sequential scan
                 step (tick-identical at any B; amortizes XLA:CPU's
                 per-step dispatch floor);
    ``assoc``    the log-depth associative lane
                 (:mod:`repro.core.replay.assoc`) — zero sequential scan
                 steps; tick-identical where certified (stateless
                 DRAM/PMEM media, bandwidth-bound traces), refuses with
                 :class:`ReplayUnsupported` otherwise;
    ``pallas``   the fused Pallas cache+latency kernel — bit-identical
                 hit/evict decisions, analytic open-loop latency (see
                 :mod:`repro.core.replay.pallas_engine`).
    """

    def __init__(self, device: MemDevice, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5, posted_writes: bool = True,
                 engine: str = "python", block_size: int = 1,
                 metrics=None) -> None:
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
        from repro.core.replay.spec import (require_metrics_lane,
                                            validate_block_size)

        self.device = device
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes
        self.engine = engine
        self.block_size = validate_block_size(block_size)
        self.metrics = metrics        # Optional[MetricsSpec]
        if metrics is not None:
            # assoc/pallas lanes have no carry slot for the accumulators:
            # refuse up front rather than returning metric-less results
            require_metrics_lane(engine)
        if self.block_size > 1 and engine != "scan":
            # blocking shapes the sequential scan's lowering only; accepting
            # it elsewhere would silently run identical replays
            raise ValueError(
                f"block_size applies to engine='scan', not {engine!r}")

    def run(self, trace: Iterable[Access], start_tick: int = 0) -> TraceResult:
        rows = list(trace) if self.engine != "python" else trace
        if self.engine != "python" and rows:
            return self._run_fast(rows, start_tick)
        # One-host case of the interleaved driver: a single shared issue
        # model keeps the two from drifting.
        multi = MultiHostDriver([self.device], outstanding=self.outstanding,
                                issue_overhead_ns=self.issue_overhead_ns,
                                posted_writes=self.posted_writes,
                                metrics=self.metrics)
        return multi.run([rows], start_tick=start_tick).per_host[0]

    def _run_fast(self, rows, start_tick: int) -> TraceResult:
        from repro.core.replay import (MultiHostReplay, ReplayEngine,
                                       ReplayUnsupported)

        if self.engine == "pallas":
            from repro.core.replay.pallas_engine import run_pallas
            from repro.core.replay.spec import trace_to_arrays
            addrs, writes, size = trace_to_arrays(rows)
            return run_pallas(self.device, addrs, writes, size=size,
                              outstanding=self.outstanding,
                              issue_overhead_ns=self.issue_overhead_ns,
                              start_tick=start_tick)
        if self.engine == "assoc":
            from repro.core.replay.assoc import AssocReplayEngine
            # no silent fallback: the caller asked for the log-depth lane,
            # so a shape it cannot certify raises ReplayUnsupported naming
            # the wider lane (engine='scan')
            return AssocReplayEngine(
                self.device, outstanding=self.outstanding,
                issue_overhead_ns=self.issue_overhead_ns,
                posted_writes=self.posted_writes).run(rows, start_tick)
        try:
            return ReplayEngine(
                self.device, outstanding=self.outstanding,
                issue_overhead_ns=self.issue_overhead_ns,
                posted_writes=self.posted_writes,
                block_size=self.block_size,
                metrics=self.metrics).run(rows, start_tick)
        except ReplayUnsupported as single_host_reason:
            # pool views and shared-fabric targets live in the multi-host
            # engine; a single host is its degenerate case
            try:
                return MultiHostReplay(
                    [self.device], outstanding=self.outstanding,
                    issue_overhead_ns=self.issue_overhead_ns,
                    posted_writes=self.posted_writes,
                    block_size=self.block_size,
                    metrics=self.metrics).run(
                        [rows], start_tick).per_host[0]
            except ReplayUnsupported:
                # the single-host diagnosis (e.g. an unsupported policy) is
                # the actionable one; don't mask it with the retry's
                raise single_host_reason from None


# ----------------------------------------------------------- multi-host
@dataclass
class MultiHostResult:
    """Per-host :class:`TraceResult`\\ s plus cluster-level aggregates."""

    per_host: List[TraceResult]
    elapsed_ticks: int      # global span: first issue to last completion
    metrics: object = None  # MetricsBundle when collected (see TraceResult)

    @property
    def num_hosts(self) -> int:
        return len(self.per_host)

    @property
    def p99_ns(self):
        """Cluster-wide p99 latency (ns) from the metrics bundle; None
        without metrics or on an empty run."""
        return (self.metrics.percentile_ns(99)
                if self.metrics is not None else None)

    @property
    def hit_rate(self):
        return self.metrics.hit_rate if self.metrics is not None else None

    @property
    def write_amplification(self):
        return (self.metrics.write_amplification
                if self.metrics is not None else None)

    @property
    def total_bytes(self) -> int:
        return sum(r.bytes_moved for r in self.per_host)

    @property
    def aggregate_bandwidth_gbps(self) -> float:
        sec = to_s(self.elapsed_ticks)
        return self.total_bytes / sec / 1e9 if sec else 0.0

    @property
    def per_host_bandwidth_gbps(self) -> List[float]:
        """Each host's bytes over the *global* span — the fair-share number a
        tenant actually experiences while the others are active."""
        sec = to_s(self.elapsed_ticks)
        return [r.bytes_moved / sec / 1e9 if sec else 0.0
                for r in self.per_host]

    @property
    def min_host_bandwidth_gbps(self) -> float:
        return min(self.per_host_bandwidth_gbps) if self.per_host else 0.0


class _HostState:
    """Issue-side state of one host inside the interleaved replay."""

    __slots__ = ("target", "slots", "now", "trace", "pending", "n", "bytes",
                 "sum_lat", "first_issue", "last_done")

    def __init__(self, target: MemDevice, outstanding: int, start_tick: int,
                 trace: Iterable[Access]) -> None:
        self.target = target
        self.slots = [start_tick] * outstanding
        heapq.heapify(self.slots)
        self.now = start_tick
        self.trace = iter(trace)
        self.pending = next(self.trace, None)
        self.n = 0
        self.bytes = 0
        self.sum_lat = 0
        self.first_issue: int | None = None
        self.last_done = start_tick

    def next_issue_tick(self) -> int:
        return max(self.now, self.slots[0])


class MultiHostDriver:
    """Replay one trace per host against shared targets, interleaved.

    Each host keeps its own LFB slots and issue clock (exactly
    :class:`TraceDriver` semantics); globally, the host with the earliest
    next issue tick goes first (ties break on host index).  Running host
    traces back-to-back instead would serialize them through the shared
    busy-until state and hide all contention — the interleave is the point.

    ``engine="scan"`` dispatches to the fused
    :class:`~repro.core.replay.MultiHostReplay`, which covers every media
    the stacked-state layer models — DRAM-class, PMEM, CXL-SSD, and cached
    CXL-SSD (private mounts, pool views, or per-host caches over a shared
    flash built with ``CachedCXLSSDDevice(hil=...)``), greedy FTL GC
    included — and refuses anything else with the actionable lane name.
    """

    def __init__(self, targets: Sequence[MemDevice], outstanding: int = 32,
                 issue_overhead_ns: float = 0.5,
                 posted_writes: bool = True, engine: str = "python",
                 block_size: int = 1, metrics=None) -> None:
        if not targets:
            raise ValueError("need at least one host target")
        if engine not in ("python", "scan"):
            raise ValueError(f"multi-host engine must be python|scan, "
                             f"got {engine!r}")
        from repro.core.replay.spec import validate_block_size

        self.targets = list(targets)
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes
        self.engine = engine
        self.block_size = validate_block_size(block_size)
        self.metrics = metrics        # Optional[MetricsSpec]
        if self.block_size > 1 and engine != "scan":
            raise ValueError(
                f"block_size applies to engine='scan', not {engine!r}")

    def run(self, traces: Sequence[Iterable[Access]],
            start_tick: int = 0) -> MultiHostResult:
        from repro.core.engine import ns

        if self.engine == "scan":
            from repro.core.replay import MultiHostReplay
            return MultiHostReplay(
                self.targets, outstanding=self.outstanding,
                issue_overhead_ns=self.issue_overhead_ns,
                posted_writes=self.posted_writes,
                block_size=self.block_size, metrics=self.metrics).run(
                    [list(t) for t in traces], start_tick)

        if len(traces) != len(self.targets):
            raise ValueError(f"{len(traces)} traces for "
                             f"{len(self.targets)} host targets")
        issue_ov = ns(self.issue_overhead_ns)
        taps = None
        run_targets = self.targets
        if self.metrics is not None:
            from repro.core.replay import metrics as replay_metrics
            taps = replay_metrics.attach_taps(self.targets)
            run_targets = taps
        hosts = [_HostState(t, self.outstanding, start_tick, tr)
                 for t, tr in zip(run_targets, traces)]
        # deterministic poison accounting: the fault plan flags a read's
        # returned data corrupt as a pure function of (host, per-host
        # access ordinal) — counted here because the analytic service path
        # never materializes response flits (the flit codec carries the
        # same flag on the protocol path)
        plans = [getattr(t, "fault_plan", None) for t in self.targets]
        poisoned = 0

        # Global issue queue: (candidate issue tick, host index), one entry
        # per host with a pending access.  A host's candidate tick depends
        # only on its own slots/clock — other hosts move shared busy-until
        # state inside the targets, never this heap — so entries are always
        # current and ties resolve on host index, deterministically.
        ready = [(h.next_issue_tick(), i) for i, h in enumerate(hosts)
                 if h.pending is not None]
        heapq.heapify(ready)
        while ready:
            _, i = heapq.heappop(ready)
            h = hosts[i]
            addr, size, write = h.pending
            slot_free = heapq.heappop(h.slots)
            issue = max(h.now, slot_free)
            if h.first_issue is None:
                h.first_issue = issue
            done = h.target.service(issue, addr, size, write,
                                    posted=write and self.posted_writes)
            heapq.heappush(h.slots, done)
            h.sum_lat += done - issue
            h.last_done = max(h.last_done, done)
            h.now = issue + issue_ov
            plan = plans[i]
            if plan is not None and plan.has_poison:
                poisoned += plan.poisoned(i, h.n, write)
            h.n += 1
            h.bytes += size
            h.pending = next(h.trace, None)
            if h.pending is not None:
                heapq.heappush(ready, (h.next_issue_tick(), i))

        bundle = None
        if taps is not None:
            bundle = replay_metrics.collect_python(
                self.metrics, self.targets, taps, poisoned=poisoned)
        first = min((h.first_issue for h in hosts
                     if h.first_issue is not None), default=start_tick)
        last = max(h.last_done for h in hosts)
        per_host = [TraceResult(accesses=h.n, bytes_moved=h.bytes,
                                elapsed_ticks=(h.last_done - h.first_issue
                                               if h.first_issue is not None else 0),
                                sum_latency_ticks=h.sum_lat,
                                end_tick=h.last_done,
                                metrics=bundle)
                    for h in hosts]
        return MultiHostResult(per_host=per_host, elapsed_ticks=last - first,
                               metrics=bundle)
