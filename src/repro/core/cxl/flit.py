"""CXL.mem sub-protocol flit codec.

Implements the transaction subset the paper adds to gem5's packet layer
(§II-B): ``M2SReq`` (master→subordinate read), ``M2SRwD`` (master→
subordinate request-with-data, i.e. write), ``S2MDRS`` (subordinate→master
data response) and ``S2MNDR`` (subordinate→master no-data response), plus the
coherence ``MetaField``/``MetaValue`` handling of §II-B-3.

A CXL flit is 64 bytes (the paper's granularity; the CXL 2.0 spec carries a
68 B flit on the wire — 64 B payload + 4 B CRC, which we model as protocol
latency, not payload).  We pack a real binary header so the codec can be
property-tested for roundtripping:

``byte 0``      opcode (CXLCommand)
``byte 1``      meta_field << 4 | meta_value
``byte 2``      snp_type
``bytes 3-4``   tag (little endian)
``bytes 5-12``  address (64-bit LE; 64 B aligned for cacheline ops)
``bytes 13-14`` length in logical blocks (for SSD-bound multi-line requests)
``byte 15``     flags (bit0: poison, bit1: dirty-evict hint)
``bytes 16-63`` inline data window (first 48 B) — full 64 B data rides in
                ``CXLFlit.data`` (header + data slots in hardware).
"""

from __future__ import annotations

import enum
import struct
from dataclasses import dataclass, field
from typing import Optional

CXL_FLIT_BYTES = 64
CACHELINE_BYTES = 64


class MemCmd(enum.Enum):
    """gem5-side memory commands (the subset the Bridge converts)."""

    ReadReq = enum.auto()
    WriteReq = enum.auto()
    ReadResp = enum.auto()
    WriteResp = enum.auto()
    CleanEvict = enum.auto()        # flush without invalidate
    InvalidateReq = enum.auto()     # invalidate
    FlushReq = enum.auto()          # writeback-flush, line stays shared
    # CXL.mem transaction types added by the paper:
    M2SReq = enum.auto()
    M2SRwD = enum.auto()
    S2MDRS = enum.auto()
    S2MNDR = enum.auto()


class CXLCommand(enum.IntEnum):
    """Opcode field inside the flit header."""

    M2SReq = 0x1
    M2SRwD = 0x2
    S2MDRS = 0x3
    S2MNDR = 0x4


class MetaField(enum.IntEnum):
    """Which metadata the host is communicating about."""

    Meta0State = 0x0
    NoOp = 0x3


class MetaValue(enum.IntEnum):
    """Host cache-state hint carried in M2S messages (§II-B-3)."""

    Invalid = 0x0   # host holds no cacheable copy
    Any = 0x2       # host may hold shared/exclusive/modified copy
    Shared = 0x3    # host retains >=1 copy in shared state


class SnpType(enum.IntEnum):
    NoOp = 0x0
    SnpData = 0x1
    SnpCur = 0x2
    SnpInv = 0x3


@dataclass
class Packet:
    """gem5-style packet traversing MemBus/IOBus."""

    cmd: MemCmd
    addr: int
    size: int = CACHELINE_BYTES
    data: Optional[bytes] = None
    req_id: int = 0
    # set by the bridge when it converts the packet
    is_cxl: bool = False
    meta_value: MetaValue = MetaValue.Any
    # CXL poison: the device flagged the returned data as corrupt; the
    # flag rides the response flit (byte 15 bit 0) end-to-end and must
    # surface to the requester as status, never as fabricated latency
    poison: bool = False

    def is_read(self) -> bool:
        return self.cmd in (MemCmd.ReadReq, MemCmd.M2SReq)

    def is_write(self) -> bool:
        return self.cmd in (MemCmd.WriteReq, MemCmd.M2SRwD)


@dataclass
class CXLFlit:
    """A decoded CXL.mem flit."""

    opcode: CXLCommand
    addr: int
    tag: int
    meta_field: MetaField = MetaField.Meta0State
    meta_value: MetaValue = MetaValue.Any
    snp_type: SnpType = SnpType.NoOp
    length_blocks: int = 1          # logical blocks (for SSD-bound requests)
    poison: bool = False
    dirty_evict: bool = False
    data: bytes = field(default=b"", repr=False)

    @property
    def is_request(self) -> bool:
        return self.opcode in (CXLCommand.M2SReq, CXLCommand.M2SRwD)


_HEADER = struct.Struct("<BBBHQHB48s")
assert _HEADER.size == CXL_FLIT_BYTES, _HEADER.size


def encode_flit(flit: CXLFlit) -> bytes:
    """Pack a flit into its 64-byte wire format (header flit)."""
    if flit.addr % CACHELINE_BYTES and flit.opcode in (CXLCommand.M2SReq, CXLCommand.M2SRwD):
        raise ValueError(f"unaligned CXL.mem address: {flit.addr:#x}")
    if not 0 <= flit.tag < (1 << 16):
        raise ValueError(f"tag out of range: {flit.tag}")
    if not 0 <= flit.length_blocks < (1 << 16):
        raise ValueError(f"length_blocks out of range: {flit.length_blocks}")
    flags = (1 if flit.poison else 0) | ((1 if flit.dirty_evict else 0) << 1)
    inline = flit.data[:48].ljust(48, b"\x00")
    return _HEADER.pack(
        int(flit.opcode),
        (int(flit.meta_field) << 4) | int(flit.meta_value),
        int(flit.snp_type),
        flit.tag,
        flit.addr,
        flit.length_blocks,
        flags,
        inline,
    )


def decode_flit(raw: bytes, data: bytes = b"") -> CXLFlit:
    """Unpack a 64-byte header flit (optionally attaching full data slots)."""
    if len(raw) != CXL_FLIT_BYTES:
        raise ValueError(f"flit must be {CXL_FLIT_BYTES} bytes, got {len(raw)}")
    op, meta, snp, tag, addr, length, flags, inline = _HEADER.unpack(raw)
    if flags & ~0b11:
        # decode-side guard: only poison (bit0) and dirty-evict (bit1) are
        # defined — a set reserved bit means a corrupt or misframed flit
        raise ValueError(f"reserved flag bits set in flit header: {flags:#04x}")
    return CXLFlit(
        opcode=CXLCommand(op),
        addr=addr,
        tag=tag,
        meta_field=MetaField(meta >> 4),
        meta_value=MetaValue(meta & 0xF),
        snp_type=SnpType(snp),
        length_blocks=length,
        poison=bool(flags & 1),
        dirty_evict=bool(flags & 2),
        data=data if data else bytes(inline).rstrip(b"\x00"),
    )


def meta_value_for(cmd: MemCmd) -> MetaValue:
    """§II-B-3 conversion logic: derive MetaValue from the gem5 request.

    * If the packet does not invalidate or flush the line → ``Any``.
    * If it invalidates → ``Invalid``.
    * If it flushes without invalidating → ``Shared``.
    """
    if cmd in (MemCmd.InvalidateReq, MemCmd.CleanEvict):
        return MetaValue.Invalid
    if cmd is MemCmd.FlushReq:
        return MetaValue.Shared
    return MetaValue.Any


def packet_to_flit(pkt: Packet, tag: int) -> CXLFlit:
    """Bridge conversion: gem5 Packet → CXL.mem flit (§II-B-2).

    ReadReq → M2SReq; WriteReq → M2SRwD.  Other commands carry their
    coherence action in the MetaValue of an M2SReq (MemRdFwd-style).
    """
    mv = meta_value_for(pkt.cmd)
    nblocks = max(1, (pkt.size + CACHELINE_BYTES - 1) // CACHELINE_BYTES)
    if pkt.cmd is MemCmd.ReadReq:
        op = CXLCommand.M2SReq
        data = b""
    elif pkt.cmd is MemCmd.WriteReq:
        op = CXLCommand.M2SRwD
        data = pkt.data or b"\x00" * pkt.size
    elif pkt.cmd in (MemCmd.InvalidateReq, MemCmd.FlushReq, MemCmd.CleanEvict):
        op = CXLCommand.M2SReq
        data = b""
    else:
        raise ValueError(f"unconvertible command reaches the bridge: {pkt.cmd}")
    return CXLFlit(
        opcode=op,
        addr=pkt.addr - (pkt.addr % CACHELINE_BYTES),
        tag=tag & 0xFFFF,
        meta_value=mv,
        length_blocks=nblocks,
        data=data,
    )


def flit_to_response_packet(flit: CXLFlit, req: Packet) -> Packet:
    """Device response flit → gem5 response packet.  The poison flag the
    device set on the flit propagates to the packet, so the requester sees
    corrupt data as *status* (this used to be dropped here — the flit codec
    packed poison but no consumer ever read it)."""
    if flit.opcode is CXLCommand.S2MDRS:
        return Packet(cmd=MemCmd.ReadResp, addr=req.addr, size=req.size,
                      data=flit.data, req_id=req.req_id, is_cxl=True,
                      poison=flit.poison)
    if flit.opcode is CXLCommand.S2MNDR:
        return Packet(cmd=MemCmd.WriteResp, addr=req.addr, size=req.size,
                      req_id=req.req_id, is_cxl=True, poison=flit.poison)
    raise ValueError(f"not a response flit: {flit.opcode}")
