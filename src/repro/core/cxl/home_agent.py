"""Home Agent: the gem5 ``Bridge`` analogue between MemBus and IOBus.

Responsibilities (paper §II-B):

* address-to-port mapping — decide whether a packet targets local memory or
  a CXL range;
* packet-format conversion — gem5 ``Packet`` → CXL flit for CXL-bound
  requests (``ReadReq``→``M2SReq``, ``WriteReq``→``M2SRwD``), warning on any
  other command;
* coherence-field handling — ``MetaValue`` from the request semantics;
* latency accounting — the CXL.mem protocol-handling latency (25 ns) is
  charged in the Home Agent event loop before forwarding; the full
  CXL network traversal is 50 ns round trip (Table I, validated against the
  authors' FPGA prototype).
"""

from __future__ import annotations

import itertools
import logging
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

from repro.core.cxl.flit import (
    CXLCommand,
    CXLFlit,
    MemCmd,
    Packet,
    decode_flit,
    encode_flit,
    flit_to_response_packet,
    packet_to_flit,
)
from repro.core.engine import EventEngine, ns

log = logging.getLogger(__name__)

# Table I / §III-A constants.
CXL_PROTOCOL_NS = 25.0        # sub-protocol processing per direction
CXL_NETWORK_RT_NS = 50.0      # total CXL.mem network round-trip latency


@dataclass(frozen=True)
class AddressRange:
    base: int
    size: int

    def contains(self, addr: int) -> bool:
        return self.base <= addr < self.base + self.size

    @property
    def end(self) -> int:
        return self.base + self.size


class HomeAgent:
    """Routes packets; converts CXL-bound ones to flits and charges latency."""

    def __init__(self, engine: EventEngine) -> None:
        self.engine = engine
        self._ports: list[Tuple[AddressRange, object, bool]] = []  # (range, device, is_cxl)
        self._tags = itertools.count()
        self._inflight: Dict[int, Tuple[Packet, Callable[[Packet], None]]] = {}
        self.stats = {
            "pkts_routed": 0,
            "pkts_converted": 0,
            "flit_bytes_m2s": 0,
            "flit_bytes_s2m": 0,
            "warnings": 0,
        }

    # ------------------------------------------------------------- topology
    def attach(self, rng: AddressRange, device: object, is_cxl: bool) -> None:
        for existing, _, _ in self._ports:
            if rng.base < existing.end and existing.base < rng.end:
                raise ValueError(f"overlapping address ranges: {rng} vs {existing}")
        self._ports.append((rng, device, is_cxl))

    def route(self, addr: int) -> Optional[Tuple[AddressRange, object, bool]]:
        for rng, dev, is_cxl in self._ports:
            if rng.contains(addr):
                return rng, dev, is_cxl
        return None

    # ------------------------------------------------------------- requests
    def send(self, pkt: Packet, on_response: Callable[[Packet], None]) -> None:
        """Issue a packet; ``on_response`` fires when the device responds."""
        port = self.route(pkt.addr)
        if port is None:
            raise ValueError(f"address {pkt.addr:#x} maps to no device")
        rng, dev, is_cxl = port
        self.stats["pkts_routed"] += 1

        if not is_cxl:
            # Local path: no conversion (paper: "If not, no packet format
            # conversion occurs").
            dev.access(pkt, on_response)
            return

        if pkt.cmd not in (MemCmd.ReadReq, MemCmd.WriteReq, MemCmd.InvalidateReq,
                           MemCmd.FlushReq, MemCmd.CleanEvict):
            # Paper: "Other requests trigger a warning."
            self.stats["warnings"] += 1
            log.warning("HomeAgent: unconvertible command %s at %#x", pkt.cmd, pkt.addr)
            return

        tag = next(self._tags) & 0xFFFF
        flit = packet_to_flit(pkt, tag)
        wire = encode_flit(flit)  # exercises the wire format
        self.stats["pkts_converted"] += 1
        self.stats["flit_bytes_m2s"] += len(wire) * max(1, flit.length_blocks if flit.opcode is CXLCommand.M2SRwD else 1)
        self._inflight[tag] = (pkt, on_response)
        pkt.is_cxl = True
        pkt.meta_value = flit.meta_value

        # Charge protocol handling in the Home Agent event loop *before*
        # forwarding (paper §II-B-2).  The 25 ns protocol cost is part of the
        # 50 ns total CXL.mem network round trip (Table I): 25 ns on the M2S
        # path here, 25 ns on the S2M path in the responder.
        def forward() -> None:
            dev.access_flit(decode_flit(wire, data=flit.data), self._make_responder(tag))

        self.engine.schedule(ns(CXL_NETWORK_RT_NS / 2), forward)

    def _make_responder(self, tag: int) -> Callable[[CXLFlit], None]:
        def respond(resp_flit: CXLFlit) -> None:
            pkt, cb = self._inflight.pop(tag)
            self.stats["flit_bytes_s2m"] += 64 * (
                resp_flit.length_blocks if resp_flit.opcode is CXLCommand.S2MDRS else 1)
            # Return half of the network round trip on the S2M path.
            def deliver() -> None:
                cb(flit_to_response_packet(resp_flit, pkt))
            self.engine.schedule(ns(CXL_NETWORK_RT_NS / 2), deliver)
        return respond
