from repro.core.cxl.flit import (
    CXL_FLIT_BYTES,
    CXLCommand,
    CXLFlit,
    MemCmd,
    MetaField,
    MetaValue,
    Packet,
    SnpType,
    decode_flit,
    encode_flit,
    packet_to_flit,
    flit_to_response_packet,
)
from repro.core.cxl.home_agent import AddressRange, HomeAgent

__all__ = [
    "CXL_FLIT_BYTES",
    "CXLCommand",
    "CXLFlit",
    "MemCmd",
    "MetaField",
    "MetaValue",
    "Packet",
    "SnpType",
    "decode_flit",
    "encode_flit",
    "packet_to_flit",
    "flit_to_response_packet",
    "AddressRange",
    "HomeAgent",
]
