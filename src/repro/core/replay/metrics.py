"""In-scan telemetry for the fused replay engines (and the python twin).

The interpreted drivers keep rich component stats — ``DRAMCache.stats``,
``FTL.stats``, ``Fabric.port_report`` — that the fused lanes silently
dropped: a compiled replay returned latency arrays and nothing else.  This
module defines the telemetry layer both paths emit in ONE schema, so a
fused run is *exactly* as observable as the interpreted run it mirrors:

* **latency histograms** — HDR-style log buckets (4 sub-buckets per
  octave), accumulated inside the scan per host AND per device, with
  exact nearest-rank percentile extraction (``p50/p95/p99``) over the
  bucket counts;
* **component counters** — the python stats dicts, counter for counter:
  cache hits/misses/MSHR coalesces/stalls/fills/writebacks/evictions,
  page-register buffer hits and flash read/RMW/flush amplification, FTL
  host vs GC writes/erases/runs (write amplification), per-port
  bytes/packets/occupancy/queueing, QoS throttle events, ECMP path
  choice counts;
* **tick-windowed time series** — bytes, latency sum, access count and
  hits per fixed tick window per host, so bursts are visible without
  materializing per-access output.

Parity is the contract: :func:`collect_python` builds the bundle from the
interpreted objects, the fused assemblers from the scan outputs, and the
golden suite pins that the two are equal on every scenario.  The fused
side has two collection modes.  With per-access outputs
(``return_latencies=True``) the scan carries only the per-port queueing
scalars and packs each media event into the flags column
(:data:`FLAG_EVENT_BITS`); the histogram/window fold and counter vector
are then pure functions of the materialized arrays, deferred to first
bundle access — replay-time overhead is a few percent.  In streaming mode
(``return_latencies=False``) there are no per-access outputs, so the scan
carries the whole layer: ONE scatter-add into a combined ``(rows, 4)``
accumulator plus one counter-vector add per access — O(buckets+windows)
state for a trace of any length.  Per-port byte/packet/occupancy totals
are pure functions of the precomputed route choices either way, so they
are reconstructed host-side with numpy at zero scan cost.

Histogram bucketing (shared by the numpy and jnp twins, property-tested
equal): values below 8 index themselves (exact small-latency buckets);
otherwise with ``e = bit_length(v) - 1`` the index is
``4*e + ((v >> (e-2)) & 3) - 4`` — four linear sub-buckets per power of
two, continuous across octave boundaries.  The numpy twin derives ``e``
via ``frexp`` (exact below 2^53), so ``hist_buckets`` is capped at 208
(indices above that are only reachable past 2^53 ticks ~ 100 days of
simulated time).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import to_ns
from repro.core.fabric.fabric import LINE_BYTES, FabricAttachedDevice
from repro.core.fabric.pool import HostPortView
from repro.core.replay.spec import DRAM, PMEM, SSD_BUF, SSD_CACHE

# Counter schema per media kind — names match the python stats dicts they
# mirror (DRAMCache.stats + policy counters, CXLSSDDevice.stats,
# PMEMDevice.stats).  Order is the fused counter-vector layout.
MEDIA_COUNTERS: Dict[str, Tuple[str, ...]] = {
    DRAM: ("accesses", "reads", "writes"),
    PMEM: ("accesses", "reads", "writes", "row_hits"),
    SSD_BUF: ("accesses", "reads", "writes", "buf_hits",
              "flash_reads", "rmw_fills", "flash_writes"),
    SSD_CACHE: ("accesses", "reads", "writes", "hits", "misses",
                "mshr_coalesced", "mshr_stalls", "fills", "writebacks",
                "evictions", "dirty_evictions"),
}

# FTL.stats, key for key (per flash instance / HIL)
FLASH_COUNTERS = ("host_reads", "host_writes", "gc_writes", "gc_erases",
                  "gc_runs")

# Fault/degradation counters — emitted ONLY when an active
# :class:`~repro.core.faults.FaultPlan` is installed, so fault-free runs
# (and the committed golden pins) keep their exact byte-for-byte schema.
FAULT_COUNTERS = ("link_retries", "failovers", "degraded_accesses",
                  "nand_read_retries", "retired_blocks", "poisoned_reads")

# per-kind "hit" counter used by MetricsBundle.hit_rate
_HIT_KEYS = ("hits", "buf_hits", "row_hits")

MAX_HIST_BUCKETS = 208   # numpy frexp stays exact below 2^53 (see module doc)


@dataclass(frozen=True)
class MetricsSpec:
    """Static (hashable) shape of the telemetry carry.

    ``hist_buckets`` log-latency buckets; time series of ``num_windows``
    windows of ``window_ticks`` ticks each (completions past the last
    window clamp into it, so nothing is dropped)."""

    hist_buckets: int = 128
    window_ticks: int = 1_000_000      # 1 us at 1 tick = 1 ps
    num_windows: int = 64

    def __post_init__(self) -> None:
        if not 8 <= self.hist_buckets <= MAX_HIST_BUCKETS:
            raise ValueError(
                f"hist_buckets must be in [8, {MAX_HIST_BUCKETS}], got "
                f"{self.hist_buckets}")
        if self.window_ticks < 1 or self.num_windows < 1:
            raise ValueError("window_ticks and num_windows must be >= 1")


# ------------------------------------------------------------- bucketing
def bucket_index(lat, num_buckets: int) -> np.ndarray:
    """numpy log-bucket index (vectorized); see the module docstring."""
    v = np.maximum(np.asarray(lat, np.int64), 0)
    vv = np.maximum(v, 1)
    _, ex = np.frexp(vv.astype(np.float64))
    e = ex.astype(np.int64) - 1                      # bit_length(v) - 1
    sub = (vv >> np.maximum(e - 2, 0).astype(np.int64)) & 3
    idx = np.where(v < 8, v, 4 * e + sub - 4)
    return np.minimum(idx, num_buckets - 1).astype(np.int64)


def bucket_index_jnp(lat, num_buckets: int):
    """jnp twin of :func:`bucket_index` (``clz``-based, exact at any
    int64)."""
    import jax
    import jax.numpy as jnp

    v = jnp.maximum(jnp.asarray(lat, jnp.int64), 0)
    vv = jnp.maximum(v, 1)
    e = 63 - jax.lax.clz(vv)
    sub = (vv >> jnp.maximum(e - 2, 0)) & 3
    idx = jnp.where(v < 8, v, 4 * e + sub - 4)
    return jnp.minimum(idx, num_buckets - 1)


def bucket_bounds(idx: int) -> Tuple[int, int]:
    """Inclusive ``(lo, hi)`` tick range of bucket ``idx`` (the top bucket
    of a spec additionally absorbs everything above its ``hi``)."""
    idx = int(idx)
    if idx < 8:
        return idx, idx
    e = (idx + 4) // 4
    sub = (idx + 4) % 4
    lo = (1 << e) + sub * (1 << (e - 2))
    return lo, lo + (1 << (e - 2)) - 1


def percentile_from_hist(hist: np.ndarray, q: float) -> Optional[Dict]:
    """Nearest-rank percentile over bucket counts: the bucket holding the
    ``ceil(q/100 * n)``-th smallest sample, as ``{bucket, lo, hi, rank,
    n}``; ``None`` on an empty histogram.  The true sample at that rank is
    guaranteed to lie in ``[lo, hi]`` (validated against
    ``numpy.percentile``'s inverted-CDF method in the tests)."""
    hist = np.asarray(hist, np.int64)
    n = int(hist.sum())
    if n == 0:
        return None
    k = max(1, int(math.ceil(q / 100.0 * n)))
    idx = int(np.searchsorted(np.cumsum(hist), k))
    lo, hi = bucket_bounds(idx)
    return {"bucket": idx, "lo": lo, "hi": hi, "rank": k, "n": n}


# ------------------------------------------------------ in-scan primitives
def acc_rows(spec: MetricsSpec, n_hosts: int, n_devs: int) -> int:
    """Row count of the combined scatter accumulator: per-host histogram +
    windows, plus a per-device histogram block when devices != hosts."""
    rows = n_hosts * (spec.hist_buckets + spec.num_windows)
    if n_devs > 1:
        rows += n_devs * spec.hist_buckets
    return rows


def acc_update(spec: MetricsSpec, acc, *, host, dev, n_hosts: int,
               n_devs: int, issue, done, size: int, hit, valid=None):
    """One access into the combined accumulator: histogram bucket row
    ``[1,0,0,0]`` and window row ``[bytes, latency, 1, hit]`` (plus the
    device-histogram row when tracked) in a single scatter-add."""
    import jax.numpy as jnp

    NB, W = spec.hist_buckets, spec.num_windows
    lat = done - issue
    b = bucket_index_jnp(lat, NB)
    wdx = jnp.clip(done // spec.window_ticks, 0, W - 1)
    one = jnp.asarray(1, jnp.int64)
    zero = jnp.asarray(0, jnp.int64)
    hrow = jnp.stack([one, zero, zero, zero])
    wrow = jnp.stack([jnp.asarray(size, jnp.int64), lat, one,
                      jnp.where(hit, one, zero)])
    base = host * (NB + W)
    ids = [base + b, base + NB + wdx]
    vals = [hrow, wrow]
    if n_devs > 1:
        ids.append(n_hosts * (NB + W) + dev * NB + b)
        vals.append(hrow)
    rows = jnp.stack(vals)
    if valid is not None:
        rows = rows * jnp.where(valid, one, zero)
    return acc.at[jnp.stack(ids)].add(rows)


def fold_arrays(spec: MetricsSpec, issues, dones, hits, size: int):
    """Single-host ``(hist, windows, dev_hist)`` from materialized
    per-access arrays — the numpy twin of repeated :func:`acc_update`,
    identical integers by construction.  When the scan already emits
    ``(issue, done, flags)`` per access (``return_latencies=True``) the
    histogram/window fold runs here, off the replay hot path (deferred to
    first bundle access); the in-scan scatter is only carried in streaming
    mode, where there are no per-access outputs to fold."""
    NB, W = spec.hist_buckets, spec.num_windows
    issues = np.asarray(issues, np.int64)
    dones = np.asarray(dones, np.int64)
    lat = dones - issues
    b = bucket_index(lat, NB)
    hist = np.bincount(b, minlength=NB).astype(np.int64)[None]
    wdx = np.clip(dones // spec.window_ticks, 0, W - 1)
    cnt = np.bincount(wdx, minlength=W).astype(np.int64)
    windows = np.zeros((1, W, 4), np.int64)
    windows[0, :, 0] = cnt * size
    np.add.at(windows[0, :, 1], wdx, lat)
    windows[0, :, 2] = cnt
    np.add.at(windows[0, :, 3], wdx, np.asarray(hits, np.int64))
    return hist, windows, hist.copy()


# Event booleans the scan packs into the per-access flags word when metrics
# are enabled with per-access outputs (``return_latencies=True``): every
# MEDIA_COUNTERS column is then a pure function of (writes, flags), so the
# counter vector needs no carry at all.  Bits 0/1 are the public hit/evict
# bits the engine always emits.
FLAG_EVENT_BITS: Dict[str, Tuple[Tuple[int, str], ...]] = {
    DRAM: (),
    PMEM: (),
    SSD_BUF: ((2, "fill"),),
    SSD_CACHE: ((2, "miss"), (3, "coalesce"), (4, "stall"),
                (5, "eviction")),
}


def media_from_flags(kind: str, writes, flags) -> np.ndarray:
    """:data:`MEDIA_COUNTERS`\\ [kind] vector from the input write column
    and the scan's (event-bit-widened) flags word — the deferred twin of
    summing :func:`media_increments` over the trace."""
    flags = np.asarray(flags)
    wr = np.asarray(writes, bool)
    n = int(flags.size)
    w = int(wr.sum())

    def cnt(bit: int) -> int:
        return int(((flags >> bit) & 1).sum())

    if kind == DRAM:
        cols = [n, n - w, w]
    elif kind == PMEM:
        cols = [n, n - w, w, cnt(0)]
    elif kind == SSD_BUF:
        fill = ((flags >> 2) & 1).astype(bool)
        cols = [n, n - w, w, cnt(0), int((fill & ~wr).sum()),
                int((fill & wr).sum()), cnt(1)]
    elif kind == SSD_CACHE:
        miss = cnt(2)
        cols = [n, n - w, w, cnt(0), miss, cnt(3), cnt(4), miss, cnt(1),
                cnt(5), cnt(1)]
    else:
        raise ValueError(kind)
    return np.asarray(cols, np.int64)


def split_acc(spec: MetricsSpec, acc, n_hosts: int, n_devs: int):
    """Decode the combined accumulator into ``(hist (H,NB), windows
    (H,W,4), dev_hist (D,NB))`` numpy arrays."""
    NB, W = spec.hist_buckets, spec.num_windows
    acc = np.asarray(acc)
    per = acc[:n_hosts * (NB + W)].reshape(n_hosts, NB + W, 4)
    hist = per[:, :NB, 0].copy()
    windows = per[:, NB:, :].copy()
    if n_devs > 1:
        dev_hist = acc[n_hosts * (NB + W):].reshape(n_devs, NB, 4)[:, :, 0]
        dev_hist = dev_hist.copy()
    else:
        dev_hist = hist.sum(axis=0, keepdims=True)
    return hist, windows, dev_hist


def media_increments(kind: str, wr, out):
    """Per-access increment vector for :data:`MEDIA_COUNTERS`\\ [kind],
    from the stack step's extras dict — one fused elementwise add."""
    import jax.numpy as jnp

    one = jnp.asarray(1, jnp.int64)
    zero = jnp.asarray(0, jnp.int64)

    def b(x):
        return jnp.where(x, one, zero)

    rd, wrt = b(~wr), b(wr)
    if kind == DRAM:
        cols = [one, rd, wrt]
    elif kind == PMEM:
        cols = [one, rd, wrt, b(out["hit"])]
    elif kind == SSD_BUF:
        fill = out["fill"]
        cols = [one, rd, wrt, b(out["hit"]), b(fill & ~wr), b(fill & wr),
                b(out["evict"])]
    elif kind == SSD_CACHE:
        miss = out["miss"]
        cols = [one, rd, wrt, b(out["hit"]), b(miss), b(out["coalesce"]),
                b(out["stall"]), b(miss), b(out["evict"]),
                b(out["eviction"]), b(out["evict"])]
    else:
        raise ValueError(kind)
    return jnp.stack(cols)


# --------------------------------------------------------------- the bundle
class MetricsBundle:
    """One run's telemetry, schema-identical between the python driver and
    the fused lanes (integers only, so golden pins compare exactly).

    ``hist (H, hist_buckets)``, ``dev_hist (D, hist_buckets)`` and
    ``windows (H, num_windows, 4)`` (bytes/lat/n/hits) are int64 arrays;
    ``media`` / ``flash`` are per-device / per-flash counter dicts.  Either
    pass them eagerly, or pass ``deferred`` — a zero-arg callable returning
    ``(hist, windows, dev_hist, media)`` — and the fold runs once on first
    access, off the replay hot path (the fused engine defers the O(N)
    histogram/window/counter fold out of ``run_arrays`` this way)."""

    def __init__(self, *, spec: MetricsSpec, hosts: Sequence[str],
                 devices: Sequence[str], hist: Optional[np.ndarray] = None,
                 dev_hist: Optional[np.ndarray] = None,
                 windows: Optional[np.ndarray] = None,
                 media: Optional[List[Dict[str, int]]] = None,
                 flash: Optional[List[Dict[str, int]]] = None,
                 ports: Optional[Dict[str, Dict]] = None,
                 ecmp: Optional[Dict[str, List[int]]] = None,
                 deferred: Optional[Callable] = None,
                 faults: Optional[Dict[str, int]] = None) -> None:
        if deferred is None and (hist is None or dev_hist is None
                                 or windows is None or media is None):
            raise ValueError(
                "MetricsBundle needs hist/dev_hist/windows/media, or a "
                "deferred fold producing them")
        self.spec = spec
        self.hosts = list(hosts)
        self.devices = list(devices)
        self.flash = flash if flash is not None else []
        self.ports = ports if ports is not None else {}
        self.ecmp = ecmp if ecmp is not None else {}
        # FAULT_COUNTERS dict when a fault plan was active; None otherwise
        # (kept out of to_jsonable when None — schema stability)
        self.faults = faults
        self._hist = hist
        self._dev_hist = dev_hist
        self._windows = windows
        self._media = media
        self._deferred = deferred

    def _force(self) -> None:
        if self._deferred is not None:
            (self._hist, self._windows, self._dev_hist,
             self._media) = self._deferred()
            self._deferred = None

    @property
    def hist(self) -> np.ndarray:
        self._force()
        return self._hist

    @property
    def dev_hist(self) -> np.ndarray:
        self._force()
        return self._dev_hist

    @property
    def windows(self) -> np.ndarray:
        self._force()
        return self._windows

    @property
    def media(self) -> List[Dict[str, int]]:
        self._force()
        return self._media

    # ------------------------------------------------------------ analysis
    def percentile(self, q: float, host: Optional[int] = None,
                   device: Optional[int] = None) -> Optional[Dict]:
        """Nearest-rank percentile over one host's, one device's, or the
        aggregate histogram; ``None`` when empty."""
        if host is not None:
            h = self.hist[host]
        elif device is not None:
            h = self.dev_hist[device]
        else:
            h = self.hist.sum(axis=0)
        return percentile_from_hist(h, q)

    def percentile_ticks(self, q: float, host: Optional[int] = None,
                         device: Optional[int] = None) -> Optional[int]:
        """The percentile bucket's upper edge in ticks (conservative)."""
        p = self.percentile(q, host=host, device=device)
        return None if p is None else int(p["hi"])

    def percentile_ns(self, q: float, host: Optional[int] = None,
                      device: Optional[int] = None) -> Optional[float]:
        t = self.percentile_ticks(q, host=host, device=device)
        return None if t is None else to_ns(t)

    @property
    def accesses(self) -> int:
        return int(sum(m.get("accesses", 0) for m in self.media))

    @property
    def hit_rate(self) -> float:
        """Hits over accesses, summed over devices — using each media
        kind's own hit counter (cache hits / buffer hits / row hits);
        0.0 for hit-less media or an empty run."""
        acc = self.accesses
        hits = 0
        for m in self.media:
            for key in _HIT_KEYS:
                if key in m:
                    hits += m[key]
                    break
        return hits / acc if acc else 0.0

    @property
    def write_amplification(self) -> float:
        """``(host + GC writes) / host writes`` over every flash instance
        (1.0 with no flash or no host writes, like
        :meth:`FTL.write_amplification`)."""
        hw = sum(f["host_writes"] for f in self.flash)
        gw = sum(f["gc_writes"] for f in self.flash)
        return (hw + gw) / hw if hw else 1.0

    # ---------------------------------------------------------- export
    def to_jsonable(self) -> Dict:
        """Deterministic, integers-only JSON form.  Histograms and windows
        are sparse ``{index: value}`` maps so golden pins stay compact;
        ``p50/p95/p99`` per host are included for readability (derived
        from the histogram, so parity follows from histogram parity)."""
        def sparse_hist(row):
            return {str(i): int(v) for i, v in enumerate(row) if v}

        def sparse_windows(rows):
            return {str(w): [int(x) for x in r]
                    for w, r in enumerate(rows) if any(r)}

        def pcts(row):
            out = {}
            for q in (50, 95, 99):
                p = percentile_from_hist(row, q)
                out[f"p{q}"] = None if p is None else int(p["hi"])
            return out

        out = {
            "spec": {"hist_buckets": self.spec.hist_buckets,
                     "window_ticks": self.spec.window_ticks,
                     "num_windows": self.spec.num_windows},
            "hosts": list(self.hosts),
            "devices": list(self.devices),
            "hist": [sparse_hist(r) for r in self.hist],
            "dev_hist": [sparse_hist(r) for r in self.dev_hist],
            "windows": [sparse_windows(r) for r in self.windows],
            "percentiles": [pcts(r) for r in self.hist],
            "media": [{k: int(v) for k, v in m.items()} for m in self.media],
            "flash": [{k: int(v) for k, v in f.items()} for f in self.flash],
            "ports": {k: dict(v) for k, v in sorted(self.ports.items())},
            "ecmp": {k: list(v) for k, v in sorted(self.ecmp.items())},
        }
        if self.faults is not None:
            out["faults"] = {k: int(self.faults[k]) for k in FAULT_COUNTERS}
        return out


# ------------------------------------------------------- python collection
def _media_hits(dev) -> int:
    if hasattr(dev, "cache"):
        return int(dev.cache.policy.hits)
    s = getattr(dev, "stats", {})
    for key in ("buf_hits", "row_hits"):
        if key in s:
            return int(s[key])
    return 0


def media_counters_of(dev) -> Dict[str, int]:
    """One device's :data:`MEDIA_COUNTERS` dict from its live stats."""
    if hasattr(dev, "cache"):
        c, pol = dev.cache.stats, dev.cache.policy
        return {"accesses": c["accesses"], "reads": c["reads"],
                "writes": c["writes"], "hits": pol.hits,
                "misses": pol.misses,
                "mshr_coalesced": c["mshr_coalesced"],
                "mshr_stalls": c["mshr_stalls"], "fills": c["fills"],
                "writebacks": c["writebacks"], "evictions": pol.evictions,
                "dirty_evictions": pol.dirty_evictions}
    s = dev.stats
    out = {"accesses": s["reads"] + s["writes"], "reads": s["reads"],
           "writes": s["writes"]}
    if "buf_hits" in s:
        out.update(buf_hits=s["buf_hits"], flash_reads=s["flash_reads"],
                   rmw_fills=s["rmw_fills"], flash_writes=s["flash_writes"])
    elif "row_hits" in s:
        out["row_hits"] = s["row_hits"]
    return {k: int(v) for k, v in out.items()}


def flash_counters_of(hil) -> Dict[str, int]:
    return {k: int(hil.ftl.stats[k]) for k in FLASH_COUNTERS}


def fault_counters_of(targets: Sequence, poisoned: int = 0
                      ) -> Optional[Dict[str, int]]:
    """:data:`FAULT_COUNTERS` dict from the interpreted objects, or
    ``None`` when no *active* fault plan is installed anywhere in the
    target stack — the bundle (and every committed golden pin) is
    byte-identical on fault-free runs.  ``poisoned`` is the driver-side
    poisoned-read count (the plan flags reads corrupt at issue ordinal;
    the analytic path has no flits to carry the bit)."""
    plan = next((p for p in (getattr(t, "fault_plan", None) for t in targets)
                 if p is not None and p.active), None)
    _, _, devices, fabric, _ = _target_layout(targets)
    if plan is None and fabric is not None:
        fp = getattr(fabric, "fault_plan", None)
        if fp is not None and fp.active:
            plan = fp
    if plan is None:
        return None
    stats = (fabric.fault_stats if fabric is not None
             else {"link_retries": 0, "failovers": 0,
                   "degraded_accesses": 0})
    hils = _unique_hils(devices)
    return {
        "link_retries": int(stats["link_retries"]),
        "failovers": int(stats["failovers"]),
        "degraded_accesses": int(stats["degraded_accesses"]),
        "nand_read_retries": sum(int(h.ftl.pal.stats["read_retries"])
                                 for h in hils),
        "retired_blocks": sum(len(h.ftl.retired_blocks) for h in hils),
        "poisoned_reads": int(poisoned),
    }


def _unique_hils(devices: Sequence) -> List:
    """Flash instances in first-appearance order — the same dedupe order
    the fused :func:`~repro.core.replay.multihost._media_setup` uses."""
    seen: Dict[int, object] = {}
    for d in devices:
        hil = getattr(d, "hil", None)
        if hil is not None:
            seen.setdefault(id(hil), hil)
    return list(seen.values())


def _ports_of(fabric) -> Dict[str, Dict]:
    """Integer port counters keyed ``"u->v"`` — :meth:`Fabric.port_report`
    minus the float derivations, same packets>0 filter."""
    out = {}
    for key in sorted(fabric.ports):
        p = fabric.ports[key]
        if not p.packets:
            continue
        out[f"{p.src}->{p.dst}"] = {
            "bytes": int(p.bytes),
            "packets": int(p.packets),
            "occupied_ticks": int(p.occupied_ticks),
            "queued_ticks": int(p.queued_ticks),
            "qos_throttle_events": int(
                getattr(p, "qos_throttle_events", 0)),
            "bytes_by_host": {h: int(b) for h, b in
                              sorted(p.bytes_by_origin.items())},
        }
    return out


def _target_layout(targets: Sequence):
    """(hosts, device labels, device objects, fabric|None, dev_of fns) for
    a homogeneous target list — mirrors the fused engines' labeling, and
    degrades gracefully for plain (fabric-less) devices."""
    first = targets[0]
    if isinstance(first, HostPortView):
        pool = first.pool
        hosts = [t.host for t in targets]
        labels = list(pool.device_nodes)
        devices = list(pool.devices)
        mapper = pool.mapper

        def dev_of(_i):
            return lambda addr: mapper.map(addr)[0]

        return (hosts, labels, devices, pool.fabric,
                [dev_of(i) for i in range(len(targets))])
    if isinstance(first, FabricAttachedDevice):
        hosts = [t.host for t in targets]
        labels = [t.device_node for t in targets]
        devices = [t.inner for t in targets]
        return (hosts, labels, devices, first.fabric,
                [(lambda i: (lambda addr: i))(i)
                 for i in range(len(targets))])
    hosts = [f"host{i}" for i in range(len(targets))]
    if len(targets) == 1:
        hosts = ["host0"]
    labels = [t.name for t in targets]
    return (hosts, labels, list(targets), None,
            [(lambda i: (lambda addr: i))(i) for i in range(len(targets))])


class MetricTap:
    """Wrap one host target, recording per-access ``(issue, done, size,
    device, hit-delta)`` — the python side of histogram/window parity —
    without touching timing."""

    def __init__(self, target, dev_of: Callable[[int], int],
                 hit_count: Callable[[], int]) -> None:
        self._dev = target
        self._dev_of = dev_of
        self._hits = hit_count
        self.records: List[Tuple[int, int, int, int, int]] = []

    def __getattr__(self, name):
        return getattr(self._dev, name)

    def service(self, now, addr, size, write, posted=False):
        h0 = self._hits()
        done = self._dev.service(now, addr, size, write, posted)
        self.records.append((int(now), int(done), int(size),
                             int(self._dev_of(addr)), self._hits() - h0))
        return done


def attach_taps(targets: Sequence) -> List[MetricTap]:
    """One :class:`MetricTap` per host target; run the (python) driver over
    the taps, then hand targets+taps to :func:`collect_python`."""
    _, _, devices, _, dev_fns = _target_layout(targets)

    def hit_count():
        return sum(_media_hits(d) for d in devices)

    return [MetricTap(t, fn, hit_count)
            for t, fn in zip(targets, dev_fns)]


def collect_python(spec: MetricsSpec, targets: Sequence,
                   taps: Sequence[MetricTap],
                   poisoned: int = 0) -> MetricsBundle:
    """Build the bundle from an interpreted run: tap records give the
    histograms/windows, the live stats dicts give every counter."""
    hosts, labels, devices, fabric, _ = _target_layout(targets)
    NB, W, T = spec.hist_buckets, spec.num_windows, spec.window_ticks
    H, D = len(hosts), len(labels)
    hist = np.zeros((H, NB), np.int64)
    dev_hist = np.zeros((D, NB), np.int64)
    windows = np.zeros((H, W, 4), np.int64)
    for i, tap in enumerate(taps):
        for issue, done, size, dev, hit in tap.records:
            b = int(bucket_index(done - issue, NB))
            hist[i, b] += 1
            dev_hist[dev, b] += 1
            w = min(max(done // T, 0), W - 1)
            windows[i, w] += (size, done - issue, 1, hit)
    bundle = MetricsBundle(
        spec=spec, hosts=hosts, devices=labels, hist=hist,
        dev_hist=dev_hist, windows=windows,
        media=[media_counters_of(d) for d in devices],
        flash=[flash_counters_of(h) for h in _unique_hils(devices)],
        ports=_ports_of(fabric) if fabric is not None else {},
        ecmp={k: list(v) for k, v in
              sorted(getattr(fabric, "ecmp_counts", {}).items())}
        if fabric is not None else {},
        faults=fault_counters_of(targets, poisoned),
    )
    return bundle


# ------------------------------------------------------- fused collection
def _flash_dicts(flash_cnt) -> List[Dict[str, int]]:
    if flash_cnt is None:
        return []
    return [dict(zip(FLASH_COUNTERS, (int(x) for x in row)))
            for row in np.asarray(flash_cnt)]


def _single_ports(device, queued, addrs: Optional[np.ndarray],
                  routes: Optional[np.ndarray], size: int, faulted=None,
                  qthr=None, n_accesses: Optional[int] = None,
                  route_counts: Optional[np.ndarray] = None):
    """``(host_label, dev_label, ports, ecmp)`` for a single-host fused
    run: port byte/packet/occupancy totals and ECMP choice counts are
    reconstructed from the route choices host-side (pure functions of the
    trace — exact, zero scan cost); ``queued`` is the per-port in-scan
    queueing accumulator and ``qthr`` its QoS-throttle twin (carried only
    on weighted mounts; ``None`` reads as all-zero, matching FCFS ports
    whose interpreted counter never moves).  ``faulted`` (from the
    engine's fault-lane precompute) overrides the clean reconstruction
    when transport faults rerouted accesses or charged retry
    serializations.  Streamed runs that never materialize the trace pass
    ``n_accesses``/``route_counts`` instead of ``addrs``/``routes``."""
    n = (int(n_accesses) if n_accesses is not None
         else int(np.asarray(addrs).size))
    ports: Dict[str, Dict] = {}
    ecmp: Dict[str, List[int]] = {}
    if isinstance(device, FabricAttachedDevice):
        fab, host, node = device.fabric, device.host, device.device_node
        queued = [int(q) for q in np.asarray(queued).reshape(-1)]
        qt = ([int(x) for x in np.asarray(qthr).reshape(-1)]
              if qthr is not None else None)
        if faulted is not None:
            for j, key in enumerate(faulted["port_keys"]):
                if not faulted["packets"][j]:
                    continue
                ports[f"{key[0]}->{key[1]}"] = {
                    "bytes": int(faulted["bytes"][j]),
                    "packets": int(faulted["packets"][j]),
                    "occupied_ticks": int(faulted["occupied"][j]),
                    "queued_ticks": queued[j],
                    "qos_throttle_events": qt[j] if qt is not None else 0,
                    "bytes_by_host": {host: int(faulted["bytes"][j])}}
            ecmp = {k: list(v) for k, v in sorted(faulted["ecmp"].items())}
        elif routes is None and route_counts is None:
            for h, (key, occ, _aft) in enumerate(
                    fab.route_occupancy(host, node, size)):
                ports[f"{key[0]}->{key[1]}"] = {
                    "bytes": n * size, "packets": n,
                    "occupied_ticks": n * int(occ),
                    "queued_ticks": queued[h],
                    "qos_throttle_events": qt[h] if qt is not None else 0,
                    "bytes_by_host": {host: n * size}}
        else:
            K = len(fab.paths(host, node))
            per_route = [fab.route_occupancy(host, node, size, choice=k)
                         for k in range(K)]
            # same port-union indexing as spec._fabric_route_tensors
            port_keys = sorted({key for hops in per_route
                                for key, _, _ in hops})
            pidx = {key: i for i, key in enumerate(port_keys)}
            counts = (np.asarray(route_counts, np.int64)
                      if route_counts is not None
                      else np.bincount(np.asarray(routes), minlength=K))
            nb = np.zeros(len(port_keys), np.int64)
            pk = np.zeros(len(port_keys), np.int64)
            occt = np.zeros(len(port_keys), np.int64)
            for k, hops in enumerate(per_route):
                for key, occ, _aft in hops:
                    j = pidx[key]
                    nb[j] += int(counts[k]) * size
                    pk[j] += int(counts[k])
                    occt[j] += int(counts[k]) * int(occ)
            for key, j in pidx.items():
                if not pk[j]:
                    continue
                ports[f"{key[0]}->{key[1]}"] = {
                    "bytes": int(nb[j]), "packets": int(pk[j]),
                    "occupied_ticks": int(occt[j]),
                    "queued_ticks": queued[j],
                    "qos_throttle_events": qt[j] if qt is not None else 0,
                    "bytes_by_host": {host: int(nb[j]) * size // size}}
            for key in ports:
                ports[key]["bytes_by_host"] = {host: ports[key]["bytes"]}
            if K > 1 and n:
                ecmp[f"{host}->{node}"] = [int(c) for c in counts]
        host_label = host
        dev_label = node
    else:
        host_label = "host0"
        dev_label = device.name
    return host_label, dev_label, ports, ecmp


def bundle_single_fused(spec: MetricsSpec, device, cfg, acc, med, queued,
                        flash_cnt, addrs: Optional[np.ndarray],
                        routes: Optional[np.ndarray], size: int,
                        faults: Optional[Dict[str, int]] = None,
                        faulted=None, qthr=None,
                        n_accesses: Optional[int] = None,
                        route_counts: Optional[np.ndarray] = None
                        ) -> MetricsBundle:
    """Assemble the bundle after a single-host *streaming* fused run
    (``return_latencies=False``): ``acc``/``med`` come straight out of the
    scan carry — O(buckets+windows) output, no per-access arrays."""
    hist, windows, dev_hist = split_acc(spec, acc, 1, 1)
    media = [dict(zip(MEDIA_COUNTERS[cfg.kind],
                      (int(x) for x in np.asarray(med))))]
    host_label, dev_label, ports, ecmp = _single_ports(
        device, queued, addrs, routes, size, faulted, qthr=qthr,
        n_accesses=n_accesses, route_counts=route_counts)
    return MetricsBundle(
        spec=spec, hosts=[host_label], devices=[dev_label], hist=hist,
        dev_hist=dev_hist, windows=windows, media=media,
        flash=_flash_dicts(flash_cnt), ports=ports, ecmp=ecmp,
        faults=faults)


def bundle_single_deferred(spec: MetricsSpec, device, cfg, issues, dones,
                           flags, writes, queued, flash_cnt,
                           addrs: Optional[np.ndarray],
                           routes: Optional[np.ndarray], size: int,
                           faults: Optional[Dict[str, int]] = None,
                           faulted=None, qthr=None,
                           n_accesses: Optional[int] = None,
                           route_counts: Optional[np.ndarray] = None
                           ) -> MetricsBundle:
    """Assemble the bundle after a single-host fused run with per-access
    outputs (``return_latencies=True``).  The histogram/window fold and the
    counter vector are pure functions of the materialized
    ``(issue, done, flags)`` columns (the scan packs every
    :data:`FLAG_EVENT_BITS` event into the flags word), so they are
    deferred to first access — replay pays only the in-scan queueing
    scalars and a few flag-bit ORs for full telemetry."""
    host_label, dev_label, ports, ecmp = _single_ports(
        device, queued, addrs, routes, size, faulted, qthr=qthr,
        n_accesses=n_accesses, route_counts=route_counts)

    def fold():
        hist, windows, dev_hist = fold_arrays(
            spec, issues, dones, flags & 1, size)
        media = [dict(zip(MEDIA_COUNTERS[cfg.kind],
                          (int(x) for x in
                           media_from_flags(cfg.kind, writes, flags))))]
        return hist, windows, dev_hist, media

    return MetricsBundle(
        spec=spec, hosts=[host_label], devices=[dev_label],
        flash=_flash_dicts(flash_cnt), ports=ports, ecmp=ecmp,
        deferred=fold, faults=faults)


def bundle_multi_fused(spec: MetricsSpec, meta: Dict, mcfg, acc, med,
                       queued, qthr, flash_cnt, devs: np.ndarray,
                       routes: np.ndarray, lens: np.ndarray, size: int,
                       params: Dict,
                       faults: Optional[Dict[str, int]] = None,
                       faulted: Optional[Dict] = None) -> MetricsBundle:
    """Assemble the bundle after a multi-host fused run.  Per-port
    byte/packet/occupancy and per-host attribution are reconstructed from
    the hop tensors + route choices (numpy, exact); ``queued``/``qthr``
    are the in-scan per-port queueing and QoS-throttle accumulators.
    ``faulted`` (from the multi-host transport-fault precompute) overrides
    the clean reconstruction — under down-window reroutes and CRC retries
    the static hop tensors no longer describe the paths taken, so the
    precompute's accumulated per-port/per-host/ECMP totals (indexed over
    the same global sorted port set as ``queued``/``qthr``) are used
    verbatim."""
    hosts, nodes = meta["hosts"], meta["nodes"]
    fabric = meta["fabric"]
    H, D = len(hosts), len(nodes)
    hist, windows, dev_hist = split_acc(spec, acc, H, D)
    med = np.asarray(med)
    names = MEDIA_COUNTERS[mcfg.stack.kind]
    media = [dict(zip(names, (int(x) for x in med[d]))) for d in range(D)]

    lens = np.asarray(lens)
    ecmp: Dict[str, List[int]] = {}
    if faulted is not None:
        port_keys = list(faulted["port_keys"])
        P = len(port_keys)
        npkts = np.asarray(faulted["packets"], np.int64)
        nbytes = np.asarray(faulted["bytes"], np.int64)
        nocc = np.asarray(faulted["occupied"], np.int64)
        by_host = np.asarray(faulted["by_host"], np.int64)
        ecmp = {k: list(v) for k, v in sorted(faulted["ecmp"].items())}
    else:
        port_keys = sorted(fabric.ports)
        P = len(port_keys)
        nbytes = np.zeros(P, np.int64)
        npkts = np.zeros(P, np.int64)
        nocc = np.zeros(P, np.int64)
        by_host = np.zeros((P, H), np.int64)
        hop_port, hop_occ = params["hop_port"], params["hop_occ"]
        hop_on = params["hop_on"]
        for i in range(H):
            L = int(lens[i])
            if not L:
                continue
            d = np.asarray(devs)[i, :L]
            r = np.asarray(routes)[i, :L]
            for h in range(mcfg.max_hops):
                on = hop_on[i, d, r, h]
                pi = hop_port[i, d, r, h][on]
                occ = hop_occ[i, d, r, h][on]
                np.add.at(npkts, pi, 1)
                np.add.at(nbytes, pi, size)
                np.add.at(nocc, pi, occ)
                np.add.at(by_host[:, i], pi, size)
    queued = np.asarray(queued).reshape(-1)
    qthr = (np.asarray(qthr).reshape(-1) if qthr is not None
            else np.zeros(P, np.int64))
    ports: Dict[str, Dict] = {}
    for j, key in enumerate(port_keys):
        if not npkts[j]:
            continue
        ports[f"{key[0]}->{key[1]}"] = {
            "bytes": int(nbytes[j]), "packets": int(npkts[j]),
            "occupied_ticks": int(nocc[j]),
            "queued_ticks": int(queued[j]),
            "qos_throttle_events": int(qthr[j]),
            "bytes_by_host": {hosts[i]: int(by_host[j, i])
                              for i in range(H) if by_host[j, i]},
        }

    if faulted is None:
        route_count = meta["route_count"]
        for i in range(H):
            L = int(lens[i])
            if not L:
                continue
            d_col = np.asarray(devs)[i, :L]
            r_col = np.asarray(routes)[i, :L]
            for d in np.unique(d_col):
                K = int(route_count[i, d])
                if K <= 1:
                    continue
                m = d_col == d
                if not m.any():
                    continue
                counts = np.bincount(r_col[m], minlength=K)
                key = f"{hosts[i]}->{nodes[d]}"
                prev = ecmp.get(key)
                if prev is None:
                    ecmp[key] = [int(c) for c in counts]
                else:                  # same (host, node) reached twice
                    ecmp[key] = [int(a + b) for a, b in zip(prev, counts)]
    return MetricsBundle(
        spec=spec, hosts=list(hosts), devices=list(nodes), hist=hist,
        dev_hist=dev_hist, windows=windows, media=media,
        flash=_flash_dicts(flash_cnt), ports=ports, ecmp=ecmp,
        faults=faults)


# -------------------------------------------------- availability (faults)
def availability_series(issues, dones, degraded, failover=None, *,
                        spec: Optional[MetricsSpec] = None,
                        start_tick: int = 0,
                        window_ticks: Optional[int] = None,
                        num_windows: Optional[int] = None) -> Dict:
    """Tick-windowed availability series + degraded-mode summary from the
    per-access ``degraded``/``failover`` flags the transport-fault
    precompute emits: per issue-tick window the access count, degraded
    count and reachable fraction; overall the degraded fraction, the
    failover latency penalty (mean failover latency minus mean
    clean-route latency, in ticks) and the total tick time spent in
    windows with any degraded access.

    Deliberately OUTSIDE the python-parity :class:`MetricsBundle` schema:
    the interpreted driver keeps no per-access flag column, so this rides
    the replay result (``ReplayResult.availability``) and the benchmark
    artifacts, never the golden-pinned bundle."""
    issues = np.asarray(issues, np.int64)
    dones = np.asarray(dones, np.int64)
    deg = np.asarray(degraded, bool)
    fo = (np.asarray(failover, bool) if failover is not None
          else np.zeros(deg.shape, bool))
    n = int(issues.size)
    T = int(window_ticks if window_ticks is not None
            else (spec.window_ticks if spec is not None else 1_000_000))
    W = int(num_windows if num_windows is not None
            else (spec.num_windows if spec is not None else 64))
    wdx = np.clip((issues - int(start_tick)) // T, 0, W - 1)
    total = np.bincount(wdx, minlength=W).astype(np.int64)
    degw = np.bincount(wdx[deg], minlength=W).astype(np.int64)
    lat = dones - issues
    nd = int(deg.sum())
    nf = int(fo.sum())
    clean = lat[~deg]
    penalty = 0.0
    if nf and clean.size:
        penalty = float(lat[fo].mean() - clean.mean())
    return {
        "window_ticks": T,
        "num_windows": W,
        "accesses": n,
        "windows": {
            str(w): {"accesses": int(total[w]), "degraded": int(degw[w]),
                     "reachable_fraction": float((total[w] - degw[w])
                                                 / total[w])}
            for w in range(W) if total[w]},
        "degraded_accesses": nd,
        "degraded_fraction": float(nd / n) if n else 0.0,
        "failovers": nf,
        "failover_latency_penalty_ticks": penalty,
        "time_in_degraded_windows_ticks": int(T * int((degw > 0).sum())),
    }


def down_window_spans(plan, issues_by_host: Sequence[np.ndarray],
                      hosts: Optional[Sequence[str]] = None) -> List[Dict]:
    """Each down-link window of ``plan`` as a duration span on the tick
    axis, one per host whose trace reaches into it: the window is declared
    over per-host access ordinals, and trace order *is* ordinal order, so
    the per-host issue column maps ordinal bounds to ticks exactly.
    Windows past the trace end are dropped; ones cut by it are clamped.
    ``obs.export.to_perfetto`` renders these as Perfetto "X" events."""
    spans: List[Dict] = []
    if plan is None or not plan.has_down:
        return spans
    for i, iss in enumerate(issues_by_host):
        iss = np.asarray(iss, np.int64)
        L = int(iss.size)
        host = hosts[i] if hosts is not None else f"host{i}"
        for u, v, a0, a1 in plan.config.down_links:
            lo = max(int(a0), 0)
            hi = min(int(a1), L)
            if hi <= lo:
                continue
            spans.append({
                "host": host,
                "link": f"{u}<->{v}",
                "first_ordinal": lo,
                "last_ordinal_exclusive": hi,
                "start_tick": int(iss[lo]),
                "end_tick": int(iss[hi - 1]),
            })
    return spans
