"""Device-stack extraction: turn a live :class:`~repro.core.devices.MemDevice`
into tensors the fused replay scan can consume.

The split mirrors JAX's static/traced divide:

* :class:`StackConfig` — hashable statics that shape the compiled program
  (device kind, array sizes, policy branch, hop count).  One compilation per
  distinct config.
* params dict — numpy scalars/arrays of *timing constants* (occupancies,
  latencies, all pre-converted to ticks with the exact same ``ns()``
  arithmetic the Python devices use) plus route tensors.  These are traced,
  so :func:`jax.vmap` can batch over them (what-if timing sweeps, topology
  sweeps) without recompiling.

Every tick constant here is computed by the *identical* float expression the
corresponding device method evaluates (``ns(size / bw)``, ``ns(nbytes *
(1.0 / bw))``, ...) so rounding agrees bit-for-bit and the fused replay stays
tick-identical to the interpreted path.

Unsupported shapes (2Q/LFRU policies, multi-line accesses, heterogeneous
multi-host targets) raise :class:`ReplayUnsupported` — the driver falls
back to the Python path instead of silently diverging.  Traces that could
outrun the FTL's log-append headroom no longer refuse: they select the
GC-capable stack lane (``StackConfig.gc``), whose scan twin runs the same
greedy collection the Python FTL does (see :mod:`repro.core.replay.stack`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.core.devices import (
    CachedCXLSSDDevice,
    CXLDRAMDevice,
    CXLLink,
    CXLSSDDevice,
    DRAMDevice,
    MemDevice,
    NullLink,
    PMEMDevice,
    POSTED_ACK_NS,
)
from repro.core.engine import ns, us
from repro.core.fabric.fabric import LINE_BYTES, FabricAttachedDevice
from repro.core.fabric.topology import SWITCH
from repro.core.ssd.hil import HIL


class ReplayUnsupported(ValueError):
    """The device/trace combination has no exact fused fast path.

    Every fast lane raises this instead of ever diverging silently; the
    message names the widest lane that still covers the shape.  The lane
    ladder, widest to fastest:

    ``python`` (everything) > ``scan``/blocked scan (all five devices —
    single- AND multi-host, fabric/ECMP/QoS mounts, pool views, shared
    flash, greedy GC) > ``assoc`` (stateless DRAM/PMEM media on a single
    route, bandwidth-bound traces).
    """


# media kinds the fused step function branches on (static)
DRAM = "dram"
PMEM = "pmem"
SSD_BUF = "ssd-buf"        # cxl-ssd: page-register buffer straight to flash
SSD_CACHE = "ssd-cache"    # cxl-ssd-cache: DRAM cache + MSHR + writeback

# media kinds with no per-access state beyond busy-until chains — the
# stacks the log-depth associative lane (repro.core.replay.assoc) covers
ASSOC_KINDS = (DRAM, PMEM)


def validate_block_size(block_size) -> int:
    """Blocked-replay knob: the scan body replays ``block_size`` accesses
    per sequential step (``lax.scan`` unroll), amortizing XLA:CPU's
    per-step thunk dispatch by ~B.  Purely a lowering change — the carry
    crosses block seams untouched, so any block size is tick-identical
    (tested for B in {1, 8, 64, len(trace)})."""
    b = int(block_size)
    if b < 1:
        raise ValueError(f"block_size must be >= 1, got {block_size!r}")
    return b


@dataclass(frozen=True)
class StackConfig:
    """Static (hashable) shape of one host->device stack."""

    kind: str                    # DRAM | PMEM | SSD_BUF | SSD_CACHE
    outstanding: int
    posted_writes: bool
    num_hops: int                # transport hops (0 = directly attached)
    num_ports: int               # busy-until vector length (>= 1)
    num_routes: int = 1          # ECMP fan-out (1 = single fixed route)
    page_bytes: int = 4096
    # cache layer (SSD_CACHE)
    cache_frames: int = 0
    cache_assoc: bool = True     # True: lru/fifo (is_lru param); False: direct
    mshr_entries: int = 0
    wb_slots: int = 0
    # flash backend (SSD_BUF / SSD_CACHE)
    channels: int = 0
    dies_per_channel: int = 0
    pages_per_block: int = 0
    buf_entries: int = 0         # SSD_BUF page registers
    num_pages: int = 0           # l2p table size (trace footprint, pow2)
    # greedy-GC lane (selected when the trace could outrun log-append
    # headroom; see repro.core.replay.stack)
    gc: bool = False
    num_blocks: int = 0
    gc_watermark_blocks: int = 0
    # in-scan telemetry: flash state grows FTL.stats counter twins (see
    # repro.core.replay.metrics); False keeps the legacy compiled program
    counters: bool = False
    # deterministic NAND fault statics from FaultPlan.nand_statics():
    # (seed, read_retry_threshold, read_retry_max, erase_fail_threshold),
    # or () when no NAND faults are planned (legacy compiled program).
    # Static because the values shape the scan body (retry rounds keyed on
    # the in-scan read sequence, erase-fail gating of the GC free-append).
    faults: Tuple[int, ...] = ()
    # transport faults (link CRC retries / down-port failover): the scan
    # consumes per-access hop columns precomputed host-side instead of the
    # static route tensors — see ReplayEngine
    fault_hops: bool = False
    # QoS observability (single host): indices into the busy-until
    # container (hop position on a fixed route, union-port index under
    # ECMP / fault hops) whose fabric port runs weighted arbitration.
    # With one origin the ack floor provably never binds (see
    # _fabric_hops), so only the qos_throttle_events counter is mirrored
    # — and only when metrics are collected, leaving the no-metrics
    # compiled program untouched.
    qos_ports: Tuple[int, ...] = ()


def _link_hops(link: CXLLink, size: int) -> Tuple[list, int]:
    """A private point-to-point link as a 1-hop route (NullLink: 0 hops)."""
    if isinstance(link, NullLink):
        return [], 0
    return [(0, ns(size / link.bw_gbps), 0)], ns(link.rt_extra_ns)


def _fabric_hops(dev: FabricAttachedDevice, size: int
                 ) -> Tuple[list, int, Tuple[int, ...]]:
    """Route tensor export: one (port_index, occ_ticks, after_ticks) per hop,
    from :meth:`Fabric.route_occupancy` (the single definition of the
    per-hop busy-until rule), plus the hop indices whose port runs weighted
    QoS arbitration.

    Single-host QoS note: a fabric with QoS weights leaves every *tick*
    unchanged — with one origin the active set is always the singleton, the
    pace equals the clean occupancy exactly (``int(occ * w/w)``), the
    virtual clock obeys the identical ``max(prev, now) + occ`` recurrence
    as the port's busy-until (same zero init, same arrival sequence), and
    the ack floor provably never binds (see :meth:`SwitchPort.qos_update`),
    so latencies are bit-identical to plain FCFS.  The *counter* twin,
    ``qos_throttle_events``, still fires whenever the virtual clock is
    ahead of the arrival — which, by that same recurrence identity, is
    exactly when the port's busy-until is — so the fused lanes mirror it
    straight off the busy-until state on the hops returned here.  ECMP, by
    contrast, changes which ports a transfer occupies, so it is exported
    as per-route tensors by :func:`_fabric_route_tensors`."""
    fab = dev.fabric
    occ_hops = fab.route_occupancy(dev.host, dev.device_node, size)
    hops = [(i, occ, after) for i, (_, occ, after) in enumerate(occ_hops)]
    qos = tuple(i for i, (key, _, _) in enumerate(occ_hops)
                if fab.ports[key].qos_enabled)
    return hops, ns(fab.rt_extra_ns), qos


def _qos_union_ports(fab, port_keys) -> Tuple[int, ...]:
    """Indices (into a sorted port-key union) of weighted-arbitration ports."""
    return tuple(i for i, key in enumerate(port_keys)
                 if fab.ports[key].qos_enabled)


def _fabric_route_tensors(dev: FabricAttachedDevice, size: int):
    """ECMP export: per-route hop tensors over the union of ports the path
    set touches.  All equal-cost routes share one hop count, so only the
    port indices differ per route.  Returns ``(hop_port (K,H) int32,
    hop_occ (K,H) int64, hop_after (K,H) int64, num_ports, rt_extra,
    qos_ports)`` — the last being the union-port indices under weighted
    arbitration (see the single-origin recurrence note on
    :func:`_fabric_hops`)."""
    fab = dev.fabric
    routes = fab.paths(dev.host, dev.device_node)
    K = len(routes)
    per_route = [fab.route_occupancy(dev.host, dev.device_node, size,
                                     choice=k) for k in range(K)]
    H = len(per_route[0])
    if any(len(r) != H for r in per_route):
        raise AssertionError("equal-cost routes must share one hop count")
    port_keys = sorted({key for hops in per_route for key, _, _ in hops})
    pidx = {key: i for i, key in enumerate(port_keys)}
    hop_port = np.zeros((K, H), np.int32)
    hop_occ = np.zeros((K, H), np.int64)
    hop_after = np.zeros((K, H), np.int64)
    for k, hops in enumerate(per_route):
        for h, (key, occ_h, after_h) in enumerate(hops):
            hop_port[k, h] = pidx[key]
            hop_occ[k, h] = occ_h
            hop_after[k, h] = after_h
    return (hop_port, hop_occ, hop_after, len(port_keys),
            ns(fab.rt_extra_ns), _qos_union_ports(fab, port_keys))


def access_route_choices(device: MemDevice, addrs: np.ndarray) -> np.ndarray:
    """Per-access ECMP route-choice column for a fabric-mounted device —
    the same :func:`~repro.core.fabric.routing.flow_choices` hash over the
    same flow key (``addr // 64``) the interpreted
    :meth:`FabricAttachedDevice.service` evaluates per access."""
    from repro.core.fabric.routing import flow_choices

    fab = device.fabric
    k = len(fab.paths(device.host, device.device_node))
    return flow_choices(device.host, device.device_node,
                        np.asarray(addrs, np.int64) // LINE_BYTES, k)


def _require_fresh(dev: MemDevice) -> None:
    if dev.stats.get("bytes", 0):
        raise ReplayUnsupported(
            f"device {dev.name!r} has prior traffic; the fused replay "
            "snapshots a fresh device (re-create it or use engine='python')")


def _ssd_params(hil: HIL) -> Dict[str, int]:
    t = hil.cfg.timing
    return {
        "hil_ov": ns(hil.cfg.hil_overhead_ns),
        "xfer_page": t.xfer_ticks(hil.cfg.page_bytes),
        "read_t": t.read_ticks,
        "prog_t": t.prog_ticks,
        "sus_t": us(t.t_suspend_us),
        "erase_t": t.erase_ticks,
    }


def _gc_possible(hil: HIL, n_accesses: int) -> bool:
    """Could this trace trigger FTL GC?  (Each access causes at most one
    demand flash program; GC's own migrations only run once GC has
    triggered.)  ``False`` selects the log-append stack — byte-identical to
    the pre-GC engine; ``True`` selects the GC-capable lane, which carries
    the full FTL bookkeeping (valid counts, inverse map, FIFO free pool)
    and runs greedy collection inside the scan."""
    ftl = hil.ftl
    blocks_needed = ftl.write_ptr_block + n_accesses // ftl.pages_per_block + 2
    return blocks_needed >= ftl.num_blocks - ftl.gc_watermark_blocks


def _gc_fields(hil: HIL, n_accesses: int) -> Dict[str, int]:
    """The GC statics for :class:`StackConfig` (empty when the headroom
    check proves GC unreachable, keeping the legacy compiled program)."""
    if not _gc_possible(hil, n_accesses):
        return {}
    return dict(gc=True, num_blocks=hil.ftl.num_blocks,
                gc_watermark_blocks=hil.ftl.gc_watermark_blocks)


def build_stack(device: MemDevice, *, size: int, outstanding: int,
                issue_overhead_ns: float, posted_writes: bool,
                n_accesses: int, max_addr: int,
                counters: bool = False) -> Tuple[StackConfig, Dict]:
    """Extract (static config, params dict) for one host->device stack."""
    _require_fresh(device)
    inner = device
    ecmp = None
    if isinstance(device, FabricAttachedDevice):
        if device.fabric.stats.get("transfers", 0):
            # shared ports may hold busy-until state from other mounts;
            # a zeroed replay would silently diverge from the python path
            raise ReplayUnsupported(
                "fabric has prior traffic; replay snapshots a fresh fabric "
                "(Fabric.reset() or re-build it, or use engine='python')")
        if len(device.fabric.paths(device.host, device.device_node)) > 1:
            ecmp = _fabric_route_tensors(device, size)
            hops, rt, qos_ports = [], ecmp[4], ecmp[5]
        else:
            hops, rt, qos_ports = _fabric_hops(device, size)
        inner = device.inner
        _require_fresh(inner)
    elif isinstance(device, (CXLDRAMDevice, CXLSSDDevice, CachedCXLSSDDevice)):
        hops, rt = _link_hops(device.link, size)
        qos_ports = ()
    elif isinstance(device, (DRAMDevice, PMEMDevice)):
        hops, rt, qos_ports = [], 0, ()
    else:
        raise ReplayUnsupported(f"no fused model for {type(device).__name__}")

    if ecmp is not None:
        hop_port, hop_occ, hop_after, n_ports, rt = ecmp[:5]
        params: Dict = {
            "issue_ov": ns(issue_overhead_ns),
            # per-route port indices into the path set's port union
            "hop_port": hop_port,
            "hop_occ": hop_occ,
            "hop_after": hop_after,
            "rt_extra": rt,
        }
        common = dict(outstanding=max(1, outstanding),
                      posted_writes=posted_writes,
                      num_hops=hop_occ.shape[1], num_ports=n_ports,
                      num_routes=hop_occ.shape[0], counters=counters,
                      qos_ports=qos_ports)
    else:
        params = {
            "issue_ov": ns(issue_overhead_ns),
            # hop h is port h on a single fixed route: positional arrays
            "hop_occ": np.asarray([h[1] for h in hops], np.int64),
            "hop_after": np.asarray([h[2] for h in hops], np.int64),
            "rt_extra": rt,
        }
        common = dict(outstanding=max(1, outstanding),
                      posted_writes=posted_writes,
                      num_hops=len(hops), num_ports=max(1, len(hops)),
                      counters=counters, qos_ports=qos_ports)

    if isinstance(inner, (DRAMDevice, CXLDRAMDevice)):
        if isinstance(inner, CXLDRAMDevice) and inner is not device:
            # Mounted behind a fabric with detach_link=False: the private
            # link is a second transport stage after the fabric.
            ih, irt = _link_hops(inner.link, size)
            if ih and ecmp is not None:
                # private link = one extra hop on every ECMP route, with
                # its own (uncontended) port slot after the fabric ports
                K = params["hop_occ"].shape[0]
                params["hop_occ"] = np.concatenate(
                    [params["hop_occ"], np.full((K, 1), ih[0][1])],
                    axis=1).astype(np.int64)
                params["hop_after"] = np.concatenate(
                    [params["hop_after"], np.full((K, 1), ih[0][2])],
                    axis=1).astype(np.int64)
                params["hop_port"] = np.concatenate(
                    [params["hop_port"],
                     np.full((K, 1), common["num_ports"])],
                    axis=1).astype(np.int32)
                params["rt_extra"] = rt + irt
                common.update(num_hops=common["num_hops"] + 1,
                              num_ports=common["num_ports"] + 1)
            elif ih:
                base = len(hops)
                params["hop_occ"] = np.concatenate(
                    [params["hop_occ"], [ih[0][1]]]).astype(np.int64)
                params["hop_after"] = np.concatenate(
                    [params["hop_after"], [ih[0][2]]]).astype(np.int64)
                params["rt_extra"] = rt + irt
                common.update(num_hops=base + 1, num_ports=base + 1)

    if inner is not device and hasattr(inner, "link") \
            and not isinstance(inner, (DRAMDevice, PMEMDevice,
                                       CXLDRAMDevice)) \
            and not isinstance(inner.link, NullLink):
        raise ReplayUnsupported(
            "fabric-mounted SSD device keeps a live private link "
            "(detach_link=False); use engine='python'")

    return _media_config(inner, common, params, size=size,
                         n_accesses=n_accesses, max_addr=max_addr)


def _media_config(inner: MemDevice, common: Dict, params: Dict, *,
                  size: int, n_accesses: int, max_addr: int
                  ) -> Tuple[StackConfig, Dict]:
    """Append the media half of the stack — kind statics + timing params —
    to an already-built transport ``common``/``params`` pair.  The single
    definition both :func:`build_stack` (single host, transport attached)
    and :func:`media_stack` (multi-host, transportless) extract through."""
    if isinstance(inner, (DRAMDevice, CXLDRAMDevice)):
        dram = inner.dram if isinstance(inner, CXLDRAMDevice) else inner
        params.update({
            "occ": ns(size / dram.t.bw_gbps),
            "load": ns(dram.t.load_ns),
            "pack": ns(POSTED_ACK_NS),
        })
        return StackConfig(kind=DRAM, **common), params

    if isinstance(inner, PMEMDevice):
        t = inner.t
        lat = np.zeros((2, 2), np.int64)        # [write][row_hit]
        lat[0, 0] = ns(t.read_ns)
        lat[0, 1] = ns(t.read_ns * t.row_hit_factor)
        lat[1, 0] = ns(t.write_ns)
        lat[1, 1] = ns(t.write_ns * t.row_hit_factor)
        params.update({
            "occ": ns(size / t.bw_gbps),
            "lat": lat,
            "pack": ns(POSTED_ACK_NS),
            "row_bytes": np.int64(t.row_bytes),
        })
        return StackConfig(kind=PMEM, **common), params

    # NAND fault statics ride the media config so every lane that builds
    # this stack (single-host scan, blocked scan, multi-host) mirrors the
    # PAL/FTL fault decisions tick-identically
    nand_faults: Tuple[int, ...] = ()
    if hasattr(inner, "hil"):
        _plan = getattr(inner.hil.ftl, "fault_plan", None)
        if _plan is not None:
            nand_faults = _plan.nand_statics()

    page_bytes = 4096
    if max_addr // page_bytes >= (1 << 38) - 1:
        raise ReplayUnsupported(
            "page id exceeds the packed-frame field (addr >= 2^50)")
    if hasattr(inner, "hil"):
        ftl = inner.hil.ftl
        if ftl.num_blocks * ftl.pages_per_block >= (1 << 31):
            raise ReplayUnsupported("physical page numbers overflow int32")
    n_pages = max(1, max_addr // page_bytes + 1)
    n_pages = 1 << (n_pages - 1).bit_length()   # pow2: stable compilations

    if isinstance(inner, CXLSSDDevice):
        from repro.core.cache.policies import LRUPolicy
        if not isinstance(inner._buf, LRUPolicy):
            raise ReplayUnsupported("cxl-ssd page-register buffer must be LRU")
        params.update(_ssd_params(inner.hil))
        params["internal"] = ns(inner.internal_latency_ns)
        return StackConfig(
            kind=SSD_BUF, page_bytes=inner.hil.cfg.page_bytes,
            channels=inner.hil.cfg.channels,
            dies_per_channel=inner.hil.cfg.dies_per_channel,
            pages_per_block=inner.hil.ftl.pages_per_block,
            buf_entries=inner._buf.capacity, num_pages=n_pages,
            faults=nand_faults,
            **_gc_fields(inner.hil, n_accesses), **common), params

    if isinstance(inner, CachedCXLSSDDevice):
        cache = inner.cache
        pol = cache.policy.name
        if pol not in ("lru", "fifo", "direct"):
            raise ReplayUnsupported(
                f"fused replay supports lru/fifo/direct, got {pol!r}; "
                "use engine='python'")
        if cache.cfg.mshr_entries < 1 or cache.cfg.writeback_buffer < 1:
            raise ReplayUnsupported("cache needs >= 1 MSHR and wb slot")
        frames = cache.cfg.capacity_pages
        params.update(_ssd_params(inner.hil))
        per_byte_ns = 1.0 / cache.cfg.dram_bw_gbps
        params.update({
            "hit_lat": ns(cache.cfg.hit_latency_ns),
            "line_xfer": ns(64 * per_byte_ns),
            "page_xfer": ns(page_bytes * per_byte_ns),
            "pack10": ns(10.0),
            "is_lru": np.bool_(pol == "lru"),
            "cap": np.int64(frames),
        })
        return StackConfig(
            kind=SSD_CACHE, page_bytes=page_bytes,
            cache_frames=frames, cache_assoc=(pol != "direct"),
            mshr_entries=cache.cfg.mshr_entries,
            wb_slots=cache.cfg.writeback_buffer,
            channels=inner.hil.cfg.channels,
            dies_per_channel=inner.hil.cfg.dies_per_channel,
            pages_per_block=inner.hil.ftl.pages_per_block,
            num_pages=n_pages, faults=nand_faults,
            **_gc_fields(inner.hil, n_accesses), **common), params

    raise ReplayUnsupported(
        f"no fused model for {type(inner).__name__}; use engine='python'")


def require_metrics_lane(engine: str) -> None:
    """Certify-or-refuse for telemetry: only the python driver and the
    stateful scan lanes can carry the metrics accumulator.  The assoc and
    pallas lanes rewrite the scan into forms with no per-access carry slot,
    so they refuse *explicitly* rather than silently returning a result
    with no (or wrong) metrics."""
    if engine in ("assoc", "pallas"):
        raise ReplayUnsupported(
            f"engine {engine!r} cannot carry in-scan metrics; use "
            "engine='scan' (or 'python'), or drop metrics collection")


def media_stack(inner: MemDevice, *, size: int, outstanding: int,
                posted_writes: bool, n_accesses: int, max_addr: int,
                counters: bool = False) -> Tuple[StackConfig, Dict]:
    """Transportless media extraction for the multi-host engine: the stack
    of one *inner* (already fabric-mounted, link-detached) device, with
    ``num_hops=0`` — the multi-host scan supplies its own route tensors and
    walks the shared ports itself.  ``n_accesses`` must count every access
    that can reach this device's flash (summed over hosts for shared
    targets), so the GC-lane selection stays conservative."""
    _require_fresh(inner)
    if hasattr(inner, "link") and not isinstance(inner.link, NullLink):
        raise ReplayUnsupported(
            f"multi-host target {inner.name!r} keeps a live private link "
            "(mount it with detach_link=True); use engine='python'")
    common = dict(outstanding=max(1, outstanding),
                  posted_writes=posted_writes, num_hops=0, num_ports=1,
                  counters=counters)
    return _media_config(inner, common, {}, size=size,
                         n_accesses=n_accesses, max_addr=max_addr)


def trace_to_arrays(trace, *, line: int = 64) -> Tuple[np.ndarray, np.ndarray, int]:
    """Validate a ``[(addr, size, write)]`` trace for the fused fast path.

    Returns ``(addrs int64, writes bool, size)``.  Requires a uniform access
    size that stays inside one 64 B line (the vectorized step services
    exactly one cache line per access, like the drivers' typical traces)."""
    rows = list(trace)
    if not rows:
        raise ReplayUnsupported("empty trace")
    addrs = np.asarray([r[0] for r in rows], np.int64)
    sizes = np.asarray([r[1] for r in rows], np.int64)
    writes = np.asarray([r[2] for r in rows], bool)
    size = int(sizes[0])
    if not (sizes == size).all():
        raise ReplayUnsupported("fused replay needs a uniform access size")
    if size < 1 or ((addrs % line) + size > line).any():
        raise ReplayUnsupported(
            "fused replay needs accesses contained in one 64 B line")
    if (addrs < 0).any():
        raise ReplayUnsupported("negative addresses")
    return addrs, writes, size


def validate_trace_columns(addrs, writes, lens=None, *, size: int = 64,
                           line: int = 64
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Validate already-columnar ``(H, L)`` multi-host trace arrays — the
    array twin of :func:`trace_to_arrays` for traces that were synthesized
    as tensors (``repro.data.workloads``) or loaded from a
    :class:`~repro.data.trace_store.TraceStore` and never existed as python
    tuple lists.  Returns canonical ``(addrs int64, writes bool,
    lens int64)``; ``lens=None`` means every host plays all ``L`` columns.
    The same single-line containment rule applies (only the first ``lens[i]``
    entries of each row are checked — padding is never replayed)."""
    addrs = np.ascontiguousarray(np.asarray(addrs, np.int64))
    writes = np.ascontiguousarray(np.asarray(writes, bool))
    if addrs.ndim != 2 or writes.shape != addrs.shape:
        raise ReplayUnsupported(
            f"trace columns must be matching (hosts, accesses) arrays, got "
            f"addrs {addrs.shape} / writes {writes.shape}")
    H, L = addrs.shape
    if lens is None:
        lens = np.full(H, L, np.int64)
    else:
        lens = np.asarray(lens, np.int64)
        if lens.shape != (H,) or (lens < 0).any() or (lens > L).any():
            raise ReplayUnsupported(
                f"lens must be (hosts,) within [0, {L}], got {lens!r}")
    if not lens.any():
        raise ReplayUnsupported("empty trace")
    live = np.arange(L) < lens[:, None]
    if size < 1 or ((addrs % line) + size > line)[live].any():
        raise ReplayUnsupported(
            "fused replay needs accesses contained in one 64 B line")
    if (addrs < 0)[live].any():
        raise ReplayUnsupported("negative addresses")
    return addrs, writes, lens
