"""Streaming replay front end: fused replay straight from a TraceStore.

``replay_stream(path_or_store, device, chunk_size=...)`` replays an
on-disk columnar trace (:class:`repro.data.trace_store.TraceStore`)
through :class:`~repro.core.replay.engine.ReplayEngine` without ever
holding the full trace in host or device memory:

* input — each chunk is a memmap slice copied on demand; a background
  :class:`~repro.data.pipeline.Prefetcher` keeps at most ``depth``
  windows queued while one replays, so peak input residency is
  ``(prefetch_depth + 1) * chunk_size * row_bytes``, independent of
  trace length;
* carry — the jitted chunk program donates its carry pytree, so device
  state is a single O(config) buffer set threaded across chunks;
* output — pass ``return_latencies=False`` (with a
  :class:`~repro.core.replay.metrics.MetricsSpec` if you want telemetry)
  for O(buckets + windows) outputs too; the default keeps per-access
  latencies, which are inherently O(trace).

Tick-identical to one-shot replay at any chunk size, or it refuses with
the same :class:`~repro.core.replay.spec.ReplayUnsupported` error.
"""

from __future__ import annotations

from typing import Optional

from repro.core.replay.engine import ReplayEngine, ReplayResult
from repro.core.replay.metrics import MetricsSpec


def replay_stream(store, device, *, chunk_size: int,
                  prefetch_depth: int = 2, outstanding: int = 32,
                  issue_overhead_ns: float = 0.5,
                  posted_writes: bool = True, block_size: int = 1,
                  metrics: Optional[MetricsSpec] = None,
                  start_tick: int = 0, return_latencies: bool = True,
                  stats: Optional[dict] = None) -> ReplayResult:
    """Replay ``store`` (a TraceStore or a path to one) on ``device``.

    ``stats``, if given a dict, is filled with the streaming memory
    model: ``chunks``, ``chunk_input_bytes`` (one window),
    ``peak_input_bound_bytes`` (the analytic ``(depth + 1) * window``
    bound: ``depth`` queued windows plus the one the producer holds
    while the queue is full) and ``peak_buffered_bytes`` (the measured
    high-water mark, always <= the bound).
    """
    from repro.data.pipeline import Prefetcher
    from repro.data.trace_store import TraceStore

    if not hasattr(store, "chunks"):
        store = TraceStore(store)
    chunk = int(chunk_size)
    engine = ReplayEngine(device, outstanding=outstanding,
                          issue_overhead_ns=issue_overhead_ns,
                          posted_writes=posted_writes,
                          block_size=block_size, metrics=metrics)
    pf = Prefetcher(store.chunks(chunk), depth=prefetch_depth)
    try:
        res = engine.run_store(store, chunk_size=chunk,
                               start_tick=start_tick,
                               return_latencies=return_latencies,
                               chunk_iter=pf)
    finally:
        pf.close()
    if stats is not None:
        window = chunk * store.row_bytes
        stats["chunks"] = -(-store.n // chunk)
        stats["chunk_input_bytes"] = window
        stats["peak_input_bound_bytes"] = (prefetch_depth + 1) * window
        stats["peak_buffered_bytes"] = pf.peak_buffered_bytes
    return res
