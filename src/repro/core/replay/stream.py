"""Streaming replay front end: fused replay straight from a TraceStore.

``replay_stream(path_or_store, device, chunk_size=...)`` replays an
on-disk columnar trace (:class:`repro.data.trace_store.TraceStore`)
through :class:`~repro.core.replay.engine.ReplayEngine` without ever
holding the full trace in host or device memory:

* input — each chunk is a memmap slice copied on demand; a background
  :class:`~repro.data.pipeline.Prefetcher` keeps at most ``depth``
  windows queued while one replays, so peak input residency is
  ``(prefetch_depth + 1) * chunk_size * row_bytes``, independent of
  trace length;
* carry — the jitted chunk program donates its carry pytree, so device
  state is a single O(config) buffer set threaded across chunks;
* output — pass ``return_latencies=False`` (with a
  :class:`~repro.core.replay.metrics.MetricsSpec` if you want telemetry)
  for O(buckets + windows) outputs too; the default keeps per-access
  latencies, which are inherently O(trace).

Crash safety: with ``checkpoint_dir=`` and ``checkpoint_every=K``, every
K chunks the full resumable state — the donated carry pytree, the
stream cursor, the per-chunk output parts, and the fault/ECMP/poison
feed accumulators — is written atomically (tmp dir + per-file fsync +
``os.replace``) with per-leaf SHA-256 through
:class:`~repro.checkpoint.manager.CheckpointManager`.  A killed run
restarted with ``resume=True`` walks back to the newest checkpoint that
verifies (torn or bit-flipped snapshots are skipped) and continues
tick-identical to an uninterrupted run — byte-equal latencies, flags,
and MetricsBundle — fault plans included.

Tick-identical to one-shot replay at any chunk size, or it refuses with
the same :class:`~repro.core.replay.spec.ReplayUnsupported` error.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.replay.engine import ReplayEngine, ReplayResult
from repro.core.replay.metrics import MetricsSpec

#: snapshot encoding version (bumped on layout changes; resume refuses
#: snapshots it cannot decode rather than guessing)
SNAPSHOT_FORMAT = 1


def _encode_snapshot(snap: Dict, *, n: int, size: int,
                     chunk: int) -> Tuple[Dict, Dict]:
    """Flatten a ``run_store`` snapshot into ``(flat_arrays, extra_json)``
    for :class:`CheckpointManager` (whose leaves are arrays and whose
    ``extra`` is JSON) — inverse of :func:`_decode_snapshot`."""
    flat = {}
    for k, v in snap["carry"].items():
        flat[f"carry/{k}"] = v
    for t, (iss, dn, fl) in enumerate(snap["parts"]):
        flat[f"parts/{t}/iss"] = iss
        flat[f"parts/{t}/dn"] = dn
        flat[f"parts/{t}/fl"] = fl
    for t, pz in enumerate(snap["poison_parts"]):
        flat[f"poison/{t}"] = np.asarray(pz, np.uint8)
    if snap["route_counts"] is not None:
        flat["route_counts"] = snap["route_counts"]
    b = snap["builder"]
    if b is not None:
        flat["builder/pkts"] = b["pkts"]
        flat["builder/occt"] = b["occt"]
        flat["builder/counters"] = b["counters"]
        flat["builder/deg"] = np.asarray(b["deg"], np.uint8)
        flat["builder/fo"] = np.asarray(b["fo"], np.uint8)
        for key, v in b["ecmp"].items():
            flat[f"builder/ecmp/{key}"] = np.asarray(v, np.int64)
    extra = {
        "format": SNAPSHOT_FORMAT,
        "seen": int(snap["seen"]),
        "psum": int(snap["psum"]),
        "n_parts": len(snap["parts"]),
        "n_poison": len(snap["poison_parts"]),
        "has_route_counts": snap["route_counts"] is not None,
        "has_builder": b is not None,
        "ecmp_keys": sorted(b["ecmp"]) if b is not None else [],
        "n": int(n), "size": int(size), "chunk": int(chunk),
    }
    return flat, extra


def _decode_snapshot(flat: Dict, extra: Dict, *, n: int,
                     size: int) -> Dict:
    """Rebuild the ``run_store`` ``resume_state`` dict from a restored
    checkpoint, validating it belongs to this trace."""
    if extra.get("format") != SNAPSHOT_FORMAT:
        raise ValueError(f"unsupported replay snapshot format "
                         f"{extra.get('format')!r}")
    if int(extra["n"]) != n or int(extra["size"]) != size:
        raise ValueError(
            f"checkpoint belongs to a different trace: snapshot pins "
            f"n={extra['n']} size={extra['size']}, store has "
            f"n={n} size={size}")
    carry = {k[len("carry/"):]: v for k, v in flat.items()
             if k.startswith("carry/")}
    parts = [(flat[f"parts/{t}/iss"], flat[f"parts/{t}/dn"],
              flat[f"parts/{t}/fl"]) for t in range(extra["n_parts"])]
    poison = [np.asarray(flat[f"poison/{t}"], bool)
              for t in range(extra["n_poison"])]
    builder = None
    if extra["has_builder"]:
        builder = {
            "pkts": flat["builder/pkts"],
            "occt": flat["builder/occt"],
            "counters": flat["builder/counters"],
            "deg": np.asarray(flat["builder/deg"], bool),
            "fo": np.asarray(flat["builder/fo"], bool),
            "ecmp": {key: flat[f"builder/ecmp/{key}"]
                     for key in extra["ecmp_keys"]},
        }
    return {
        "seen": int(extra["seen"]),
        "psum": int(extra["psum"]),
        "parts": parts,
        "poison_parts": poison,
        "route_counts": (flat["route_counts"]
                         if extra["has_route_counts"] else None),
        "builder": builder,
        "carry": carry,
    }


def replay_stream(store, device, *, chunk_size: int,
                  prefetch_depth: int = 2, outstanding: int = 32,
                  issue_overhead_ns: float = 0.5,
                  posted_writes: bool = True, block_size: int = 1,
                  metrics: Optional[MetricsSpec] = None,
                  start_tick: int = 0, return_latencies: bool = True,
                  stats: Optional[dict] = None,
                  checkpoint_dir: Optional[str] = None,
                  checkpoint_every: int = 0,
                  checkpoint_keep: int = 3,
                  resume: bool = False) -> ReplayResult:
    """Replay ``store`` (a TraceStore or a path to one) on ``device``.

    ``stats``, if given a dict, is filled with the streaming memory
    model: ``chunks``, ``chunk_input_bytes`` (one window),
    ``peak_input_bound_bytes`` (the analytic ``(depth + 1) * window``
    bound: ``depth`` queued windows plus the one the producer holds
    while the queue is full) and ``peak_buffered_bytes`` (the measured
    high-water mark, always <= the bound); when checkpointing is active
    it also records ``checkpoints_written`` and ``resumed_from`` (the
    access cursor the run continued from, 0 for a fresh start).

    ``checkpoint_dir`` + ``checkpoint_every=K`` snapshot the resumable
    state every K chunks; ``resume=True`` restarts from the newest
    verifiable snapshot under ``checkpoint_dir`` (falling back past torn
    or corrupt ones, or to a fresh start when none exists) and is
    guaranteed byte-identical to the uninterrupted run.
    """
    from repro.data.pipeline import Prefetcher
    from repro.data.trace_store import TraceStore

    if not hasattr(store, "chunks"):
        store = TraceStore(store)
    chunk = int(chunk_size)
    every = int(checkpoint_every)
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True needs checkpoint_dir=")
    if every and checkpoint_dir is None:
        raise ValueError("checkpoint_every needs checkpoint_dir=")
    engine = ReplayEngine(device, outstanding=outstanding,
                          issue_overhead_ns=issue_overhead_ns,
                          posted_writes=posted_writes,
                          block_size=block_size, metrics=metrics)
    mgr = None
    if checkpoint_dir is not None:
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
    resume_state = None
    if resume:
        try:
            flat, extra, _step = mgr.restore_latest_good()
        except FileNotFoundError:
            flat = None      # nothing usable: fresh start
        if flat is not None:
            resume_state = _decode_snapshot(flat, extra, n=int(store.n),
                                            size=int(store.size))
    seen0 = int(resume_state["seen"]) if resume_state is not None else 0
    written = 0
    on_chunk = None
    if mgr is not None and every > 0:
        pending = {"chunks": 0}

        def on_chunk(seen, snapshot):
            nonlocal written
            pending["chunks"] += 1
            if pending["chunks"] % every == 0 and seen < store.n:
                snap = snapshot()
                flat, extra = _encode_snapshot(
                    snap, n=int(store.n), size=int(store.size), chunk=chunk)
                mgr.save(int(seen), flat, extra=extra)
                written += 1

    pf = Prefetcher(store.chunks(chunk, start=seen0) if seen0
                    else store.chunks(chunk), depth=prefetch_depth)
    try:
        res = engine.run_store(store, chunk_size=chunk,
                               start_tick=start_tick,
                               return_latencies=return_latencies,
                               chunk_iter=pf, resume_state=resume_state,
                               on_chunk=on_chunk)
    finally:
        pf.close()
    if stats is not None:
        window = chunk * store.row_bytes
        stats["chunks"] = -(-(store.n - seen0) // chunk)
        stats["chunk_input_bytes"] = window
        stats["peak_input_bound_bytes"] = (prefetch_depth + 1) * window
        stats["peak_buffered_bytes"] = pf.peak_buffered_bytes
        if mgr is not None:
            stats["checkpoints_written"] = written
            stats["resumed_from"] = seen0
    return res
