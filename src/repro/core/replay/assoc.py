"""Log-depth associative replay lane: zero sequential scan steps.

The scan engine (:mod:`repro.core.replay.engine`) pays XLA:CPU's per-step
thunk dispatch once per access.  This lane removes the sequential scan
entirely for the stateless media stacks (DRAM- and PMEM-class): every
busy-until chain in the stack is a **max-plus recurrence**

    ``free_i = max(arr_i, free_{i-1}) + svc_i``

which composes associatively — a chain over N accesses is an
:func:`jax.lax.associative_scan` (log depth), and a chain with *constant*
service time collapses further to one ``cummax`` (see :func:`busy_until`
and the tandem stages inside the solver).  Transport hops, the media
occupancy chain, and the issue-pacing recurrence are all of this shape;
PMEM row-hit state and posted-write tails are pure elementwise data.

The one genuine feedback loop is the LFB ring: ``issue_i = max(now_i,
popped_i)`` where ``popped_i`` is the slot freed by an *earlier completion*.
Two facts make it tractable:

* completions are pushed in issue order and every pushed completion is
  ``>=`` every previously popped value (each completion exceeds its own
  issue tick by the stack's fixed minimum latency), so the popped sequence
  is exactly the **sorted** completion stream, offset by the LFB depth;
* the full system is a monotone set of max-plus constraints whose *least*
  fixed point is precisely what the sequential fold computes.

The solver therefore Kleene-iterates the data-parallel closed form
(pacing scan -> tandem transport/media -> sort -> popped floor) from below
until it reaches a fixed point, then **certifies** the candidate:

* fixed point: one more sweep changes nothing;
* strict suffix property: ``min_{j>=i} done_j > popped_i`` for every i,
  which proves the sorted-pop identity held index by index, hence the
  candidate satisfies the *causal* recurrence, whose solution is unique.

A certified solution is tick-identical to ``TraceDriver`` — not "close",
identical (property-tested).  If the iteration does not converge inside
``max_sweeps`` (latency/window-bound traces, where the LFB feedback chains
through most of the trace), the lane raises :class:`ReplayUnsupported` and
callers fall back to the blocked scan — exactness is never bought with
silence.  Convergence is fast (2-4 sweeps) exactly in the streaming regime
the drivers are sized for: ``outstanding ~ latency/occupancy`` (Little's
law) makes the media occupancy chain, not the LFB ring, the binding
constraint.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.replay.engine import ReplayResult
from repro.core.replay.spec import (
    ASSOC_KINDS,
    DRAM,
    ReplayUnsupported,
    StackConfig,
    build_stack,
    trace_to_arrays,
)

def _neg(dtype):
    """"Never" sentinel for inactive elements of a gated chain: far enough
    below any tick that max() ignores it even after every accumulated
    service time is added, far enough above the dtype's minimum that the
    additions cannot wrap (callers already require the *real* tick range to
    stay well inside the dtype)."""
    return -(int(jnp.iinfo(dtype).max) // 4)


# ------------------------------------------------------------- primitives
def _affine_max(left, right):
    """Compose two affine-max transforms ``f -> max(f + A, B)`` (left first).

    This is the associative algebra every busy-until fold lives in: one
    element is ``A = svc_i, B = arr_i + svc_i``; a composed segment tracks
    the service it accumulates (A) and the best restart value (B).
    """
    a1, b1 = left
    a2, b2 = right
    return a1 + a2, jnp.maximum(b1 + a2, b2)


def busy_until(arrivals, services, active=None, init=None):
    """Associative form of the sequential busy-until fold.

    Sequential semantics (the switch-port / link / media / QoS
    virtual-finish-time rule)::

        free = init
        for i in range(N):
            if active[i]:
                free = max(arrivals[i], free) + services[i]
            out[i] = free

    ``services`` may vary per element (QoS-weighted paces, mixed transfer
    sizes); ``active`` gates elements that bypass the chain (e.g. cache
    hits on a fill path).  Returns the chain value right after each
    element, exactly equal to the fold (property-tested).  Log depth via
    :func:`jax.lax.associative_scan`.
    """
    arrivals = jnp.asarray(arrivals)
    services = jnp.asarray(services)
    neg = _neg(jnp.result_type(arrivals, services))
    if init is None:
        init = neg
    if active is None:
        a = services
        b = arrivals + services
    else:
        a = jnp.where(active, services, 0)
        b = jnp.where(active, arrivals + services, neg)
    cum_a, cum_b = jax.lax.associative_scan(_affine_max, (a, b))
    return jnp.maximum(init + cum_a, cum_b)


def port_busy_until(arrivals, services, ports, num_ports, init=0):
    """Associative form of P independent busy-until chains selected per
    element — the ECMP route-choice shape, where access *i* occupies port
    ``ports[i]`` out of the path set's port union.

    Sequential semantics::

        free = [init] * num_ports
        for i in range(N):
            free[ports[i]] = max(arrivals[i], free[ports[i]]) + services[i]
            out[i] = free[ports[i]]

    Each element is an affine-max transform on a (P,)-vector state that is
    one-hot in its own port; segments compose elementwise per port, so the
    whole interleaved multi-chain history is one associative scan over
    (N, P) accumulants.  Returns each element's own-port value after its
    update, exactly equal to the fold (property-tested).
    """
    arrivals = jnp.asarray(arrivals)
    services = jnp.asarray(services)
    ports = jnp.asarray(ports)
    neg = _neg(jnp.result_type(arrivals, services))
    onehot = jnp.arange(num_ports)[None, :] == ports[:, None]
    a = jnp.where(onehot, services[:, None], 0)
    b = jnp.where(onehot, (arrivals + services)[:, None], neg)
    cum_a, cum_b = jax.lax.associative_scan(_affine_max, (a, b))
    free = jnp.maximum(init + cum_a, cum_b)                    # (N, P)
    return jnp.take_along_axis(free, ports[:, None], axis=1)[:, 0]


def _local_sort(x, block):
    """Sort an array whose elements sit within ``block // 2`` positions of
    their sorted slot (bounded displacement).

    Completion streams have this shape: the media occupancy chain grows by
    at least ``occ`` per access, so two completions can only be out of
    order if their indices are within (tail spread / occ) of each other —
    a bound the caller computes from the device's timing constants.  Two
    passes of small independent sorts (aligned ``block``-wide rows, then
    rows offset by half a block) then produce the full sorted order at
    ~N log(block) cost, vectorized across rows — an order of magnitude
    cheaper than XLA:CPU's whole-array comparator sort at 200k elements.

    The solver certifies the result is globally sorted before trusting it
    (a sorted permutation IS the sort), so an undershot displacement bound
    surfaces as a refusal, never as silent divergence.
    """
    n = x.shape[0]
    big = jnp.iinfo(x.dtype).max
    pad = (-n) % block
    y = jnp.concatenate([x, jnp.full(pad, big, x.dtype)]) if pad else x
    m = y.shape[0]
    y = jnp.sort(y.reshape(-1, block), axis=1).reshape(-1)
    if m > block:
        h = block // 2
        mid = jnp.sort(y[h:m - h].reshape(-1, block), axis=1).reshape(-1)
        y = jnp.concatenate([y[:h], mid, y[m - h:]])
    return y[:n]


# ------------------------------------------------------------------ solver
class _NumpyOps:
    """CPU backend of the solver: numpy's accumulate/sort run the handful
    of vectorized passes in a few ms where XLA:CPU's comparator sort alone
    costs ~70ms at 200k elements."""

    xp = np

    @staticmethod
    def cummax(x):
        return np.maximum.accumulate(x)

    @staticmethod
    def rcummin(x):
        return np.minimum.accumulate(x[::-1])[::-1]

    @staticmethod
    def sort(x, sort_block):
        return np.sort(x)


class _JnpOps:
    """Accelerator backend: the same passes as eager jnp ops (few enough
    per sweep that dispatch overhead is irrelevant), with the sorted
    completion stream from the bounded-displacement block sort."""

    xp = jnp

    @staticmethod
    def cummax(x):
        return jax.lax.cummax(x)

    @staticmethod
    def rcummin(x):
        return jax.lax.cummin(x, reverse=True)

    @staticmethod
    def sort(x, sort_block):
        return _local_sort(x, sort_block)


def _solve_core(ops, cfg: StackConfig, p: Dict, addrs, writes, start_tick,
                max_sweeps: int, sort_block: int):
    """The certified Kleene solve, written once against a tiny ops shim so
    the numpy (CPU) and jnp (accelerator) backends share every formula.
    Returns ``(issues, dones, hit_flags, sweeps, certified)``."""
    xp = ops.xp
    n = int(addrs.shape[0])
    depth = cfg.outstanding
    start = int(start_tick)
    ar = xp.arange(n, dtype=xp.int64)
    ov = p["issue_ov"]

    # ---- elementwise media data: return-path tails + hit flags
    posted = writes if cfg.posted_writes else xp.zeros(n, bool)
    if cfg.kind == DRAM:
        tail = xp.where(posted, p["pack"], p["load"])
        hit = xp.zeros(n, bool)
    else:                                    # PMEM: row-buffer locality is
        row = addrs // p["row_bytes"]        # pure data, no timing feedback
        hit = xp.concatenate([xp.zeros(1, bool), row[1:] == row[:-1]])
        lat = p["lat"][xp.where(writes, 1, 0), xp.where(hit, 1, 0)]
        tail = xp.where(posted, p["pack"], lat)

    def stage(arr, svc):
        # constant-service busy-until chain seeded at 0 (fresh port/media)
        return xp.maximum(ops.cummax(arr - svc * ar), 0) + svc * (ar + 1)

    def forward(u):
        """Issue ticks -> completion ticks: the tandem of transport-hop and
        media busy-until chains, mirrored stage for stage."""
        t = u
        for h in range(cfg.num_hops):
            t = stage(t, p["hop_occ"][h]) + p["hop_after"][h]
        t = t + p["rt_extra"]
        return stage(t, p["occ"]) + tail

    def pacing(floor):
        return ops.cummax(floor - ov * ar) + ov * ar

    floor0 = xp.full(n, start, xp.int64)
    floor, sorted_ok = floor0, True
    u = pacing(floor0)
    dones = floor0
    converged = False
    sweeps = 0
    for sweeps in range(1, max_sweeps + 1):
        dones = forward(u)
        if n > depth:
            srt = ops.sort(dones, sort_block)
            sorted_ok = bool((srt[1:] >= srt[:-1]).all())
            floor = xp.where(ar < depth, start,
                             srt[xp.clip(ar - depth, 0, n - 1)])
        u2 = pacing(floor)
        if bool((u2 == u).all()):
            converged = True
            break
        u = u2
    # On convergence ``dones == forward(u)`` (the sweep evaluated forward
    # on the value it converged to).  Certification: converged => fixed
    # point; the popped stream was genuinely sorted (a sorted permutation
    # of the completions IS their sort, so an undershot displacement bound
    # in the block sort surfaces here); and the strict suffix property
    # proves the sorted-pop identity was valid at every index — together
    # the candidate solves the causal recurrence, whose solution is unique.
    suffmin = ops.rcummin(dones)
    certified = (converged and sorted_ok
                 and bool((suffmin > floor).all()))
    return (np.asarray(u), np.asarray(dones), np.asarray(hit), sweeps,
            certified)


# ------------------------------------------------------------------ facade
class AssocReplayEngine:
    """Fully data-parallel stand-in for :class:`TraceDriver` on stateless
    media stacks (``dram``, ``cxl-dram``, ``pmem``, directly attached or
    fabric-mounted on a single route).

    ``run`` either returns ticks **identical** to
    ``TraceDriver(device, ...).run`` or raises :class:`ReplayUnsupported`
    (stateful media, ECMP fan-out, or a latency-bound trace whose LFB
    feedback defeats the ``max_sweeps`` budget) — never a silently
    approximate result.  Fall back to ``engine="scan"`` on refusal.
    """

    def __init__(self, device, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5,
                 posted_writes: bool = True, max_sweeps: int = 24,
                 backend: str = "auto") -> None:
        if backend not in ("auto", "numpy", "jax"):
            raise ValueError(f"backend must be auto|numpy|jax, got "
                             f"{backend!r}")
        self.device = device
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes
        self.max_sweeps = max(1, int(max_sweeps))
        self.backend = backend

    def run(self, trace, start_tick: int = 0) -> ReplayResult:
        addrs, writes, size = trace_to_arrays(trace)
        return self.run_arrays(addrs, writes, size=size,
                               start_tick=start_tick)

    def run_arrays(self, addrs: np.ndarray, writes: np.ndarray, *,
                   size: int = 64, start_tick: int = 0) -> ReplayResult:
        addrs = np.asarray(addrs, np.int64)
        writes = np.asarray(writes, bool)
        if addrs.size == 0:
            raise ReplayUnsupported("empty trace")
        if start_tick < 0 and getattr(getattr(self.device, "fabric", None),
                                      "qos_enabled", False):
            # same contract as ReplayEngine: the lone-origin QoS no-floor
            # proof assumes non-negative ticks
            raise ReplayUnsupported(
                "QoS replay needs start_tick >= 0; use engine='python'")
        plan = getattr(self.device, "fault_plan", None)
        if plan is None:
            plan = getattr(getattr(self.device, "fabric", None),
                           "fault_plan", None)
        if plan is not None and plan.active:
            raise ReplayUnsupported(
                f"active fault plan ({', '.join(plan.class_names())}) "
                "perturbs per-access service times with no associative "
                "closed form; the fused scan lane replays every fault "
                "class tick-identically — use engine='scan' (or "
                "engine='python')")
        cfg, params = build_stack(
            self.device, size=size, outstanding=self.outstanding,
            issue_overhead_ns=self.issue_overhead_ns,
            posted_writes=self.posted_writes, n_accesses=addrs.size,
            max_addr=int(addrs.max(initial=0)))
        if cfg.kind not in ASSOC_KINDS:
            raise ReplayUnsupported(
                f"{cfg.kind!r} media keeps per-access state (cache frames / "
                "flash FTL) with no associative closed form; use "
                "engine='scan' (optionally blocked)")
        if cfg.num_routes > 1:
            raise ReplayUnsupported(
                "ECMP stacks occupy a different port set per access; the "
                "associative lane covers single-route mounts — use "
                "engine='scan'")
        min_lat = int(np.sum(params["hop_occ"]) + np.sum(params["hop_after"])
                      + params["rt_extra"] + params["occ"])
        if min_lat < 1:
            # the sorted-pop certification needs completions to strictly
            # exceed their issue ticks
            raise ReplayUnsupported(
                "zero-latency stack cannot be certified; use engine='scan'")
        occ = int(params["occ"])
        if occ < 1:
            raise ReplayUnsupported(
                "zero media occupancy voids the bounded-displacement sort "
                "(completions need not be locally ordered); use "
                "engine='scan'")
        # Completion displacement bound: the media chain grows >= occ per
        # access, so two completions can only swap order within
        # (tail spread / occ) indices — the block width of the local sort.
        if cfg.kind == DRAM:
            tails = [int(params["load"])]
        else:
            tails = [int(t) for t in np.asarray(params["lat"]).ravel()]
        if self.posted_writes:
            tails.append(int(params["pack"]))
        spread = max(tails) - min(tails)
        sort_block = max(32, 2 * (spread // occ + 1))
        backend = self.backend
        if backend == "auto":
            backend = "numpy" if jax.default_backend() == "cpu" else "jax"
        if backend == "numpy":
            issues, dones, hits, sweeps, certified = _solve_core(
                _NumpyOps, cfg, params, addrs, writes, start_tick,
                self.max_sweeps, sort_block)
        else:
            with enable_x64():
                pj = jax.tree.map(jnp.asarray, params)
                issues, dones, hits, sweeps, certified = _solve_core(
                    _JnpOps, cfg, pj, jnp.asarray(addrs),
                    jnp.asarray(writes), start_tick, self.max_sweeps,
                    sort_block)
        if not certified:
            raise ReplayUnsupported(
                f"associative solve not certified after "
                f"{sweeps}/{self.max_sweeps} sweeps (latency-bound "
                "trace: the LFB feedback chains through the whole "
                "trace); use engine='scan'")
        self._last_sweeps = int(sweeps)
        first = int(issues[0])
        last = max(int(dones.max(initial=0)), start_tick)
        return ReplayResult(
            accesses=int(addrs.size),
            bytes_moved=int(addrs.size) * size,
            elapsed_ticks=last - first,
            sum_latency_ticks=int((dones - issues).sum()),
            end_tick=last,
            latency_ticks=dones - issues,
            hit_flags=hits,
            evict_flags=np.zeros(addrs.size, bool),
        )
