"""Fused multi-host replay: N hosts interleaved in one :func:`jax.lax.scan`.

The scan reproduces :class:`repro.core.workloads.driver.MultiHostDriver`'s
global issue ordering exactly: each step selects the host with the earliest
candidate issue tick (``max(own clock, own oldest LFB slot)``, ties to the
lowest host index — the heap's ``(tick, index)`` order), pops that host's
next access, walks its precomputed route over the *shared* per-port
busy-until vector, and serializes on the target device's media state.
Contention between hosts therefore emerges from the same shared state as in
the interpreted driver, tick for tick.

The device media is the stackable state layer of
:mod:`repro.core.replay.stack`: one private media lane per mounted device
(per host in mount mode, per pool device in pool mode) over zero or more
flash instances — so the full cached-CXL-SSD stack replays fused, including
the pooled-flash shape (per-host private DRAM caches sharing one FTL/PAL
flash array, built by handing several :class:`CachedCXLSSDDevice` front
ends one ``hil=``) and greedy FTL garbage collection.

QoS and ECMP are mirrored operation-for-operation:

* **ECMP** — the per-access route choice is precomputed host-side with the
  same :func:`~repro.core.fabric.routing.flow_choices` hash the interpreted
  path evaluates per access, and the hop tensors gain a route axis.
* **QoS** — per-port per-host virtual-finish-time and last-arrival carries
  replicate :meth:`SwitchPort.qos_update`: the weight sum runs over hosts
  in sorted-name order (the same float64 add order as the Python ``dict``
  walk), the pace uses the identical ``int(occ * (W / w))`` truncation, and
  the resulting floor binds the final host acknowledgment only — the
  physical port walk is untouched, exactly like the interpreted path.

Supported targets (homogeneous): :class:`FabricAttachedDevice` mounts and
:class:`HostPortView` pool views over any media the stack layer models —
DRAM-class (heterogeneous timing allowed), PMEM, CXL-SSD, cached CXL-SSD
(lru/fifo/direct, identical configuration across targets).  The pool's
address mapper is applied host-side (it is a pure function of the address),
so interleave and segment modes cost nothing in the scan.  Anything else
raises :class:`ReplayUnsupported` naming the widest lane that still covers
the shape (the ``engine='python'`` fallback) — lanes refuse, they never
silently diverge.

Transport faults (link CRC-retry bursts, port/link down windows with ECMP
exclusion and failover reroutes, poison status) mirror tick-identically on
per-host mounts: every (host, ordinal) pair walks the same pure
:meth:`Fabric.select_faulted` route selection the interpreted mount
performs — keyed on that host's own access ordinal, exactly the per-mount
``_fault_ord`` counter — and the hop columns ride per-access ``(H, L,
max_hops)`` tensors with CRC retries pre-charged into the physical
occupancy while the QoS virtual clock paces on the clean column.  Pool
views with link/down faults refuse (interleaving scrambles the per-host
fault ordinals); an unreachable down segment raises
:class:`~repro.core.faults.DeviceUnreachable` at prepare, matching the
first access the python driver would fail on.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.devices import (CXLDRAMDevice, DRAMDevice, NullLink,
                                POSTED_ACK_NS)
from repro.core.engine import ns
from repro.core.fabric.fabric import LINE_BYTES, Fabric, FabricAttachedDevice
from repro.core.fabric.pool import HostPortView
from repro.core.fabric.routing import flow_choices, flow_hash
from repro.core.fabric.switch import ACTIVE_WINDOW_OCC
from repro.core.replay import stack
from repro.core.replay.spec import (DRAM, ReplayUnsupported, StackConfig,
                                    media_stack, trace_to_arrays,
                                    validate_block_size,
                                    validate_trace_columns)
from repro.core.replay.stack import MAX_ACCESSES, _i64
from repro.core.workloads.driver import MultiHostResult, TraceResult

BIG = 1 << 62
# "never arrived" sentinel for the QoS last-arrival carry: far enough below
# zero that sentinel + activity window can never exceed a valid tick.
NEVER = -(1 << 61)


@dataclass(frozen=True)
class MultiCfg:
    num_hosts: int
    outstanding: int
    posted_writes: bool
    num_ports: int
    max_hops: int
    num_devs: int
    stack: StackConfig           # media/flash statics (transportless)
    n_flash: int = 0             # flash instances (0 for flash-less media)
    max_routes: int = 1
    qos: bool = False
    # host indices in sorted-host-name order: the QoS weight sum must add
    # floats in exactly the order SwitchPort.qos_update's sorted() walk does
    host_order: Tuple[int, ...] = ()
    # transport faults active: hop columns ride per-access (H, L, max_hops)
    # tensors instead of the static per-(host, dev, route) hop tensors
    fault_hops: bool = False


def _port_index(fabric: Fabric) -> Dict[Tuple[str, str], int]:
    return {key: i for i, key in enumerate(sorted(fabric.ports))}


def _route_rows(fabric: Fabric, host: str, node: str, size: int,
                pidx: Dict[Tuple[str, str], int], max_hops: int,
                choice: int):
    hops = fabric.route_occupancy(host, node, size, choice=choice)
    if len(hops) > max_hops:
        raise AssertionError("max_hops underestimated")
    port = np.zeros(max_hops, np.int32)
    occ = np.zeros(max_hops, np.int64)
    after = np.zeros(max_hops, np.int64)
    on = np.zeros(max_hops, bool)
    for h, (key, occ_h, after_h) in enumerate(hops):
        port[h] = pidx[key]
        occ[h] = occ_h
        after[h] = after_h
        on[h] = True
    return port, occ, after, on


def _extract_targets(targets: Sequence, size: int):
    """Shared fabric + route/QoS tensors and metadata for mounts or pool
    views (the media half is extracted separately by :func:`_media_setup`,
    which needs the mapped address range)."""
    first = targets[0]
    if isinstance(first, FabricAttachedDevice):
        fabric = first.fabric
        if not all(isinstance(t, FabricAttachedDevice)
                   and t.fabric is fabric for t in targets):
            raise ReplayUnsupported("hosts must share one fabric")
        hosts = [t.host for t in targets]
        nodes = [t.device_node for t in targets]
        inners = [t.inner for t in targets]
        dev_of = {n: i for i, n in enumerate(nodes)}
        if len(dev_of) != len(nodes):
            raise ReplayUnsupported(
                "fused mount mode needs one private device per host "
                "(share devices through a MemoryPool instead)")
        mapper = None
    elif isinstance(first, HostPortView):
        pool = first.pool
        if not all(isinstance(t, HostPortView) and t.pool is pool
                   for t in targets):
            raise ReplayUnsupported("pool views must share one MemoryPool")
        fabric = pool.fabric
        hosts = [t.host for t in targets]
        nodes = pool.device_nodes
        inners = list(pool.devices)
        mapper = pool.mapper
    else:
        raise ReplayUnsupported(
            f"multi-host fused replay supports FabricAttachedDevice / "
            f"HostPortView targets, got {type(first).__name__}; "
            "use engine='python'")
    for t in list(targets) + inners:
        if t.stats.get("bytes", 0):
            raise ReplayUnsupported("targets must be fresh (no prior traffic)")
    if fabric.stats.get("transfers", 0):
        raise ReplayUnsupported(
            "fabric has prior traffic; replay snapshots a fresh fabric "
            "(Fabric.reset() or re-build it, or use engine='python')")
    qos = fabric.qos_enabled
    if qos and len(set(hosts)) != len(hosts):
        raise ReplayUnsupported(
            "QoS arbitration keys per-origin state by host name; give each "
            "host view a distinct host node (or use engine='python')")
    plan = getattr(fabric, "fault_plan", None)
    if plan is None:
        plan = next((q for q in (getattr(t, "fault_plan", None)
                                 for t in targets) if q is not None), None)
    if plan is not None and not plan.active:
        plan = None
    if (plan is not None and (plan.has_link or plan.has_down)
            and mapper is not None):
        raise ReplayUnsupported(
            f"multi-host fused replay mirrors transport faults "
            f"({', '.join(plan.class_names())}) on per-host fabric mounts "
            "only — pool address interleaving scrambles the per-host fault "
            "ordinals; use engine='python' for faulted pools")

    pidx = _port_index(fabric)
    pairs = ([(i, i) for i in range(len(hosts))] if mapper is None else
             [(i, d) for i in range(len(hosts)) for d in range(len(nodes))])
    max_hops = max(fabric.routing.hops(hosts[i], nodes[d]) for i, d in pairs)
    H, NDEV = len(hosts), len(nodes)
    route_count = np.ones((H, NDEV), np.int32)
    for i, d in pairs:
        route_count[i, d] = len(fabric.paths(hosts[i], nodes[d]))
    K = int(route_count.max())
    hop_port = np.zeros((H, NDEV, K, max_hops), np.int32)
    hop_occ = np.zeros((H, NDEV, K, max_hops), np.int64)
    hop_after = np.zeros((H, NDEV, K, max_hops), np.int64)
    hop_on = np.zeros((H, NDEV, K, max_hops), bool)
    for i, h in enumerate(hosts):
        for d, n in enumerate(nodes):
            if mapper is None and d != i:
                continue        # mount mode: host i only reaches device i
            for k in range(route_count[i, d]):
                (hop_port[i, d, k], hop_occ[i, d, k], hop_after[i, d, k],
                 hop_on[i, d, k]) = _route_rows(fabric, h, n, size, pidx,
                                                max_hops, k)
    params = {
        "hop_port": hop_port, "hop_occ": hop_occ, "hop_after": hop_after,
        "hop_on": hop_on,
        "rt_extra": ns(fabric.rt_extra_ns),
    }
    host_order: Tuple[int, ...] = ()
    if qos:
        ports_sorted = sorted(fabric.ports)
        params["qos_on"] = np.asarray(
            [fabric.ports[key].qos_enabled for key in ports_sorted], bool)
        params["qos_w"] = np.asarray(
            [[fabric.ports[key].weight_of(hname) for hname in hosts]
             for key in ports_sorted], np.float64)
        host_order = tuple(int(j) for j in
                           sorted(range(H), key=lambda j: hosts[j]))
    # transport faults ride the fabric: the interpreted mount passes an
    # ordinal into traverse_qos only when the plan sits on the *fabric*
    # (FabricAttachedDevice.service checks fabric.fault_plan), so the
    # fused columns key on exactly that
    fab_plan = getattr(fabric, "fault_plan", None)
    if fab_plan is not None and not fab_plan.active:
        fab_plan = None
    transport_plan = (fab_plan if fab_plan is not None
                      and (fab_plan.has_link or fab_plan.has_down) else None)
    meta = dict(fabric=fabric, mapper=mapper, hosts=hosts, nodes=nodes,
                inners=inners, route_count=route_count, qos=qos,
                host_order=host_order, num_ports=len(pidx),
                max_hops=max_hops, max_routes=K, num_devs=NDEV,
                fault_plan=plan, transport_plan=transport_plan)
    return params, meta


def _dram_class(dev):
    """Bare DRAM, or CXL-DRAM whose private link the fabric mount
    neutralized (the only shapes with per-device timing arrays)."""
    if isinstance(dev, DRAMDevice):
        return dev
    if isinstance(dev, CXLDRAMDevice) and isinstance(dev.link, NullLink):
        return dev.dram
    return None


def _params_equal(a: Dict, b: Dict) -> bool:
    return set(a) == set(b) and all(np.array_equal(a[k], b[k]) for k in a)


def _media_setup(inners: Sequence, *, size: int, outstanding: int,
                 posted_writes: bool, n_accesses: int, max_addr: int,
                 counters: bool = False):
    """The media half of the multi-host stack: one
    :class:`~repro.core.replay.spec.StackConfig` shared by every target,
    media timing params, and the media-lane -> flash-instance map (deduped
    by the backing :class:`HIL` object, so front ends built over one shared
    ``hil=`` contend on one flash state — exactly like the interpreted
    path).  Heterogeneous timing is allowed for DRAM-class media (per-device
    arrays); every other kind must be identically configured."""
    specs = [media_stack(d, size=size, outstanding=outstanding,
                         posted_writes=posted_writes, n_accesses=n_accesses,
                         max_addr=max_addr, counters=counters)
             for d in inners]
    cfg0, mp0 = specs[0]
    for k, (cfgk, mpk) in enumerate(specs[1:], start=1):
        if cfgk != cfg0 or (cfg0.kind != DRAM
                            and not _params_equal(mpk, mp0)):
            raise ReplayUnsupported(
                f"multi-host targets must be identically configured "
                f"({cfg0.kind!r} media differs at target {k}); "
                "use engine='python'")
    if cfg0.kind == DRAM:
        drams = [_dram_class(d) for d in inners]
        media_params = {
            "dev_occ": np.asarray([ns(size / d.t.bw_gbps) for d in drams],
                                  np.int64),
            "dev_load": np.asarray([ns(d.t.load_ns) for d in drams],
                                   np.int64),
            "dev_pack": np.asarray([ns(POSTED_ACK_NS)] * len(drams),
                                   np.int64),
        }
        return cfg0, media_params, np.zeros(len(inners), np.int32), 0
    if not stack.has_flash(cfg0):
        return cfg0, mp0, np.zeros(len(inners), np.int32), 0
    flash_lane: Dict[int, int] = {}
    flash_of = np.zeros(len(inners), np.int32)
    for i, d in enumerate(inners):
        flash_of[i] = flash_lane.setdefault(id(d.hil), len(flash_lane))
    return cfg0, mp0, flash_of, len(flash_lane)


def _multi_init(cfg: MultiCfg, start_tick, mspec=None,
                want_lat: bool = True):
    """The full multi-host carry pytree at ``start_tick`` — per-host LFB
    slots / clocks / trace cursors, shared port busy-untils, stamp counter,
    stacked media/flash state, the QoS virtual-finish / last-arrival
    tables, and the aux accumulators.  Built eagerly by the chunked driver
    (buffer-donated across chunk calls) and traced by :func:`_run_multi`;
    identical structure either way, which is what makes chunked multi-host
    replay tick-identical to one-shot."""
    H, O = cfg.num_hosts, cfg.outstanding
    state0 = stack.init_state(cfg.stack, cfg.num_devs,
                              cfg.n_flash if cfg.n_flash else None)
    aux0 = {}
    if mspec is not None:
        from repro.core.replay import metrics as _metrics
        aux0["acc"] = jnp.zeros(
            (_metrics.acc_rows(mspec, H, cfg.num_devs), 4), jnp.int64)
        aux0["med"] = jnp.zeros(
            (cfg.num_devs, len(_metrics.MEDIA_COUNTERS[cfg.stack.kind])),
            jnp.int64)
        aux0["q"] = jnp.zeros(cfg.num_ports, jnp.int64)
        if cfg.qos:
            aux0["qthr"] = jnp.zeros(cfg.num_ports, jnp.int64)
        fc0 = stack.flash_counters(state0)
        if fc0 is not None:
            # snapshot carry: padded steps are strictly trailing, so the
            # last *valid* snapshot is the true end-of-trace total
            aux0["flash"] = fc0
        if cfg.stack.faults:
            aux0["faults"] = jnp.stack(stack.fault_counters(state0))
    if not want_lat:
        aux0["first"] = jnp.full(H, BIG, jnp.int64)
        aux0["last"] = jnp.full(H, start_tick, jnp.int64)
        aux0["sum"] = jnp.zeros(H, jnp.int64)
        aux0["cnt"] = jnp.zeros(H, jnp.int64)
        aux0["bad"] = jnp.zeros((), bool)
        aux0["gcs"] = _i64(0)
    return (jnp.full((H, O), start_tick, jnp.int64),   # per-host LFB slots
            jnp.full(H, start_tick, jnp.int64),        # per-host issue clock
            jnp.zeros(H, jnp.int64),                   # per-host trace index
            jnp.zeros(cfg.num_ports, jnp.int64),       # shared port busy
            _i64(1),                                   # global stamp counter
            # stacked media/flash state: one lane per mounted device
            state0,
            # QoS: per-port per-host virtual finish + last arrival
            jnp.zeros((cfg.num_ports, H), jnp.int64),
            jnp.full((cfg.num_ports, H), NEVER, jnp.int64),
            aux0)


def _make_multi_step(cfg: MultiCfg, p: Dict, lens, lookup, mspec=None,
                     want_lat: bool = True, size: int = 64):
    """The per-step body of the multi-host scan, parameterized by
    ``lookup(i, ix) -> (addr, write, dev, route, fault_cols)`` so the same
    compiled logic can read either the full padded ``(H, L)`` trace arrays
    (the one-shot path) or a per-host ``(H, S)`` sliding window re-based on
    the carry's trace cursors (the chunked path).  ``fault_cols`` is
    ``None`` on the clean path; under an active transport plan it is a
    dict of five per-access hop columns (port / charged occupancy / after /
    on-mask / clean occupancy) — the QoS mirror paces on the *clean*
    occupancy while the physical busy-until charges retries, exactly like
    ``SwitchPort.qos_update`` + ``transmit(retries=...)``."""
    H = cfg.num_hosts

    def step(carry, _):
        slots, now, idx, port_busy, ctr, st, vft, last_arr, aux = carry
        cand = jnp.where(idx < lens,
                         jnp.maximum(now, jnp.min(slots, axis=1)), BIG)
        i = jnp.argmin(cand)                 # ties -> lowest host index
        valid = idx[i] < lens[i]             # padded steps are trailing
        row = slots[i]
        k = jnp.argmin(row)
        issue = jnp.maximum(now[i], row[k])
        a, wr, dev, r, fc = lookup(i, idx[i])
        posted = wr if cfg.posted_writes else jnp.zeros((), bool)
        t = issue
        floor = _i64(0)
        qacc = aux.get("q")
        qthr = aux.get("qthr")
        for h in range(cfg.max_hops):
            if fc is not None:
                on = fc["on"][h]
                pi = fc["p"][h]
                occ_h = fc["o"][h]      # retries charged: occ * (1 + r)
                occ_c = fc["oc"][h]     # clean: the QoS entitlement
                after_h = fc["a"][h]
            else:
                on = p["hop_on"][i, dev, r, h]
                pi = p["hop_port"][i, dev, r, h]
                occ_h = p["hop_occ"][i, dev, r, h]
                occ_c = occ_h
                after_h = p["hop_after"][i, dev, r, h]
            if cfg.qos:
                # mirror of SwitchPort.qos_update at arrival tick t
                qon = on & p["qos_on"][pi]
                prev = vft[pi, i]
                win = occ_c * ACTIVE_WINDOW_OCC
                w_active = jnp.float64(0.0)
                for j in cfg.host_order:   # sorted-name order, like dict walk
                    member = (j == i) | (last_arr[pi, j] + win > t)
                    w_active = w_active + jnp.where(member, p["qos_w"][pi, j],
                                                    0.0)
                pace = (occ_c.astype(jnp.float64)
                        * (w_active / p["qos_w"][pi, i])).astype(jnp.int64)
                floor = jnp.maximum(
                    floor, jnp.where(qon & (prev > t), prev + pace, 0))
                vft = vft.at[pi, i].set(
                    jnp.where(qon, jnp.maximum(prev, t) + pace, prev))
                last_arr = last_arr.at[pi, i].set(
                    jnp.where(qon, t, last_arr[pi, i]))
                if qthr is not None:
                    # SwitchPort.qos_update's nonzero-floor return is the
                    # python qos_throttle_events bump, hop for hop
                    qthr = qthr.at[pi].add(
                        jnp.where(qon & (prev > t) & valid, 1, 0))
            start = jnp.maximum(t, port_busy[pi])
            if qacc is not None:
                # SwitchPort.transmit: queued_ticks += start - now
                qacc = qacc.at[pi].add(jnp.where(on & valid, start - t, 0))
            done_h = start + occ_h
            port_busy = port_busy.at[pi].set(
                jnp.where(on, done_h, port_busy[pi]))
            t = jnp.where(on, done_h + after_h, t)
        t = t + p["rt_extra"]
        if cfg.stack.kind == DRAM:
            # DRAM-class media keeps per-device timing arrays (heterogeneous
            # pools); the stack step reads its scalar names
            p_med = {"occ": p["dev_occ"][dev], "load": p["dev_load"][dev],
                     "pack": p["dev_pack"][dev]}
        else:
            p_med = p
        st, out = stack.step(cfg.stack, p_med, st, dict(
            lane=dev, flash_lane=(p["flash_of"][dev] if cfg.n_flash else 0),
            t=t, addr=a, write=wr, posted=posted, ctr=ctr))
        done = out["done"]
        if cfg.qos:
            done = jnp.maximum(done, floor)   # ack floor, data path untouched
        bad, gcs = stack.flash_health(st)
        if mspec is not None:
            from repro.core.replay import metrics as _metrics
            aux = {**aux,
                   "acc": _metrics.acc_update(
                       mspec, aux["acc"], host=i, dev=dev, n_hosts=H,
                       n_devs=cfg.num_devs, issue=issue, done=done,
                       size=size, hit=out["hit"], valid=valid),
                   "med": aux["med"].at[dev].add(
                       _metrics.media_increments(cfg.stack.kind, wr, out)
                       * jnp.where(valid, 1, 0)),
                   "q": qacc}
            if qthr is not None:
                aux = {**aux, "qthr": qthr}
            if "flash" in aux:
                aux = {**aux, "flash": jnp.where(
                    valid, stack.flash_counters(st), aux["flash"])}
            if "faults" in aux:
                aux = {**aux, "faults": jnp.where(
                    valid, jnp.stack(stack.fault_counters(st)),
                    aux["faults"])}
        if not want_lat:
            neg = _i64(-BIG)
            aux = {**aux,
                   "first": aux["first"].at[i].min(
                       jnp.where(valid, issue, BIG)),
                   "last": aux["last"].at[i].max(
                       jnp.where(valid, done, neg)),
                   "sum": aux["sum"].at[i].add(
                       jnp.where(valid, done - issue, 0)),
                   "cnt": aux["cnt"].at[i].add(jnp.where(valid, 1, 0)),
                   "bad": aux["bad"] | (bad & valid),
                   "gcs": jnp.where(valid, gcs, aux["gcs"])}
        slots = slots.at[i, k].set(done)
        now = now.at[i].set(issue + p["issue_ov"])
        idx = idx.at[i].set(idx[i] + 1)
        ys = (i, issue, done, bad, gcs) if want_lat else None
        return ((slots, now, idx, port_busy, ctr + 1, st, vft, last_arr,
                 aux), ys)

    return step


@functools.partial(jax.jit, static_argnums=(0, 7, 8, 9, 10))
def _run_multi(cfg: MultiCfg, p: Dict, devs, addrs, writes, lens, start_tick,
               block: int = 1, mspec=None, want_lat: bool = True,
               size: int = 64):
    init = _multi_init(cfg, start_tick, mspec, want_lat)

    def lookup(i, ix):
        r = p["route"][i, ix] if cfg.max_routes > 1 else 0
        fc = ({"p": p["fhp"][i, ix], "o": p["fho"][i, ix],
               "a": p["fha"][i, ix], "on": p["fhon"][i, ix],
               "oc": p["fhoc"][i, ix]} if cfg.fault_hops else None)
        return addrs[i, ix], writes[i, ix], devs[i, ix], r, fc

    step = _make_multi_step(cfg, p, lens, lookup, mspec, want_lat, size)
    # Blocked replay: `block` steps per sequential scan iteration (unroll).
    # The carry — including the per-host candidate race state (slots, now,
    # idx) — crosses block seams untouched, so the earliest-candidate-host
    # selection and its lowest-index tie-break behave identically whether a
    # tie lands mid-block or exactly on a seam (regression-tested).
    n_total = addrs.shape[0] * addrs.shape[1]
    carry, ys = jax.lax.scan(step, init, None, length=n_total, unroll=block)
    who, issues, dones, bad, gcs = (ys if want_lat
                                    else (None, None, None, None, None))
    return who, issues, dones, bad, gcs, carry[8]


@functools.partial(jax.jit, static_argnums=(0, 6, 7, 8, 9),
                   donate_argnums=(1,))
def _run_multi_chunk(cfg: MultiCfg, carry, p: Dict, wins: Dict, lens, base,
                     block: int = 1, mspec=None, want_lat: bool = True,
                     size: int = 64):
    """One jitted window of the chunked multi-host replay: ``S`` scan steps
    over per-host ``(H, S)`` trace windows, each window starting at that
    host's ``base`` cursor.  Every step consumes at most one access from
    exactly one host, so ``S`` steps can never outrun an ``S``-wide
    window; trailing padded reads (an exhausted host re-picked once all
    candidates hit the sentinel) clip into the window and are discarded by
    the same validity gates as the one-shot path.  The carry is donated —
    threading state across an arbitrarily long trace allocates O(window),
    not O(trace)."""
    S = wins["addr"].shape[1]

    def lookup(i, ix):
        j = jnp.clip(ix - base[i], 0, S - 1)
        r = wins["route"][i, j] if cfg.max_routes > 1 else 0
        fc = ({"p": wins["fhp"][i, j], "o": wins["fho"][i, j],
               "a": wins["fha"][i, j], "on": wins["fhon"][i, j],
               "oc": wins["fhoc"][i, j]} if cfg.fault_hops else None)
        return wins["addr"][i, j], wins["wr"][i, j], wins["dev"][i, j], r, fc

    step = _make_multi_step(cfg, p, lens, lookup, mspec, want_lat, size)
    return jax.lax.scan(step, carry, None, length=S, unroll=block)


def _map_addrs(mapper, host_idx: int, addrs: np.ndarray):
    """Host-side pool address mapping (pure per-address arithmetic)."""
    if mapper is None:
        return np.full(addrs.shape, host_idx, np.int32), addrs
    if mapper.mode == "interleave":
        frame, off = np.divmod(addrs, mapper.granularity)
        dev = (frame % mapper.num_devices).astype(np.int32)
        local = (frame // mapper.num_devices) * mapper.granularity + off
        return dev, local
    dev64, local = np.divmod(addrs, mapper.segment_bytes)
    if (dev64 >= mapper.num_devices).any():
        raise ReplayUnsupported("address beyond pool capacity")
    return dev64.astype(np.int32), local


# -------------------------------------------------- transport fault columns
def _fault_cols_multi(meta: Dict, plan, addrs: np.ndarray,
                      lens: np.ndarray, size: int):
    """Per-host per-access transport hop columns under the installed
    link-retry / down-window plan — the multi-host twin of the single-host
    :class:`~repro.core.replay.engine._FaultColumnBuilder`, with the host
    axis and the *global* sorted-port index (so the shared ``port_busy`` /
    QoS ``vft``/``last_arr`` carries and the ``qos_on``/``qos_w`` params
    keep their indexing untouched).

    Every (host, ordinal) walks the same pure route selection the
    interpreted mount performs (:meth:`Fabric.select_faulted`, keyed on
    that host's *own* access ordinal — the per-mount ``_fault_ord``
    counter) and the same per-hop occupancy rule, pre-charging CRC-retry
    serializations into the occupancy column; the clean occupancy rides a
    separate column for the QoS virtual clock.  Raises
    :class:`~repro.core.faults.DeviceUnreachable` at precompute for the
    same segments the python driver would fail on.

    Returns ``(cols, num_hops, faulted, fstats, deg, fo)``: the five
    ``(H, L, num_hops)`` hop columns, the widest (failover-inclusive) hop
    count, the accumulated per-port/per-host/ECMP totals for
    :func:`~repro.core.replay.metrics.bundle_multi_fused`'s ``faulted=``
    override, the shared fault-counter totals, and per-host ``(H, L)``
    degraded/failover availability flags."""
    fab = meta["fabric"]
    hosts, nodes = meta["hosts"], meta["nodes"]
    pidx = _port_index(fab)
    P = len(pidx)
    H, L = addrs.shape
    lens = np.asarray(lens, np.int64)
    # candidate path set per host: one entry per distinct down segment —
    # the route chosen for an ordinal depends only on its segment's down
    # set and the flow hash, never on the ordinal itself
    occ_of: List[Dict[Tuple[str, ...], list]] = [dict() for _ in range(H)]
    for i in range(H):
        n_i = int(lens[i])
        if not n_i:
            continue
        segs = (plan.down_segments(n_i) if plan.has_down
                else [(0, n_i, frozenset())])
        for _, _, down in segs:
            ps = fab.routing.paths(hosts[i], nodes[i], down=down)
            for q in (ps if fab.ecmp else [ps[0]]):
                key = tuple(q)
                if key not in occ_of[i]:
                    occ_of[i][key] = fab.path_occupancy(q, size)
    FH = max((len(hops) for d in occ_of for hops in d.values()), default=1)
    fhp = np.zeros((H, L, FH), np.int32)
    fho = np.zeros((H, L, FH), np.int64)
    fha = np.zeros((H, L, FH), np.int64)
    fhon = np.zeros((H, L, FH), bool)
    fhoc = np.zeros((H, L, FH), np.int64)
    deg = np.zeros((H, L), bool)
    fo = np.zeros((H, L), bool)
    pkts = np.zeros(P, np.int64)
    occt = np.zeros(P, np.int64)
    by_host = np.zeros((P, H), np.int64)
    ecmp: Dict[str, List[int]] = {}
    link_retries = failovers = degraded = 0
    for i in range(H):
        host, node = hosts[i], nodes[i]
        K = len(fab.paths(host, node))
        for j in range(int(lens[i])):
            line_addr = int(addrs[i, j]) // LINE_BYTES
            path, dg, fv = fab.select_faulted(host, node, line_addr, j)
            if dg:
                deg[i, j] = True
                degraded += 1
                if fv:
                    fo[i, j] = True
                    failovers += 1
            elif fab.ecmp and K > 1:
                # mirror traverse_qos: clean ECMP choices still count
                k = flow_hash(host, node, line_addr) % K
                ecmp.setdefault(f"{host}->{node}", [0] * K)[k] += 1
            for h, (pk, occ, after) in enumerate(occ_of[i][tuple(path)]):
                rt = plan.link_retries(pk, j) if plan.has_link else 0
                link_retries += rt
                pi = pidx[pk]
                fhp[i, j, h] = pi
                fho[i, j, h] = occ * (1 + rt)
                fha[i, j, h] = after
                fhon[i, j, h] = True
                fhoc[i, j, h] = occ
                pkts[pi] += 1
                occt[pi] += occ * (1 + rt)
                by_host[pi, i] += size    # goodput: retries move 0 bytes
    faulted = {"port_keys": sorted(fab.ports), "packets": pkts,
               "bytes": pkts * size, "occupied": occt, "by_host": by_host,
               "ecmp": ecmp}
    fstats = {"link_retries": int(link_retries),
              "failovers": int(failovers),
              "degraded_accesses": int(degraded)}
    cols = {"fhp": fhp, "fho": fho, "fha": fha, "fhon": fhon, "fhoc": fhoc}
    return cols, FH, faulted, fstats, deg, fo


class MultiHostReplay:
    """Fused, vectorized stand-in for :class:`MultiHostDriver` (pooled or
    per-host fabric targets over any stack-layer media — DRAM-class, PMEM,
    CXL-SSD, cached CXL-SSD with private or shared flash — QoS weights,
    ECMP, and greedy FTL GC included).  ``run`` is tick-identical to the
    interpreted driver for supported shapes."""

    def __init__(self, targets: Sequence, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5,
                 posted_writes: bool = True, block_size: int = 1,
                 metrics=None) -> None:
        if not targets:
            raise ReplayUnsupported("need at least one host target")
        self.targets = list(targets)
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes
        self.block_size = validate_block_size(block_size)
        self.last_gc_runs = 0    # flash GC collections in the last run
        self.metrics = metrics   # Optional[MetricsSpec]
        self.last_metrics = None  # MetricsBundle of the last run
        self._meta = None

    def prepare(self, traces: Sequence):
        """Extract (cfg, params, devs, addrs, writes, lens, size) tensors —
        the compiled program's inputs.  Exposed so sweeps can batch them.
        Per-access route choices ride inside ``params["route"]``."""
        if len(traces) != len(self.targets):
            raise ValueError(f"{len(traces)} traces for "
                             f"{len(self.targets)} host targets")
        parsed = [trace_to_arrays(tr) for tr in traces]
        size = parsed[0][2]
        if any(pz != size for _, _, pz in parsed):
            raise ReplayUnsupported("hosts must share one access size")
        H = len(self.targets)
        L = max(a.size for a, _, _ in parsed)
        addrs = np.zeros((H, L), np.int64)
        writes = np.zeros((H, L), bool)
        lens = np.asarray([a.size for a, _, _ in parsed], np.int64)
        for i, (a, w, _) in enumerate(parsed):
            addrs[i, :a.size] = a
            writes[i, :a.size] = w
        return self.prepare_arrays(addrs, writes, lens=lens, size=size)

    def prepare_arrays(self, addrs, writes, *, lens=None, size: int = 64):
        """:meth:`prepare` for traces that already live as ``(H, L)``
        columns — on-device workload synthesis (:mod:`repro.data.workloads`)
        or :class:`~repro.data.trace_store.TraceStore` loads — so fleet-scale
        inputs never round-trip through per-access python tuples.  Pool
        address mapping and ECMP route-choice hashing stay host-side
        numpy column ops (pure per-address arithmetic, bit-equal to the
        per-access scalar path)."""
        addrs, writes, lens = validate_trace_columns(
            addrs, writes, lens, size=size)
        H, L = addrs.shape
        if H != len(self.targets):
            raise ValueError(f"{H} trace rows for "
                             f"{len(self.targets)} host targets")
        params, meta = _extract_targets(self.targets, size)
        self._meta = meta        # labels/fabric for metrics bundle assembly
        devs = np.zeros((H, L), np.int32)
        routes = np.zeros((H, L), np.int32)
        mapper, route_count = meta["mapper"], meta["route_count"]
        tplan = meta["transport_plan"]
        if mapper is not None:
            addrs = addrs.copy()    # mapping rewrites to device-local addrs
        for i in range(H):
            n = int(lens[i])
            dev, local = _map_addrs(mapper, i, addrs[i, :n])
            addrs[i, :n] = local
            devs[i, :n] = dev
            if meta["max_routes"] > 1 and tplan is None:
                # same hash, same flow key (device-local line address) as
                # HostPortView / FabricAttachedDevice evaluate per access
                for d in np.unique(dev):
                    m = dev == d
                    routes[i, :n][m] = flow_choices(
                        meta["hosts"][i], meta["nodes"][d],
                        local[m] // LINE_BYTES, int(route_count[i, d]))
        stack_cfg, media_params, flash_of, n_flash = _media_setup(
            meta["inners"], size=size, outstanding=self.outstanding,
            posted_writes=self.posted_writes, n_accesses=int(lens.sum()),
            max_addr=int(addrs.max(initial=0)),
            counters=self.metrics is not None)
        if stack.has_flash(stack_cfg) and H * L > MAX_ACCESSES:
            raise ReplayUnsupported(
                f"multi-host SSD replay of {H}x{L} steps exceeds the "
                f"packed-stamp budget ({MAX_ACCESSES}); split the traces "
                "or use engine='python'")
        params.update(media_params)
        params["flash_of"] = flash_of
        params["issue_ov"] = ns(self.issue_overhead_ns)
        params["route"] = routes
        max_hops, max_routes = meta["max_hops"], meta["max_routes"]
        if tplan is not None:
            # link-retry / down-window columns: per-access hop tensors
            # replace the static per-(host, dev, route) ones; the ECMP
            # choice (over survivors) is baked into the columns, so the
            # route axis collapses
            fcols, fh, faulted, fstats, degf, fof = _fault_cols_multi(
                meta, tplan, addrs, lens, size)
            params.update(fcols)
            meta["faulted"] = faulted
            meta["fault_stats"] = fstats
            meta["deg_flags"] = degf
            meta["fo_flags"] = fof
            max_hops, max_routes = fh, 1
        # poison status parity: the driver tallies each target plan's
        # deterministic (host, ordinal) poison flags on the service path
        poisoned = 0
        for i, tgt in enumerate(self.targets):
            tp = getattr(tgt, "fault_plan", None)
            if tp is not None and tp.has_poison:
                n_i = int(lens[i])
                poisoned += int(tp.poisoned_np(
                    i, np.arange(n_i, dtype=np.int64),
                    writes[i, :n_i]).sum())
        meta["poisoned_reads"] = poisoned
        cfg = MultiCfg(num_hosts=H, outstanding=self.outstanding,
                       posted_writes=self.posted_writes,
                       num_ports=meta["num_ports"],
                       max_hops=max_hops, num_devs=meta["num_devs"],
                       stack=stack_cfg, n_flash=n_flash,
                       max_routes=max_routes, qos=meta["qos"],
                       host_order=meta["host_order"],
                       fault_hops=tplan is not None)
        return cfg, params, devs, addrs, writes, lens, size

    @property
    def fault_flags(self):
        """Per-host ``(degraded, failover)`` flag arrays (each ``(H, L)``
        bool) from the last :meth:`prepare` under an active transport
        plan, else ``None`` — the availability-sweep lane folds these into
        reachable-fraction / time-in-degraded curves."""
        if self._meta is None or "deg_flags" not in self._meta:
            return None
        return self._meta["deg_flags"], self._meta["fo_flags"]

    @staticmethod
    def aggregate(who, issues, dones, lens, size: int,
                  start_tick: int = 0) -> MultiHostResult:
        """Fold per-step (host, issue, done) streams into per-host results.

        Padded steps beyond sum(lens) pick exhausted hosts (cand == BIG);
        they replay "past the end" deterministically but must be dropped."""
        who = np.asarray(who)
        issues = np.asarray(issues)
        dones = np.asarray(dones)
        lens = np.asarray(lens)
        valid = np.arange(who.size) < int(lens.sum())
        per_host: List[TraceResult] = []
        firsts, lasts = [], []
        for i in range(lens.size):
            m = valid & (who == i)
            iss, dn = issues[m], dones[m]
            n = int(m.sum())
            first = int(iss[0]) if n else None
            last = max(int(dn.max(initial=0)), start_tick) if n else start_tick
            per_host.append(TraceResult(
                accesses=n, bytes_moved=n * size,
                elapsed_ticks=(last - first) if first is not None else 0,
                sum_latency_ticks=int((dn - iss).sum()),
                end_tick=last))
            if first is not None:
                firsts.append(first)
            lasts.append(last)
        first_all = min(firsts, default=start_tick)
        return MultiHostResult(per_host=per_host,
                               elapsed_ticks=max(lasts) - first_all)

    @staticmethod
    def _aggregate_scalars(aux, lens, size: int,
                           start_tick: int = 0) -> MultiHostResult:
        """The ``return_latencies=False`` twin of :meth:`aggregate`: fold
        the in-scan per-host first/last/sum/count scalars (O(hosts) output,
        never O(trace)) into the same result shape."""
        firsts = np.asarray(aux["first"])
        lasts = np.asarray(aux["last"])
        sums = np.asarray(aux["sum"])
        cnts = np.asarray(aux["cnt"])
        lens = np.asarray(lens)
        per_host: List[TraceResult] = []
        first_list, last_list = [], []
        for i in range(lens.size):
            n = int(cnts[i])
            first = int(firsts[i]) if n else None
            last = max(int(lasts[i]), start_tick) if n else start_tick
            per_host.append(TraceResult(
                accesses=n, bytes_moved=n * size,
                elapsed_ticks=(last - first) if first is not None else 0,
                sum_latency_ticks=int(sums[i]),
                end_tick=last))
            if first is not None:
                first_list.append(first)
            last_list.append(last)
        first_all = min(first_list, default=start_tick)
        return MultiHostResult(per_host=per_host,
                               elapsed_ticks=max(last_list) - first_all)

    def _run_chunked(self, cfg, params, devs, addrs, writes, lens,
                     start_tick, mspec, want_lat, size, chunk):
        """Chunked multi-host replay: the scan consumes per-host sliding
        windows of ``chunk`` accesses, re-sliced host-side from each
        host's carry cursor after every window (each step consumes at most
        one access, so a ``chunk``-wide window per host can never be
        outrun).  The carry — the shared port busy-untils, QoS
        virtual-finish/last-arrival tables, media/flash state and metrics
        accumulators — is buffer-donated across windows; the windows are
        contiguous slices, so feeding them from memmapped columns keeps
        peak input residency O(hosts * chunk).  Tick-identical to the
        one-shot scan: both run the same step body over the same access
        sequence, only the lookup re-bases."""
        from repro.core.replay.engine import _dealias

        if chunk < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk!r}")
        routes = params["route"]
        fkeys = ("fhp", "fho", "fha", "fhon", "fhoc")
        fcols = ({k: params[k] for k in fkeys} if cfg.fault_hops else None)
        skip = {"route", *fkeys}
        pj = jax.tree.map(jnp.asarray,
                          {k: v for k, v in params.items() if k not in skip})
        lens_np = np.asarray(lens, np.int64)
        lj = jnp.asarray(lens_np)
        H = cfg.num_hosts
        total = int(lens_np.sum())
        carry = _multi_init(cfg, _i64(start_tick), mspec, want_lat)
        parts = []
        n_calls = max(1, -(-total // chunk))
        for _ in range(n_calls):
            base = np.minimum(np.asarray(carry[2], np.int64), lens_np)
            wa = np.zeros((H, chunk), np.int64)
            ww = np.zeros((H, chunk), bool)
            wd = np.zeros((H, chunk), np.int32)
            wr_ = np.zeros((H, chunk), np.int32)
            wf = ({k: np.zeros((H, chunk) + v.shape[2:], v.dtype)
                   for k, v in fcols.items()} if fcols is not None else None)
            for i in range(H):
                b = int(base[i])
                e = min(b + chunk, int(lens_np[i]))
                if e > b:
                    wa[i, :e - b] = addrs[i, b:e]
                    ww[i, :e - b] = writes[i, b:e]
                    wd[i, :e - b] = devs[i, b:e]
                    if cfg.max_routes > 1:
                        wr_[i, :e - b] = routes[i, b:e]
                    if wf is not None:
                        for k, v in fcols.items():
                            wf[k][i, :e - b] = v[i, b:e]
            wins = {"addr": jnp.asarray(wa), "wr": jnp.asarray(ww),
                    "dev": jnp.asarray(wd)}
            if cfg.max_routes > 1:
                wins["route"] = jnp.asarray(wr_)
            if wf is not None:
                wins.update({k: jnp.asarray(v) for k, v in wf.items()})
            carry, ys = _run_multi_chunk(
                cfg, _dealias(carry), pj, wins, lj, jnp.asarray(base),
                self.block_size, mspec, want_lat, size)
            if want_lat:
                parts.append(tuple(np.asarray(y) for y in ys))
        if want_lat:
            who, issues, dones, bad, gcs = (
                np.concatenate([pt[j] for pt in parts]) for j in range(5))
        else:
            who = issues = dones = bad = gcs = None
        return who, issues, dones, bad, gcs, carry[8]

    def _execute(self, traces: Sequence, start_tick: int,
                 want_lat: bool = True, chunk_size=None):
        return self._execute_prepared(self.prepare(traces), start_tick,
                                      want_lat, chunk_size)

    def _dispatch(self, cfg, params, devs, addrs, writes, lens, start_tick,
                  mspec, want_lat, size, chunk_size):
        """The raw compiled-run dispatch (called under ``enable_x64``) —
        the single override point for lanes that run the same prepared
        tensors through a different program (the sharded fleet lane)."""
        if chunk_size is not None:
            return self._run_chunked(
                cfg, params, devs, addrs, writes, lens, start_tick,
                mspec, want_lat, size, int(chunk_size))
        pj = jax.tree.map(jnp.asarray, params)
        return _run_multi(
            cfg, pj, jnp.asarray(devs), jnp.asarray(addrs),
            jnp.asarray(writes), jnp.asarray(lens), _i64(start_tick),
            self.block_size, mspec, want_lat, size)

    def _execute_prepared(self, prep, start_tick: int,
                          want_lat: bool = True, chunk_size=None):
        cfg, params, devs, addrs, writes, lens, size = prep
        if cfg.qos and start_tick < 0:
            raise ReplayUnsupported(
                "QoS replay needs start_tick >= 0 (the virtual-clock and "
                "arrival sentinels assume non-negative ticks)")
        mspec = self.metrics
        with enable_x64():
            who, issues, dones, bad, gcs, aux = self._dispatch(
                cfg, params, devs, addrs, writes, lens, start_tick,
                mspec, want_lat, size, chunk_size)
            if want_lat:
                bad = np.asarray(bad)
                gcs = np.asarray(gcs)
        # padded steps (beyond sum(lens)) replay past the end and may dirty
        # the sticky flash flags — judge health at the last *valid* step
        total = int(np.asarray(lens).sum())
        if want_lat:
            self.last_gc_runs = int(gcs[total - 1]) if total else 0
            bad_last = bool(bad[total - 1]) if total else False
        else:
            self.last_gc_runs = int(aux["gcs"]) if total else 0
            bad_last = bool(aux["bad"]) if total else False
        if bad_last:
            raise ReplayUnsupported(
                "FTL ran out of free blocks during GC (device overfilled) — "
                "the interpreted path raises there too; shrink the traces "
                "or use engine='python' for the exact error")
        bundle = None
        if mspec is not None:
            from repro.core.replay import metrics as _metrics
            fcnt = (np.asarray(aux["flash"]) if "flash" in aux else None)
            fdict = None
            if (self._meta.get("fault_plan") is not None
                    or self._meta.get("poisoned_reads")):
                rr, rb = (np.asarray(aux["faults"]) if "faults" in aux
                          else (0, 0))
                fs = self._meta.get("fault_stats") or {}
                fdict = {"link_retries": fs.get("link_retries", 0),
                         "failovers": fs.get("failovers", 0),
                         "degraded_accesses": fs.get("degraded_accesses", 0),
                         "nand_read_retries": int(rr),
                         "retired_blocks": int(rb),
                         "poisoned_reads":
                             int(self._meta.get("poisoned_reads", 0))}
            bundle = _metrics.bundle_multi_fused(
                mspec, self._meta, cfg, aux["acc"], aux["med"], aux["q"],
                aux.get("qthr"), fcnt, devs, params["route"], lens, size,
                params, faults=fdict, faulted=self._meta.get("faulted"))
        self.last_metrics = bundle
        if want_lat:
            who, issues, dones = (np.asarray(who), np.asarray(issues),
                                  np.asarray(dones))
        return who, issues, dones, lens, size, aux, bundle

    @staticmethod
    def _attach(res: MultiHostResult, bundle) -> MultiHostResult:
        if bundle is not None:
            res.metrics = bundle
            for r in res.per_host:
                r.metrics = bundle
        return res

    def run(self, traces: Sequence, start_tick: int = 0,
            return_latencies: bool = True,
            chunk_size=None) -> MultiHostResult:
        who, issues, dones, lens, size, aux, bundle = self._execute(
            traces, start_tick, want_lat=bool(return_latencies),
            chunk_size=chunk_size)
        if return_latencies:
            res = self.aggregate(who, issues, dones, lens, size, start_tick)
        else:
            res = self._aggregate_scalars(aux, lens, size, start_tick)
        return self._attach(res, bundle)

    def run_arrays(self, addrs, writes, *, lens=None, size: int = 64,
                   start_tick: int = 0, return_latencies: bool = True,
                   chunk_size=None) -> MultiHostResult:
        """:meth:`run` over already-columnar ``(H, L)`` trace arrays (see
        :meth:`prepare_arrays`) — the fleet-scale entry point: synthesized
        or store-loaded traces replay without ever materializing python
        tuple lists."""
        prep = self.prepare_arrays(addrs, writes, lens=lens, size=size)
        who, issues, dones, lens, size, aux, bundle = self._execute_prepared(
            prep, start_tick, want_lat=bool(return_latencies),
            chunk_size=chunk_size)
        if return_latencies:
            res = self.aggregate(who, issues, dones, lens, size, start_tick)
        else:
            res = self._aggregate_scalars(aux, lens, size, start_tick)
        return self._attach(res, bundle)

    def run_recorded(self, traces: Sequence, start_tick: int = 0,
                     chunk_size=None
                     ) -> Tuple[MultiHostResult, List[np.ndarray]]:
        """:meth:`run` plus the per-access latency stream of every host
        (in that host's issue order) — tensors the scan already produced
        for free, exposed for conformance pinning and tail analysis."""
        who, issues, dones, lens, size, aux, bundle = self._execute(
            traces, start_tick, chunk_size=chunk_size)
        res = self.aggregate(who, issues, dones, lens, size, start_tick)
        valid = np.arange(who.size) < int(np.asarray(lens).sum())
        lat = [(dones - issues)[valid & (who == i)]
               for i in range(len(self.targets))]
        return self._attach(res, bundle), lat
