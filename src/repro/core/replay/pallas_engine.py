"""Pallas-kernel trace replay for the cached CXL-SSD (engine="pallas").

This is the accelerator-resident fast path: the fused Pallas kernel
(:func:`repro.kernels.cache_sim.cache_sim_fused`) replays the DRAM-cache
state machine and emits latency in the same sequential pass, with the cache
state held in VMEM scratch.

Fidelity contract (different from the scan engine's tick-exactness):

* hit / dirty-evict decisions are bit-identical to the vectorized cache
  oracle (:mod:`repro.core.cache.trace_sim`) and hence to the Python policy
  objects — the fully-associative LRU/FIFO cache maps to ``num_sets=1,
  ways=capacity``, direct-mapped to ``num_sets=capacity, ways=1``;
* latency follows a closed-loop analytic model (LFB-ring arrival throttling
  + fill-path busy-until queueing, nanosecond resolution) validated against
  :func:`repro.kernels.ref.cache_sim_fused_ref` — it tracks the shape of
  the exact replay but does not model MSHR coalescing, writeback stalls, or
  flash channel contention.  Use engine="scan" when ticks must match the
  interpreted driver exactly.
"""

from __future__ import annotations

import numpy as np

from repro.core.devices import CachedCXLSSDDevice
from repro.core.engine import TICKS_PER_NS
from repro.core.fabric.fabric import FabricAttachedDevice
from repro.core.replay.spec import ReplayUnsupported


def _cached_inner(device) -> CachedCXLSSDDevice:
    inner = device.inner if isinstance(device, FabricAttachedDevice) else device
    if not isinstance(inner, CachedCXLSSDDevice):
        raise ReplayUnsupported(
            "engine='pallas' models the cached CXL-SSD; use engine='scan' "
            f"for {type(inner).__name__}")
    return inner


def pallas_params(device, issue_overhead_ns: float) -> dict:
    """Derive the fused kernel's geometry + ns-resolution latency model
    from a live device."""
    inner = _cached_inner(device)
    cfg = inner.cache.cfg
    pol = inner.cache.policy.name
    if pol not in ("lru", "fifo", "direct"):
        raise ReplayUnsupported(f"pallas path supports lru/fifo/direct, "
                                f"got {pol!r}")
    frames = cfg.capacity_pages
    num_sets, ways = (frames, 1) if pol == "direct" else (1, frames)
    t = inner.hil.cfg.timing
    page = inner.hil.cfg.page_bytes
    miss_ns = (inner.hil.cfg.hil_overhead_ns + t.t_read_us * 1e3
               + page / t.channel_mbps * 1e3          # flash channel xfer
               + page / cfg.dram_bw_gbps              # cache-DRAM fill
               + cfg.hit_latency_ns)
    # A dirty eviction injects one flash program into the W-deep writeback
    # buffer; beyond its drain capacity the demand path stalls.  Amortize
    # that backpressure as program-time / W per dirty evict.
    wb_ns = (inner.hil.cfg.hil_overhead_ns
             + t.t_prog_us * 1e3) / max(1, cfg.writeback_buffer)
    return dict(num_sets=num_sets, ways=ways, policy=pol,
                issue_ns=max(1, int(round(issue_overhead_ns))),
                hit_ns=int(round(cfg.hit_latency_ns)),
                miss_ns=int(round(miss_ns)),
                miss_occ_ns=int(round(page / cfg.dram_bw_gbps)),
                wb_ns=int(round(wb_ns)))


def run_pallas(device, addrs: np.ndarray, writes: np.ndarray, *,
               size: int = 64, outstanding: int = 32,
               issue_overhead_ns: float = 0.5, start_tick: int = 0,
               interpret: bool | None = None, validate: bool = False):
    """Replay (addrs, writes) through the fused Pallas kernel; returns a
    :class:`~repro.core.replay.engine.ReplayResult`.

    ``interpret=None`` auto-detects: the real kernel on a TPU backend,
    op-level interpret emulation elsewhere (CPU/GPU).

    ``validate=True`` recomputes the latency stream from the kernel's own
    decisions + arrivals through the associative busy-until formulation
    shared with the replay engines
    (:func:`repro.kernels.cache_sim.fill_latency_assoc`) and raises if the
    two disagree bit-for-bit — a cheap end-to-end cross-check of the
    in-kernel sequential chain, run on every golden-trace conformance
    pass."""
    import jax

    from repro.core.replay.engine import ReplayResult
    from repro.kernels.cache_sim import cache_sim_fused

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    plan = getattr(device, "fault_plan", None)
    if plan is None:
        plan = getattr(getattr(device, "fabric", None), "fault_plan", None)
    if plan is not None and plan.active:
        raise ReplayUnsupported(
            f"active fault plan ({', '.join(plan.class_names())}): the "
            "pallas kernel models the fault-free cached CXL-SSD; the "
            "fused scan lane replays every fault class tick-identically "
            "— use engine='scan' (or engine='python')")
    kw = pallas_params(device, issue_overhead_ns)
    # int32-nanosecond budget: arrival/busy cursors grow by at most
    # (miss_occ + issue) per access, plus one service term on top.
    n = int(np.asarray(addrs).shape[-1])
    worst_ns = (n * (kw["miss_occ_ns"] + kw["issue_ns"])
                + kw["miss_ns"] + kw["wb_ns"])
    if worst_ns >= 2**31:
        raise ReplayUnsupported(
            f"trace of {n} accesses can overflow the kernel's int32 "
            f"nanosecond clock (worst case {worst_ns} ns); split the trace "
            "or use engine='scan'")
    pages64 = np.asarray(addrs, np.int64) // 4096
    if pages64.size and int(pages64.max()) >= 2**31:
        raise ReplayUnsupported(
            "page id exceeds the kernel's int32 tag range (addr >= 2^43); "
            "use engine='scan'")
    pages = pages64.astype(np.int32)
    hits, evicts, lat_ns, arr_ns = cache_sim_fused(
        pages, np.asarray(writes, bool), outstanding=max(1, outstanding),
        interpret=interpret, **kw)
    hits = np.asarray(hits)
    evicts = np.asarray(evicts)
    if validate:
        from repro.kernels.cache_sim import fill_latency_assoc
        lat2 = np.asarray(fill_latency_assoc(
            hits, evicts, arr_ns, hit_ns=kw["hit_ns"], miss_ns=kw["miss_ns"],
            miss_occ_ns=kw["miss_occ_ns"], wb_ns=kw["wb_ns"]))
        if not np.array_equal(lat2, np.asarray(lat_ns)):
            bad = int(np.flatnonzero(lat2 != np.asarray(lat_ns))[0])
            raise AssertionError(
                f"pallas kernel latency diverged from the associative "
                f"reconstruction at access {bad}: kernel "
                f"{int(np.asarray(lat_ns)[bad])}, assoc {int(lat2[bad])}")
    lat = np.asarray(lat_ns).astype(np.int64) * TICKS_PER_NS
    issues = start_tick + np.asarray(arr_ns).astype(np.int64) * TICKS_PER_NS
    dones = issues + lat
    n = pages.size
    return ReplayResult(
        accesses=n, bytes_moved=n * size,
        elapsed_ticks=int(dones.max(initial=start_tick) - issues[0]),
        sum_latency_ticks=int(lat.sum()),
        end_tick=int(dones.max(initial=start_tick)),
        latency_ticks=lat, hit_flags=hits, evict_flags=evicts)
