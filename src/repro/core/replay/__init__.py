"""Fused, vectorized device-stack trace replay.

The hot path of trace-driven evaluation, collapsed into one compiled
program: DRAM-cache decisions, CXL link/fabric occupancy, and SSD channel
service times all advance inside a single :func:`jax.lax.scan` (one step
per access), tick-identical to the interpreted
:class:`~repro.core.workloads.driver.TraceDriver` path.

* :class:`ReplayEngine` — single host, any of the five paper devices,
  directly attached or fabric-mounted.
* :class:`MultiHostReplay` — N hosts interleaved onto shared fabric ports
  and pooled DRAM media (the :class:`MultiHostDriver` fast path).
* :mod:`repro.core.replay.sweep` — vmap-batched design-space sweeps over
  timing parameters, replacement policy, capacity, and topology.
"""

from repro.core.replay.engine import ReplayEngine, ReplayResult
from repro.core.replay.multihost import MultiHostReplay
from repro.core.replay.spec import ReplayUnsupported, StackConfig, build_stack
from repro.core.replay.sweep import cache_design_sweep, host_count_sweep

__all__ = [
    "ReplayEngine",
    "ReplayResult",
    "MultiHostReplay",
    "ReplayUnsupported",
    "StackConfig",
    "build_stack",
    "cache_design_sweep",
    "host_count_sweep",
]
