"""Fused, vectorized device-stack trace replay.

The hot path of trace-driven evaluation, collapsed into compiled programs
that are tick-identical to the interpreted
:class:`~repro.core.workloads.driver.TraceDriver` path:

* :class:`ReplayEngine` — single host, any of the five paper devices,
  directly attached or fabric-mounted, one :func:`jax.lax.scan` step per
  access (``block_size=B`` replays B accesses per sequential step,
  amortizing the per-step dispatch floor — tick-identical at any B).
* :class:`AssocReplayEngine` — the log-depth lane for stateless DRAM/PMEM
  media: every busy-until chain lowered to associative max-plus scans,
  zero sequential scan steps; certified tick-exact or it refuses
  (:mod:`repro.core.replay.assoc`).
* :class:`MultiHostReplay` — N hosts interleaved onto shared fabric ports
  and pooled media (the :class:`MultiHostDriver` fast path), blocked the
  same way — any stack-layer media, cached CXL-SSD with private or shared
  flash included.
* :class:`ShardedMultiHostReplay` — the same program ``shard_map``-ed
  over the host axis (:mod:`repro.core.replay.shard`): ``H`` hosts on
  ``D`` devices at ``~H/D`` per-device state, tick-identical to the
  unsharded lane (private-flash fabric mounts; pooled shapes refuse).
* :mod:`repro.core.replay.stack` — the host-stackable device-state layer
  both engines consume (``init_state(cfg, n_hosts)`` / ``step(state,
  access)`` pytrees with a leading host axis; greedy FTL GC inside the
  scan).
* :mod:`repro.core.replay.stream` — :func:`replay_stream`, the streaming
  front end: fused replay straight from an on-disk columnar
  :class:`~repro.data.trace_store.TraceStore` in O(chunk) input memory
  (prefetched windows + donated carry), tick-identical at any chunk
  size.
* :mod:`repro.core.replay.sweep` — vmap-batched design-space sweeps over
  timing parameters, replacement policy, capacity, topology, and host
  count.
* :mod:`repro.core.replay.metrics` — :class:`MetricsSpec`-configured
  telemetry accumulated *inside* the scan (latency histograms with
  p50/p95/p99, component counters, tick-windowed time series), schema- and
  value-identical to the interpreted drivers' stats dicts; exportable to
  Perfetto via :mod:`repro.obs`.
"""

from repro.core.replay.assoc import (
    AssocReplayEngine,
    busy_until,
    port_busy_until,
)
from repro.core.replay.engine import ReplayEngine, ReplayResult
from repro.core.replay.metrics import MetricsBundle, MetricsSpec
from repro.core.replay.multihost import MultiHostReplay
from repro.core.replay.shard import ShardedMultiHostReplay, shard_count
from repro.core.replay.spec import (
    ReplayUnsupported,
    StackConfig,
    build_stack,
    media_stack,
    validate_block_size,
)
from repro.core.replay.stack import init_state, media_init, media_step, step
from repro.core.replay.stream import replay_stream
from repro.core.replay.sweep import cache_design_sweep, host_count_sweep

__all__ = [
    "AssocReplayEngine",
    "MetricsBundle",
    "MetricsSpec",
    "ReplayEngine",
    "ReplayResult",
    "MultiHostReplay",
    "ReplayUnsupported",
    "ShardedMultiHostReplay",
    "shard_count",
    "StackConfig",
    "build_stack",
    "busy_until",
    "cache_design_sweep",
    "host_count_sweep",
    "init_state",
    "media_init",
    "media_stack",
    "media_step",
    "port_busy_until",
    "replay_stream",
    "step",
    "validate_block_size",
]
