"""Fused device-stack trace replay in a single :func:`jax.lax.scan`.

One scan step = one trace access, end to end: LFB slot recycling (the
driver's bounded-outstanding issue model), link/fabric transport with
per-port busy-until occupancy, then the device media — DRAM timing, PMEM
row-buffer, the CXL-SSD page-register buffer, or the full DRAM-cache layer
(fully-associative LRU/FIFO or direct-mapped frames, MSHR coalescing and
stalls, bounded writeback buffer) backed by the HIL/FTL/PAL flash model
(log-append allocation with greedy garbage collection when the trace can
outrun the headroom, per-die array occupancy with program suspend,
per-channel bus occupancy).

The stateful media/flash machinery lives in :mod:`repro.core.replay.stack`
— the host-stackable state layer this engine consumes at ``H=1`` and
:class:`~repro.core.replay.multihost.MultiHostReplay` consumes at ``H=N``.
The step function mirrors the interpreted path *operation for operation* —
every ``max(now, busy_until)``, every separately-rounded ``ns()`` constant —
so the replay is **tick-identical** to
:meth:`repro.core.workloads.driver.TraceDriver.run` over the same device
(property-tested in ``tests/test_replay.py``).  Scope cuts are host-checked
at spec time so they can never silently diverge (one 64 B line per access,
packed-field ranges); runtime-only divergence (a GC free-pool underrun,
where the interpreted FTL raises "out of space") surfaces as
:class:`ReplayUnsupported` via the stack's sticky ``bad`` flag — refuse,
never drift.

Streaming: the scan body is factored so the same compiled chunk program
can either consume the whole trace in one call (the legacy one-shot path)
or be driven by an outer chunk loop that threads the full carry pytree —
LFB slots, issue clock, port busy-untils, stacked media/flash state and
the metrics accumulators — across chunk boundaries with buffer donation
(:func:`_chunked_scan`).  Peak *input* residency is then O(chunk) instead
of O(trace); pair with ``return_latencies=False`` (PR 6's streaming
accumulators) for O(chunk) end to end.  ``ReplayEngine.run_store`` replays
straight from an on-disk columnar :class:`~repro.data.trace_store.TraceStore`
without ever materializing the trace.

Performance notes (XLA:CPU executes a scan body as a sequence of fusion
thunks, so the step is written to minimize thunks and buffer copies):

* cache frames live in ONE packed int64 per frame —
  ``stamp<<39 | page<<1 | dirty`` — so residency is one fused
  compare+argmax, the LRU/FIFO victim is one plain ``argmin`` (invalid
  frames are -1, below every packed value), and each access commits exactly
  one scatter;
* the entire miss machinery (MSHR allocate/stall, eviction writeback queue,
  FTL/PAL flash timing) sits behind one :func:`jax.lax.cond`, which
  passes the big carry buffers through untouched on hits — and the greedy-GC
  migration loop sits behind a second cond inside that one;
* MSHR/writeback tables use value sentinels (page ``-1`` = free slot,
  ready ``BIG``) instead of separate mask arrays;
* transport port busy-until state is a tuple of scalars (hop *h* always
  uses port *h* on a single-host route), fusing into neighboring
  elementwise work.

Tick arithmetic runs in int64 under :func:`jax.experimental.enable_x64`
(scoped — the rest of the process keeps JAX's default 32-bit types; the
golden suite also runs under ambient ``JAX_ENABLE_X64=1`` in CI to guard
both entry modes); at 1 tick = 1 ps, int32 would overflow after 2.1 ms of
simulated time.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.fabric.fabric import FabricAttachedDevice
from repro.core.replay import stack
from repro.core.replay.spec import (
    ReplayUnsupported,
    StackConfig,
    build_stack,
    trace_to_arrays,
    validate_block_size,
)
# The packed-frame layout and sentinels are owned by the stack layer now;
# importers take them from repro.core.replay.stack directly.
from repro.core.replay.stack import BIG, MAX_ACCESSES, _i64
from repro.core.workloads.driver import TraceResult


def _qos_mask(cfg: StackConfig):
    """Boolean constant over the busy-until vector: which ports run
    weighted QoS arbitration (from the static ``cfg.qos_ports``)."""
    m = np.zeros(cfg.num_ports, bool)
    if cfg.qos_ports:
        m[list(cfg.qos_ports)] = True
    return jnp.asarray(m)


# ---------------------------------------------------------------- transport
def _transport(cfg: StackConfig, p: Dict, pb: Tuple, t, qacc=None, qthr=None):
    """Routed store-and-forward transport: the vectorized form of
    :meth:`SwitchPort.transmit` along the precomputed route (hop *h* is
    port *h*), plus the CXL.mem round-trip extra.  ``qacc`` (optional, a
    tuple like ``pb``) accumulates per-port queueing — the
    ``queued_ticks += start - now`` of :meth:`SwitchPort.transmit` — for
    the metrics carry.

    ``qthr`` (optional, same container) accumulates the per-port
    ``qos_throttle_events`` twin of :meth:`SwitchPort.qos_update` on the
    hops ``cfg.qos_ports`` marks as weighted: with a single origin the
    pace equals the clean occupancy exactly, so the virtual finish time
    obeys the *same* recurrence as the port's busy-until
    (``max(state, t) + occ`` from 0) and ``pb[h]`` at arrival IS the
    origin's virtual finish — the counter bumps exactly when the
    interpreted ``prev > now`` does, with no extra carry.  (The ack floor
    provably never binds for one origin — see
    :func:`repro.core.replay.spec._fabric_hops` — so only the counter
    needs mirroring.)"""
    pb = list(pb)
    q = list(qacc) if qacc is not None else None
    qt = list(qthr) if qthr is not None else None
    for h in range(cfg.num_hops):
        if qt is not None and h in cfg.qos_ports:
            qt[h] = qt[h] + jnp.where(pb[h] > t, 1, 0)
        start = jnp.maximum(t, pb[h])
        if q is not None:
            q[h] = q[h] + (start - t)
        done = start + p["hop_occ"][h]
        pb[h] = done
        t = done + p["hop_after"][h]
    return (tuple(pb), t + p["rt_extra"],
            tuple(q) if q is not None else None,
            tuple(qt) if qt is not None else None)


def _transport_cols(cfg: StackConfig, p: Dict, pb, t, cols, qacc=None,
                    vft=None, qthr=None):
    """Fault-lane transport: each access carries its own hop columns
    (precomputed host-side under the installed
    :class:`~repro.core.faults.FaultPlan`) — port index, occupancy with
    CRC retries already charged (``occ * (1 + retries)``), store-and-forward
    extra, an on-mask padding shorter routes up to the widest failover
    route, and the retry-free *clean* occupancy.  Off hops are no-ops on
    every piece of state, so mixed hop counts (down-window reroutes onto
    longer paths) stay exact.  ``pb`` is the port busy-until vector over
    the union of ports any access touches.

    ``vft``/``qthr`` mirror :meth:`SwitchPort.qos_update` on the weighted
    union ports: CRC retries stretch the port's serialization but never
    the origin's entitlement, so the virtual clock advances by the clean
    occupancy column and needs its own carry here — the busy-until
    recurrence identity the retry-free lanes exploit breaks once
    ``occ * (1 + retries)`` and the clean pace diverge."""
    hop_port, hop_occ, hop_after, hop_on, hop_clean = cols
    qmask = _qos_mask(cfg) if qthr is not None else None
    for h in range(cfg.num_hops):
        on = hop_on[h]
        pi = hop_port[h]
        if qthr is not None:
            qon = on & qmask[pi]
            prev = vft[pi]
            qthr = qthr.at[pi].add(jnp.where(qon & (prev > t), 1, 0))
            vft = vft.at[pi].set(
                jnp.where(qon, jnp.maximum(prev, t) + hop_clean[h], prev))
        start = jnp.maximum(t, pb[pi])
        if qacc is not None:
            qacc = qacc.at[pi].add(jnp.where(on, start - t, 0))
        done = start + hop_occ[h]
        pb = pb.at[pi].set(jnp.where(on, done, pb[pi]))
        t = jnp.where(on, done + hop_after[h], t)
    return pb, t + p["rt_extra"], qacc, vft, qthr


def _transport_ecmp(cfg: StackConfig, p: Dict, pb, t, route, qacc=None,
                    qthr=None):
    """ECMP transport: hop *h* of the chosen route occupies the port
    ``hop_port[route, h]`` of the path set's port union, so the busy-until
    state is a vector indexed per access instead of a positional tuple.
    All equal-cost routes share one hop count (static).  ``qacc``
    (optional, a vector like ``pb``) accumulates per-port queueing;
    ``qthr`` mirrors the per-port QoS throttle counter on the weighted
    union ports — ``pb[pi]`` at arrival doubles as the origin's virtual
    finish time, exactly as in :func:`_transport`."""
    qmask = _qos_mask(cfg) if qthr is not None else None
    for h in range(cfg.num_hops):
        pi = p["hop_port"][route, h]
        if qthr is not None:
            qthr = qthr.at[pi].add(jnp.where(qmask[pi] & (pb[pi] > t), 1, 0))
        start = jnp.maximum(t, pb[pi])
        if qacc is not None:
            qacc = qacc.at[pi].add(start - t)
        done = start + p["hop_occ"][route, h]
        pb = pb.at[pi].set(done)
        t = done + p["hop_after"][route, h]
    return pb, t + p["rt_extra"], qacc, qthr


# ---------------------------------------------------------- fault columns
class _FaultColumnBuilder:
    """Per-access transport hop columns for a fabric mount under an active
    link-retry / down-window plan, producible one contiguous ordinal range
    at a time.

    Every access ordinal walks the *same* pure route selection the
    interpreted path uses (:meth:`Fabric.select_faulted` — degraded-set
    masking, ECMP over survivors, recomputed fallback routes) and the same
    per-hop occupancy rule (:meth:`Fabric.path_occupancy`), pre-charging
    CRC-retry serializations into the occupancy column; the clean (retry-
    free) occupancy rides its own column for the QoS virtual clock.
    Raises :class:`~repro.core.faults.DeviceUnreachable` for the same
    accesses the python driver would — at construction, since the plan's
    down segments already determine which route sets go empty.

    The static shapes — the port union and the hop width ``num_hops`` —
    are derived from the plan's :meth:`~FaultPlan.down_segments` alone
    (route sets depend on the down set, never on the address), so columns
    for any ordinal range compute without seeing the rest of the trace.
    That is what lets transport faults *stream*: ``run_store`` builds
    columns chunk by chunk, and the accumulated port/ECMP/counter totals
    round-trip through :meth:`state`/:meth:`load_state` so a checkpointed
    run resumes mid-trace bit-exactly."""

    def __init__(self, device, plan, size: int, n: int,
                 keep_flags: bool = True) -> None:
        from repro.core.devices import CXLDRAMDevice
        from repro.core.replay.spec import _link_hops

        self.fab = device.fabric
        self.plan = plan
        self.host, self.node = device.host, device.device_node
        self.size = int(size)
        self.n = int(n)
        self.keep_flags = keep_flags
        fab = self.fab
        segs = (plan.down_segments(self.n) if plan.has_down
                else [(0, self.n, frozenset())])
        # union of every path any ordinal can take: per down segment, the
        # surviving (ECMP) set — or its recomputed failover routes — which
        # is exactly the candidate set select_faulted chooses from.  An
        # all-paths-down segment raises DeviceUnreachable here, matching
        # the first access the interpreted driver would fail on.
        self._occ: Dict[Tuple[str, ...], list] = {}
        for _, _, down in segs:
            ps = fab.routing.paths(self.host, self.node, down=down)
            for q in (ps if fab.ecmp else [ps[0]]):
                key = tuple(q)
                if key not in self._occ:
                    self._occ[key] = fab.path_occupancy(q, self.size)
        self.K = len(fab.paths(self.host, self.node))
        # a fabric-mounted CXL-DRAM kept on its private link
        # (detach_link=False) pays one extra uncontended transport stage
        # after the fabric — same append build_stack does for the clean
        # route tensors
        self._ih: list = []
        if isinstance(device.inner, CXLDRAMDevice):
            self._ih, _ = _link_hops(device.inner.link, self.size)
        self.port_keys = sorted({pk for hops in self._occ.values()
                                 for pk, _, _ in hops})
        self._pidx = {k: i for i, k in enumerate(self.port_keys)}
        base = len(self.port_keys)
        self.num_hops = (max(len(h) for h in self._occ.values())
                         + (1 if self._ih else 0))
        self.num_ports = base + (1 if self._ih else 0)
        self._pkts = np.zeros(max(base, 1), np.int64)
        self._occt = np.zeros(max(base, 1), np.int64)
        self._ecmp: Dict[str, List[int]] = {}
        self._link_retries = 0
        self._failovers = 0
        self._degraded = 0
        self._deg_parts: List[np.ndarray] = []
        self._fo_parts: List[np.ndarray] = []

    def columns(self, addrs: np.ndarray, lo: int) -> Dict[str, np.ndarray]:
        """Hop columns for ordinals ``[lo, lo + len(addrs))``; updates the
        running port/ECMP/counter totals and (when ``keep_flags``) the
        per-access degraded/failover availability flags."""
        from repro.core.fabric.fabric import LINE_BYTES
        from repro.core.fabric.routing import flow_hash

        fab, plan = self.fab, self.plan
        host, node = self.host, self.node
        addrs = np.asarray(addrs, np.int64)
        m = int(addrs.size)
        H = self.num_hops
        P = len(self.port_keys)
        hp = np.zeros((m, H), np.int32)
        ho = np.zeros((m, H), np.int64)
        ha = np.zeros((m, H), np.int64)
        hon = np.zeros((m, H), bool)
        hoc = np.zeros((m, H), np.int64)
        deg = np.zeros(m, bool)
        fo = np.zeros(m, bool)
        for r in range(m):
            j = lo + r
            line_addr = int(addrs[r]) // LINE_BYTES
            path, dg, fv = fab.select_faulted(host, node, line_addr, j)
            if dg:
                deg[r] = True
                self._degraded += 1
                if fv:
                    fo[r] = True
                    self._failovers += 1
            elif fab.ecmp and self.K > 1:
                # mirror traverse_qos: clean ECMP choices still count
                k = flow_hash(host, node, line_addr) % self.K
                counts = self._ecmp.setdefault(f"{host}->{node}",
                                               [0] * self.K)
                counts[k] += 1
            for h, (pk, occ, after) in enumerate(self._occ[tuple(path)]):
                rt = plan.link_retries(pk, j) if plan.has_link else 0
                self._link_retries += rt
                i = self._pidx[pk]
                hp[r, h] = i
                ho[r, h] = occ * (1 + rt)
                ha[r, h] = after
                hon[r, h] = True
                hoc[r, h] = occ
                self._pkts[i] += 1
                self._occt[i] += occ * (1 + rt)
            if self._ih:
                # off-hops between row end and H-1 are no-ops, so the
                # private hop sits at the fixed last column for every access
                hp[r, H - 1] = P
                ho[r, H - 1] = self._ih[0][1]
                ha[r, H - 1] = self._ih[0][2]
                hon[r, H - 1] = True
                hoc[r, H - 1] = self._ih[0][1]
        if self.keep_flags:
            self._deg_parts.append(deg)
            self._fo_parts.append(fo)
        return {"hp": hp, "ho": ho, "ha": ha, "hon": hon, "hoc": hoc}

    @property
    def fstats(self) -> Dict[str, int]:
        return {"link_retries": int(self._link_retries),
                "failovers": int(self._failovers),
                "degraded_accesses": int(self._degraded)}

    def faulted(self) -> Dict:
        """Host-side port/ECMP totals for metrics reconstruction."""
        return {
            "port_keys": self.port_keys,
            "packets": self._pkts.copy(),
            "bytes": self._pkts * self.size,  # goodput: retries move 0 bytes
            "occupied": self._occt.copy(),    # retries DO occupy the wire
            "ecmp": {k: list(v) for k, v in self._ecmp.items()},
        }

    def flags(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-access ``(degraded, failover)`` availability flags over
        every ordinal range built so far (empty without ``keep_flags``)."""
        if not self._deg_parts:
            z = np.zeros(0, bool)
            return z, z
        return (np.concatenate(self._deg_parts),
                np.concatenate(self._fo_parts))

    # ------------------------------------------------- checkpoint support
    def state(self) -> Dict:
        """The accumulator totals as a flat-array pytree (checkpointable)."""
        deg, fo = self.flags()
        return {"pkts": self._pkts.copy(), "occt": self._occt.copy(),
                "ecmp": {k: np.asarray(v, np.int64)
                         for k, v in self._ecmp.items()},
                "counters": np.asarray(
                    [self._link_retries, self._failovers, self._degraded],
                    np.int64),
                "deg": deg, "fo": fo}

    def load_state(self, st: Dict) -> None:
        self._pkts = np.asarray(st["pkts"], np.int64).copy()
        self._occt = np.asarray(st["occt"], np.int64).copy()
        self._ecmp = {k: [int(x) for x in np.asarray(v)]
                      for k, v in st["ecmp"].items()}
        c = np.asarray(st["counters"], np.int64)
        self._link_retries = int(c[0])
        self._failovers = int(c[1])
        self._degraded = int(c[2])
        deg = np.asarray(st["deg"], bool)
        fo = np.asarray(st["fo"], bool)
        self._deg_parts = [deg.copy()] if deg.size else []
        self._fo_parts = [fo.copy()] if fo.size else []


def _fault_transport_cols(device, plan, addrs: np.ndarray, size: int):
    """One-shot wrapper over :class:`_FaultColumnBuilder` for whole-trace
    callers.  Returns ``(cols, faulted, fstats, num_ports, num_hops,
    degraded_flags, failover_flags)``."""
    addrs = np.asarray(addrs, np.int64)
    b = _FaultColumnBuilder(device, plan, size, int(addrs.size))
    d = b.columns(addrs, 0)
    deg, fo = b.flags()
    return ((d["hp"], d["ho"], d["ha"], d["hon"], d["hoc"]), b.faulted(),
            b.fstats, b.num_ports, b.num_hops, deg, fo)


# ------------------------------------------------------------------ runner
def _init_carry(cfg: StackConfig, state, start_tick, mspec=None,
                want_lat: bool = True):
    """The full replay carry pytree at ``start_tick`` — LFB slots, issue
    clock, stamp counter, port busy-untils, the stacked media/flash state,
    and the aux (metrics / streaming-summary / QoS) accumulators.  Built
    eagerly by the chunked driver (so it can be buffer-donated across
    chunk calls) and traced by the one-shot entry points; both produce the
    identical structure, which is what makes chunked replay tick-identical
    to one-shot at any chunk size."""
    ecmp = cfg.num_routes > 1
    vec_pb = ecmp or cfg.fault_hops
    aux0 = {}
    if mspec is not None:
        from repro.core.replay import metrics as _metrics
        if not want_lat:
            aux0["acc"] = jnp.zeros((_metrics.acc_rows(mspec, 1, 1), 4),
                                    jnp.int64)
            aux0["med"] = jnp.zeros(len(_metrics.MEDIA_COUNTERS[cfg.kind]),
                                    jnp.int64)
        aux0["q"] = (jnp.zeros(cfg.num_ports, jnp.int64) if vec_pb
                     else tuple(_i64(0) for _ in range(cfg.num_ports)))
        if cfg.qos_ports:
            aux0["qthr"] = (jnp.zeros(cfg.num_ports, jnp.int64) if vec_pb
                            else tuple(_i64(0) for _ in range(cfg.num_ports)))
            if cfg.fault_hops:
                # retries decouple the QoS virtual clock from the port
                # busy-until, so the fault lane carries it explicitly
                aux0["vft"] = jnp.zeros(cfg.num_ports, jnp.int64)
    if not want_lat:
        aux0["first"] = _i64(BIG)
        aux0["last"] = _i64(start_tick)
        aux0["sum"] = _i64(0)
    return (jnp.full(cfg.outstanding, start_tick, jnp.int64),  # LFB slots
            _i64(start_tick),                                  # issue clock
            _i64(1),                                           # stamp counter
            # port busy-until: positional tuple on a fixed route (fuses into
            # elementwise work), an indexable vector under ECMP/fault hops
            jnp.zeros(cfg.num_ports, jnp.int64) if vec_pb
            else tuple(_i64(0) for _ in range(cfg.num_ports)),
            state,
            aux0)


def _scan_chunk(cfg: StackConfig, p: Dict, carry, xs: Dict, block=1,
                mspec=None, want_lat=True, size=64):
    """Scan one contiguous span of accesses from an explicit carry.

    ``xs`` is a dict of per-access columns: ``addr``/``wr`` always,
    ``route`` under ECMP, the five ``hp``/``ho``/``ha``/``hon``/``hoc``
    hop columns under fault hops, and optionally ``valid`` — the ragged-
    tail mask.  A masked step computes normally but commits *nothing*:
    one blanket ``where`` keeps the entire previous carry (busy-untils,
    media/GC state, stamp counter, every accumulator), so a zero-padded
    tail chunk is a pure no-op and any chunking of the trace replays
    tick-identically to one shot.  Key presence is static, so the
    unmasked (full-chunk) program compiles without the gate."""
    fh = cfg.fault_hops
    ecmp = cfg.num_routes > 1
    masked = "valid" in xs

    def step(carry, x):
        slots, now, ctr, pb, st, aux = carry
        addr, wr = x["addr"], x["wr"]
        k = jnp.argmin(slots)
        issue = jnp.maximum(now, slots[k])
        posted = wr if cfg.posted_writes else jnp.zeros((), bool)
        qacc = aux.get("q")
        qthr = aux.get("qthr")
        vft = aux.get("vft")
        if fh:
            pb, t, qacc, vft, qthr = _transport_cols(
                cfg, p, pb, issue, (x["hp"], x["ho"], x["ha"], x["hon"],
                                    x["hoc"]), qacc, vft, qthr)
        elif ecmp:
            pb, t, qacc, qthr = _transport_ecmp(cfg, p, pb, issue,
                                                x["route"], qacc, qthr)
        else:
            pb, t, qacc, qthr = _transport(cfg, p, pb, issue, qacc, qthr)
        st, out = stack.step(cfg, p, st, dict(
            lane=0, flash_lane=0, t=t, addr=addr, write=wr, posted=posted,
            ctr=ctr))
        done = out["done"]
        if mspec is not None:
            from repro.core.replay import metrics as _metrics
            aux = {**aux, "q": qacc}
            if qthr is not None:
                aux["qthr"] = qthr
            if vft is not None:
                aux["vft"] = vft
            if "acc" in aux:
                aux["med"] = aux["med"] + _metrics.media_increments(
                    cfg.kind, wr, out)
                aux["acc"] = _metrics.acc_update(
                    mspec, aux["acc"], host=0, dev=0, n_hosts=1,
                    n_devs=1, issue=issue, done=done, size=size,
                    hit=out["hit"])
        if not want_lat:
            aux = {**aux,
                   "first": jnp.minimum(aux["first"], issue),
                   "last": jnp.maximum(aux["last"], done),
                   "sum": aux["sum"] + (done - issue)}
        flags = jnp.where(out["hit"], 1, 0) | jnp.where(out["evict"], 2, 0)
        if mspec is not None and want_lat:
            from repro.core.replay import metrics as _metrics
            for bit, key in _metrics.FLAG_EVENT_BITS[cfg.kind]:
                flags = flags | jnp.where(out[key], 1 << bit, 0)
        new = (slots.at[k].set(done), issue + p["issue_ov"], ctr + 1, pb,
               st, aux)
        if masked:
            v = x["valid"]
            new = jax.tree.map(lambda old, nxt: jnp.where(v, nxt, old),
                               carry, new)
        ys = ((issue, done, flags.astype(jnp.int32)) if want_lat else None)
        return new, ys

    return jax.lax.scan(step, carry, xs, unroll=block)


def _scan_stack(cfg: StackConfig, p: Dict, state, addrs, writes, start_tick,
                routes=None, cols=None, block=1, mspec=None, want_lat=True,
                size=64):
    """The scan proper, parameterized by the initial stacked state so sweeps
    can vary it per vmap lane (e.g. capacity via disabled frames).
    ``state`` is a :func:`repro.core.replay.stack.init_state` pytree with
    one media lane.  ``routes`` is the per-access ECMP choice column
    (required when ``cfg.num_routes > 1``, ignored otherwise).  ``block``
    is the blocked replay width: the scan body replays ``block`` accesses
    per sequential step (scan unroll), with the carry crossing block seams
    untouched — tick-identical at any block size, but the per-step dispatch
    floor is paid once per block instead of once per access.

    ``mspec`` (a :class:`~repro.core.replay.metrics.MetricsSpec`, static)
    grows the carry with the telemetry accumulators.  With per-access
    outputs (``want_lat=True``) that is *only* the per-port queueing (and,
    on weighted-QoS mounts, throttle-counter) scalars: every media counter
    is packed as an event bit into the flags column
    (:data:`metrics.FLAG_EVENT_BITS`) and the histogram/window/counter
    fold is deferred to first bundle access, so the metrics lane stays
    within a few percent of the bare scan.  In streaming mode the
    histogram+window scatter and the media counter-vector add ride the
    carry instead — O(buckets+windows) state, no per-access outputs to
    fold.  ``want_lat=False`` drops the per-access
    stacked outputs entirely (``ys=None``), carrying only first-issue /
    last-done / latency-sum scalars — O(buckets+windows) output for a
    trace of any length.  Both knobs default off, leaving the compiled
    no-metrics program byte-identical to the legacy body (the aux carry is
    an empty pytree)."""
    ecmp = cfg.num_routes > 1
    fh = cfg.fault_hops
    if ecmp and routes is None:
        # callers without a route column (e.g. cache_design_sweep) follow
        # the replay layer's fallback contract, so refuse accordingly
        raise ReplayUnsupported(
            "ECMP stack needs a per-access route column; this entry point "
            "supports single-route mounts only (use engine='python')")
    if fh and cols is None:
        raise ReplayUnsupported(
            "fault-hops stack needs precomputed per-access hop columns; "
            "use ReplayEngine.run_arrays (or engine='python')")
    xs = {"addr": addrs, "wr": writes}
    if fh:
        xs.update(zip(("hp", "ho", "ha", "hon", "hoc"), cols))
    elif ecmp:
        xs["route"] = routes
    init = _init_carry(cfg, state, start_tick, mspec, want_lat)
    carry, ys = _scan_chunk(cfg, p, init, xs, block=block, mspec=mspec,
                            want_lat=want_lat, size=size)
    issues, dones, flags = ys if want_lat else (None, None, None)
    return issues, dones, flags, carry[4], carry[5]


@functools.partial(jax.jit, static_argnums=(0, 5, 6, 7, 8))
def _run_stack(cfg: StackConfig, p: Dict, addrs, writes, start_tick,
               block: int = 1, mspec=None, want_lat: bool = True,
               size: int = 64):
    return _scan_stack(cfg, p, stack.init_state(cfg), addrs, writes,
                       start_tick, block=block, mspec=mspec,
                       want_lat=want_lat, size=size)


@functools.partial(jax.jit, static_argnums=(0, 6, 7, 8, 9))
def _run_stack_ecmp(cfg: StackConfig, p: Dict, addrs, writes, routes,
                    start_tick, block: int = 1, mspec=None,
                    want_lat: bool = True, size: int = 64):
    return _scan_stack(cfg, p, stack.init_state(cfg), addrs, writes,
                       start_tick, routes=routes, block=block, mspec=mspec,
                       want_lat=want_lat, size=size)


@functools.partial(jax.jit, static_argnums=(0, 6, 7, 8, 9))
def _run_stack_faulted(cfg: StackConfig, p: Dict, addrs, writes, cols,
                       start_tick, block: int = 1, mspec=None,
                       want_lat: bool = True, size: int = 64):
    return _scan_stack(cfg, p, stack.init_state(cfg), addrs, writes,
                       start_tick, cols=cols, block=block, mspec=mspec,
                       want_lat=want_lat, size=size)


# --------------------------------------------------------------- streaming
@functools.partial(jax.jit, static_argnums=(0, 4, 5, 6, 7),
                   donate_argnums=(2,))
def _replay_chunk(cfg: StackConfig, p: Dict, carry, xs: Dict, block: int = 1,
                  mspec=None, want_lat: bool = True, size: int = 64):
    """One jitted chunk of the streaming replay.  The carry is donated:
    XLA reuses its buffers for the output carry, so threading state across
    an arbitrarily long trace allocates O(chunk), not O(trace)."""
    return _scan_chunk(cfg, p, carry, xs, block=block, mspec=mspec,
                       want_lat=want_lat, size=size)


def _pad_rows(v: np.ndarray, chunk: int) -> np.ndarray:
    v = np.asarray(v)
    pad = chunk - v.shape[0]
    if pad <= 0:
        return v
    return np.concatenate([v, np.zeros((pad,) + v.shape[1:], v.dtype)])


def _dealias(tree):
    """Copy any carry leaf whose device buffer aliases an earlier leaf.

    XLA may return two identical outputs (e.g. a never-touched port's
    busy-until and its zero QoS counter) in ONE shared buffer; donating
    that carry back would donate the same buffer twice, which XLA
    rejects.  Copies only the duplicated (scalar-sized) leaves."""
    seen = set()

    def fix(x):
        try:
            ptr = x.unsafe_buffer_pointer()
        except Exception:
            return x
        if ptr in seen:
            return jnp.array(x, copy=True)
        seen.add(ptr)
        return x

    return jax.tree.map(fix, tree)


def _restore_carry(template, flat: Dict[str, np.ndarray]):
    """Rebuild a carry pytree from the flat ``{path: ndarray}`` form a
    checkpoint snapshot stores, validated leaf by leaf against the
    structure/shape/dtype of a freshly built ``template`` (so a snapshot
    from a different config or chunk program fails loudly, never
    silently).  Must run under ``enable_x64``."""
    from repro.checkpoint.manager import _flatten

    flat_t, treedef = _flatten(template)
    leaves = []
    for key, tmpl in flat_t.items():
        arr = flat.get(key)
        if arr is None:
            raise KeyError(f"resume state missing carry leaf {key!r}")
        tmpl = jnp.asarray(tmpl)
        arr = np.asarray(arr)
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"resume carry leaf {key!r} has shape {arr.shape}, "
                f"expected {tuple(tmpl.shape)} — snapshot from a "
                "different replay configuration?")
        leaves.append(jnp.asarray(arr, dtype=tmpl.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _chunked_scan(cfg: StackConfig, p: Dict, chunks, n: int, chunk: int,
                  start_tick, block=1, mspec=None, want_lat=True, size=64,
                  carry=None, seen=0, parts=None, on_chunk=None):
    """Outer streaming loop: replay ``n`` accesses arriving as an iterator
    of ``(lo, hi, cols)`` numpy chunk dicts, threading the full carry
    pytree across chunk boundaries with buffer donation.  A short chunk is
    zero-padded up to ``chunk`` and masked with a per-access ``valid``
    column (masked steps advance *nothing* — see :func:`_scan_chunk`), so
    the jitted chunk program compiles at most twice (full chunk + masked
    chunk) and the result is tick-identical to the one-shot scan at any
    chunk size.  Must run under ``enable_x64``; ``chunks`` must cover
    exactly ``[seen, n)`` in order.

    ``carry``/``seen``/``parts`` resume a previously checkpointed run from
    access ``seen`` (default: a fresh carry from access 0).  ``on_chunk``,
    if given, fires as ``on_chunk(seen, carry, parts)`` after each chunk
    lands — the carry is live (not yet donated to the next chunk), so a
    checkpoint hook can ``device_get`` it safely."""
    if carry is None:
        carry = _init_carry(cfg, stack.init_state(cfg), _i64(start_tick),
                            mspec, want_lat)
    parts = list(parts) if parts else []
    seen = int(seen)
    for lo, hi, cols in chunks:
        m = hi - lo
        if not 0 < m <= chunk or lo != seen:
            raise AssertionError(
                f"chunk iterator out of order: [{lo}, {hi}) after {seen}")
        seen = hi
        if m < chunk:
            cols = {k: _pad_rows(v, chunk) for k, v in cols.items()}
            cols["valid"] = np.arange(chunk) < m
        xs = {k: jnp.asarray(v) for k, v in cols.items()}
        carry, ys = _replay_chunk(cfg, p, _dealias(carry), xs, block, mspec,
                                  want_lat, size)
        if want_lat:
            iss, dn, fl = ys
            parts.append((np.asarray(iss[:m]), np.asarray(dn[:m]),
                          np.asarray(fl[:m])))
        if on_chunk is not None:
            on_chunk(seen, carry, parts)
    if seen != n:
        raise AssertionError(f"chunk iterator produced {seen} of {n} accesses")
    if want_lat:
        issues = np.concatenate([x[0] for x in parts])
        dones = np.concatenate([x[1] for x in parts])
        flags = np.concatenate([x[2] for x in parts])
    else:
        issues = dones = flags = None
    return issues, dones, flags, carry[4], carry[5]


# ------------------------------------------------------------------ facade
@dataclass
class ReplayResult(TraceResult):
    """A :class:`TraceResult` plus the per-access tensors the fused scan
    already produced for free."""

    latency_ticks: Optional[np.ndarray] = None   # done - issue, per access
    hit_flags: Optional[np.ndarray] = None
    evict_flags: Optional[np.ndarray] = None
    gc_runs: int = 0                             # flash GC collections run
    # per-access poison status (bit 6 of the flags word) when an active
    # fault plan schedules poison; None otherwise.  Status only — a
    # poisoned read never fabricates latency.
    poison_flags: Optional[np.ndarray] = None
    # tick-windowed availability series + degraded-mode summary
    # (metrics.availability_series) when a transport fault plan is active
    # and per-access outputs were kept.  Host-side observability only —
    # deliberately outside the python-parity MetricsBundle schema.
    availability: Optional[Dict] = None

    @property
    def hits(self) -> int:
        return int(self.hit_flags.sum()) if self.hit_flags is not None else 0


class ReplayEngine:
    """Fused, vectorized stand-in for :class:`TraceDriver` (one host).

    ``run`` is tick-identical to ``TraceDriver(device, ...).run`` for the
    supported stacks (all five paper devices, directly attached or mounted
    behind a switch fabric; cache policies lru/fifo/direct; FTL greedy GC
    included).  Unsupported shapes raise :class:`ReplayUnsupported` so
    callers can fall back.

    ``chunk_size`` (on ``run``/``run_arrays``) switches to the streaming
    chunk loop — same ticks, same metrics, O(chunk) peak *device* input
    residency; ``run_store`` additionally streams the input columns from
    an on-disk :class:`~repro.data.trace_store.TraceStore`, so the host
    never materializes the trace either.
    """

    def __init__(self, device, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5,
                 posted_writes: bool = True, block_size: int = 1,
                 metrics=None) -> None:
        self.device = device
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes
        self.block_size = validate_block_size(block_size)
        self.metrics = metrics        # Optional[MetricsSpec]

    def run(self, trace, start_tick: int = 0,
            return_latencies: bool = True,
            chunk_size: Optional[int] = None) -> ReplayResult:
        addrs, writes, size = trace_to_arrays(trace)
        return self.run_arrays(addrs, writes, size=size,
                               start_tick=start_tick,
                               return_latencies=return_latencies,
                               chunk_size=chunk_size)

    # shared refusal + fault-plan discovery for every entry point
    def _common_refusals(self, n: int, start_tick: int):
        if n == 0:
            raise ReplayUnsupported("empty trace")
        if n > MAX_ACCESSES:
            raise ReplayUnsupported(
                f"trace longer than {MAX_ACCESSES} accesses (packed-stamp "
                "budget); split the trace or use engine='python'")
        if start_tick < 0 and getattr(getattr(self.device, "fabric", None),
                                      "qos_enabled", False):
            # with start_tick >= 0 a lone origin's QoS floor provably never
            # binds (see spec._fabric_hops); negative ticks void the proof
            raise ReplayUnsupported(
                "QoS replay needs start_tick >= 0; use engine='python'")

    def _active_plan(self):
        # active fault plan discovery: install() sets it on the mount (and
        # on the shared fabric); direct devices carry it themselves
        plan = getattr(self.device, "fault_plan", None)
        if plan is None:
            plan = getattr(getattr(self.device, "fabric", None),
                           "fault_plan", None)
        if plan is not None and not plan.active:
            plan = None
        return plan

    def run_arrays(self, addrs: np.ndarray, writes: np.ndarray, *,
                   size: int = 64, start_tick: int = 0,
                   return_latencies: bool = True,
                   chunk_size: Optional[int] = None) -> ReplayResult:
        addrs = np.asarray(addrs, np.int64)
        writes = np.asarray(writes, bool)
        self._common_refusals(int(addrs.size), start_tick)
        if chunk_size is not None and int(chunk_size) < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size!r}")
        mspec = self.metrics
        want_lat = bool(return_latencies)
        plan = self._active_plan()
        cfg, params = build_stack(
            self.device, size=size, outstanding=self.outstanding,
            issue_overhead_ns=self.issue_overhead_ns,
            posted_writes=self.posted_writes, n_accesses=addrs.size,
            max_addr=int(addrs.max(initial=0)),
            counters=mspec is not None)
        routes = None
        fcols = None
        faulted = None
        deg_flags = fo_flags = None
        fstats = {"link_retries": 0, "failovers": 0, "degraded_accesses": 0}
        if (plan is not None and (plan.has_link or plan.has_down)
                and isinstance(self.device, FabricAttachedDevice)):
            # transport faults: replace the static route tensors with
            # per-access hop columns (raises DeviceUnreachable exactly
            # where the interpreted driver would)
            (fcols, faulted, fstats, n_ports, n_hops, deg_flags,
             fo_flags) = _fault_transport_cols(self.device, plan, addrs,
                                               size)
            qp = tuple(
                i for i, key in enumerate(faulted["port_keys"])
                if self.device.fabric.ports[key].qos_enabled)
            cfg = dataclasses.replace(cfg, fault_hops=True,
                                      num_hops=n_hops, num_ports=n_ports,
                                      num_routes=1, qos_ports=qp)
            params = {k: v for k, v in params.items()
                      if k not in ("hop_port", "hop_occ", "hop_after")}
        poisoned = None
        if plan is not None and plan.has_poison:
            poisoned = plan.poisoned_np(
                0, np.arange(addrs.size, dtype=np.int64), writes)
        with enable_x64():
            pj = jax.tree.map(jnp.asarray, params)
            if cfg.num_routes > 1:
                from repro.core.replay.spec import access_route_choices
                routes = access_route_choices(self.device, addrs)
            if chunk_size is not None:
                chunk = int(chunk_size)
                n = int(addrs.size)

                def _feed():
                    for lo in range(0, n, chunk):
                        hi = min(lo + chunk, n)
                        d = {"addr": addrs[lo:hi], "wr": writes[lo:hi]}
                        if cfg.fault_hops:
                            for key, c in zip(("hp", "ho", "ha", "hon",
                                               "hoc"), fcols):
                                d[key] = c[lo:hi]
                        elif cfg.num_routes > 1:
                            d["route"] = routes[lo:hi]
                        yield lo, hi, d

                issues, dones, flags, final, aux = _chunked_scan(
                    cfg, pj, _feed(), n, chunk, start_tick,
                    self.block_size, mspec, want_lat, size)
            elif cfg.fault_hops:
                issues, dones, flags, final, aux = _run_stack_faulted(
                    cfg, pj, jnp.asarray(addrs), jnp.asarray(writes),
                    tuple(jnp.asarray(c) for c in fcols), _i64(start_tick),
                    self.block_size, mspec, want_lat, size)
            elif cfg.num_routes > 1:
                issues, dones, flags, final, aux = _run_stack_ecmp(
                    cfg, pj, jnp.asarray(addrs), jnp.asarray(writes),
                    jnp.asarray(routes), _i64(start_tick), self.block_size,
                    mspec, want_lat, size)
            else:
                issues, dones, flags, final, aux = _run_stack(
                    cfg, pj, jnp.asarray(addrs), jnp.asarray(writes),
                    _i64(start_tick), self.block_size, mspec, want_lat,
                    size)
            return self._finish(
                cfg, n=int(addrs.size), size=size, start_tick=start_tick,
                want_lat=want_lat, issues=issues, dones=dones, flags=flags,
                final=final, aux=aux, plan=plan, fstats=fstats,
                poisoned=poisoned, faulted=faulted, writes=writes,
                addrs=addrs, routes=routes, deg_flags=deg_flags,
                fo_flags=fo_flags)

    def run_store(self, store, *, chunk_size: int, start_tick: int = 0,
                  return_latencies: bool = True, chunk_iter=None,
                  resume_state: Optional[Dict] = None,
                  on_chunk=None) -> ReplayResult:
        """Streaming replay from an on-disk columnar trace
        (:class:`~repro.data.trace_store.TraceStore`, or anything
        duck-typed like one: ``n``, ``size``, ``max_addr``, ``writes()``
        and ``chunks(chunk_size, start=...)``).  Input residency is
        O(chunk) — columns are memmap-sliced per chunk (optionally through
        a prefetching ``chunk_iter``; see
        :func:`repro.core.replay.stream.replay_stream`), the jitted chunk
        program donates its carry, and nothing host-side ever holds the
        full addr column.  With ``return_latencies=True`` the per-access
        *outputs* are still materialized (inherently O(trace)); pass
        ``return_latencies=False`` for bounded-memory replay end to end.

        Every active fault class streams, transport included: link-retry /
        down-window plans get their per-access hop columns built chunk by
        chunk (:class:`_FaultColumnBuilder` — static shapes derive from
        the plan's down segments, never from the trace), tick-identical to
        the one-shot fault lane.

        ``on_chunk(seen, snapshot)`` fires after each chunk lands;
        ``snapshot()`` captures the full resumable state (carry pytree,
        per-access output parts, feed accumulators) as host numpy — the
        checkpoint layer decides cadence and persistence.  Passing a
        previously captured snapshot back as ``resume_state`` (with
        ``chunk_iter`` starting at ``resume_state['seen']``, or ``None``
        to let the store seek) continues the run bit-exactly."""
        n = int(store.n)
        size = int(store.size)
        chunk = int(chunk_size)
        if chunk < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size!r}")
        self._common_refusals(n, start_tick)
        mspec = self.metrics
        want_lat = bool(return_latencies)
        plan = self._active_plan()
        builder = None
        if (plan is not None and (plan.has_link or plan.has_down)
                and isinstance(self.device, FabricAttachedDevice)):
            builder = _FaultColumnBuilder(self.device, plan, size, n,
                                          keep_flags=want_lat)
        cfg, params = build_stack(
            self.device, size=size, outstanding=self.outstanding,
            issue_overhead_ns=self.issue_overhead_ns,
            posted_writes=self.posted_writes, n_accesses=n,
            max_addr=int(store.max_addr), counters=mspec is not None)
        if builder is not None:
            qp = tuple(
                i for i, key in enumerate(builder.port_keys)
                if self.device.fabric.ports[key].qos_enabled)
            cfg = dataclasses.replace(cfg, fault_hops=True,
                                      num_hops=builder.num_hops,
                                      num_ports=builder.num_ports,
                                      num_routes=1, qos_ports=qp)
            params = {k: v for k, v in params.items()
                      if k not in ("hop_port", "hop_occ", "hop_after")}
        ecmp = cfg.num_routes > 1
        K = 0
        route_counts = None
        if ecmp:
            K = len(self.device.fabric.paths(self.device.host,
                                             self.device.device_node))
            route_counts = np.zeros(K, np.int64)
        has_poison = plan is not None and plan.has_poison
        psum = 0
        poison_parts: List[np.ndarray] = []
        seen0 = 0
        parts0 = None
        if resume_state is not None:
            seen0 = int(resume_state["seen"])
            if not 0 <= seen0 <= n:
                raise ValueError(
                    f"resume cursor {seen0} outside trace of {n} accesses")
            parts0 = ([tuple(np.asarray(a) for a in t)
                       for t in resume_state["parts"]] if want_lat else None)
            psum = int(resume_state.get("psum", 0))
            poison_parts = [np.asarray(x, bool)
                            for x in resume_state.get("poison_parts", [])]
            if route_counts is not None and \
                    resume_state.get("route_counts") is not None:
                route_counts[:] = np.asarray(resume_state["route_counts"])
            if builder is not None and \
                    resume_state.get("builder") is not None:
                builder.load_state(resume_state["builder"])
        if chunk_iter is not None:
            src = chunk_iter
        elif seen0:
            src = store.chunks(chunk, start=seen0)
        else:
            src = store.chunks(chunk)   # duck-typed stores may lack start=

        def _feed():
            nonlocal psum
            from repro.core.fabric.fabric import LINE_BYTES
            from repro.core.fabric.routing import flow_choices
            for lo, hi, cols in src:
                d = {"addr": np.asarray(cols["addr"], np.int64),
                     "wr": np.asarray(cols["wr"], bool)}
                if builder is not None:
                    d.update(builder.columns(d["addr"], lo))
                elif ecmp:
                    r = flow_choices(self.device.host,
                                     self.device.device_node,
                                     d["addr"] // LINE_BYTES, K)
                    route_counts[:] += np.bincount(r, minlength=K)
                    d["route"] = np.asarray(r, np.int32)
                if has_poison:
                    pz = plan.poisoned_np(
                        0, np.arange(lo, hi, dtype=np.int64), d["wr"])
                    psum += int(pz.sum())
                    if want_lat:
                        poison_parts.append(np.asarray(pz, bool))
                yield lo, hi, d

        def _snapshot(seen, carry, parts):
            # everything the run needs to continue from `seen`, as host
            # numpy — feed accumulators are exactly in sync because the
            # feed builds columns lazily, one pulled chunk at a time
            from repro.checkpoint.manager import _flatten
            return {
                "seen": int(seen),
                "carry": {k: np.asarray(jax.device_get(v))
                          for k, v in _flatten(carry)[0].items()},
                "parts": [tuple(np.asarray(a) for a in t) for t in parts],
                "psum": int(psum),
                "route_counts": (None if route_counts is None
                                 else route_counts.copy()),
                "poison_parts": [np.asarray(x, bool) for x in poison_parts],
                "builder": builder.state() if builder is not None else None,
            }

        cb = None
        if on_chunk is not None:
            def cb(seen, carry, parts):
                on_chunk(seen, lambda: _snapshot(seen, carry, parts))

        with enable_x64():
            pj = jax.tree.map(jnp.asarray, params)
            carry0 = None
            if resume_state is not None:
                template = _init_carry(cfg, stack.init_state(cfg),
                                       _i64(start_tick), mspec, want_lat)
                carry0 = _restore_carry(template, resume_state["carry"])
            issues, dones, flags, final, aux = _chunked_scan(
                cfg, pj, _feed(), n, chunk, start_tick, self.block_size,
                mspec, want_lat, size, carry=carry0, seen=seen0,
                parts=parts0, on_chunk=cb)
            poisoned = None
            if has_poison:
                poisoned = (np.concatenate(poison_parts) if want_lat
                            else None)
            deg_flags = fo_flags = None
            if builder is not None:
                fstats = dict(builder.fstats)
                fstats["poisoned_reads"] = psum
                faulted = builder.faulted()
                if want_lat:
                    deg_flags, fo_flags = builder.flags()
            else:
                fstats = {"link_retries": 0, "failovers": 0,
                          "degraded_accesses": 0, "poisoned_reads": psum}
                faulted = None
            return self._finish(
                cfg, n=n, size=size, start_tick=start_tick,
                want_lat=want_lat, issues=issues, dones=dones, flags=flags,
                final=final, aux=aux, plan=plan, fstats=fstats,
                poisoned=poisoned, faulted=faulted,
                writes=(store.writes() if (mspec is not None and want_lat)
                        else None),
                addrs=None, routes=None, n_accesses=n,
                route_counts=route_counts, poison_total=psum,
                deg_flags=deg_flags, fo_flags=fo_flags)

    # shared post-processing: health check, poison bit, fault counters,
    # metrics bundle, result assembly (identical for one-shot / chunked /
    # store-streamed paths — called under enable_x64)
    def _finish(self, cfg, *, n, size, start_tick, want_lat, issues, dones,
                flags, final, aux, plan, fstats, poisoned, faulted, writes,
                addrs, routes, n_accesses=None, route_counts=None,
                poison_total=None, deg_flags=None, fo_flags=None):
        bad, gcs = stack.flash_health(final)
        bad, gcs = bool(bad), int(gcs)
        if want_lat:
            issues = np.asarray(issues)
            dones = np.asarray(dones)
            flags = np.asarray(flags)
            if poisoned is not None:
                # status bit only (bit 6): the hist/media folds read
                # bits 0..5, so the bundle stays untouched by poison
                flags = flags | (poisoned.astype(np.int32) << 6)
        fdict = None
        if plan is not None:
            rr, rb = stack.fault_counters(final)
            if poison_total is None:
                poison_total = (int(poisoned.sum()) if poisoned is not None
                                else 0)
            fdict = {
                "link_retries": fstats["link_retries"],
                "failovers": fstats["failovers"],
                "degraded_accesses": fstats["degraded_accesses"],
                "nand_read_retries": int(rr),
                "retired_blocks": int(rb),
                "poisoned_reads": poison_total,
            }
        mb = None
        mspec = self.metrics
        if mspec is not None:
            from repro.core.replay import metrics as _metrics
            fcnt = stack.flash_counters(final)
            fcnt = np.asarray(fcnt) if fcnt is not None else None
            qthr = aux.get("qthr")
            if want_lat:
                mb = _metrics.bundle_single_deferred(
                    mspec, self.device, cfg, issues, dones, flags,
                    writes, aux["q"], fcnt, addrs, routes, size,
                    faults=fdict, faulted=faulted, qthr=qthr,
                    n_accesses=n_accesses, route_counts=route_counts)
            else:
                mb = _metrics.bundle_single_fused(
                    mspec, self.device, cfg, aux["acc"], aux["med"],
                    aux["q"], fcnt, addrs, routes, size,
                    faults=fdict, faulted=faulted, qthr=qthr,
                    n_accesses=n_accesses, route_counts=route_counts)
        if bad:
            raise ReplayUnsupported(
                "FTL ran out of free blocks during GC (device overfilled) — "
                "the interpreted path raises there too; shrink the trace or "
                "use engine='python' for the exact error")
        avail = None
        if (want_lat and deg_flags is not None
                and int(np.asarray(deg_flags).size) == n):
            from repro.core.replay import metrics as _metrics
            avail = _metrics.availability_series(
                issues, dones, deg_flags, fo_flags,
                spec=self.metrics, start_tick=start_tick)
        if want_lat:
            first = int(issues[0])
            last = max(int(dones.max(initial=0)), start_tick)
            lat_sum = int((dones - issues).sum())
        else:
            first = int(aux["first"])
            last = max(int(aux["last"]), start_tick)
            lat_sum = int(aux["sum"])
        return ReplayResult(
            accesses=n,
            bytes_moved=n * size,
            elapsed_ticks=last - first,
            sum_latency_ticks=lat_sum,
            end_tick=last,
            latency_ticks=dones - issues if want_lat else None,
            hit_flags=(flags & 1).astype(bool) if want_lat else None,
            evict_flags=(flags & 2).astype(bool) if want_lat else None,
            gc_runs=gcs,
            poison_flags=(((flags >> 6) & 1).astype(bool)
                          if want_lat and poisoned is not None else None),
            availability=avail,
            metrics=mb,
        )
