"""Fused device-stack trace replay in a single :func:`jax.lax.scan`.

One scan step = one trace access, end to end: LFB slot recycling (the
driver's bounded-outstanding issue model), link/fabric transport with
per-port busy-until occupancy, then the device media — DRAM timing, PMEM
row-buffer, the CXL-SSD page-register buffer, or the full DRAM-cache layer
(fully-associative LRU/FIFO or direct-mapped frames, MSHR coalescing and
stalls, bounded writeback buffer) backed by the HIL/FTL/PAL flash model
(log-append allocation, per-die array occupancy with program suspend,
per-channel bus occupancy).

The step function mirrors the interpreted path *operation for operation* —
every ``max(now, busy_until)``, every separately-rounded ``ns()`` constant —
so the replay is **tick-identical** to
:meth:`repro.core.workloads.driver.TraceDriver.run` over the same device
(property-tested in ``tests/test_replay.py``).  Scope cuts are host-checked
at spec time so they can never silently diverge (one 64 B line per access,
no FTL garbage collection, packed-field ranges).

Performance notes (XLA:CPU executes a scan body as a sequence of fusion
thunks, so the step is written to minimize thunks and buffer copies):

* cache frames live in ONE packed int64 per frame —
  ``stamp<<39 | page<<1 | dirty`` — so residency is one fused
  compare+argmax, the LRU/FIFO victim is one plain ``argmin`` (invalid
  frames are -1, below every packed value), and each access commits exactly
  one scatter;
* the entire miss machinery (MSHR allocate/stall, eviction writeback queue,
  FTL/PAL flash timing) sits behind one :func:`jax.lax.cond`, which
  passes the big carry buffers through untouched on hits;
* MSHR/writeback tables use value sentinels (page ``-1`` = free slot,
  ready ``BIG``) instead of separate mask arrays;
* transport port busy-until state is a tuple of scalars (hop *h* always
  uses port *h* on a single-host route), fusing into neighboring
  elementwise work.

Tick arithmetic runs in int64 under :func:`jax.experimental.enable_x64`
(scoped — the rest of the process keeps JAX's default 32-bit types); at
1 tick = 1 ps, int32 would overflow after 2.1 ms of simulated time.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.replay.spec import (
    DRAM,
    PMEM,
    SSD_BUF,
    SSD_CACHE,
    ReplayUnsupported,
    StackConfig,
    build_stack,
    trace_to_arrays,
    validate_block_size,
)
from repro.core.workloads.driver import TraceResult

# Plain ints: they stay weakly typed so they promote to int64 inside the
# enable_x64 scope (a jnp.int64 built at import time would truncate to int32).
BIG = 1 << 62          # order-infinity that survives additions
FREE = -1              # free-slot sentinel (pages/addresses are >= 0)

# Packed cache-frame layout: stamp-major so argmin == OrderedDict order.
STAMP_SHIFT = 39
PAGE_BITS = 38
PAGE_FIELD = ((1 << PAGE_BITS) - 1) << 1      # bits [38:1]
STAMP_FIELD = -(1 << STAMP_SHIFT)             # bits [63:39] (sign-extended ok)
MAX_PAGE = (1 << PAGE_BITS) - 2               # strict: all-ones is reserved
MAX_ACCESSES = (1 << 23) - 1                  # stamp<<39 must stay positive


def _i64(x):
    return jnp.asarray(x, jnp.int64)


# ---------------------------------------------------------------- transport
def _transport(cfg: StackConfig, p: Dict, pb: Tuple, t):
    """Routed store-and-forward transport: the vectorized form of
    :meth:`SwitchPort.transmit` along the precomputed route (hop *h* is
    port *h*), plus the CXL.mem round-trip extra."""
    pb = list(pb)
    for h in range(cfg.num_hops):
        start = jnp.maximum(t, pb[h])
        done = start + p["hop_occ"][h]
        pb[h] = done
        t = done + p["hop_after"][h]
    return tuple(pb), t + p["rt_extra"]


def _transport_ecmp(cfg: StackConfig, p: Dict, pb, t, route):
    """ECMP transport: hop *h* of the chosen route occupies the port
    ``hop_port[route, h]`` of the path set's port union, so the busy-until
    state is a vector indexed per access instead of a positional tuple.
    All equal-cost routes share one hop count (static)."""
    for h in range(cfg.num_hops):
        pi = p["hop_port"][route, h]
        start = jnp.maximum(t, pb[pi])
        done = start + p["hop_occ"][route, h]
        pb = pb.at[pi].set(done)
        t = done + p["hop_after"][route, h]
    return pb, t + p["rt_extra"]


# -------------------------------------------------------------- flash (PAL)
def _pal_read(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """Mirror of :meth:`PAL._schedule` (read path, program-suspend rule)."""
    C, D = cfg.channels, cfg.dies_per_channel
    ch = ppn % C
    i = ch * D + (ppn // C) % D
    db, dp, cb = f["die_busy"], f["die_prog"], f["chan_busy"]
    dbi, dpi, cbi = db[i], dp[i], cb[ch]
    ds = jnp.maximum(t, dbi)
    resume = jnp.minimum(dpi, ds + p["sus_t"])
    ds = jnp.where(dpi > ds, resume, ds)
    array_done = ds + p["read_t"]
    new_dp = jnp.where(dpi > ds, dpi + p["read_t"], dpi)
    bus_start = jnp.maximum(array_done, cbi)
    done = bus_start + p["xfer_page"]
    f = {**f,
         "die_busy": db.at[i].set(jnp.where(en, done, dbi)),
         "die_prog": dp.at[i].set(jnp.where(en, new_dp, dpi)),
         "chan_busy": cb.at[ch].set(jnp.where(en, done, cbi))}
    return f, done


def _pal_prog(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """Mirror of :meth:`PAL._schedule` (program path: bus in, then array)."""
    C, D = cfg.channels, cfg.dies_per_channel
    ch = ppn % C
    i = ch * D + (ppn // C) % D
    db, dp, cb = f["die_busy"], f["die_prog"], f["chan_busy"]
    dbi, dpi, cbi = db[i], dp[i], cb[ch]
    ds = jnp.maximum(jnp.maximum(t, dbi), dpi)
    bus_start = jnp.maximum(ds, cbi)
    bus_done = bus_start + p["xfer_page"]
    done = bus_done + p["prog_t"]
    f = {**f,
         "die_busy": db.at[i].set(jnp.where(en, bus_done, dbi)),
         "die_prog": dp.at[i].set(jnp.where(en, done, dpi)),
         "chan_busy": cb.at[ch].set(jnp.where(en, bus_done, cbi))}
    return f, done


def _hil_write(cfg: StackConfig, p: Dict, f: Dict, t, lpn, en):
    """HIL overhead + FTL log-append write.  (FTL ``_invalidate`` only moves
    valid-page counts, which are timing-neutral until GC — and GC-prone
    traces are rejected at spec time.)"""
    t0 = t + p["hil_ov"]
    need = f["wpp"] >= cfg.pages_per_block
    wpb = jnp.where(need, f["nfree"], f["wpb"])
    nfree = jnp.where(need, f["nfree"] + 1, f["nfree"])
    wpp = jnp.where(need, 0, f["wpp"])
    ppn = wpb * cfg.pages_per_block + wpp
    f = {**f,
         "wpb": jnp.where(en, wpb, f["wpb"]),
         "nfree": jnp.where(en, nfree, f["nfree"]),
         "wpp": jnp.where(en, wpp + 1, f["wpp"]),
         "l2p": f["l2p"].at[lpn].set(
             jnp.where(en, ppn.astype(jnp.int32), f["l2p"][lpn]))}
    return _pal_prog(cfg, p, f, t0, ppn, en)


def _hil_read(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """HIL overhead + FTL read of a programmed page (callers check the
    mapping table first, exactly like the cache's ``is_written`` gate)."""
    return _pal_read(cfg, p, f, t + p["hil_ov"], jnp.maximum(ppn, 0), en)


# ------------------------------------------------------------- device steps
def _dram_step(cfg: StackConfig, p: Dict, md: Dict, t, addr, wr, posted, ctr):
    start = jnp.maximum(t, md["busy"])
    occ_done = start + p["occ"]
    done = occ_done + jnp.where(posted, p["pack"], p["load"])
    md = {**md, "busy": occ_done}
    false = jnp.zeros((), bool)
    return md, done, false, false


def _pmem_step(cfg: StackConfig, p: Dict, md: Dict, t, addr, wr, posted, ctr):
    row = addr // p["row_bytes"]
    row_hit = row == md["row"]
    lat = p["lat"][jnp.where(wr, 1, 0), jnp.where(row_hit, 1, 0)]
    start = jnp.maximum(t, md["busy"])
    occ_done = start + p["occ"]
    done = occ_done + jnp.where(posted, p["pack"], lat)
    md = {**md, "busy": occ_done, "row": row}
    return md, done, row_hit, jnp.zeros((), bool)


def _buf_step(cfg: StackConfig, p: Dict, md: Dict, t, addr, wr, posted, ctr):
    """CXL-SSD page-register buffer: LRU over a handful of open pages;
    misses amplify to 4 KB flash ops (read-modify-write for writes)."""
    page = addr // cfg.page_bytes
    frames = md["frames"]
    pfield = page << 1
    match = (frames & PAGE_FIELD) == pfield
    match = match & (frames >= 0)
    fidx = jnp.argmax(match)
    hit = match[fidx]
    miss = ~hit
    old = frames[fidx]

    def miss_fn(op):
        frames, f = op
        vic = jnp.argmin(frames)
        vval = frames[vic]
        ev_dirty = (vval >= 0) & ((vval & 1) > 0)
        ev_page = (vval & PAGE_FIELD) >> 1
        ppn = f["l2p"][page]
        was_written = ppn >= 0
        f, rdone = _hil_read(cfg, p, f, t, _i64(ppn), was_written)
        done0 = jnp.where(was_written, rdone, t)
        f, _ = _hil_write(cfg, p, f, done0, ev_page, ev_dirty)
        return f, done0, vic, ev_dirty

    def hit_fn(op):
        frames, f = op
        return f, t, fidx, jnp.zeros((), bool)

    f, done0, vic, flushed = jax.lax.cond(miss, miss_fn, hit_fn,
                                          (frames, md["flash"]))

    # single commit: LRU touch on hit, insert over the victim on miss
    touch_val = (ctr << STAMP_SHIFT) | pfield | ((old & 1) | wr)
    insert_val = (ctr << STAMP_SHIFT) | pfield | wr
    idx = jnp.where(miss, vic, fidx)
    val = jnp.where(miss, insert_val, touch_val)
    frames = frames.at[idx].set(val)

    done = done0 + p["internal"]
    md = {**md, "frames": frames, "flash": f}
    return md, done, hit, flushed


def _cache_step(cfg: StackConfig, p: Dict, md: Dict, t, addr, wr, posted, ctr):
    """The paper's DRAM cache layer, one access: MSHR coalesce -> resident
    hit -> miss (MSHR stall, evict + writeback queue, flash fill).  Mirrors
    :meth:`repro.core.cache.dram_cache.DRAMCache.access` branch for branch."""
    page = addr // cfg.page_bytes
    frames = md["frames"]
    pfield = page << 1

    # ---- MSHR lookup (in-flight fill rides the existing SSD read)
    mm = md["mpage"] == page
    m_idx = jnp.argmax(mm)
    m_exists = mm[m_idx]
    m_ready = md["mready"][m_idx]
    coalesce = m_exists & (m_ready > t)

    # ---- residency
    if cfg.cache_assoc:
        match = ((frames & PAGE_FIELD) == pfield) & (frames >= 0)
        fidx = jnp.argmax(match)
        resident = match[fidx]
    else:
        fidx = page % p["cap"]
        fv = frames[fidx]
        resident = (fv >= 0) & ((fv & PAGE_FIELD) == pfield)
    hit = (~coalesce) & resident
    miss = (~coalesce) & (~resident)
    old = frames[fidx]

    # ---- hit: 64 B transfer occupies cache-DRAM bandwidth
    xstart = jnp.maximum(t, md["dram_busy"])
    xdone = xstart + p["line_xfer"]

    # ---- miss machinery behind one cond (hits pass the buffers through)
    def miss_fn(op):
        frames, mpage, mready, wtick, f = op
        # MSHR allocate (stall if the table is full)
        mfull = jnp.sum(mpage >= 0) >= cfg.mshr_entries
        vic_ready = jnp.min(mready)             # free slots hold BIG
        start1 = jnp.where(mfull, jnp.maximum(t, vic_ready), t)
        kill = mfull & (mready <= vic_ready)
        mpage = jnp.where(kill, FREE, mpage)
        mready = jnp.where(kill, BIG, mready)
        # write-allocate insert: victim = argmin of packed stamps (invalid
        # frames are -1, below every valid packed value)
        vic = jnp.argmin(frames) if cfg.cache_assoc else fidx
        vval = frames[vic]
        ev_valid = vval >= 0
        ev_page = (vval & PAGE_FIELD) >> 1
        do_wb = ev_valid & ((vval & 1) > 0)
        # writeback queue: background flash write, stall only if full.
        # Mutations are gated on do_wb — Python touches the queue only via
        # _queue_writeback, which clean misses never call.
        dead = wtick <= start1                   # reap(now)
        wtick = jnp.where(do_wb & dead, FREE, wtick)
        wfull = jnp.sum(~dead) >= cfg.wb_slots
        wmin = jnp.min(jnp.where(dead, BIG, wtick))
        stall = jnp.where(wfull, wmin, start1)
        wtick = jnp.where(do_wb & wfull & (wtick <= stall), FREE, wtick)
        f, wdone = _hil_write(cfg, p, f, stall, ev_page, do_wb)
        wslot = jnp.argmin(wtick)
        wtick = wtick.at[wslot].set(jnp.where(do_wb, wdone, wtick[wslot]))
        start2 = jnp.where(do_wb, jnp.maximum(start1, stall), start1)
        # fill from flash (virgin pages skip the read), then cache-DRAM
        ppn = f["l2p"][page]
        was_written = ppn >= 0
        f, rdone = _hil_read(cfg, p, f, start2, _i64(ppn), was_written)
        flash_done = jnp.where(was_written, rdone, start2)
        fill_done = jnp.maximum(flash_done, md["dram_busy"]) + p["page_xfer"]
        # MSHR insert (dict semantics: existing key overwrites) + expiry
        slot = jnp.where(m_exists, m_idx, jnp.argmin(mpage))
        mpage = mpage.at[slot].set(page)
        mready = mready.at[slot].set(fill_done)
        kill2 = mready <= t
        mpage = jnp.where(kill2, FREE, mpage)
        mready = jnp.where(kill2, BIG, mready)
        return (mpage, mready, wtick, f, start2, fill_done, vic, do_wb)

    def pass_fn(op):
        frames, mpage, mready, wtick, f = op
        return (mpage, mready, wtick, f, t, t, fidx, jnp.zeros((), bool))

    mpage, mready, wtick, f, start2, fill_done, vic, do_wb = jax.lax.cond(
        miss, miss_fn, pass_fn,
        (frames, md["mpage"], md["mready"], md["wtick"], md["flash"]))

    # ---- single frame commit: touch (hit / coalesced store) or insert
    touch_en = (coalesce & wr & resident) | hit
    stamp_bits = jnp.where(p["is_lru"], ctr << STAMP_SHIFT, old & STAMP_FIELD)
    touch_val = stamp_bits | pfield | ((old & 1) | wr)
    insert_val = (ctr << STAMP_SHIFT) | pfield | wr
    idx = jnp.where(miss, vic, fidx)
    val = jnp.where(miss, insert_val, jnp.where(touch_en, touch_val, old))
    frames = frames.at[idx].set(val)

    dram_busy = jnp.where(hit, xdone,
                          jnp.where(miss, fill_done, md["dram_busy"]))
    ret_co = jnp.where(wr, t + p["hit_lat"], m_ready + p["hit_lat"])
    ret_hit = jnp.where(wr,
                        jnp.where(posted, t + p["pack10"], t + p["hit_lat"]),
                        jnp.maximum(xdone, t + p["hit_lat"]))
    ret_miss = jnp.where(wr, start2 + p["hit_lat"], fill_done + p["hit_lat"])
    ret = jnp.where(coalesce, ret_co, jnp.where(hit, ret_hit, ret_miss))

    md = {**md, "frames": frames, "mpage": mpage, "mready": mready,
          "wtick": wtick, "dram_busy": dram_busy, "flash": f}
    return md, jnp.maximum(t, ret), hit, do_wb


_STEPS = {DRAM: _dram_step, PMEM: _pmem_step, SSD_BUF: _buf_step,
          SSD_CACHE: _cache_step}


# -------------------------------------------------------------- state init
def _flash_init(cfg: StackConfig):
    C, D = cfg.channels, cfg.dies_per_channel
    return {
        "l2p": jnp.full(cfg.num_pages, -1, jnp.int32),
        "wpb": _i64(0), "wpp": _i64(0), "nfree": _i64(1),
        "die_busy": jnp.zeros(C * D, jnp.int64),
        "die_prog": jnp.zeros(C * D, jnp.int64),
        "chan_busy": jnp.zeros(C, jnp.int64),
    }


def _media_init(cfg: StackConfig):
    if cfg.kind == DRAM:
        return {"busy": _i64(0)}
    if cfg.kind == PMEM:
        return {"busy": _i64(0), "row": _i64(-1)}
    if cfg.kind == SSD_BUF:
        return {"frames": jnp.full(cfg.buf_entries, -1, jnp.int64),
                "flash": _flash_init(cfg)}
    if cfg.kind == SSD_CACHE:
        return {"frames": jnp.full(cfg.cache_frames, -1, jnp.int64),
                "mpage": jnp.full(cfg.mshr_entries, FREE, jnp.int64),
                "mready": jnp.full(cfg.mshr_entries, BIG, jnp.int64),
                "wtick": jnp.full(cfg.wb_slots, FREE, jnp.int64),
                "dram_busy": _i64(0),
                "flash": _flash_init(cfg)}
    raise ValueError(cfg.kind)


# ------------------------------------------------------------------ runner
def _scan_stack(cfg: StackConfig, p: Dict, media, addrs, writes, start_tick,
                routes=None, block=1):
    """The scan proper, parameterized by the initial media state so sweeps
    can vary it per vmap lane (e.g. capacity via disabled frames).
    ``routes`` is the per-access ECMP choice column (required when
    ``cfg.num_routes > 1``, ignored otherwise).  ``block`` is the blocked
    replay width: the scan body replays ``block`` accesses per sequential
    step (scan unroll), with the carry crossing block seams untouched —
    tick-identical at any block size, but the per-step dispatch floor is
    paid once per block instead of once per access."""
    dev_step = _STEPS[cfg.kind]
    ecmp = cfg.num_routes > 1
    if ecmp and routes is None:
        # callers without a route column (e.g. cache_design_sweep) follow
        # the replay layer's fallback contract, so refuse accordingly
        raise ReplayUnsupported(
            "ECMP stack needs a per-access route column; this entry point "
            "supports single-route mounts only (use engine='python')")
    init = (jnp.full(cfg.outstanding, start_tick, jnp.int64),  # LFB slots
            _i64(start_tick),                                  # issue clock
            _i64(1),                                           # stamp counter
            # port busy-until: positional tuple on a fixed route (fuses into
            # elementwise work), an indexable vector under ECMP
            jnp.zeros(cfg.num_ports, jnp.int64) if ecmp
            else tuple(_i64(0) for _ in range(cfg.num_ports)),
            media)

    def step(carry, x):
        slots, now, ctr, pb, md = carry
        if ecmp:
            addr, wr, route = x
        else:
            addr, wr = x
        k = jnp.argmin(slots)
        issue = jnp.maximum(now, slots[k])
        posted = wr if cfg.posted_writes else jnp.zeros((), bool)
        if ecmp:
            pb, t = _transport_ecmp(cfg, p, pb, issue, route)
        else:
            pb, t = _transport(cfg, p, pb, issue)
        md, done, hit, evict = dev_step(cfg, p, md, t, addr, wr, posted, ctr)
        slots = slots.at[k].set(done)
        flags = jnp.where(hit, 1, 0) | jnp.where(evict, 2, 0)
        return ((slots, issue + p["issue_ov"], ctr + 1, pb, md),
                (issue, done, flags.astype(jnp.int32)))

    xs = (addrs, writes, routes) if ecmp else (addrs, writes)
    carry, (issues, dones, flags) = jax.lax.scan(step, init, xs, unroll=block)
    return issues, dones, flags, carry[4]


@functools.partial(jax.jit, static_argnums=(0, 5))
def _run_stack(cfg: StackConfig, p: Dict, addrs, writes, start_tick,
               block: int = 1):
    return _scan_stack(cfg, p, _media_init(cfg), addrs, writes, start_tick,
                       block=block)


@functools.partial(jax.jit, static_argnums=(0, 6))
def _run_stack_ecmp(cfg: StackConfig, p: Dict, addrs, writes, routes,
                    start_tick, block: int = 1):
    return _scan_stack(cfg, p, _media_init(cfg), addrs, writes, start_tick,
                       routes=routes, block=block)


# ------------------------------------------------------------------ facade
@dataclass
class ReplayResult(TraceResult):
    """A :class:`TraceResult` plus the per-access tensors the fused scan
    already produced for free."""

    latency_ticks: Optional[np.ndarray] = None   # done - issue, per access
    hit_flags: Optional[np.ndarray] = None
    evict_flags: Optional[np.ndarray] = None

    @property
    def hits(self) -> int:
        return int(self.hit_flags.sum()) if self.hit_flags is not None else 0


class ReplayEngine:
    """Fused, vectorized stand-in for :class:`TraceDriver` (one host).

    ``run`` is tick-identical to ``TraceDriver(device, ...).run`` for the
    supported stacks (all five paper devices, directly attached or mounted
    behind a switch fabric; cache policies lru/fifo/direct).  Unsupported
    shapes raise :class:`ReplayUnsupported` so callers can fall back.
    """

    def __init__(self, device, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5,
                 posted_writes: bool = True, block_size: int = 1) -> None:
        self.device = device
        self.outstanding = max(1, outstanding)
        self.issue_overhead_ns = issue_overhead_ns
        self.posted_writes = posted_writes
        self.block_size = validate_block_size(block_size)

    def run(self, trace, start_tick: int = 0) -> ReplayResult:
        addrs, writes, size = trace_to_arrays(trace)
        return self.run_arrays(addrs, writes, size=size,
                               start_tick=start_tick)

    def run_arrays(self, addrs: np.ndarray, writes: np.ndarray, *,
                   size: int = 64, start_tick: int = 0) -> ReplayResult:
        addrs = np.asarray(addrs, np.int64)
        writes = np.asarray(writes, bool)
        if addrs.size == 0:
            raise ReplayUnsupported("empty trace")
        if addrs.size > MAX_ACCESSES:
            raise ReplayUnsupported(
                f"trace longer than {MAX_ACCESSES} accesses (packed-stamp "
                "budget); split the trace or use engine='python'")
        if start_tick < 0 and getattr(getattr(self.device, "fabric", None),
                                      "qos_enabled", False):
            # with start_tick >= 0 a lone origin's QoS floor provably never
            # binds (see spec._fabric_hops); negative ticks void the proof
            raise ReplayUnsupported(
                "QoS replay needs start_tick >= 0; use engine='python'")
        cfg, params = build_stack(
            self.device, size=size, outstanding=self.outstanding,
            issue_overhead_ns=self.issue_overhead_ns,
            posted_writes=self.posted_writes, n_accesses=addrs.size,
            max_addr=int(addrs.max(initial=0)))
        with enable_x64():
            pj = jax.tree.map(jnp.asarray, params)
            if cfg.num_routes > 1:
                from repro.core.replay.spec import access_route_choices
                routes = access_route_choices(self.device, addrs)
                issues, dones, flags, _ = _run_stack_ecmp(
                    cfg, pj, jnp.asarray(addrs), jnp.asarray(writes),
                    jnp.asarray(routes), _i64(start_tick), self.block_size)
            else:
                issues, dones, flags, _ = _run_stack(
                    cfg, pj, jnp.asarray(addrs), jnp.asarray(writes),
                    _i64(start_tick), self.block_size)
            issues = np.asarray(issues)
            dones = np.asarray(dones)
            flags = np.asarray(flags)
        first = int(issues[0])
        last = max(int(dones.max(initial=0)), start_tick)
        return ReplayResult(
            accesses=int(addrs.size),
            bytes_moved=int(addrs.size) * size,
            elapsed_ticks=last - first,
            sum_latency_ticks=int((dones - issues).sum()),
            end_tick=last,
            latency_ticks=dones - issues,
            hit_flags=(flags & 1).astype(bool),
            evict_flags=(flags & 2).astype(bool),
        )
