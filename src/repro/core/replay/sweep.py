"""vmap-batched design-space sweeps over the fused replay engine.

One compiled call evaluates a whole batch of simulator configurations
against the same (or per-lane) traces:

* :func:`cache_design_sweep` — batch over DRAM-cache **capacity**
  (disabled-frame masking inside a fixed frame array), **policy**
  (LRU/FIFO via the traced ``is_lru`` flag), and any **timing constant**
  (hit latency, link occupancy, flash timing, ...), on the full
  cached-CXL-SSD stack.  Each lane runs the same tick-exact step function
  the single-config engine runs, so lane *k* of the batch equals a
  standalone :class:`~repro.core.replay.engine.ReplayEngine` run with that
  config (tested).
* :func:`host_count_sweep` — batch over **host count** on the fused
  multi-host replay: one compiled program, one vmap lane per host count,
  inactive hosts masked out of the issue race by zero-length traces
  (``sharded=True`` instead reuses one cached shard_map program — the
  masked lengths are traced — across every host count sharing the shard
  shape).
* :func:`fault_seed_sweep` — batch over **fault-plan seed** on the fused
  multi-host replay under an active transport fault plan: the per-seed
  precomputed hop columns (retry-stretched occupancies, failover routes)
  are the ONLY batched leaves, so one compiled program yields the full
  tail-latency-under-failure / availability distribution across seeds.

On CPU these amortize compile time and per-step dispatch; on TPU/GPU the
lanes vectorize across the batch dimension, which is where the
design-space throughput multiplier comes from.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.core.replay import stack
from repro.core.replay.engine import _scan_stack
from repro.core.replay.metrics import availability_series
from repro.core.replay.multihost import MultiHostReplay, _run_multi
from repro.core.replay.spec import SSD_CACHE, ReplayUnsupported, build_stack
from repro.core.replay.stack import MAX_ACCESSES, PAGE_FIELD, _i64
from repro.core.workloads.driver import MultiHostResult

# A disabled frame: never matches (page field all-ones is reserved) and is
# never chosen as victim (above every valid packed value and every -1).
DISABLED = (1 << 62) | PAGE_FIELD


# Module-level jitted runners (like engine._run_stack / multihost._run_multi)
# so repeated sweep calls with the same static shape hit the compile cache.
@functools.partial(jax.jit, static_argnums=(0, 3, 4))
def _run_cache_lanes(cfg, pj: Dict, trace_args, batched: frozenset,
                     trace_ax):
    axes = {k: (0 if k in batched else None) for k in pj}
    a, w = trace_args

    def one(p1, a1, w1):
        st = stack.init_state(cfg)
        frames = jnp.where(
            jnp.arange(cfg.cache_frames) < p1["cap"],
            jnp.asarray(-1, jnp.int64),
            jnp.asarray(DISABLED, jnp.int64))
        st = {**st, "media": {**st["media"], "frames": frames[None]}}
        return _scan_stack(cfg, p1, st, a1, w1, _i64(0))

    return jax.vmap(one, in_axes=(axes, trace_ax, trace_ax))(pj, a, w)


#: the per-seed transport-fault hop columns — the only params leaves that
#: change with the FaultPlan seed (down segments, and hence every static
#: shape, come from the FaultConfig alone)
_FAULT_KEYS = ("fhp", "fho", "fha", "fhon", "fhoc")


@functools.partial(jax.jit, static_argnums=(0, 6))
def _run_fault_lanes(cfg, pj: Dict, devs, addrs, writes, lens,
                     batched: frozenset):
    axes = {k: (0 if k in batched else None) for k in pj}
    return jax.vmap(
        lambda p1: _run_multi(cfg, p1, devs, addrs, writes, lens, _i64(0)),
        in_axes=(axes,))(pj)


@functools.partial(jax.jit, static_argnums=(0,))
def _run_multi_lanes(cfg, pj: Dict, devs, addrs, writes, lane_lens):
    return jax.vmap(
        lambda lens_k: _run_multi(cfg, pj, devs, addrs, writes, lens_k,
                                  _i64(0)))(lane_lens)


def cache_design_sweep(device, addrs, writes, *,
                       capacity_frames: Sequence[int],
                       is_lru: Sequence[bool],
                       timing_overrides: Optional[Dict[str, Sequence]] = None,
                       outstanding: int = 32,
                       issue_overhead_ns: float = 0.5,
                       posted_writes: bool = True) -> Dict[str, np.ndarray]:
    """Replay a trace under B cached-device configs in one compiled call.

    ``capacity_frames[k]`` / ``is_lru[k]`` / ``timing_overrides[name][k]``
    describe lane k; all sequences must share length B.  ``device`` provides
    the base config and must have ``capacity_pages >= max(capacity_frames)``.
    ``addrs``/``writes`` may be (N,) — shared by every lane — or (B, N) for
    per-lane traces.  Returns stacked per-lane arrays (``latency_ticks``,
    ``hit_flags`` of shape (B, N)) plus derived (B,) summaries.
    """
    addrs = np.asarray(addrs, np.int64)
    writes = np.asarray(writes, bool)
    caps = np.asarray(capacity_frames, np.int64)
    lru = np.asarray(is_lru, bool)
    B = caps.size
    if lru.size != B:
        raise ValueError("capacity_frames and is_lru must share a length")
    if addrs.shape[-1] > MAX_ACCESSES:
        raise ReplayUnsupported(
            f"trace longer than {MAX_ACCESSES} accesses (packed-stamp "
            "budget); split the trace")
    cfg, params = build_stack(
        device, size=64, outstanding=outstanding,
        issue_overhead_ns=issue_overhead_ns, posted_writes=posted_writes,
        n_accesses=addrs.shape[-1], max_addr=int(addrs.max(initial=0)))
    if cfg.kind != SSD_CACHE:
        raise ReplayUnsupported("cache_design_sweep needs a cached CXL-SSD")
    if not cfg.cache_assoc:
        raise ReplayUnsupported(
            "the policy axis covers lru/fifo; sweep direct-mapped separately")
    if caps.max() > cfg.cache_frames or caps.min() < 1:
        raise ReplayUnsupported("capacity lane exceeds the device's frames")
    params["is_lru"] = lru
    params["cap"] = caps
    batched = {"is_lru", "cap"}
    for name, vals in (timing_overrides or {}).items():
        if name not in params:
            raise ValueError(f"unknown timing parameter {name!r}")
        vals = np.asarray(vals)
        if vals.shape[0] != B:
            raise ValueError(f"override {name!r} must have {B} lanes")
        params[name] = vals
        batched.add(name)

    trace_ax = 0 if addrs.ndim == 2 else None
    with enable_x64():
        pj = {k: jnp.asarray(v) for k, v in params.items()}
        issues, dones, flags, final, _ = _run_cache_lanes(
            cfg, pj, (jnp.asarray(addrs), jnp.asarray(writes)),
            frozenset(batched), trace_ax)
        issues = np.asarray(issues)
        dones = np.asarray(dones)
        flags = np.asarray(flags)
        flash = final["flash"]
        if flash is not None and "bad" in flash:
            # certify-or-refuse, per lane: a lane whose FTL ran out of free
            # blocks during GC replayed past the point where the
            # interpreted path raises — its numbers must not escape
            bad_lanes = [k for k, b in
                         enumerate(np.asarray(flash["bad"]).reshape(B, -1))
                         if b.any()]
            if bad_lanes:
                raise ReplayUnsupported(
                    f"sweep lane(s) {bad_lanes}: FTL ran out of free blocks "
                    "during GC (device overfilled); use engine='python'")
    lat = dones - issues
    return {
        "latency_ticks": lat,
        "hit_flags": (flags & 1).astype(bool),
        "evict_flags": (flags & 2).astype(bool),
        "sum_latency_ticks": lat.sum(axis=1),
        "hit_rate": (flags & 1).mean(axis=1),
        "elapsed_ticks": dones.max(axis=1) - issues[:, 0],
    }


def host_count_sweep(targets: Sequence, traces: Sequence,
                     host_counts: Sequence[int],
                     outstanding: int = 32,
                     issue_overhead_ns: float = 0.5,
                     posted_writes: bool = True,
                     sharded: bool = False,
                     devices: Optional[Sequence] = None,
                     info: Optional[Dict] = None) -> List[MultiHostResult]:
    """Replay the same multi-host scenario at several host counts with ONE
    compiled program.

    ``targets``/``traces`` describe the largest configuration; lane k keeps
    the first ``host_counts[k]`` hosts and masks the rest out with
    zero-length traces (an absent host issues nothing, so the shared-port
    and media contention it would have caused never happens — identical to
    running the smaller scenario).  Lane k is tick-identical to
    ``MultiHostReplay(targets[:k]).run(traces[:k])`` over the *same shared
    fabric* (tested against :class:`MultiHostDriver`).  Any stack-layer
    media works, cached CXL-SSD included — absent hosts leave their private
    cache lanes (and the shared flash) untouched.

    ``sharded=True`` runs each host count through
    :class:`~repro.core.replay.shard.ShardedMultiHostReplay` on ``devices``
    (default ``jax.devices()``): the masked length vector is a *traced*
    argument of the cached shard_map program, so every host count sharing
    the shard shape reuses one compiled program — the same amortization the
    unsharded path gets from vmap lanes, at ``~H/D`` per-device state.
    Pass a dict as ``info`` to receive the execution report
    (``{"sharded", "device_count", "hosts_per_device"}``).
    """
    if sharded:
        from repro.core.replay.shard import ShardedMultiHostReplay
        eng = ShardedMultiHostReplay(targets, outstanding=outstanding,
                                     issue_overhead_ns=issue_overhead_ns,
                                     posted_writes=posted_writes,
                                     devices=devices)
        cfg, params, devs, addrs, writes, lens, size = eng.prepare(traces)
        out: List[MultiHostResult] = []
        with enable_x64():
            for h in host_counts:
                lane = np.where(np.arange(lens.size) < h, lens, 0)
                who, issues, dones, bad, _, _ = eng._dispatch(
                    cfg, params, devs, addrs, writes, lane, 0,
                    None, True, size, None)
                who = np.asarray(who)
                issues = np.asarray(issues)
                dones = np.asarray(dones)
                total = int(lane.sum())
                if total and bool(np.asarray(bad)[total - 1]):
                    raise ReplayUnsupported(
                        f"host-count lane {h}: FTL ran out of free blocks "
                        "during GC; use engine='python'")
                out.append(eng.aggregate(who, issues, dones, lane, size))
        if info is not None:
            info.update(dict(eng.last_mesh, sharded=True))
        return out
    eng = MultiHostReplay(targets, outstanding=outstanding,
                          issue_overhead_ns=issue_overhead_ns,
                          posted_writes=posted_writes)
    cfg, params, devs, addrs, writes, lens, size = eng.prepare(traces)
    if info is not None:
        info.update({"sharded": False, "device_count": 1,
                     "hosts_per_device": int(lens.size)})
    lane_lens = np.stack([
        np.where(np.arange(lens.size) < h, lens, 0) for h in host_counts])
    with enable_x64():
        pj = jax.tree.map(jnp.asarray, params)
        who, issues, dones, bad, _, _ = _run_multi_lanes(
            cfg, pj, jnp.asarray(devs), jnp.asarray(addrs),
            jnp.asarray(writes), jnp.asarray(lane_lens))
        who = np.asarray(who)
        issues = np.asarray(issues)
        dones = np.asarray(dones)
        bad = np.asarray(bad)
    for k in range(len(host_counts)):
        total = int(lane_lens[k].sum())
        if total and bool(bad[k, total - 1]):
            raise ReplayUnsupported(
                f"host-count lane {host_counts[k]}: FTL ran out of free "
                "blocks during GC; use engine='python'")
    return [eng.aggregate(who[k], issues[k], dones[k], lane_lens[k], size)
            for k in range(len(host_counts))]


def fault_seed_sweep(make_targets, traces: Sequence, seeds: Sequence[int],
                     *, outstanding: int = 32,
                     issue_overhead_ns: float = 0.5,
                     posted_writes: bool = True,
                     window_ticks: Optional[int] = None,
                     num_windows: int = 32) -> List[Dict]:
    """Replay one multi-host scenario under B transport-fault seeds in ONE
    compiled vmapped call — the fleet-scale availability sweep.

    ``make_targets(seed)`` builds fresh fabric-mounted targets with a
    ``FaultPlan(cfg, seed=seed)`` installed; every seed must share the
    FaultConfig (down windows and the derived hop/port shapes are config
    properties — a seed that changed them could not share the compiled
    program, and the sweep refuses).  Only the precomputed per-access hop
    columns (retry-stretched occupancies, failover paths) differ across
    lanes, so they are the sole batched leaves.

    Lane k is tick-identical to
    ``MultiHostReplay(make_targets(seeds[k])).run(traces)`` (and hence to
    the interpreted ``MultiHostDriver``).  Each returned dict carries the
    per-seed ``result`` (:class:`MultiHostResult`), pooled
    ``latency_ticks`` (valid accesses, global issue order),
    ``availability`` (:func:`~repro.core.replay.metrics.availability_series`
    over the pooled per-access degraded/failover flags) and the
    ``fault_stats`` counter dict.  With ``window_ticks=None`` the window
    width is derived from the batch (max completion tick over all lanes /
    ``num_windows``) so every lane's availability curve shares one axis.
    """
    cfg0 = base = devs = addrs = writes = lens = None
    size = 0
    stacked: Dict[str, List] = {k: [] for k in _FAULT_KEYS}
    flags, stats = [], []
    for s in seeds:
        eng = MultiHostReplay(make_targets(s), outstanding=outstanding,
                              issue_overhead_ns=issue_overhead_ns,
                              posted_writes=posted_writes)
        cfg, params, dv, ad, wr, ln, sz = eng.prepare(traces)
        if not cfg.fault_hops:
            raise ReplayUnsupported(
                "fault_seed_sweep needs an active transport fault plan "
                "(link-retry and/or down-window classes) installed on the "
                "shared fabric; for fault-free host scaling use "
                "host_count_sweep")
        if cfg0 is None:
            cfg0, base, devs, addrs, writes, lens, size = \
                cfg, params, dv, ad, wr, ln, sz
        elif cfg != cfg0:
            raise ReplayUnsupported(
                "fault seeds changed the compiled shape — down windows "
                "(and the hop/port geometry they induce) must come from "
                "the shared FaultConfig, not the per-lane seed")
        for k in _FAULT_KEYS:
            stacked[k].append(params[k])
        flags.append(eng.fault_flags)
        stats.append(dict(eng._meta["fault_stats"]))
    pj = dict(base)
    for k in _FAULT_KEYS:
        pj[k] = np.stack(stacked[k])
    with enable_x64():
        pj = jax.tree.map(jnp.asarray, pj)
        who, issues, dones, bad, _, _ = _run_fault_lanes(
            cfg0, pj, jnp.asarray(devs), jnp.asarray(addrs),
            jnp.asarray(writes), jnp.asarray(lens),
            frozenset(_FAULT_KEYS))
        who = np.asarray(who)
        issues = np.asarray(issues)
        dones = np.asarray(dones)
        bad = np.asarray(bad)
    lens = np.asarray(lens)
    total = int(lens.sum())
    valid = np.arange(who.shape[1]) < total
    if window_ticks is None:
        max_end = int(dones[:, valid].max(initial=0)) if total else 1
        window_ticks = max(1, -(-max_end // num_windows))
    out: List[Dict] = []
    for k, s in enumerate(seeds):
        if total and bool(bad[k, total - 1]):
            raise ReplayUnsupported(
                f"fault seed lane {s}: FTL ran out of free blocks during "
                "GC (device overfilled); use engine='python'")
        res = MultiHostReplay.aggregate(who[k], issues[k], dones[k],
                                        lens, size)
        deg, fo = flags[k]
        iss_h, dn_h, deg_h, fo_h = [], [], [], []
        for i in range(lens.size):
            m = valid & (who[k] == i)
            iss_h.append(issues[k][m])
            dn_h.append(dones[k][m])
            deg_h.append(deg[i, :lens[i]])
            fo_h.append(fo[i, :lens[i]])
        iss = np.concatenate(iss_h)
        dn = np.concatenate(dn_h)
        av = availability_series(iss, dn, np.concatenate(deg_h),
                                 np.concatenate(fo_h),
                                 window_ticks=window_ticks,
                                 num_windows=num_windows)
        out.append({"seed": int(s), "result": res,
                    "latency_ticks": dn - iss,
                    "availability": av, "fault_stats": stats[k]})
    return out
