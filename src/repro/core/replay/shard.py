"""Rack-scale sharded fused multi-host replay: ``shard_map`` over the host
axis.

:class:`ShardedMultiHostReplay` partitions the leading host axis of the
fused multi-host scan — the per-host LFB slots / clocks / trace cursors,
the :mod:`repro.core.replay.stack` media and (private) flash lanes, and the
per-host trace / route / fault columns — across ``D`` JAX devices with
``jax.experimental.shard_map``, so an ``H``-host replay holds ``~H/D``
per-device state.  The *shared* simulator state stays explicitly
replicated: the per-port busy-until vector, the QoS virtual-finish /
last-arrival tables and the global stamp counter are updated identically on
every shard from broadcast winner inputs, so replicas never diverge.

Two collectives per scan step mirror the global issue order exactly:

1. **winner election** — each shard races its local hosts
   (``max(own clock, oldest LFB slot)``, ties to the lowest local index)
   and ``all_gather``\\ s its ``(candidate tick, local index)`` pair; the
   argmin over shard minima (ties to the lowest shard) reproduces the
   interpreted heap's global ``(tick, host index)`` order *exactly*,
   because hosts are block-assigned to shards (host ``i`` lives on shard
   ``i // (H/D)`` — the same block assignment the ``multi_pod`` topology
   builder uses for pods).
2. **record broadcast** — the owning shard packs the winner's access
   ``(addr, write)`` plus its per-hop transport rows (port index, charged
   and clean occupancy, post-hop latency, on-mask) into one int64 vector,
   zero-gated ``psum`` broadcasts it, and every shard then walks the same
   shared-port / QoS-mirror update the unsharded lane walks — replicated
   arithmetic on replicated state.

The media step runs SPMD-lockstep on every shard (``lax.cond`` branches
diverge per shard, which is fine — there is no collective inside the
stack), with the lane *writeback* gated to the owner via
:func:`repro.core.replay.stack.step`'s ``en`` flag; every use of the
non-owner's garbage outputs is owner-gated before it reaches an
accumulator.  Padded trailing steps broadcast a zero record (no port or
QoS mutation) — valid outputs are unaffected, exactly like the unsharded
lane's discarded trailing steps.

**Certify or refuse.**  The sharded lane is tick-identical (latencies,
MetricsBundle, fault counters) to :class:`MultiHostReplay` — and hence to
the interpreted :class:`MultiHostDriver` — for per-host fabric *mounts*
over any stack medium with *private* flash, QoS / ECMP / transport-fault
columns included (property-tested at H in {2, 8, 32}).  Pooled views
(one address space interleaved across shards) and shared-flash HILs
(one flash state coupled across shards every step) refuse with the
widest covering lane named, as does ``chunk_size`` (stream per shard or
use the unsharded chunked lane).

On a CPU dev box, force a multi-device host platform with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* importing
jax; the shard count is the largest divisor of ``H`` not exceeding the
available (or passed) devices, so any H runs on any box — ``D=1`` is the
degenerate single-shard program, still the exact same SPMD code path.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.fabric.switch import ACTIVE_WINDOW_OCC
from repro.core.replay import stack
from repro.core.replay.multihost import BIG, NEVER, MultiCfg, MultiHostReplay
from repro.core.replay.spec import DRAM, ReplayUnsupported
from repro.core.replay.stack import _i64

#: params leaves that are sharded along the host axis (everything else in
#: the params dict rides replicated)
_FAULT_KEYS = ("fhp", "fho", "fha", "fhon", "fhoc")


def shard_count(num_hosts: int, devices: Optional[Sequence] = None) -> int:
    """The shard count used for ``num_hosts``: the largest divisor of the
    host count that does not exceed the available (or given) devices."""
    n = len(devices) if devices is not None else jax.device_count()
    d = max(1, min(n, num_hosts))
    while num_hosts % d:
        d -= 1
    return d


def _body(cfg: MultiCfg, D: int, mspec, want_lat: bool, size: int,
          block: int, start_tick, sh: Dict, rep: Dict):
    """The per-shard program: local init, the elected-winner scan, and the
    post-scan reductions that make every output replicated."""
    from repro.core.replay import metrics as _metrics

    H, O = cfg.num_hosts, cfg.outstanding
    Hl = H // D
    L = sh["addrs"].shape[1]
    MH = cfg.max_hops
    me = jax.lax.axis_index("hosts")
    addrs_l, writes_l, lens_l = sh["addrs"], sh["writes"], sh["lens"]

    st0 = stack.init_state(cfg.stack, Hl)
    aux0 = {}
    if mspec is not None:
        # replicated-*shaped*, locally accumulated: each shard adds only
        # its owner-steps, the post-scan psum folds them to global totals
        aux0["acc"] = jnp.zeros(
            (_metrics.acc_rows(mspec, H, cfg.num_devs), 4), jnp.int64)
        aux0["med"] = jnp.zeros(
            (cfg.num_devs, len(_metrics.MEDIA_COUNTERS[cfg.stack.kind])),
            jnp.int64)
        aux0["q"] = jnp.zeros(cfg.num_ports, jnp.int64)
        if cfg.qos:
            aux0["qthr"] = jnp.zeros(cfg.num_ports, jnp.int64)
        fc0 = stack.flash_counters(st0)
        if fc0 is not None:
            aux0["flash"] = fc0                     # local (Hl, 5) snapshot
        if cfg.stack.faults:
            aux0["faults"] = jnp.stack(stack.fault_counters(st0))
    if not want_lat:
        aux0["first"] = jnp.full(Hl, BIG, jnp.int64)
        aux0["last"] = jnp.full(Hl, start_tick, jnp.int64)
        aux0["sum"] = jnp.zeros(Hl, jnp.int64)
        aux0["cnt"] = jnp.zeros(Hl, jnp.int64)
        aux0["bad"] = jnp.zeros((), bool)
        aux0["gcs"] = _i64(0)
    init = (jnp.full((Hl, O), start_tick, jnp.int64),
            jnp.full(Hl, start_tick, jnp.int64),
            jnp.zeros(Hl, jnp.int64),
            jnp.zeros(cfg.num_ports, jnp.int64),
            _i64(1),
            st0,
            jnp.zeros((cfg.num_ports, H), jnp.int64),
            jnp.full((cfg.num_ports, H), NEVER, jnp.int64),
            aux0)

    def step(carry, _):
        slots, now, idx, port_busy, ctr, st, vft, last_arr, aux = carry
        # -- collective 1: winner election (global lowest-(tick, index))
        cand = jnp.where(idx < lens_l,
                         jnp.maximum(now, jnp.min(slots, axis=1)), BIG)
        li0 = jnp.argmin(cand)
        g = jax.lax.all_gather(
            jnp.stack([cand[li0], li0.astype(jnp.int64)]), "hosts")
        w = jnp.argmin(g[:, 0])          # ties -> lowest shard
        li = g[w, 1]                     # winner's local lane (owner shard)
        issue = g[w, 0]                  # == max(now, min slot) when valid
        valid = issue < BIG
        am = me == w
        gate = am & valid
        i_glob = w * Hl + li
        # -- collective 2: the owner's access record, broadcast to all
        ix = jnp.clip(idx[li], 0, L - 1)
        a0 = addrs_l[li, ix]
        w0 = writes_l[li, ix].astype(jnp.int64)
        if cfg.fault_hops:
            on_v = sh["fhon"][li, ix].astype(jnp.int64)
            pi_v = sh["fhp"][li, ix].astype(jnp.int64)
            occ_v = sh["fho"][li, ix]
            aft_v = sh["fha"][li, ix]
            occc_v = sh["fhoc"][li, ix]
        else:
            r = sh["route"][li, ix] if cfg.max_routes > 1 else 0
            on_v = sh["hop_on"][li, r].astype(jnp.int64)
            pi_v = sh["hop_port"][li, r].astype(jnp.int64)
            occ_v = sh["hop_occ"][li, r]
            aft_v = sh["hop_after"][li, r]
            occc_v = occ_v
        rec = jnp.concatenate([jnp.stack([a0, w0]), on_v, pi_v, occ_v,
                               aft_v, occc_v])
        rec = jax.lax.psum(jnp.where(gate, rec, 0), "hosts")
        a = rec[0]
        wr = rec[1] > 0
        posted = wr if cfg.posted_writes else jnp.zeros((), bool)
        # -- replicated transport walk + QoS mirror (identical on every
        # shard: broadcast inputs, replicated state — byte-for-byte the
        # unsharded loop, reading the record instead of the lookup)
        t = jnp.where(valid, issue, _i64(0))
        floor = _i64(0)
        qacc = aux.get("q")
        qthr = aux.get("qthr")
        for h in range(MH):
            on = rec[2 + h] > 0
            pi = rec[2 + MH + h]
            occ_h = rec[2 + 2 * MH + h]
            aft_h = rec[2 + 3 * MH + h]
            occ_c = rec[2 + 4 * MH + h]
            if cfg.qos:
                qon = on & rep["qos_on"][pi]
                prev = vft[pi, i_glob]
                win = occ_c * ACTIVE_WINDOW_OCC
                w_active = jnp.float64(0.0)
                for j in cfg.host_order:   # sorted-name order, like dict walk
                    member = (j == i_glob) | (last_arr[pi, j] + win > t)
                    w_active = w_active + jnp.where(member,
                                                    rep["qos_w"][pi, j], 0.0)
                pace = (occ_c.astype(jnp.float64)
                        * (w_active / rep["qos_w"][pi, i_glob])
                        ).astype(jnp.int64)
                floor = jnp.maximum(
                    floor, jnp.where(qon & (prev > t), prev + pace, 0))
                vft = vft.at[pi, i_glob].set(
                    jnp.where(qon, jnp.maximum(prev, t) + pace, prev))
                last_arr = last_arr.at[pi, i_glob].set(
                    jnp.where(qon, t, last_arr[pi, i_glob]))
                if qthr is not None:
                    qthr = qthr.at[pi].add(
                        jnp.where(qon & (prev > t) & valid, 1, 0))
            start = jnp.maximum(t, port_busy[pi])
            if qacc is not None:
                qacc = qacc.at[pi].add(jnp.where(on & valid, start - t, 0))
            done_h = start + occ_h
            port_busy = port_busy.at[pi].set(
                jnp.where(on, done_h, port_busy[pi]))
            t = jnp.where(on, done_h + aft_h, t)
        t = t + rep["rt_extra"]
        # -- SPMD media step: every shard runs it on lane `li` of its own
        # local state, only the owner commits (en gate); non-owner outputs
        # are garbage and every use below is owner-gated
        if cfg.stack.kind == DRAM:
            p_med = {"occ": rep["dev_occ"][i_glob],
                     "load": rep["dev_load"][i_glob],
                     "pack": rep["dev_pack"][i_glob]}
        else:
            p_med = rep
        st, out = stack.step(cfg.stack, p_med, st, dict(
            lane=li, flash_lane=li, t=t, addr=a, write=wr, posted=posted,
            ctr=ctr, en=gate))
        done = out["done"]
        if cfg.qos:
            done = jnp.maximum(done, floor)
        bad_l, gcs_l = stack.flash_health(st)
        if mspec is not None:
            aux = {**aux,
                   "acc": _metrics.acc_update(
                       mspec, aux["acc"], host=i_glob, dev=i_glob, n_hosts=H,
                       n_devs=cfg.num_devs, issue=issue, done=done,
                       size=size, hit=out["hit"], valid=gate),
                   "med": aux["med"].at[i_glob].add(
                       _metrics.media_increments(cfg.stack.kind, wr, out)
                       * jnp.where(gate, 1, 0)),
                   "q": qacc}
            if qthr is not None:
                aux = {**aux, "qthr": qthr}
            if "flash" in aux:
                aux = {**aux, "flash": jnp.where(
                    valid, stack.flash_counters(st), aux["flash"])}
            if "faults" in aux:
                aux = {**aux, "faults": jnp.where(
                    valid, jnp.stack(stack.fault_counters(st)),
                    aux["faults"])}
        if not want_lat:
            aux = {**aux,
                   "first": aux["first"].at[li].min(
                       jnp.where(gate, issue, BIG)),
                   "last": aux["last"].at[li].max(
                       jnp.where(gate, done, _i64(-BIG))),
                   "sum": aux["sum"].at[li].add(
                       jnp.where(gate, done - issue, 0)),
                   "cnt": aux["cnt"].at[li].add(jnp.where(gate, 1, 0)),
                   "bad": aux["bad"] | (bad_l & valid),
                   "gcs": jnp.where(valid, gcs_l, aux["gcs"])}
        k = jnp.argmin(slots[li])
        slots = slots.at[li, k].set(jnp.where(gate, done, slots[li, k]))
        now = now.at[li].set(
            jnp.where(gate, issue + rep["issue_ov"], now[li]))
        idx = idx.at[li].set(jnp.where(gate, idx[li] + 1, idx[li]))
        ys = ((i_glob, issue, jnp.where(gate, done, 0),
               jnp.where(bad_l, 1, 0), gcs_l) if want_lat else None)
        return ((slots, now, idx, port_busy, ctr + 1, st, vft, last_arr,
                 aux), ys)

    carry, ys = jax.lax.scan(step, init, None, length=H * L, unroll=block)
    aux = carry[8]
    # -- post-scan reductions: every returned leaf becomes replicated
    if want_lat:
        who, issues, d_gated, bad_i, gcs_loc = ys
        dones = jax.lax.psum(d_gated, "hosts")
        bad = jax.lax.psum(bad_i, "hosts") > 0
        gcs = jax.lax.psum(gcs_loc, "hosts")
    else:
        who = issues = dones = bad = gcs = None
    if mspec is not None:
        aux = {**aux,
               "acc": jax.lax.psum(aux["acc"], "hosts"),
               "med": jax.lax.psum(aux["med"], "hosts")}
        if "flash" in aux:
            aux = {**aux, "flash": jax.lax.all_gather(
                aux["flash"], "hosts").reshape(H, -1)}
        if "faults" in aux:
            aux = {**aux, "faults": jax.lax.psum(aux["faults"], "hosts")}
    if not want_lat:
        gathered = {k: jax.lax.all_gather(aux[k], "hosts").reshape(H)
                    for k in ("first", "last", "sum", "cnt")}
        aux = {**aux, **gathered,
               "bad": jax.lax.psum(
                   jnp.where(aux["bad"], 1, 0), "hosts") > 0,
               "gcs": jax.lax.psum(aux["gcs"], "hosts")}
    return who, issues, dones, bad, gcs, aux


@functools.lru_cache(maxsize=64)
def _build_runner(cfg: MultiCfg, devices: Tuple, block: int, mspec,
                  want_lat: bool, size: int):
    """One jitted shard_map program per (static shape, device set) — cached
    so sweeps and repeated runs (including traced-``lens`` reuse across
    host counts) never recompile."""
    mesh = Mesh(np.array(devices), ("hosts",))
    D = len(devices)
    body = functools.partial(_body, cfg, D, mspec, want_lat, size, block)
    f = shard_map(body, mesh=mesh, in_specs=(P(), P("hosts"), P()),
                  out_specs=P(), check_rep=False)
    return jax.jit(f)


class ShardedMultiHostReplay(MultiHostReplay):
    """:class:`MultiHostReplay` with the host axis sharded across devices
    (see the module docstring for the SPMD structure and the exactness /
    refusal contract).  ``devices=None`` uses ``jax.devices()``; the shard
    count is :func:`shard_count` of the host count.  ``last_mesh`` reports
    ``{"device_count", "hosts_per_device"}`` after a run."""

    def __init__(self, targets: Sequence, outstanding: int = 32,
                 issue_overhead_ns: float = 0.5,
                 posted_writes: bool = True, block_size: int = 1,
                 metrics=None, devices: Optional[Sequence] = None) -> None:
        super().__init__(targets, outstanding=outstanding,
                         issue_overhead_ns=issue_overhead_ns,
                         posted_writes=posted_writes, block_size=block_size,
                         metrics=metrics)
        self.devices = tuple(devices) if devices is not None else None
        self.last_mesh = None

    def _shard_tensors(self, cfg, params, lens, addrs, writes):
        """Split the prepared tensors into the host-sharded dict and the
        replicated dict (compacting the mount-diagonal hop tensors from
        ``(H, H, K, max_hops)`` to ``(H, K, max_hops)`` — the O(H^2) -> O(H)
        reduction that makes fleet-scale routing state shardable)."""
        H = cfg.num_hosts
        sh = {"addrs": np.ascontiguousarray(addrs),
              "writes": np.ascontiguousarray(writes),
              "lens": np.asarray(lens, np.int64)}
        if cfg.fault_hops:
            for k in _FAULT_KEYS:
                sh[k] = params[k]
        else:
            diag = np.arange(H)
            for k in ("hop_port", "hop_occ", "hop_after", "hop_on"):
                sh[k] = np.ascontiguousarray(params[k][diag, diag])
            if cfg.max_routes > 1:
                sh["route"] = params["route"]
        skip = {"hop_port", "hop_occ", "hop_after", "hop_on", "route",
                "flash_of", *_FAULT_KEYS}
        rep = {k: v for k, v in params.items() if k not in skip}
        return sh, rep

    def _dispatch(self, cfg, params, devs, addrs, writes, lens, start_tick,
                  mspec, want_lat, size, chunk_size):
        if chunk_size is not None:
            raise ReplayUnsupported(
                "sharded multi-host replay is one-shot (per-host columns "
                "already live device-side); use MultiHostReplay with "
                "chunk_size= for streaming, or stream per shard")
        meta = self._meta
        if meta["mapper"] is not None:
            raise ReplayUnsupported(
                "sharded replay partitions per-host fabric mounts; pool "
                "views interleave one address space across every shard — "
                "use the unsharded MultiHostReplay lane")
        H = cfg.num_hosts
        if cfg.n_flash and cfg.n_flash != H:
            raise ReplayUnsupported(
                "sharded replay needs a private flash per host (a shared "
                "HIL couples every shard's state on every step); use the "
                "unsharded MultiHostReplay lane for pooled flash")
        if cfg.num_devs != H:
            raise ReplayUnsupported(
                "sharded replay expects one mounted device per host")
        devices = (self.devices if self.devices is not None
                   else tuple(jax.devices()))
        D = shard_count(H, devices)
        mesh_devs = tuple(devices[:D])
        self.last_mesh = {"device_count": D, "hosts_per_device": H // D}
        sh, rep = self._shard_tensors(cfg, params, lens, addrs, writes)
        run = _build_runner(cfg, mesh_devs, self.block_size, mspec,
                            want_lat, size)
        sh = jax.tree.map(jnp.asarray, sh)
        rep = jax.tree.map(jnp.asarray, rep)
        return run(_i64(start_tick), sh, rep)
