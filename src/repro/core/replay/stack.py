"""Stackable device-state layer: the stateful media/flash machinery of the
fused replay, factored out of the single-host scan body so that *any* number
of hosts can stack private state over shared state.

One lane of state = one Python device object's mutable fields, as pytrees:

* **media state** — the per-device front end (DRAM busy-until, PMEM open
  row, the CXL-SSD page-register file, or the full DRAM-cache layer: packed
  LRU/FIFO/direct frames, MSHR table, writeback buffer, cache-DRAM
  busy-until).  Private per mounted device — per host in mount mode, per
  pool device in pool mode.
* **flash state** — the SimpleSSD backend (FTL mapping + write pointer +
  free-block pool, PAL die/channel occupancy).  Shared by every front end
  built over the same :class:`~repro.core.ssd.hil.HIL`, so pooled-flash
  scenarios (per-host caches over one flash array) contend on the same
  busy-until state the interpreted path does.

The public surface is host-stackable:

* :func:`init_state`\\ ``(cfg, n_hosts, n_flash)`` — state pytrees with a
  leading host (media) / flash-instance axis;
* :func:`step`\\ ``(cfg, p, state, access) -> (state, out)`` — one access
  against lane ``access["lane"]`` / ``access["flash_lane"]``, returning the
  completion tick plus hit/evict flags.

:class:`~repro.core.replay.engine.ReplayEngine` consumes it at ``H=1``
(statically sliced, so the compiled program is the old single-host body),
:class:`~repro.core.replay.multihost.MultiHostReplay` at ``H=N`` with
per-access lane gather/scatter.  Every step function mirrors the interpreted
device *operation for operation* — see :mod:`repro.core.replay.engine` for
the tick-identity contract and the XLA:CPU packing notes.

Garbage collection: when the spec layer decides a trace could outrun the
log-append headroom (``StackConfig.gc``), the flash state grows the full
FTL bookkeeping (``p2l`` inverse map, per-block valid counts, FIFO
free-block queue) and block allocation gains a greedy-GC step — victim
select (fewest valid pages, ties low, matching ``min``/``argmin``), valid
pages migrated as a masked read+program loop, erase, victim appended to the
free queue — mirroring :meth:`repro.core.ssd.ftl.FTL._collect` tick for
tick.  A free-pool underrun (the interpreted path raises "FTL out of
space") sets a sticky ``bad`` flag that callers must surface as
:class:`~repro.core.replay.spec.ReplayUnsupported` — certify-or-refuse,
never silent divergence.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.faults import erase_fails_jnp, nand_read_retries_jnp
from repro.core.replay.spec import (
    DRAM,
    PMEM,
    SSD_BUF,
    SSD_CACHE,
    StackConfig,
)

# Plain ints: they stay weakly typed so they promote to int64 inside the
# enable_x64 scope (a jnp.int64 built at import time would truncate to int32).
BIG = 1 << 62          # order-infinity that survives additions
FREE = -1              # free-slot sentinel (pages/addresses are >= 0)

# Packed cache-frame layout: stamp-major so argmin == OrderedDict order.
STAMP_SHIFT = 39
PAGE_BITS = 38
PAGE_FIELD = ((1 << PAGE_BITS) - 1) << 1      # bits [38:1]
STAMP_FIELD = -(1 << STAMP_SHIFT)             # bits [63:39] (sign-extended ok)
MAX_PAGE = (1 << PAGE_BITS) - 2               # strict: all-ones is reserved
MAX_ACCESSES = (1 << 23) - 1                  # stamp<<39 must stay positive


def _i64(x):
    return jnp.asarray(x, jnp.int64)


# -------------------------------------------------------------- flash (PAL)
def _pal_read(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """Mirror of :meth:`PAL._schedule` (read path, program-suspend rule).

    With NAND fault statics (``cfg.faults``) the read charges
    ``1 + retries`` full sense+transfer rounds, keyed on the in-state read
    sequence number — the exact twin of :meth:`PAL.read_page` consulting
    the plan on its ``_rd_seq`` (the sequence only advances on enabled
    reads, like the python path only calls the PAL for real reads)."""
    C, D = cfg.channels, cfg.dies_per_channel
    ch = ppn % C
    i = ch * D + (ppn // C) % D
    db, dp, cb = f["die_busy"], f["die_prog"], f["chan_busy"]
    dbi, dpi, cbi = db[i], dp[i], cb[ch]
    read_t, xfer = p["read_t"], p["xfer_page"]
    if cfg.faults:
        retries = nand_read_retries_jnp(cfg.faults, f["rd_seq"])
        rounds = 1 + retries
        read_t = read_t * rounds
        xfer = xfer * rounds
        f = {**f,
             "rd_seq": f["rd_seq"] + jnp.where(en, 1, 0),
             "c_rr": f["c_rr"] + jnp.where(en, retries, 0)}
    ds = jnp.maximum(t, dbi)
    resume = jnp.minimum(dpi, ds + p["sus_t"])
    ds = jnp.where(dpi > ds, resume, ds)
    array_done = ds + read_t
    new_dp = jnp.where(dpi > ds, dpi + read_t, dpi)
    bus_start = jnp.maximum(array_done, cbi)
    done = bus_start + xfer
    f = {**f,
         "die_busy": db.at[i].set(jnp.where(en, done, dbi)),
         "die_prog": dp.at[i].set(jnp.where(en, new_dp, dpi)),
         "chan_busy": cb.at[ch].set(jnp.where(en, done, cbi))}
    return f, done


def _pal_prog(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """Mirror of :meth:`PAL._schedule` (program path: bus in, then array)."""
    C, D = cfg.channels, cfg.dies_per_channel
    ch = ppn % C
    i = ch * D + (ppn // C) % D
    db, dp, cb = f["die_busy"], f["die_prog"], f["chan_busy"]
    dbi, dpi, cbi = db[i], dp[i], cb[ch]
    ds = jnp.maximum(jnp.maximum(t, dbi), dpi)
    bus_start = jnp.maximum(ds, cbi)
    bus_done = bus_start + p["xfer_page"]
    done = bus_done + p["prog_t"]
    f = {**f,
         "die_busy": db.at[i].set(jnp.where(en, bus_done, dbi)),
         "die_prog": dp.at[i].set(jnp.where(en, done, dpi)),
         "chan_busy": cb.at[ch].set(jnp.where(en, bus_done, cbi))}
    return f, done


def _pal_erase(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """Mirror of :meth:`PAL.erase_block` (array-only, program waits out)."""
    C, D = cfg.channels, cfg.dies_per_channel
    ch = ppn % C
    i = ch * D + (ppn // C) % D
    dbi = f["die_busy"][i]
    start = jnp.maximum(jnp.maximum(t, dbi), f["die_prog"][i])
    done = start + p["erase_t"]
    f = {**f, "die_busy": f["die_busy"].at[i].set(jnp.where(en, done, dbi))}
    return f, done


# ----------------------------------------------------- FTL free-block FIFO
def _free_pop(cfg: StackConfig, f: Dict, en):
    """``free_blocks.pop(0)``; an empty pool sets the sticky ``bad`` flag
    (the interpreted FTL raises "out of space" there)."""
    nb = cfg.num_blocks
    head, cnt, q = f["fq_head"], f["fq_count"], f["free_q"]
    v = q[head]
    fm = f["free_mask"]
    f = {**f,
         "fq_head": jnp.where(en, (head + 1) % nb, head),
         "fq_count": jnp.where(en, cnt - 1, cnt),
         "free_mask": fm.at[v].set(jnp.where(en, False, fm[v])),
         "bad": f["bad"] | (en & (cnt <= 0))}
    return f, _i64(v)


def _free_append(cfg: StackConfig, f: Dict, v, en):
    """``free_blocks.append(v)`` (erased victims re-enter at the back)."""
    nb = cfg.num_blocks
    head, cnt, q = f["fq_head"], f["fq_count"], f["free_q"]
    pos = (head + cnt) % nb
    fm = f["free_mask"]
    return {**f,
            "free_q": q.at[pos].set(jnp.where(en, v.astype(q.dtype), q[pos])),
            "fq_count": jnp.where(en, cnt + 1, cnt),
            "free_mask": fm.at[v].set(jnp.where(en, True, fm[v]))}


# -------------------------------------------------------------- FTL + GC
def _collect(cfg: StackConfig, p: Dict, f: Dict, now):
    """Mirror of :meth:`FTL._collect`: greedy victim (fewest valid pages,
    excluding the write block and free blocks, ties to the lowest block id),
    valid pages migrated read+program in offset order on a serial tick
    chain, erase, victim appended to the free pool.  Runs under a
    :func:`jax.lax.cond`, so non-GC allocations pay nothing."""
    nb, ppb = cfg.num_blocks, cfg.pages_per_block
    cand = (jnp.arange(nb) != f["wpb"]) & (~f["free_mask"])
    if cfg.faults:
        # grown bad blocks never re-enter candidacy (FTL.retired_blocks)
        cand = cand & (~f["rtr_mask"])
    any_cand = cand.any()
    score = jnp.where(cand, f["valid"], jnp.asarray(2**31 - 1, jnp.int32))
    victim = jnp.argmin(score)               # ties -> lowest block id
    base = victim * ppb

    def body(off, carry):
        f, t = carry
        ppn = base + off
        lpn = f["p2l"][ppn]
        live = any_cand & (lpn >= 0)
        f, rdone = _pal_read(cfg, p, f, t, ppn, live)
        t = jnp.where(live, rdone, t)
        # _next_ppn(t, allow_gc=False): migration draws straight from the
        # watermark-reserved pool, never re-entering GC
        need = f["wpp"] >= ppb
        f, v = _free_pop(cfg, f, live & need)
        wpb = jnp.where(need, v, f["wpb"])
        wpp = jnp.where(need, 0, f["wpp"])
        new_ppn = wpb * ppb + wpp
        f = {**f,
             "wpb": jnp.where(live, wpb, f["wpb"]),
             "wpp": jnp.where(live, wpp + 1, f["wpp"])}
        f, pdone = _pal_prog(cfg, p, f, t, new_ppn, live)
        t = jnp.where(live, pdone, t)
        # p2l.pop(ppn); l2p[lpn] = new_ppn; p2l[new_ppn] = lpn; valid moves
        lsafe = jnp.maximum(lpn, 0)
        p2l = f["p2l"].at[ppn].set(jnp.where(live, FREE, f["p2l"][ppn]))
        p2l = p2l.at[new_ppn].set(jnp.where(live, lpn, p2l[new_ppn]))
        l2p = f["l2p"].at[lsafe].set(
            jnp.where(live, new_ppn.astype(jnp.int32), f["l2p"][lsafe]))
        valid = f["valid"].at[new_ppn // ppb].add(jnp.where(live, 1, 0))
        valid = valid.at[victim].add(jnp.where(live, -1, 0))
        f = {**f, "p2l": p2l, "l2p": l2p, "valid": valid}
        if "c_gw" in f:
            f = {**f, "c_gw": f["c_gw"] + jnp.where(live, 1, 0)}
        return f, t

    f, t = jax.lax.fori_loop(0, ppb, body, (f, now))
    f, edone = _pal_erase(cfg, p, f, t, base, any_cand)
    t = jnp.where(any_cand, edone, t)
    if "c_ge" in f:
        # python bumps gc_erases only when a victim existed (the
        # no-candidate early return skips the erase)
        f = {**f, "c_ge": f["c_ge"] + jnp.where(any_cand, 1, 0)}
    fail = jnp.zeros((), bool)
    if cfg.faults:
        # mirror of FTL._collect's erase-fail consult: a failed erase
        # retires the victim (it never returns to the free pool); the
        # erase sequence advances exactly when the python one does (a
        # victim existed — the no-candidate early return skips both)
        fail = any_cand & erase_fails_jnp(cfg.faults, f["er_seq"])
        rtr = f["rtr_mask"]
        f = {**f,
             "er_seq": f["er_seq"] + jnp.where(any_cand, 1, 0),
             "rtr_mask": rtr.at[victim].set(rtr[victim] | fail),
             "c_rb": f["c_rb"] + jnp.where(fail, 1, 0)}
    return _free_append(cfg, f, victim, any_cand & ~fail), t


def _ftl_invalidate(cfg: StackConfig, f: Dict, lpn, en):
    """Mirror of :meth:`FTL._invalidate` (valid-count + inverse-map upkeep —
    only tracked on GC-capable stacks, where it decides victims)."""
    old = f["l2p"][lpn]
    has = en & (old >= 0)
    osafe = jnp.maximum(old, 0)
    return {**f,
            "valid": f["valid"].at[old // cfg.pages_per_block].add(
                jnp.where(has, -1, 0)),
            "p2l": f["p2l"].at[osafe].set(
                jnp.where(has, FREE, f["p2l"][osafe]))}


def _alloc_ppn(cfg: StackConfig, p: Dict, f: Dict, t, en):
    """Mirror of :meth:`FTL._next_ppn`: returns ``(f, ppn, gc_done)``."""
    need = f["wpp"] >= cfg.pages_per_block
    if not cfg.gc:
        # log-append lane: the free pool is a pristine counter (spec-time
        # headroom check guarantees GC can never trigger)
        wpb = jnp.where(need, f["nfree"], f["wpb"])
        nfree = jnp.where(need, f["nfree"] + 1, f["nfree"])
        wpp = jnp.where(need, 0, f["wpp"])
        ppn = wpb * cfg.pages_per_block + wpp
        f = {**f,
             "wpb": jnp.where(en, wpb, f["wpb"]),
             "nfree": jnp.where(en, nfree, f["nfree"]),
             "wpp": jnp.where(en, wpp + 1, f["wpp"])}
        return f, ppn, t
    trigger = en & need & (f["fq_count"] <= cfg.gc_watermark_blocks)
    f = {**f, "gcs": f["gcs"] + jnp.where(trigger, 1, 0)}
    f, gc_done = jax.lax.cond(
        trigger,
        lambda op: _collect(cfg, p, op[0], op[1]),
        lambda op: op,
        (f, t))
    f, v = _free_pop(cfg, f, en & need)
    wpb = jnp.where(need, v, f["wpb"])
    wpp = jnp.where(need, 0, f["wpp"])
    ppn = wpb * cfg.pages_per_block + wpp
    f = {**f,
         "wpb": jnp.where(en, wpb, f["wpb"]),
         "wpp": jnp.where(en, wpp + 1, f["wpp"])}
    return f, ppn, jnp.where(en, gc_done, t)


def _hil_write(cfg: StackConfig, p: Dict, f: Dict, t, lpn, en):
    """HIL overhead + FTL write: invalidate (GC stacks), allocate — running
    greedy GC when the free pool is at the watermark — then program."""
    t0 = t + p["hil_ov"]
    if "c_hw" in f:
        f = {**f, "c_hw": f["c_hw"] + jnp.where(en, 1, 0)}
    if cfg.gc:
        f = _ftl_invalidate(cfg, f, lpn, en)
    f, ppn, t1 = _alloc_ppn(cfg, p, f, t0, en)
    f = {**f,
         "l2p": f["l2p"].at[lpn].set(
             jnp.where(en, ppn.astype(jnp.int32), f["l2p"][lpn]))}
    if cfg.gc:
        f = {**f,
             "p2l": f["p2l"].at[ppn].set(
                 jnp.where(en, lpn.astype(jnp.int32), f["p2l"][ppn])),
             "valid": f["valid"].at[ppn // cfg.pages_per_block].add(
                 jnp.where(en, 1, 0))}
    return _pal_prog(cfg, p, f, t1, ppn, en)


def _hil_read(cfg: StackConfig, p: Dict, f: Dict, t, ppn, en):
    """HIL overhead + FTL read of a programmed page (callers check the
    mapping table first, exactly like the cache's ``is_written`` gate)."""
    if "c_hr" in f:
        f = {**f, "c_hr": f["c_hr"] + jnp.where(en, 1, 0)}
    return _pal_read(cfg, p, f, t + p["hil_ov"], jnp.maximum(ppn, 0), en)


# ------------------------------------------------------------- device steps
def _dram_step(cfg: StackConfig, p: Dict, md: Dict, f, t, addr, wr, posted,
               ctr):
    start = jnp.maximum(t, md["busy"])
    occ_done = start + p["occ"]
    done = occ_done + jnp.where(posted, p["pack"], p["load"])
    md = {**md, "busy": occ_done}
    return md, f, done, {}


def _pmem_step(cfg: StackConfig, p: Dict, md: Dict, f, t, addr, wr, posted,
               ctr):
    row = addr // p["row_bytes"]
    row_hit = row == md["row"]
    lat = p["lat"][jnp.where(wr, 1, 0), jnp.where(row_hit, 1, 0)]
    start = jnp.maximum(t, md["busy"])
    occ_done = start + p["occ"]
    done = occ_done + jnp.where(posted, p["pack"], lat)
    md = {**md, "busy": occ_done, "row": row}
    return md, f, done, {"hit": row_hit}


def _buf_step(cfg: StackConfig, p: Dict, md: Dict, f: Dict, t, addr, wr,
              posted, ctr):
    """CXL-SSD page-register buffer: LRU over a handful of open pages;
    misses amplify to 4 KB flash ops (read-modify-write for writes)."""
    page = addr // cfg.page_bytes
    frames = md["frames"]
    pfield = page << 1
    match = (frames & PAGE_FIELD) == pfield
    match = match & (frames >= 0)
    fidx = jnp.argmax(match)
    hit = match[fidx]
    miss = ~hit
    old = frames[fidx]

    def miss_fn(op):
        frames, f = op
        vic = jnp.argmin(frames)
        vval = frames[vic]
        ev_dirty = (vval >= 0) & ((vval & 1) > 0)
        ev_page = (vval & PAGE_FIELD) >> 1
        ppn = f["l2p"][page]
        was_written = ppn >= 0
        f, rdone = _hil_read(cfg, p, f, t, _i64(ppn), was_written)
        done0 = jnp.where(was_written, rdone, t)
        f, _ = _hil_write(cfg, p, f, done0, ev_page, ev_dirty)
        return f, done0, vic, ev_dirty, was_written

    def hit_fn(op):
        frames, f = op
        false = jnp.zeros((), bool)
        return f, t, fidx, false, false

    f, done0, vic, flushed, filled = jax.lax.cond(
        miss, miss_fn, hit_fn, (frames, f))

    # single commit: LRU touch on hit, insert over the victim on miss
    touch_val = (ctr << STAMP_SHIFT) | pfield | ((old & 1) | wr)
    insert_val = (ctr << STAMP_SHIFT) | pfield | wr
    idx = jnp.where(miss, vic, fidx)
    val = jnp.where(miss, insert_val, touch_val)
    frames = frames.at[idx].set(val)

    done = done0 + p["internal"]
    md = {**md, "frames": frames}
    return md, f, done, {"hit": hit, "evict": flushed, "fill": filled}


def _cache_step(cfg: StackConfig, p: Dict, md: Dict, f: Dict, t, addr, wr,
                posted, ctr):
    """The paper's DRAM cache layer, one access: MSHR coalesce -> resident
    hit -> miss (MSHR stall, evict + writeback queue, flash fill).  Mirrors
    :meth:`repro.core.cache.dram_cache.DRAMCache.access` branch for branch."""
    page = addr // cfg.page_bytes
    frames = md["frames"]
    pfield = page << 1

    # ---- MSHR lookup (in-flight fill rides the existing SSD read)
    mm = md["mpage"] == page
    m_idx = jnp.argmax(mm)
    m_exists = mm[m_idx]
    m_ready = md["mready"][m_idx]
    coalesce = m_exists & (m_ready > t)

    # ---- residency
    if cfg.cache_assoc:
        match = ((frames & PAGE_FIELD) == pfield) & (frames >= 0)
        fidx = jnp.argmax(match)
        resident = match[fidx]
    else:
        fidx = page % p["cap"]
        fv = frames[fidx]
        resident = (fv >= 0) & ((fv & PAGE_FIELD) == pfield)
    hit = (~coalesce) & resident
    miss = (~coalesce) & (~resident)
    old = frames[fidx]

    # ---- hit: 64 B transfer occupies cache-DRAM bandwidth
    xstart = jnp.maximum(t, md["dram_busy"])
    xdone = xstart + p["line_xfer"]

    # ---- miss machinery behind one cond (hits pass the buffers through)
    def miss_fn(op):
        frames, mpage, mready, wtick, f = op
        # MSHR allocate (stall if the table is full)
        mfull = jnp.sum(mpage >= 0) >= cfg.mshr_entries
        vic_ready = jnp.min(mready)             # free slots hold BIG
        start1 = jnp.where(mfull, jnp.maximum(t, vic_ready), t)
        kill = mfull & (mready <= vic_ready)
        mpage = jnp.where(kill, FREE, mpage)
        mready = jnp.where(kill, BIG, mready)
        # write-allocate insert: victim = argmin of packed stamps (invalid
        # frames are -1, below every valid packed value)
        vic = jnp.argmin(frames) if cfg.cache_assoc else fidx
        vval = frames[vic]
        ev_valid = vval >= 0
        ev_page = (vval & PAGE_FIELD) >> 1
        do_wb = ev_valid & ((vval & 1) > 0)
        # writeback queue: background flash write, stall only if full.
        # Mutations are gated on do_wb — Python touches the queue only via
        # _queue_writeback, which clean misses never call.
        dead = wtick <= start1                   # reap(now)
        wtick = jnp.where(do_wb & dead, FREE, wtick)
        wfull = jnp.sum(~dead) >= cfg.wb_slots
        wmin = jnp.min(jnp.where(dead, BIG, wtick))
        stall = jnp.where(wfull, wmin, start1)
        wtick = jnp.where(do_wb & wfull & (wtick <= stall), FREE, wtick)
        f, wdone = _hil_write(cfg, p, f, stall, ev_page, do_wb)
        wslot = jnp.argmin(wtick)
        wtick = wtick.at[wslot].set(jnp.where(do_wb, wdone, wtick[wslot]))
        start2 = jnp.where(do_wb, jnp.maximum(start1, stall), start1)
        # fill from flash (virgin pages skip the read), then cache-DRAM
        ppn = f["l2p"][page]
        was_written = ppn >= 0
        f, rdone = _hil_read(cfg, p, f, start2, _i64(ppn), was_written)
        flash_done = jnp.where(was_written, rdone, start2)
        fill_done = jnp.maximum(flash_done, md["dram_busy"]) + p["page_xfer"]
        # MSHR insert (dict semantics: existing key overwrites) + expiry
        slot = jnp.where(m_exists, m_idx, jnp.argmin(mpage))
        mpage = mpage.at[slot].set(page)
        mready = mready.at[slot].set(fill_done)
        kill2 = mready <= t
        mpage = jnp.where(kill2, FREE, mpage)
        mready = jnp.where(kill2, BIG, mready)
        return (mpage, mready, wtick, f, start2, fill_done, vic, do_wb,
                mfull, ev_valid)

    def pass_fn(op):
        frames, mpage, mready, wtick, f = op
        false = jnp.zeros((), bool)
        return (mpage, mready, wtick, f, t, t, fidx, false, false, false)

    (mpage, mready, wtick, f, start2, fill_done, vic, do_wb, stalled,
     evicted) = jax.lax.cond(
        miss, miss_fn, pass_fn,
        (frames, md["mpage"], md["mready"], md["wtick"], f))

    # ---- single frame commit: touch (hit / coalesced store) or insert
    touch_en = (coalesce & wr & resident) | hit
    stamp_bits = jnp.where(p["is_lru"], ctr << STAMP_SHIFT, old & STAMP_FIELD)
    touch_val = stamp_bits | pfield | ((old & 1) | wr)
    insert_val = (ctr << STAMP_SHIFT) | pfield | wr
    idx = jnp.where(miss, vic, fidx)
    val = jnp.where(miss, insert_val, jnp.where(touch_en, touch_val, old))
    frames = frames.at[idx].set(val)

    dram_busy = jnp.where(hit, xdone,
                          jnp.where(miss, fill_done, md["dram_busy"]))
    ret_co = jnp.where(wr, t + p["hit_lat"], m_ready + p["hit_lat"])
    ret_hit = jnp.where(wr,
                        jnp.where(posted, t + p["pack10"], t + p["hit_lat"]),
                        jnp.maximum(xdone, t + p["hit_lat"]))
    ret_miss = jnp.where(wr, start2 + p["hit_lat"], fill_done + p["hit_lat"])
    ret = jnp.where(coalesce, ret_co, jnp.where(hit, ret_hit, ret_miss))

    md = {**md, "frames": frames, "mpage": mpage, "mready": mready,
          "wtick": wtick, "dram_busy": dram_busy}
    return md, f, jnp.maximum(t, ret), {
        "hit": hit, "evict": do_wb, "miss": miss, "coalesce": coalesce,
        "stall": stalled, "eviction": evicted}


_STEPS = {DRAM: _dram_step, PMEM: _pmem_step, SSD_BUF: _buf_step,
          SSD_CACHE: _cache_step}

# media kinds whose state splits into a private front end + a flash backend
FLASH_KINDS = (SSD_BUF, SSD_CACHE)


def has_flash(cfg: StackConfig) -> bool:
    return cfg.kind in FLASH_KINDS


# -------------------------------------------------------------- state init
def flash_init(cfg: StackConfig) -> Dict:
    """One flash instance's state (one :class:`HIL`: FTL map + write pointer
    + free pool, PAL die/channel busy-until)."""
    C, D = cfg.channels, cfg.dies_per_channel
    f = {
        "l2p": jnp.full(cfg.num_pages, -1, jnp.int32),
        "wpb": _i64(0), "wpp": _i64(0),
        "die_busy": jnp.zeros(C * D, jnp.int64),
        "die_prog": jnp.zeros(C * D, jnp.int64),
        "chan_busy": jnp.zeros(C, jnp.int64),
    }
    if cfg.gc:
        nb = cfg.num_blocks
        f.update({
            # free_blocks = deque(1..nb-1): slot nb-1 is initially unused
            "free_q": jnp.where(jnp.arange(nb) < nb - 1,
                                jnp.arange(nb) + 1, 0).astype(jnp.int32),
            "fq_head": _i64(0),
            "fq_count": _i64(nb - 1),
            "free_mask": jnp.arange(nb) >= 1,
            "p2l": jnp.full(nb * cfg.pages_per_block, FREE, jnp.int32),
            "valid": jnp.zeros(nb, jnp.int32),
            "gcs": _i64(0),
            "bad": jnp.zeros((), bool),
        })
    else:
        f["nfree"] = _i64(1)
    if cfg.faults:
        # deterministic NAND faults: in-state read/erase sequence numbers
        # (the PAL/FTL twins), retry/retirement totals, retired-block mask
        f["rd_seq"] = _i64(0)
        f["c_rr"] = _i64(0)
        if cfg.gc:
            f["er_seq"] = _i64(0)
            f["rtr_mask"] = jnp.zeros(cfg.num_blocks, bool)
            f["c_rb"] = _i64(0)
    if cfg.counters:
        # FTL.stats twins (host vs GC traffic); gc_runs rides on "gcs"
        f["c_hr"] = _i64(0)
        f["c_hw"] = _i64(0)
        if cfg.gc:
            f["c_gw"] = _i64(0)
            f["c_ge"] = _i64(0)
    return f


def media_init(cfg: StackConfig) -> Dict:
    """One front end's private state (no flash — see :func:`flash_init`)."""
    if cfg.kind == DRAM:
        return {"busy": _i64(0)}
    if cfg.kind == PMEM:
        return {"busy": _i64(0), "row": _i64(-1)}
    if cfg.kind == SSD_BUF:
        return {"frames": jnp.full(cfg.buf_entries, -1, jnp.int64)}
    if cfg.kind == SSD_CACHE:
        return {"frames": jnp.full(cfg.cache_frames, -1, jnp.int64),
                "mpage": jnp.full(cfg.mshr_entries, FREE, jnp.int64),
                "mready": jnp.full(cfg.mshr_entries, BIG, jnp.int64),
                "wtick": jnp.full(cfg.wb_slots, FREE, jnp.int64),
                "dram_busy": _i64(0)}
    raise ValueError(cfg.kind)


def media_step(cfg: StackConfig, p: Dict, md: Dict, f: Optional[Dict], t,
               addr, wr, posted, ctr):
    """One access against one unstacked (media, flash) lane pair.  Returns
    ``(md, f, done, extras)`` where ``extras`` is a per-kind dict of event
    flags (``hit``/``evict``/``miss``/``coalesce``/``stall``/...) feeding
    :func:`repro.core.replay.metrics.media_increments`; ``f`` passes
    through untouched for flash-less kinds."""
    return _STEPS[cfg.kind](cfg, p, md, f, t, addr, wr, posted, ctr)


# ------------------------------------------------------- stacked interface
def init_state(cfg: StackConfig, n_hosts: int = 1,
               n_flash: Optional[int] = None) -> Dict:
    """State pytrees with a leading lane axis: ``media`` gets ``n_hosts``
    private lanes, ``flash`` gets ``n_flash`` instances (default: one per
    host; irrelevant for flash-less kinds).  ``n_flash < n_hosts`` is the
    pooled-flash shape: several private front ends over shared FTL/PAL."""
    if n_flash is None:
        n_flash = n_hosts
    media = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[media_init(cfg) for _ in range(n_hosts)])
    flash = None
    if has_flash(cfg):
        flash = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[flash_init(cfg) for _ in range(n_flash)])
    return {"media": media, "flash": flash}


def _n_lanes(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def step(cfg: StackConfig, p: Dict, state: Dict, access: Dict
         ) -> Tuple[Dict, Dict]:
    """One access against the stacked state.

    ``access`` keys: ``lane`` (media lane), ``flash_lane``, ``t`` (arrival
    tick after transport), ``addr``, ``write``, ``posted``, ``ctr`` (global
    monotone stamp).  Returns ``(state, out)`` with ``out`` carrying
    ``done`` (completion tick) and ``hit``/``evict`` flags.

    An optional ``en`` key (scalar bool) gates the lane *writeback*: when
    false the step still executes — every SPMD replica of a sharded replay
    runs the same program — but the lane state is left untouched, so only
    the shard that owns the issuing host commits the mutation.  Callers
    gating with ``en`` must also gate every use of ``out`` (``done`` and the
    event flags are garbage on a disabled step).

    With one lane the gather/scatter degenerates to static slicing, so the
    compiled single-host program is exactly the pre-refactor scan body.
    """
    media, flash = state["media"], state["flash"]
    en = access.get("en")
    single = _n_lanes(media) == 1
    lane = 0 if single else access["lane"]
    md = jax.tree.map(lambda x: x[lane], media)
    f = None
    if flash is not None:
        fsingle = _n_lanes(flash) == 1
        flane = 0 if fsingle else access["flash_lane"]
        f = jax.tree.map(lambda x: x[flane], flash)
    md, f, done, ex = media_step(
        cfg, p, md, f, access["t"], access["addr"], access["write"],
        access["posted"], access["ctr"])
    if en is None:
        wb = lambda full, v, i: full.at[i].set(v)
    else:
        wb = lambda full, v, i: full.at[i].set(jnp.where(en, v, full[i]))
    media = jax.tree.map(lambda full, v: wb(full, v, lane), media, md)
    if flash is not None:
        flash = jax.tree.map(lambda full, v: wb(full, v, flane), flash, f)
    false = jnp.zeros((), bool)
    return ({"media": media, "flash": flash},
            {**ex, "done": done, "hit": ex.get("hit", false),
             "evict": ex.get("evict", false)})


def flash_health(state: Dict) -> Tuple[object, object]:
    """``(bad_any, gc_total)`` across every flash lane — ``bad_any`` is the
    sticky certify-or-refuse bit, ``gc_total`` the GC-run counter (both
    zero-shaped constants for flash-less or log-append stacks)."""
    flash = state["flash"]
    if flash is None or "bad" not in flash:
        return jnp.zeros((), bool), _i64(0)
    return flash["bad"].any(), flash["gcs"].sum()


def flash_counters(state: Dict):
    """Per-flash-lane :data:`~repro.core.replay.metrics.FLASH_COUNTERS`
    snapshot, ``(n_flash, 5)`` int64 — ``None`` when the stack carries no
    counters (``StackConfig.counters=False``) or no flash at all."""
    flash = state["flash"]
    if flash is None or "c_hr" not in flash:
        return None
    z = jnp.zeros_like(flash["c_hr"])
    return jnp.stack([flash["c_hr"], flash["c_hw"],
                      flash.get("c_gw", z), flash.get("c_ge", z),
                      flash.get("gcs", z)], axis=-1)


def fault_counters(state: Dict):
    """``(nand_read_retries, retired_blocks)`` totals across every flash
    lane — kept out of :func:`flash_counters` so the pinned (n, 5) metrics
    shape is untouched; both zero for stacks built without fault statics."""
    flash = state["flash"]
    if flash is None or "c_rr" not in flash:
        return _i64(0), _i64(0)
    retired = flash["c_rb"].sum() if "c_rb" in flash else _i64(0)
    return flash["c_rr"].sum(), retired
