from repro.core.ssd.pal import NANDTiming, PAL
from repro.core.ssd.ftl import FTL
from repro.core.ssd.hil import HIL, SSDConfig

__all__ = ["NANDTiming", "PAL", "FTL", "HIL", "SSDConfig"]
