"""HIL — Host Interface Layer (SimpleSSD's ``HIL::Read/Write``).

The CXL-SSD device calls ``HIL.read/write`` with byte addresses; the HIL
splits requests into 4 KB logical pages, drives the FTL, and returns the
completion *tick* — exactly the contract the paper describes ("the gem5
simulator determines the latency of access requests based on the Tick value
returned by SimpleSSD").

``InitSimpleSSDEngine`` mirrors the paper's gem5-side initialization hook.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.ssd.ftl import FTL
from repro.core.ssd.pal import NANDTiming, PAL


@dataclass
class SSDConfig:
    capacity_bytes: int = 16 << 30          # Table I: 16 GB
    page_bytes: int = 4096
    channels: int = 8
    dies_per_channel: int = 4
    pages_per_block: int = 256
    timing: NANDTiming = field(default_factory=NANDTiming)
    # host-interface DMA/firmware overhead per request (NVMe-class firmware
    # path, amortized; SimpleSSD charges a comparable fixed HIL cost)
    hil_overhead_ns: float = 2000.0


class HIL:
    def __init__(self, cfg: SSDConfig | None = None) -> None:
        self.cfg = cfg or SSDConfig()
        self.pal = PAL(self.cfg.channels, self.cfg.dies_per_channel,
                       self.cfg.page_bytes, self.cfg.timing)
        total_pages = self.cfg.capacity_bytes // self.cfg.page_bytes
        self.ftl = FTL(self.pal, total_pages, self.cfg.pages_per_block)
        self.stats = {"read_reqs": 0, "write_reqs": 0,
                      "read_pages": 0, "write_pages": 0}

    # ------------------------------------------------------------------ api
    def _pages(self, addr: int, size: int) -> range:
        first = addr // self.cfg.page_bytes
        last = (addr + max(size, 1) - 1) // self.cfg.page_bytes
        return range(first, last + 1)

    def _overhead(self) -> int:
        from repro.core.engine import ns
        return ns(self.cfg.hil_overhead_ns)

    def read(self, now: int, addr: int, size: int) -> int:
        """SimpleSSD ``HIL::Read``: returns completion tick."""
        self.stats["read_reqs"] += 1
        t0 = now + self._overhead()
        done = t0
        for lpn in self._pages(addr, size):
            self.stats["read_pages"] += 1
            done = max(done, self.ftl.read(t0, lpn))
        return done

    def is_written(self, addr: int, size: int = 1) -> bool:
        """True if any page in [addr, addr+size) has ever been programmed —
        lets a cache skip the flash read when filling a virgin page."""
        return any(lpn in self.ftl.l2p for lpn in self._pages(addr, size))

    def write(self, now: int, addr: int, size: int) -> int:
        """SimpleSSD ``HIL::Write``: returns completion tick."""
        self.stats["write_reqs"] += 1
        t0 = now + self._overhead()
        done = t0
        for lpn in self._pages(addr, size):
            self.stats["write_pages"] += 1
            done = max(done, self.ftl.write(t0, lpn))
        return done


def InitSimpleSSDEngine(cfg: SSDConfig | None = None) -> HIL:
    """Paper §II-A: gem5 calls this at init to set up the SimpleSSD engine."""
    return HIL(cfg)
