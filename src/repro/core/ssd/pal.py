"""PAL — Parallelism Abstraction Layer (SimpleSSD terminology).

Models the NAND flash backend: ``channels × packages(dies)`` with per-die
array occupancy and per-channel bus occupancy.  Timing defaults follow
SimpleSSD's MLC profile (officially validated, which is what the paper leans
on for accuracy): ``tR = 45 µs``, ``tPROG = 660 µs``, ``tBERS = 3.5 ms``,
channel bus at 1.2 GB/s (ONFI 4-class NV-DDR3).

A page operation occupies its die for the array time and its channel for the
data-transfer time; the PAL serializes conflicting operations by keeping
``busy_until`` ticks per resource — an analytic queueing model that matches
event-driven behavior for FCFS scheduling without simulating every DMA beat.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.engine import ns, us


@dataclass
class NANDTiming:
    t_read_us: float = 45.0         # tR: array read
    t_prog_us: float = 660.0        # tPROG: array program
    t_erase_us: float = 3500.0      # tBERS: block erase
    channel_mbps: float = 1200.0    # channel bus MB/s (10^6 B/s)
    t_suspend_us: float = 10.0      # program-suspend latency (reads preempt
                                    # in-flight programs, standard NAND feature)

    def xfer_ticks(self, nbytes: int) -> int:
        return ns(nbytes / self.channel_mbps * 1e3)  # bytes / (MB/s) -> ns

    @property
    def read_ticks(self) -> int:
        return us(self.t_read_us)

    @property
    def prog_ticks(self) -> int:
        return us(self.t_prog_us)

    @property
    def erase_ticks(self) -> int:
        return us(self.t_erase_us)

    @classmethod
    def mlc(cls) -> "NANDTiming":
        """SimpleSSD's validated MLC profile (storage-class SSD)."""
        return cls()

    @classmethod
    def low_latency(cls) -> "NANDTiming":
        """Z-NAND / XL-Flash class low-latency NAND — what memory-semantic
        CXL-SSDs (Samsung MS-SSD, paper refs [7], [16]) are built from.
        Keeps uncached access in the paper's 'microseconds to tens of
        microseconds' band instead of MLC's ~100 µs."""
        return cls(t_read_us=3.0, t_prog_us=100.0, t_erase_us=1000.0,
                   channel_mbps=1200.0)


@dataclass
class _DieState:
    busy_until: int = 0        # array busy for same-class ops
    program_until: int = 0     # in-flight program window (suspendable)
    reads: int = 0
    programs: int = 0
    erases: int = 0
    suspends: int = 0


class PAL:
    """NAND backend with explicit channel/die occupancy."""

    def __init__(self, channels: int = 8, dies_per_channel: int = 4,
                 page_bytes: int = 4096, timing: NANDTiming | None = None) -> None:
        self.channels = channels
        self.dies_per_channel = dies_per_channel
        self.page_bytes = page_bytes
        self.timing = timing or NANDTiming()
        self._dies = [[_DieState() for _ in range(dies_per_channel)]
                      for _ in range(channels)]
        self._channel_busy_until = [0] * channels
        self.stats = {"reads": 0, "programs": 0, "erases": 0,
                      "bytes_read": 0, "bytes_programmed": 0,
                      "die_wait_ticks": 0, "channel_wait_ticks": 0,
                      "read_retries": 0}
        # deterministic NAND fault injection (repro.core.faults.install):
        # read-retry decisions key on the per-PAL read sequence number,
        # which the fused scan's flash state mirrors exactly
        self.fault_plan = None
        self._rd_seq = 0

    # -------------------------------------------------------------- helpers
    def locate(self, ppn: int) -> tuple[int, int]:
        """Physical page number → (channel, die).  Pages stripe channel-first
        so sequential PPNs exploit channel-level parallelism."""
        ch = ppn % self.channels
        die = (ppn // self.channels) % self.dies_per_channel
        return ch, die

    def _schedule(self, now: int, ch: int, die: int, array_ticks: int,
                  xfer_first: bool, rounds: int = 1) -> int:
        """Reserve die + channel; return completion tick.

        Reads: array sense first, then channel transfer out.  ``rounds``
        charges that many full sense+transfer passes (NAND read-retry with
        shifted reference voltages; 1 = clean read).
        Programs: channel transfer in first, then array program.
        """
        d = self._dies[ch][die]
        xfer = self.timing.xfer_ticks(self.page_bytes)
        if not xfer_first and rounds > 1:
            array_ticks = array_ticks * rounds
            xfer = xfer * rounds
        if xfer_first:  # program: bus in, then array
            die_start = max(now, d.busy_until, d.program_until)
            self.stats["die_wait_ticks"] += die_start - now
            bus_start = max(die_start, self._channel_busy_until[ch])
            self.stats["channel_wait_ticks"] += bus_start - die_start
            bus_done = bus_start + xfer
            done = bus_done + array_ticks
            self._channel_busy_until[ch] = bus_done
            d.busy_until = bus_done      # array handed to (suspendable) program
            d.program_until = done
        else:  # read: array, then bus out. Reads may SUSPEND an in-flight
            # program: wait at most t_suspend, and push the program out by
            # the time stolen.
            die_start = max(now, d.busy_until)
            if d.program_until > die_start:
                suspend_done = die_start + us(self.timing.t_suspend_us)
                resume_at = min(d.program_until, suspend_done)
                d.suspends += 1
                die_start = resume_at
            self.stats["die_wait_ticks"] += die_start - now
            array_done = die_start + array_ticks
            if d.program_until > die_start:
                d.program_until += array_ticks  # stolen array time
            bus_start = max(array_done, self._channel_busy_until[ch])
            self.stats["channel_wait_ticks"] += bus_start - array_done
            done = bus_start + xfer
            self._channel_busy_until[ch] = done
            d.busy_until = done
        return done

    # ------------------------------------------------------------------ ops
    def read_page(self, now: int, ppn: int) -> int:
        ch, die = self.locate(ppn)
        self._dies[ch][die].reads += 1
        self.stats["reads"] += 1
        self.stats["bytes_read"] += self.page_bytes
        retries = 0
        if self.fault_plan is not None:
            retries = self.fault_plan.nand_read_retries(self._rd_seq)
            self._rd_seq += 1
            self.stats["read_retries"] += retries
        return self._schedule(now, ch, die, self.timing.read_ticks,
                              xfer_first=False, rounds=1 + retries)

    def program_page(self, now: int, ppn: int) -> int:
        ch, die = self.locate(ppn)
        self._dies[ch][die].programs += 1
        self.stats["programs"] += 1
        self.stats["bytes_programmed"] += self.page_bytes
        return self._schedule(now, ch, die, self.timing.prog_ticks, xfer_first=True)

    def erase_block(self, now: int, ppn_of_block: int) -> int:
        ch, die = self.locate(ppn_of_block)
        d = self._dies[ch][die]
        d.erases += 1
        self.stats["erases"] += 1
        start = max(now, d.busy_until, d.program_until)
        done = start + self.timing.erase_ticks
        d.busy_until = done
        return done

    @property
    def total_dies(self) -> int:
        return self.channels * self.dies_per_channel
