"""FTL — page-level flash translation layer with greedy garbage collection.

LPN→PPN page mapping; writes are log-structured (next free page, striped
across channels/dies by PPN layout in :mod:`repro.core.ssd.pal`).  GC
triggers when the free-block pool drops below a watermark: the block with the
fewest valid pages is victimized, its valid pages migrated (read+program),
then erased.  Write amplification is tracked explicitly — the DRAM cache in
front of the SSD exists precisely to cut this traffic (paper §II-C) and to
extend endurance (paper §IV).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.ssd.pal import PAL

FREE = 0xFFFFFFFF

# GC policy constants — shared with the fused replay's scan twin
# (repro.core.replay.stack mirrors the greedy discipline these define, so
# keep the two in sync through these names rather than re-deriving them):
# * victim = the non-free, non-write-pointer block with the fewest valid
#   pages, ties to the lowest block id (Python ``min`` == ``argmin``);
# * GC triggers at block allocation when the free pool has at most
#   ``gc_watermark_blocks`` entries;
# * the free-block pool is a FIFO (pop from the front, erased victims
#   append at the back).
DEFAULT_OP_RATIO = 0.07          # physical over-provisioning: phys/logical - 1
DEFAULT_GC_WATERMARK = 0.05      # watermark as a fraction of num_blocks
MIN_GC_WATERMARK_BLOCKS = 2      # floor of the watermark
MIN_NUM_BLOCKS = 4               # smallest device the FTL will lay out


class FTL:
    def __init__(self, pal: PAL, total_pages: int, pages_per_block: int = 256,
                 op_ratio: float = DEFAULT_OP_RATIO,
                 gc_watermark: float = DEFAULT_GC_WATERMARK) -> None:
        self.pal = pal
        self.pages_per_block = pages_per_block
        # over-provisioning: physical > logical
        self.logical_pages = total_pages
        phys_pages = int(total_pages * (1 + op_ratio))
        self.num_blocks = max(
            MIN_NUM_BLOCKS,
            (phys_pages + pages_per_block - 1) // pages_per_block)
        self.phys_pages = self.num_blocks * pages_per_block
        self.gc_watermark_blocks = max(MIN_GC_WATERMARK_BLOCKS,
                                       int(self.num_blocks * gc_watermark))

        self.l2p: dict[int, int] = {}
        self.p2l: dict[int, int] = {}
        self.valid_count = [0] * self.num_blocks        # valid pages per block
        self.write_ptr_block = 0
        self.write_ptr_page = 0
        self.free_blocks = list(range(1, self.num_blocks))
        self.stats = {"host_writes": 0, "host_reads": 0, "gc_writes": 0,
                      "gc_erases": 0, "gc_runs": 0}
        # deterministic fault injection (repro.core.faults.install): a
        # failed erase grows the victim bad — it is retired from both the
        # free pool and future GC candidacy, shrinking over-provisioning
        self.fault_plan = None
        self._erase_seq = 0
        self.retired_blocks: set[int] = set()

    # -------------------------------------------------------------- mapping
    def _block_of(self, ppn: int) -> int:
        return ppn // self.pages_per_block

    def _next_ppn(self, now: int, allow_gc: bool = True) -> tuple[int, int]:
        """Allocate the next physical page; may trigger GC. Returns (ppn, gc_done_tick).

        ``allow_gc=False`` is the migration-path allocator: GC destination
        pages draw straight from the (watermark-reserved) free pool, because
        re-entering ``_collect`` from inside ``_collect`` would recurse on
        the same victim forever — the watermark exists precisely to reserve
        blocks for in-flight collections.
        """
        gc_done = now
        if self.write_ptr_page >= self.pages_per_block:
            if allow_gc and len(self.free_blocks) <= self.gc_watermark_blocks:
                gc_done = self._collect(now)
            if not self.free_blocks:
                raise RuntimeError("FTL out of space — device overfilled")
            self.write_ptr_block = self.free_blocks.pop(0)
            self.write_ptr_page = 0
        ppn = self.write_ptr_block * self.pages_per_block + self.write_ptr_page
        self.write_ptr_page += 1
        return ppn, gc_done

    def _invalidate(self, lpn: int) -> None:
        old = self.l2p.get(lpn)
        if old is not None:
            self.valid_count[self._block_of(old)] -= 1
            self.p2l.pop(old, None)

    def _collect(self, now: int) -> int:
        """Greedy GC: victimize the fullest-of-invalid block."""
        self.stats["gc_runs"] += 1
        candidates = [b for b in range(self.num_blocks)
                      if b != self.write_ptr_block
                      and b not in self.free_blocks
                      and b not in self.retired_blocks]
        if not candidates:
            return now
        victim = min(candidates, key=lambda b: self.valid_count[b])
        t = now
        base = victim * self.pages_per_block
        for off in range(self.pages_per_block):
            ppn = base + off
            lpn = self.p2l.get(ppn)
            if lpn is None:
                continue
            # migrate valid page
            t = self.pal.read_page(t, ppn)
            new_ppn, _ = self._next_ppn(t, allow_gc=False)
            t = self.pal.program_page(t, new_ppn)
            self.p2l.pop(ppn)
            self.l2p[lpn] = new_ppn
            self.p2l[new_ppn] = lpn
            self.valid_count[self._block_of(new_ppn)] += 1
            self.valid_count[victim] -= 1
            self.stats["gc_writes"] += 1
        t = self.pal.erase_block(t, base)
        self.stats["gc_erases"] += 1
        fail = False
        if self.fault_plan is not None:
            fail = self.fault_plan.erase_fails(self._erase_seq)
            self._erase_seq += 1
        if fail:
            # grown bad block: retire instead of returning to the pool —
            # the device degrades (less over-provisioning) rather than
            # serving corrupt data; running out entirely surfaces as the
            # existing "out of space" error
            self.retired_blocks.add(victim)
        else:
            self.free_blocks.append(victim)
        return t

    # ------------------------------------------------------------------ ops
    def read(self, now: int, lpn: int) -> int:
        """Read a logical page; returns completion tick."""
        self.stats["host_reads"] += 1
        ppn = self.l2p.get(lpn)
        if ppn is None:
            # unwritten page: served from the mapping table (no NAND access);
            # charge one channel transfer for the all-zeros response.
            return now + self.pal.timing.xfer_ticks(self.pal.page_bytes)
        return self.pal.read_page(now, ppn)

    def write(self, now: int, lpn: int) -> int:
        """Write (update) a logical page; returns completion tick."""
        self.stats["host_writes"] += 1
        self._invalidate(lpn)
        ppn, t = self._next_ppn(now)
        done = self.pal.program_page(t, ppn)
        self.l2p[lpn] = ppn
        self.p2l[ppn] = lpn
        self.valid_count[self._block_of(ppn)] += 1
        return done

    @property
    def write_amplification(self) -> float:
        hw = self.stats["host_writes"]
        return (hw + self.stats["gc_writes"]) / hw if hw else 1.0
