"""The five memory devices evaluated in the paper (§III).

``dram``          local DDR4-2400
``cxl-dram``      DRAM behind the CXL.mem link
``pmem``          persistent memory (SpecPMT timing: 150 ns R / 500 ns W)
``cxl-ssd``       SSD memory expander, no DRAM cache (SimpleSSD backend)
``cxl-ssd-cache`` SSD expander + the paper's DRAM cache layer

Every device implements two access paths:

* ``service(now, addr, size, write) -> completion_tick`` — the analytic
  busy-until fast path used by trace drivers (millions of accesses);
* ``access(pkt, cb)`` / ``access_flit(flit, cb)`` — the event-driven path
  used through the :class:`~repro.core.cxl.home_agent.HomeAgent` in
  full-system mode (integration tests exercise both and assert they agree).

Bandwidth emerges from per-access media occupancy (Little's law: enough
outstanding 64 B requests saturate ``64 B / occupancy``); latency from the
device constants of Table I.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.cache.dram_cache import DRAMCache, DRAMCacheConfig, PAGE_BYTES
from repro.core.cxl.flit import CXLCommand, CXLFlit, MemCmd, Packet
from repro.core.engine import EventEngine, ns
from repro.core.ssd.hil import HIL, SSDConfig

LINE = 64
POSTED_ACK_NS = 10.0   # store accepted into the write queue


# --------------------------------------------------------------------- base
class MemDevice:
    name = "abstract"
    is_cxl = False

    def __init__(self, engine: Optional[EventEngine] = None) -> None:
        self.engine = engine
        self.stats = {"reads": 0, "writes": 0, "bytes": 0}
        # deterministic fault injection (repro.core.faults.install): the
        # device marks read-response flits poisoned per the plan, keyed on
        # its own flit ordinal — corrupt data surfaces as status, never as
        # fabricated latency
        self.fault_plan = None
        self._flit_ord = 0

    def _poison_next(self, write: bool) -> bool:
        plan = self.fault_plan
        if plan is None or not plan.has_poison:
            return False
        ordinal = self._flit_ord
        self._flit_ord += 1
        return plan.poisoned(0, ordinal, write)

    # analytic fast path ---------------------------------------------------
    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        """``posted=True`` models regular stores retiring into the write queue
        (slot freed at accept time); ``posted=False`` models loads and
        persistent stores (clwb/fence) that wait for the media — the Viper
        case that exposes PMEM's 500 ns writes (paper Fig. 5/6)."""
        raise NotImplementedError

    def _count(self, size: int, write: bool) -> None:
        self.stats["writes" if write else "reads"] += 1
        self.stats["bytes"] += size

    # fabric mount hook ----------------------------------------------------
    def detach_link(self) -> "MemDevice":
        """Replace this device's private point-to-point CXL link (if any)
        with a :class:`NullLink`, so a switch fabric can own transport
        instead.  No-op for devices without a ``link`` (dram, pmem).
        Returns ``self`` for chaining."""
        if hasattr(self, "link"):
            self.link = NullLink()
        return self

    # event-driven path ------------------------------------------------------
    def access(self, pkt: Packet, cb: Callable[[Packet], None]) -> None:
        done = self.service(self.engine.now, pkt.addr, pkt.size, pkt.is_write())
        resp = Packet(cmd=MemCmd.WriteResp if pkt.is_write() else MemCmd.ReadResp,
                      addr=pkt.addr, size=pkt.size, req_id=pkt.req_id)
        self.engine.schedule_at(done, lambda: cb(resp))

    def access_flit(self, flit: CXLFlit, cb: Callable[[CXLFlit], None]) -> None:
        write = flit.opcode is CXLCommand.M2SRwD
        size = flit.length_blocks * LINE
        done = self.service(self.engine.now, flit.addr, size, write)
        resp = CXLFlit(
            opcode=CXLCommand.S2MNDR if write else CXLCommand.S2MDRS,
            addr=flit.addr, tag=flit.tag, length_blocks=flit.length_blocks,
            data=b"" if write else b"\x00" * min(size, LINE),
            poison=self._poison_next(write),
        )
        self.engine.schedule_at(done, lambda: cb(resp))


# --------------------------------------------------------------------- DRAM
@dataclass
class DRAMTiming:
    load_ns: float = 80.0           # idle random-load latency, DDR4-2400
    bw_gbps: float = 19.2           # one channel (Table I: 1 memory channel)


class DRAMDevice(MemDevice):
    name = "dram"

    def __init__(self, engine: Optional[EventEngine] = None,
                 timing: DRAMTiming | None = None) -> None:
        super().__init__(engine)
        self.t = timing or DRAMTiming()
        self._busy = 0

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        occ = ns(size / self.t.bw_gbps)  # bytes / (GB/s) == ns
        start = max(now, self._busy)
        self._busy = start + occ
        if write and posted:
            return start + occ + ns(POSTED_ACK_NS)
        return start + occ + ns(self.t.load_ns)


# ----------------------------------------------------------------- CXL link
class CXLLink:
    """PCIe 4.0 x8-class CXL link: 16 GB/s per direction."""

    def __init__(self, bw_gbps: float = 16.0, rt_extra_ns: float = 50.0) -> None:
        self.bw_gbps = bw_gbps
        self.rt_extra_ns = rt_extra_ns  # Table I: total CXL.mem network latency
        self._busy = 0

    def traverse(self, now: int, nbytes: int) -> int:
        occ = ns(nbytes / self.bw_gbps)
        start = max(now, self._busy)
        self._busy = start + occ
        return start + occ + ns(self.rt_extra_ns)


class NullLink(CXLLink):
    """Zero-cost link: transport is modeled elsewhere (the fabric layer).

    Used by :class:`repro.core.fabric.FabricAttachedDevice` to neutralize a
    CXL device's private point-to-point link so the switch fabric owns the
    full transport path and link latency is not double-counted.
    """

    def __init__(self) -> None:
        super().__init__(bw_gbps=float("inf"), rt_extra_ns=0.0)

    def traverse(self, now: int, nbytes: int) -> int:
        return now


class CXLDRAMDevice(MemDevice):
    name = "cxl-dram"
    is_cxl = True

    def __init__(self, engine: Optional[EventEngine] = None,
                 timing: DRAMTiming | None = None,
                 link: CXLLink | None = None) -> None:
        super().__init__(engine)
        self.dram = DRAMDevice(engine, timing)
        self.link = link or CXLLink()

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        t = self.link.traverse(now, size)
        return self.dram.service(t, addr, size, write, posted)


# --------------------------------------------------------------------- PMEM
@dataclass
class PMEMTiming:
    read_ns: float = 150.0          # SpecPMT
    write_ns: float = 500.0
    row_bytes: int = 256            # Table I: PMEM rowbuffer 256 B
    row_hit_factor: float = 0.6     # open-row access cuts media latency
    bw_gbps: float = 12.5           # ~0.65 x DDR4 channel (paper Fig. 3)


class PMEMDevice(MemDevice):
    name = "pmem"

    def __init__(self, engine: Optional[EventEngine] = None,
                 timing: PMEMTiming | None = None) -> None:
        super().__init__(engine)
        self.t = timing or PMEMTiming()
        self._busy = 0
        self._open_row = -1
        self.stats["row_hits"] = 0

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        row = addr // self.t.row_bytes
        lat = self.t.write_ns if write else self.t.read_ns
        if row == self._open_row:
            lat *= self.t.row_hit_factor
            self.stats["row_hits"] += 1
        self._open_row = row
        occ = ns(size / self.t.bw_gbps)
        start = max(now, self._busy)
        self._busy = start + occ
        if write and posted:
            return start + occ + ns(POSTED_ACK_NS)
        return start + occ + ns(lat)


# ------------------------------------------------------------------ CXL-SSD
def _memory_semantic_ssd() -> SSDConfig:
    """Default CXL-SSD build: low-latency NAND (see NANDTiming.low_latency)."""
    from repro.core.ssd.pal import NANDTiming
    return SSDConfig(timing=NANDTiming.low_latency(), hil_overhead_ns=1000.0)


class CXLSSDDevice(MemDevice):
    """Uncached SSD memory expander — the paper's motivating pain point.

    Without a DRAM cache layer, the controller only has NAND page registers
    (a handful of open 4 KB pages).  Every 64 B access that misses them
    amplifies to a 4 KB flash page operation (§II-A granularity mismatch);
    a 64 B *write* miss is a read-modify-write — the page must be fetched
    before the line can merge.  Average access latency is therefore in the
    microseconds-to-tens-of-microseconds band.
    """

    name = "cxl-ssd"
    is_cxl = True

    def __init__(self, engine: Optional[EventEngine] = None,
                 ssd_cfg: SSDConfig | None = None,
                 link: CXLLink | None = None,
                 page_registers: int = 4,
                 internal_latency_ns: float = 250.0) -> None:
        super().__init__(engine)
        self.hil = HIL(ssd_cfg or _memory_semantic_ssd())
        self.link = link or CXLLink()
        self.internal_latency_ns = internal_latency_ns
        from repro.core.cache.policies import LRUPolicy
        self._buf = LRUPolicy(max(1, page_registers))  # open-page registers
        self.stats.update({"buf_hits": 0, "flash_reads": 0, "flash_writes": 0,
                           "rmw_fills": 0})

    def _flush_if_evicted(self, now: int, page: Optional[int]) -> None:
        if page is not None:
            self.hil.write(now, page * PAGE_BYTES, PAGE_BYTES)
            self.stats["flash_writes"] += 1

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        t = self.link.traverse(now, size)
        page = addr // PAGE_BYTES
        if self._buf.lookup(page):
            self.stats["buf_hits"] += 1
            self._buf.touch(page, dirty=write)
            return t + ns(self.internal_latency_ns)
        # Miss: fetch the page into a register (read amplification).  Writes
        # are read-modify-write unless the page was never programmed.
        done = t
        if self.hil.is_written(page * PAGE_BYTES):
            self.stats["rmw_fills" if write else "flash_reads"] += 1
            done = self.hil.read(t, page * PAGE_BYTES, PAGE_BYTES)
        ev = self._buf.insert(page, dirty=write)
        if ev is not None and ev.dirty:
            self._flush_if_evicted(done, ev.page)
        return done + ns(self.internal_latency_ns)


class CachedCXLSSDDevice(MemDevice):
    """The paper's contribution: CXL-SSD fronted by the DRAM cache layer.

    ``hil=`` mounts an *existing* flash backend instead of building a fresh
    one: several cached front-ends sharing one ``HIL`` model the pooled
    CXL-SSD shape — per-host private DRAM caches over shared FTL/PAL flash
    — where cross-host contention emerges from the shared die/channel
    busy-until state (and the shared free-block pool under GC)."""

    name = "cxl-ssd-cache"
    is_cxl = True

    def __init__(self, engine: Optional[EventEngine] = None,
                 ssd_cfg: SSDConfig | None = None,
                 cache_cfg: DRAMCacheConfig | None = None,
                 link: CXLLink | None = None,
                 hil: HIL | None = None) -> None:
        super().__init__(engine)
        if hil is not None and ssd_cfg is not None:
            raise ValueError("pass ssd_cfg or a shared hil, not both")
        self.hil = hil if hil is not None else HIL(ssd_cfg or
                                                  _memory_semantic_ssd())
        self.cache = DRAMCache(cache_cfg or DRAMCacheConfig(), self.hil)
        self.link = link or CXLLink()

    def service(self, now: int, addr: int, size: int, write: bool,
                posted: bool = False) -> int:
        self._count(size, write)
        t = self.link.traverse(now, size)
        done = t
        for line_addr in range(addr - addr % LINE, addr + size, LINE):
            done = max(done, self.cache.access(t, line_addr, write, posted=posted))
        return done

    @property
    def hit_rate(self) -> float:
        return self.cache.hit_rate


DEVICE_NAMES = ["dram", "cxl-dram", "pmem", "cxl-ssd", "cxl-ssd-cache"]


def make_device(name: str, engine: Optional[EventEngine] = None,
                **kwargs) -> MemDevice:
    table = {
        "dram": DRAMDevice,
        "cxl-dram": CXLDRAMDevice,
        "pmem": PMEMDevice,
        "cxl-ssd": CXLSSDDevice,
        "cxl-ssd-cache": CachedCXLSSDDevice,
    }
    try:
        cls = table[name]
    except KeyError:
        raise ValueError(f"unknown device {name!r}; choose from {DEVICE_NAMES}") from None
    # Constructor errors (e.g. bad kwargs) propagate with their real message —
    # only the name lookup is guarded.
    return cls(engine, **kwargs)
