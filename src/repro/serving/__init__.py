from repro.serving.scheduler import BatchScheduler, Request, SchedulerConfig

__all__ = ["BatchScheduler", "Request", "SchedulerConfig"]
