"""Continuous-batching serving scheduler.

Production serving keeps a fixed decode batch full: finished sequences free
their slot, queued requests claim it mid-flight (prefill-on-join), and the
per-slot KV ranges live in the ring buffer managed by the decode step.  The
scheduler owns:

  * a FIFO admission queue with per-request prompt/max-token budgets;
  * slot lifecycle (join → prefill token-feed → decode → retire on EOS or
    budget), with per-slot position counters so RoPE phases stay correct;
  * eviction of retired slots' KV pages into the TieredStore (the paper's
    capacity tier) for later lookback/re-join, when one is attached.

The model interface is the framework's ``serve_step`` (one token per slot
per tick); joining sequences are prefilled by feeding their prompt tokens
through the same step — simple, always-batched, and correct for the ring
KV cache (each slot's writes land at its own positions).

Note the deliberate simplification vs. per-slot position tracking: the
ring buffer is indexed by the GLOBAL step counter, so slots that join late
waste the slots' earlier ring positions.  With window-bounded caches (SWA)
this is harmless; for full caches the context budget shrinks by the join
offset — acceptable for the framework's scope and flagged here.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax.numpy as jnp
import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the scheduler
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class SchedulerConfig:
    batch_slots: int = 4
    pad_id: int = 0


class BatchScheduler:
    """Drives ``serve_step`` with a continuously-full batch."""

    def __init__(self, serve_step: Callable, init_state: Callable,
                 cfg: SchedulerConfig, vocab: int) -> None:
        self._step = serve_step
        self._init_state = init_state
        self.cfg = cfg
        self.vocab = vocab
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * cfg.batch_slots
        self._cursor: List[int] = [0] * cfg.batch_slots  # prompt feed pos
        self.completed: Dict[int, Request] = {}
        self.state = None
        self.ticks = 0

    # ------------------------------------------------------------------ api
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                self.slots[i] = self.queue.popleft()
                self._cursor[i] = 0

    def _next_feed(self) -> np.ndarray:
        """Token each slot feeds this tick: prompt token (prefill phase) or
        its last generated token (decode phase); pad for empty slots."""
        toks = np.full((self.cfg.batch_slots,), self.cfg.pad_id, np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            cur = self._cursor[i]
            if cur < len(req.prompt):
                toks[i] = req.prompt[cur]
            elif req.output:
                toks[i] = req.output[-1]
            else:  # first decode token comes from the prompt's last logits
                toks[i] = req.prompt[-1]
        return toks

    def _absorb(self, logits: np.ndarray) -> None:
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self._cursor[i] += 1
            if self._cursor[i] < len(req.prompt):
                continue  # still prefilling: discard logits
            tok = int(np.argmax(logits[i][: self.vocab]))
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if hit_eos or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.completed[req.rid] = req
                self.slots[i] = None

    def run(self, max_ticks: int = 1000) -> Dict[int, Request]:
        """Tick until every submitted request completes (or max_ticks)."""
        if self.state is None:
            self.state = self._init_state(self.cfg.batch_slots)
        while (self.queue or any(self.slots)) and self.ticks < max_ticks:
            self._admit()
            toks = jnp.asarray(self._next_feed())
            logits, self.state = self._step(self.state, toks)
            self._absorb(np.asarray(logits))
            self.ticks += 1
        return self.completed

    @property
    def occupancy(self) -> float:
        return sum(s is not None for s in self.slots) / self.cfg.batch_slots
