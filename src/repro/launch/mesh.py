"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — DP over pods, with
    optional pipeline parallelism over the pod axis (distributed/pipeline)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, n_pod: int = 0):
    """Small host-device mesh for tests (requires forced host device count)."""
    if n_pod:
        return jax.make_mesh((n_pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
