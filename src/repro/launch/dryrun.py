import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

# ^ MUST precede any jax import (jax locks device count at first init).
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract the roofline inputs.

For each cell:
  * build ShapeDtypeStruct stand-ins for params / optimizer / decode state /
    batch (never allocating),
  * ``jax.jit(step, in_shardings=..., out_shardings=...).lower(...).compile()``
    under the 16x16 (single-pod) or 2x16x16 (multi-pod) mesh,
  * record ``memory_analysis()`` / ``cost_analysis()`` / the collective
    schedule parsed from the optimized HLO, into ``results/dryrun/*.json``.

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system, not in the script.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                                cell_applicable, get_arch)
from repro.distributed.sharding import MeshAxes, batch_spec, decode_state_specs, \
    opt_state_specs, param_specs
from repro.distributed.step import (make_prefill_step, make_serve_step,
                                    make_train_step)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_decode_state, init_params
from repro.optim.adamw import adamw_init
from repro.optim.schedules import wsd_schedule

DTYPE = jnp.bfloat16

# TPU v5e-class constants (per chip) for the roofline terms.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
             "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
             "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, default_group: int) -> dict:
    """Sum per-device wire bytes per collective type (ring cost model)."""
    stats = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # -start carries the shape; -done would double count
        result_bytes = _shape_bytes(m.group(1))
        op = m.group(2).lower()
        g = default_group
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        g = max(g, 2)
        if op == "all-reduce":
            wire = 2 * (g - 1) / g * result_bytes
        elif op == "all-gather":
            wire = (g - 1) / g * result_bytes
        elif op == "reduce-scatter":
            wire = (g - 1) * result_bytes          # input = g x result
        elif op == "all-to-all":
            wire = (g - 1) / g * result_bytes
        else:  # collective-permute
            wire = result_bytes
        ent = stats.setdefault(op, {"count": 0, "result_bytes": 0,
                                    "wire_bytes": 0.0})
        ent["count"] += 1
        ent["result_bytes"] += result_bytes
        ent["wire_bytes"] += wire
    stats["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def _sharded_bytes(shape_tree, spec_tree, mesh) -> int:
    """Analytic per-device bytes for a ShapeDtypeStruct tree + spec tree."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shape_tree),
                          jax.tree.leaves(spec_tree,
                                          is_leaf=lambda x: isinstance(x, P))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        for axes in tuple(spec):
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                denom *= mesh.shape[a]
        total += n * leaf.dtype.itemsize // denom
    return total


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train / 2*N*D inference (+ attention)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn_mult = 3.0  # fwd + bwd
        s_ctx = shape.seq_len / 2  # causal average context
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
        s_ctx = shape.seq_len / 2
    else:  # decode: one token against seq_len of history
        tokens = shape.global_batch * 1
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
        s_ctx = shape.seq_len
    if cfg.swa_window:
        s_ctx = min(s_ctx, cfg.swa_window)
    hd = cfg.resolved_head_dim
    attn = 4.0 * tokens * s_ctx * cfg.n_heads * hd * cfg.n_layers * attn_mult
    return base + attn


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        toks = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    else:
        toks = (B, cfg.n_codebooks) if cfg.n_codebooks else (B,)
    batch = {"tokens": sds(toks, jnp.int32)}
    if cfg.cross_attn_every and shape.kind in ("train", "prefill"):
        batch["frontend"] = sds((B, cfg.n_frontend_tokens, cfg.d_model), DTYPE)
    return batch


def _compile_one(cfg: ArchConfig, shape: ShapeConfig, mesh, ax,
                 batch_replicated: bool, unroll: bool = False,
                 opts: dict = None):
    """Lower+compile one step; returns (compiled, state_bytes).
    ``opts``: hillclimb variants — {'compress': bool (int8 grad all-reduce)}."""
    opts = opts or {}
    fsdp = bool(opts.get("fsdp"))
    fsdp_model = bool(opts.get("fsdp_model"))
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda k: init_params(k, cfg, DTYPE), key)
    if fsdp or fsdp_model:
        from repro.distributed.sharding import fsdp_param_specs
        if cfg.moe is not None:
            raise ValueError("fsdp variant targets dense/ssm archs (MoE EP "
                             "needs the model axis)")
        shard_axes = (ax.tp,) if fsdp_model else tuple(ax.dp) + (ax.tp,)
        pspecs = fsdp_param_specs(params_shape, cfg, mesh, ax, axes=shard_axes)
        b_axes = tuple(ax.dp) if fsdp_model else tuple(ax.dp) + (ax.tp,)
        seq_axes = None
        n_b = int(np.prod([mesh.shape[a] for a in b_axes]))
        if shape.global_batch % n_b != 0 and "pod" in b_axes:
            # multi-pod with batch < devices: batch over (data, model),
            # sequence over pod (FSDP + sequence parallelism)
            b_axes = tuple(a for a in b_axes if a != "pod")
            seq_axes = "pod"
        bspec_map = {"tokens": P(b_axes, seq_axes, None) if cfg.n_codebooks
                     else P(b_axes, seq_axes)}
        if cfg.cross_attn_every:
            bspec_map["frontend"] = P(b_axes, None, None)
    else:
        kind = ("decode" if (shape.kind == "decode"
                             and opts.get("resident_experts")) else "train")
        pspecs = param_specs(params_shape, cfg, mesh, ax, kind=kind)
        bspec_map = batch_spec(cfg, ax, shape.kind, batch_replicated)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    bspec = {k: NamedSharding(mesh, v) for k, v in bspec_map.items()
             if k in input_specs(cfg, shape)}
    batch = input_specs(cfg, shape)

    if shape.kind == "train":
        moment_dtype = opts.get("moment_dtype", "f32")
        opt_shape = jax.eval_shape(
            lambda p: adamw_init(p, moment_dtype), params_shape)
        ospecs = opt_state_specs(opt_shape, pspecs, mesh, ax, zero1=True)
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                           is_leaf=lambda x: isinstance(x, P))
        lr_fn = wsd_schedule(3e-4, 100, 10_000, 1_000)
        compress = bool(opts.get("compress"))
        from repro.optim.adamw import AdamWConfig
        step_fn = make_train_step(cfg, None if (fsdp or fsdp_model) else mesh,
                                  lr_fn=lr_fn,
                                  adamw_cfg=AdamWConfig(moment_dtype=moment_dtype),
                                  unroll=unroll, compress_grads=compress,
                                  accum_steps=int(opts.get("accum_steps", 1)),
                                  remat_policy=opts.get("remat_policy"))
        if compress:
            from repro.optim.compression import compress_init
            comp_shape = jax.eval_shape(compress_init, params_shape)
            cspecs = jax.tree.map(lambda sp: sp, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))
            csh = {"residual": jax.tree.map(
                lambda sp: NamedSharding(mesh, sp), cspecs,
                is_leaf=lambda x: isinstance(x, P))}
            from repro.optim.compression import CompressionState
            csh = CompressionState(residual=csh["residual"])
            jitted = jax.jit(step_fn,
                             in_shardings=(psh, osh, bspec,
                                           NamedSharding(mesh, P()), csh),
                             out_shardings=(psh, osh, NamedSharding(mesh, P()),
                                            csh),
                             donate_argnums=(0, 1, 4))
            args = (params_shape, opt_shape, batch,
                    jax.ShapeDtypeStruct((), jnp.int32), comp_shape)
        else:
            jitted = jax.jit(step_fn,
                             in_shardings=(psh, osh, bspec,
                                           NamedSharding(mesh, P())),
                             out_shardings=(psh, osh, NamedSharding(mesh, P())),
                             donate_argnums=(0, 1))
            args = (params_shape, opt_shape, batch,
                    jax.ShapeDtypeStruct((), jnp.int32))
        if "mu" in opt_shape:
            state_bytes = (_sharded_bytes(params_shape, pspecs, mesh)
                           + _sharded_bytes(opt_shape["mu"], ospecs["mu"], mesh)
                           + _sharded_bytes(opt_shape["nu"], ospecs["nu"], mesh))
        else:
            state_bytes = _sharded_bytes(params_shape, pspecs, mesh) + sum(
                _sharded_bytes(opt_shape[k], ospecs[k], mesh)
                for k in ("mu_q", "mu_s", "nu_q", "nu_s"))
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(cfg, None if (fsdp or fsdp_model) else mesh,
                                    unroll=unroll)
        b = None if batch_replicated else (
            ax.dp if not (fsdp or fsdp_model) else tuple(ax.dp))
        logits_spec = NamedSharding(
            mesh, P(b, None, None, ax.tp) if cfg.n_codebooks
            else P(b, None, ax.tp))
        jitted = jax.jit(step_fn, in_shardings=(psh, bspec),
                         out_shardings=logits_spec)
        args = (params_shape, batch)
        state_bytes = _sharded_bytes(params_shape, pspecs, mesh)
    else:  # decode
        frontend = None
        if cfg.cross_attn_every:
            frontend = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_frontend_tokens, cfg.d_model), DTYPE)
        state_shape = jax.eval_shape(
            lambda p, f: init_decode_state(p, cfg, shape.global_batch,
                                           shape.seq_len, DTYPE, frontend=f),
            params_shape, frontend)
        dspecs = decode_state_specs(state_shape, cfg, mesh, ax, batch_replicated)
        # fill unspecified leaves (cur) replicated
        dsh = jax.tree.map(lambda s: NamedSharding(mesh, s), dspecs,
                           is_leaf=lambda x: isinstance(x, P))
        step_fn = make_serve_step(
            cfg, mesh, batch_replicated, unroll=unroll,
            resident_experts=bool(opts.get("resident_experts")))
        b = None if batch_replicated else ax.dp
        logits_spec = NamedSharding(
            mesh, P(b, None, ax.tp) if cfg.n_codebooks else P(b, ax.tp))
        tok_sh = NamedSharding(mesh, P(b, None) if cfg.n_codebooks else P(b))
        jitted = jax.jit(step_fn,
                         in_shardings=(psh, dsh, tok_sh),
                         out_shardings=(logits_spec, dsh),
                         donate_argnums=(1,))
        args = (params_shape, state_shape, batch["tokens"])
        state_bytes = (_sharded_bytes(params_shape, pspecs, mesh)
                       + _sharded_bytes(state_shape, dspecs, mesh))

    with mesh:
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled, int(state_bytes)


def _metrics(compiled, tp_size: int) -> dict:
    out = {}
    try:
        ca = compiled.cost_analysis()
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes"] = float(ca.get("bytes accessed", 0.0))
    except Exception:
        out["flops"] = out["bytes"] = 0.0
    coll = parse_collectives(compiled.as_text(), tp_size)
    out["wire"] = float(coll.get("total_wire_bytes", 0.0))
    out["collectives"] = coll
    return out


def _probe_cfg(cfg: ArchConfig, shape: ShapeConfig, n_layers: int) -> ArchConfig:
    """Depth-reduced, trip-1-inner-scan config for probe compiles: attention
    tiles = full sequence and a single SSD chunk, so XLA's count-body-once
    cost analysis sees every FLOP exactly once."""
    import dataclasses
    if cfg.attn_impl == "triangular":
        # 8x8 block grid -> <=36 causal pairs, auto-unrolled: counted exactly
        blk = max(shape.seq_len // 8, 1)
    else:
        blk = max(shape.seq_len, 1)
    kw = dict(n_layers=n_layers, attn_block=blk)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm,
                                        chunk=max(shape.seq_len, 1))
    return dataclasses.replace(cfg, **kw)


VARIANTS = {
    "baseline": {},
    "tri": {"attn_impl": "triangular"},            # triangular flash attention
    "compress": {"compress": True},                # int8 grad all-reduce
    "tri+compress": {"attn_impl": "triangular", "compress": True},
    "kvq8": {"kv_dtype": "int8"},                  # int8 KV cache (decode)
    "mb4": {"accum_steps": 4},                     # 4-way grad accumulation
    "tri+mb4": {"attn_impl": "triangular", "accum_steps": 4},
    "fsdp": {"fsdp": True},                        # ZeRO-3 instead of TP (train)
    "fsdp+tri": {"fsdp": True, "attn_impl": "triangular"},
    "fsdp+tri+compress": {"fsdp": True, "attn_impl": "triangular",
                          "compress": True},
    "repx": {"resident_experts": True},            # resident-expert decode
    "repx+kvq8": {"resident_experts": True, "kv_dtype": "int8"},
    # FSDP over the MODEL axis only (weight-gather TP replacement) with DP
    # over data — for prefill where global batch < device count
    "fsdpm": {"fsdp_model": True},
    "fsdpm+tri": {"fsdp_model": True, "attn_impl": "triangular"},
    "opt8": {"moment_dtype": "int8"},              # 8-bit Adam moments
    "fsdp+tri+opt8": {"fsdp": True, "attn_impl": "triangular",
                      "moment_dtype": "int8"},
    # remat policy: save no-batch-dim dot results (skips remat re-gathers)
    "fsdp+tri+sdots": {"fsdp": True, "attn_impl": "triangular",
                       "remat_policy": "dots"},
}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Path, probes: bool = True,
             variant: str = "baseline") -> dict:
    import dataclasses
    cfg = get_arch(arch_id)
    vopts = dict(VARIANTS[variant])
    if "attn_impl" in vopts:
        cfg = dataclasses.replace(cfg, attn_impl=vopts.pop("attn_impl"))
    if "kv_dtype" in vopts:
        cfg = dataclasses.replace(cfg, kv_dtype=vopts.pop("kv_dtype"))
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "variant": variant,
           "chips": 512 if multi_pod else 256}

    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec["status"] = "SKIP"
        rec["reason"] = why
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ax = MeshAxes.for_mesh(mesh)
    tp_size = mesh.shape[ax.tp]
    batch_replicated = shape.global_batch < np.prod(
        [mesh.shape[a] for a in ax.dp])

    compiled, state_bytes = _compile_one(cfg, shape, mesh, ax,
                                         batch_replicated, opts=vopts)
    rec["compile_s"] = round(time.time() - t0, 1)
    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("temp_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)} if mem is not None else None
    except Exception as e:  # CPU backend may not implement it
        rec["memory_analysis"] = f"unavailable: {e}"
    raw = _metrics(compiled, tp_size)
    rec["cost_analysis"] = {"flops": raw["flops"],
                            "bytes accessed": raw["bytes"]}
    rec["collectives"] = raw["collectives"]

    # ---- probe compiles: correct for XLA counting loop bodies once.
    # Depth-reduced unrolled compiles -> linear fit total(L) = outside +
    # body*L, per metric.  Two probe FLAVORS:
    #   * trip-1 inner scans (attention tile = S, one SSD chunk): every FLOP
    #     and collective appears exactly once -> exact flops/wire;
    #   * normal tiles: the flash/SSD block buffers stay loop-internal
    #     (VMEM-resident on the TPU target), so 'bytes accessed' approximates
    #     HBM traffic instead of counting on-chip score tiles.
    if probes:
        try:
            import dataclasses
            l1 = cfg.cross_attn_every if cfg.cross_attn_every else 1
            l2 = 2 * l1

            def fit(m1, m2, key_):
                body = (m2[key_] - m1[key_]) / (l2 - l1)
                outside = m1[key_] - body * l1
                return max(outside + body * cfg.n_layers, 0.0)

            ms_exact = []
            ms_tiled = []
            for L in (l1, l2):
                pc = _probe_cfg(cfg, shape, L)
                pcomp, _ = _compile_one(pc, shape, mesh, ax,
                                        batch_replicated, unroll=True,
                                        opts=vopts)
                ms_exact.append(_metrics(pcomp, tp_size))
                tc = dataclasses.replace(cfg, n_layers=L)
                tcomp, _ = _compile_one(tc, shape, mesh, ax,
                                        batch_replicated, unroll=True,
                                        opts=vopts)
                ms_tiled.append(_metrics(tcomp, tp_size))
            rec["corrected"] = {
                "flops": fit(ms_exact[0], ms_exact[1], "flops"),
                "wire": fit(ms_exact[0], ms_exact[1], "wire"),
                "bytes": fit(ms_tiled[0], ms_tiled[1], "bytes"),
            }
            rec["probe"] = {
                "l1": l1, "l2": l2,
                "exact": [{k: m[k] for k in ("flops", "bytes", "wire")}
                          for m in ms_exact],
                "tiled": [{k: m[k] for k in ("flops", "bytes", "wire")}
                          for m in ms_tiled]}
        except Exception as e:
            rec["corrected"] = None
            rec["probe_error"] = f"{type(e).__name__}: {e}"

    rec["analytic_state_bytes_per_device"] = int(state_bytes)
    rec["model_flops_global"] = model_flops(cfg, shape)
    rec["status"] = "OK"

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "baseline" else f"_{variant}"
    fname = out_dir / f"{arch_id}_{shape_name}_{mesh_name}{suffix}.json"
    fname.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch} x {shape} x {'multi' if multi else 'single'}"
                try:
                    rec = run_cell(arch, shape, multi, out_dir,
                                   variant=args.variant)
                    extra = (f" ({rec.get('compile_s', '?')}s)"
                             if rec["status"] == "OK" else
                             f" [{rec.get('reason', '')}]")
                    print(f"[dryrun] {tag}: {rec['status']}{extra}", flush=True)
                    if rec["status"] == "OK":
                        ma = rec.get("memory_analysis")
                        ca = rec.get("cost_analysis")
                        print(f"         mem={ma} cost={ca}", flush=True)
                        print(f"         collectives={rec['collectives'].get('total_wire_bytes', 0):.3e}B "
                              f"state={rec['analytic_state_bytes_per_device']/2**30:.2f}GiB/dev",
                              flush=True)
                except Exception:
                    failures += 1
                    print(f"[dryrun] {tag}: FAIL", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
