"""Serving driver: batched token generation with the tiered KV store.

Demonstrates the paper's architecture end to end at serving time: the HBM
ring buffer holds the hot KV window while evicted segments land in the
capacity tier ("CXL-SSD") managed by the CXL-SSD-Sim replacement policies —
with simulated device timing attached so the run reports how much CXL-SSD
latency the DRAM/HBM cache layer absorbed.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \\
      --reduced --batch 4 --prompt-len 32 --gen 64 --policy lru
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.core.devices import make_device
from repro.distributed.step import make_serve_step
from repro.models.transformer import init_decode_state, init_params
from repro.tiered.store import TieredStore, TieredStoreConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--policy", default="lru",
                    choices=["lru", "fifo", "2q", "lfru", "direct"])
    ap.add_argument("--kv-page-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)

    serve_step = jax.jit(make_serve_step(cfg, mesh=None), donate_argnums=(1,))
    state = init_decode_state(params, cfg, args.batch, args.context)

    # Tiered store for evicted KV pages: page = (layers, batch, page_tokens,
    # kv, hd) segment. Backed by a simulated CXL-SSD.
    hd = cfg.resolved_head_dim
    n_kv_pages = max(args.context // args.kv_page_tokens * 4, 8)
    tiered = None
    if cfg.n_heads:
        tiered = TieredStore(
            TieredStoreConfig(
                n_logical_pages=n_kv_pages,
                page_shape=(cfg.n_layers, args.batch, args.kv_page_tokens,
                            cfg.n_kv_heads, hd),
                hbm_pages=max(n_kv_pages // 4, 2),
                policy=args.policy),
            backing=make_device("cxl-ssd"))

    rng = np.random.default_rng(args.seed)
    if cfg.n_codebooks:
        tokens = rng.integers(0, cfg.vocab, (args.batch, cfg.n_codebooks))
    else:
        tokens = rng.integers(0, cfg.vocab, (args.batch,))
    tokens = jnp.asarray(tokens, jnp.int32)

    t0 = time.perf_counter()
    n_steps = args.prompt_len + args.gen
    ring = state["k"].shape[2] if cfg.n_heads else 0
    for step in range(n_steps):
        logits, state = serve_step(params, state, tokens)
        # greedy next token (mask vocab padding)
        logits = logits[..., :cfg.vocab]
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # When the ring buffer wraps, archive the about-to-be-overwritten KV
        # segment into the capacity tier (the paper's DRAM-cache-of-SSD flow)
        if tiered is not None and ring and (step + 1) % args.kv_page_tokens == 0:
            seg = (step + 1) // args.kv_page_tokens - 1
            lo = (seg * args.kv_page_tokens) % ring
            if lo + args.kv_page_tokens <= ring:
                page = np.asarray(state["k"][:, :, lo:lo + args.kv_page_tokens])
                page = np.transpose(page, (0, 1, 2, 3, 4))
                tiered.write_page(seg % n_kv_pages,
                                  np.transpose(page, (0, 1, 2, 3, 4)))
                # touch a few historical pages (re-prefill / lookback reads)
                if seg > 2:
                    picks = rng.integers(0, seg, size=2) % n_kv_pages
                    tiered.read_pages(list(picks))
    dt = time.perf_counter() - t0
    print(f"[serve] arch={cfg.name} batch={args.batch} steps={n_steps} "
          f"({dt:.2f}s, {args.batch*n_steps/dt:.1f} tok/s)")
    if tiered is not None:
        print(f"[serve] tiered-KV: hit-rate={tiered.hit_rate:.3f} "
              f"fills={tiered.stats['fills']} "
              f"writebacks={tiered.stats['writebacks']} "
              f"coalesced={tiered.stats['coalesced']} "
              f"sim-CXL-SSD-time={tiered.sim_time_us:.1f}us")


if __name__ == "__main__":
    main()
