"""Training driver: config -> mesh -> jit'd train step -> loop with
checkpointing, straggler watchdog, WSD schedule and preemption-safe restart.

Examples:
  # tiny CPU run (reduced config), a few hundred steps:
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \\
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

  # production lowering is exercised by repro.launch.dryrun; this driver
  # runs the same step function on whatever devices exist.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import get_arch
from repro.data.pipeline import ShardedLoader
from repro.distributed.step import make_train_step
from repro.distributed.straggler import StragglerWatchdog
from repro.models.transformer import init_params
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedules import wsd_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    opt_state = adamw_init(params)
    loader = ShardedLoader(cfg, args.seq, args.batch, seed=args.seed + 1)

    lr_fn = wsd_schedule(args.lr, warmup_steps=max(args.steps // 20, 5),
                         stable_steps=int(args.steps * 0.7),
                         decay_steps=max(int(args.steps * 0.25), 1))
    train_step = jax.jit(make_train_step(cfg, mesh=None, lr_fn=lr_fn,
                                         adamw_cfg=AdamWConfig()),
                         donate_argnums=(0, 1))

    start_step = 0
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (state, extra, start_step) = ckpt.restore(
            {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        loader.restore(extra["loader"])
        print(f"[train] resumed from step {start_step}")

    watchdog = StragglerWatchdog()
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in loader.next().items()}
        watchdog.start_step()
        params, opt_state, loss = train_step(
            params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(loss)
        rep = watchdog.end_step()
        losses.append(loss)
        if rep.flagged:
            print(f"[watchdog] step {step} slow: {rep.duration_s:.3f}s "
                  f"(ewma {rep.ewma_s:.3f}s)"
                  + (" -> EVICT ADVISED" if rep.evict_advised else ""))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(lr_fn(step)):.2e} "
                  f"({rep.duration_s:.2f}s/step)", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, {"params": params, "opt": opt_state},
                            extra={"loader": loader.state()})
    if ckpt:
        ckpt.wait()
        ckpt.save(args.steps, {"params": params, "opt": opt_state},
                  extra={"loader": loader.state()})
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    print(f"[train] done: loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
