"""Roofline analysis over the dry-run artifacts (single-pod mesh).

Per (arch x shape) cell, from ``results/dryrun/*_single.json``:

  compute term    = HLO_FLOPs / peak_FLOPs            (per chip, seconds)
  memory term     = HLO_bytes / HBM_bw
  collective term = collective_wire_bytes / ICI_bw

``cost_analysis()`` on the SPMD-partitioned module is already per-device;
collective wire bytes come from the HLO parse in dryrun.py (ring cost
model).  The dominant term is the bottleneck; roofline fraction =
compute_term / max(all terms) (how close the cell is to being
compute-bound at peak).  MODEL_FLOPS / HLO_FLOPs flags remat/redundancy
waste (MODEL_FLOPS is the analytic 6*N*D / 2*N*D + attention count).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16, per chip (TPU v5e-class)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def analytic_memory_bytes(rec: dict) -> float:
    """Fusion-aware analytic HBM traffic per device per step (lower bound).

    ``cost_analysis()['bytes accessed']`` counts every HLO op's operands
    unfused (~50x real traffic on fused TPU programs), so the memory term
    used for bottleneck classification comes from this explicit model:

      train:   3x weight reads (fwd + remat-fwd + bwd) + grad r/w +
               f32 moment r/w + layer checkpoints (w + r + recompute w) +
               flash KV re-streaming per q-tile (x2 for bwd) + logits
      prefill: 1x weights + activations + KV streaming + logits
      decode:  1x weights + full KV-cache read + state r/w   (= the
               analytic state bytes, which decode must touch once)
    """
    from repro.configs.base import SHAPES, get_arch
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["chips"]
    tp = 16
    dp = chips // tp
    state = rec.get("analytic_state_bytes_per_device", 0)

    if shape.kind == "decode":
        return float(state)  # one pass over params + KV + state

    params_dev = cfg.param_count() * 2 / tp          # bf16, TP-sharded
    B_loc = max(shape.global_batch // dp, 1)
    S = shape.seq_len
    D = cfg.d_model
    L = cfg.n_layers
    act_ckpt = L * B_loc * S * D * 2                  # bf16 layer carries
    hd = cfg.resolved_head_dim
    nq = max(S // max(cfg.attn_block, 1), 1)
    kv_layer = 2 * B_loc * S * cfg.n_kv_heads * hd * 2 / tp
    if cfg.swa_window:
        nq = max(min(nq, cfg.swa_window // max(cfg.attn_block, 1) + 1), 1)
    logits = B_loc * S * cfg.padded_vocab * 4 / tp

    if shape.kind == "train":
        n_active_dev = cfg.active_param_count() * 2 / tp
        weights = 3 * n_active_dev + 2 * params_dev          # reads + grads
        opt = 2 * 2 * cfg.param_count() * 4 / chips          # mu/nu r+w, ZeRO
        acts = 3 * act_ckpt
        kv = 2 * L * nq * kv_layer
        return weights + opt + acts + kv + 4 * logits
    # prefill
    n_active_dev = cfg.active_param_count() * 2 / tp
    return n_active_dev + act_ckpt + L * nq * kv_layer + logits


def analyze(rec: dict) -> Optional[dict]:
    if rec.get("status") != "OK":
        return None
    ca = rec.get("cost_analysis") or {}
    if not isinstance(ca, dict):
        return None
    corr = rec.get("corrected") or {}
    flops_dev = float(corr.get("flops") or ca.get("flops", 0.0))
    hlo_bytes_dev = float(corr.get("bytes") or ca.get("bytes accessed", 0.0))
    coll = rec.get("collectives", {})
    wire = float(corr.get("wire") or coll.get("total_wire_bytes", 0.0))
    chips = rec["chips"]
    mem_bytes_dev = analytic_memory_bytes(rec)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = mem_bytes_dev / HBM_BW
    memory_s_upper = hlo_bytes_dev / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    model_flops_dev = rec.get("model_flops_global", 0.0) / chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "memory_s_upper": memory_s_upper,
        "collective_s": collective_s,
        "dominant": dominant,
        "roofline_fraction": compute_s / bound_s if bound_s else 0.0,
        "model_flops_dev": model_flops_dev,
        "hlo_flops_dev": flops_dev,
        "useful_flops_ratio": (model_flops_dev / flops_dev
                               if flops_dev else 0.0),
        "state_gib_dev": rec.get("analytic_state_bytes_per_device", 0) / 2**30,
        "loop_corrected": bool(corr),
        "collective_detail": {k: v for k, v in coll.items()
                              if isinstance(v, dict)},
    }


def load_all(results_dir: str, mesh: str = "single") -> List[dict]:
    rows = []
    for f in sorted(Path(results_dir).glob(f"*_{mesh}.json")):
        rec = json.loads(f.read_text())
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def table(rows: List[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'roof%':>6s} {'useful%':>8s} "
           f"{'GiB/dev':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {100*r['roofline_fraction']:6.1f} "
            f"{100*min(r['useful_flops_ratio'], 9.99):8.1f} "
            f"{r['state_gib_dev']:8.2f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = load_all(args.results, args.mesh)
    print(table(rows))
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
