"""JAX-native seeded workload generators: fleet-scale traces that never
materialize in python.

Every generator is a **pure function of ``(spec, seed, host, i)``** — no RNG
state, no wall clock — in the exact idiom of
:mod:`repro.core.faults.plan`: a splitmix64 decision hash with three
bit-equal twins (scalar python int, vectorized numpy ``uint64``, traced
``jnp.uint64``), property-tested against each other.  The jnp twin lets a
sharded fleet replay synthesize each host's trace **on the device that owns
that host's shard**, so million-access multi-tenant traffic costs zero
host->device transfers and zero python per-access objects; the numpy twin
feeds :meth:`repro.data.trace_store.TraceStore.write` for the streaming /
chunked path; the scalar twin is the oracle the tests pin both against.

Four access patterns (CXL-fabric congestion-study staples):

``zipfian``   page rank drawn from a Zipf(s) distribution over the
              footprint via a precomputed float64 CDF + ``searchsorted``
              (page 0 is the hottest) — multi-tenant skew.
``hotspot``   a ``hot_frac`` coin sends the access into the first
              ``hot_pages`` pages, else uniformly into the cold remainder —
              tenant-with-a-hot-set.
``bursty``    on/off modulation over the access index: ON windows hammer
              the hot set, OFF windows stride through the cold footprint —
              bursty tenants that synchronize across hosts when given the
              same phase.
``scan``      periodic sequential sweep ``(i * stride) % footprint`` —
              backup/compaction traffic.

Writes are an independent hash coin against ``write_frac`` and the
sub-page line offset is a third hash stream, so two kinds sharing a seed
still draw independent decisions (per-stream salts, like the fault
classes).  All twins run their integer arithmetic mod 2^64; the jnp twin
needs x64 (use the ``enable_x64()`` scope every replay engine already
opens, or call inside one).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.faults.plan import (_M32, _M64, _rate_threshold, fault_hash,
                                    fault_hash_np)

# per-stream salts: page choice, hotspot gate, line offset and write coin
# draw from independent hash streams under one seed (like the fault classes)
SALT_PAGE = 0x9A6E
SALT_GATE = 0x6A7E
SALT_OFF = 0x0FF5
SALT_WRITE = 0x3717

WORKLOAD_KINDS = ("zipfian", "hotspot", "bursty", "scan")


@dataclass(frozen=True)
class WorkloadSpec:
    """Static shape of one synthetic workload (hashable, so compiled
    generator programs key on it)."""

    kind: str
    num_pages: int                  # footprint, in pages
    page_bytes: int = 4096
    line_offsets: int = 64          # sub-page 64 B line slots drawn per access
    write_frac: float = 0.3
    # zipfian
    zipf_s: float = 1.0             # skew exponent (1.0 = classic Zipf)
    # hotspot / bursty hot set
    hot_frac: float = 0.9           # hotspot: P(access lands in the hot set)
    hot_pages: int = 0              # hot-set size (0 -> num_pages // 16)
    # bursty on/off modulation (over the access index)
    on_len: int = 64
    off_len: int = 192
    cold_stride: int = 17           # OFF-window stride through the footprint
    # scan
    stride_pages: int = 1

    def __post_init__(self):
        if self.kind not in WORKLOAD_KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"choose from {WORKLOAD_KINDS}")
        if self.num_pages < 2:
            raise ValueError("workload needs a footprint of >= 2 pages")
        if not 1 <= self.line_offsets * 64 <= self.page_bytes:
            raise ValueError("line_offsets must fit inside one page")
        hp = self.hot_set_pages
        if self.kind in ("hotspot", "bursty") and not 1 <= hp < self.num_pages:
            raise ValueError(
                f"hot_pages must be in [1, num_pages) (got {hp} of "
                f"{self.num_pages})")
        if self.kind == "bursty" and (self.on_len < 1 or self.off_len < 0):
            raise ValueError("bursty needs on_len >= 1 and off_len >= 0")
        if self.kind == "scan" and self.stride_pages < 1:
            raise ValueError("scan needs stride_pages >= 1")

    @property
    def hot_set_pages(self) -> int:
        return self.hot_pages if self.hot_pages else max(
            1, self.num_pages // 16)


def zipf_cdf(num_pages: int, s: float) -> np.ndarray:
    """Float64 rank CDF of Zipf(s) over ``num_pages`` ranks — the shared
    lookup table every twin searches (identical bits, so ``searchsorted``
    cannot disagree across scalar/numpy/jnp)."""
    w = 1.0 / np.power(np.arange(1, num_pages + 1, dtype=np.float64), s)
    cdf = np.cumsum(w)
    return cdf / cdf[-1]


def _u01(h):
    """Top 53 hash bits as a float64 in [0, 1) — exact in every twin (the
    uint64 -> float64 conversion of a value < 2^53 is lossless and the
    2^-53 scale is a power of two)."""
    return (h >> 11) * (2.0 ** -53)


# ------------------------------------------------------------ scalar twin
def access_at(spec: WorkloadSpec, seed: int, host: int, i: int):
    """The scalar oracle: ``(addr, write)`` of access ``i`` of ``host``."""
    page = _page_scalar(spec, seed, host, i)
    off = fault_hash(seed, SALT_OFF, host, i) % spec.line_offsets
    wr = (fault_hash(seed, SALT_WRITE, host, i) & _M32) \
        < _rate_threshold(spec.write_frac)
    return page * spec.page_bytes + off * 64, bool(wr)


def _page_scalar(spec: WorkloadSpec, seed: int, host: int, i: int) -> int:
    h = fault_hash(seed, SALT_PAGE, host, i)
    if spec.kind == "zipfian":
        cdf = zipf_cdf(spec.num_pages, spec.zipf_s)
        return min(int(np.searchsorted(cdf, _u01(h), side="right")),
                   spec.num_pages - 1)
    if spec.kind == "hotspot":
        hot = (fault_hash(seed, SALT_GATE, host, i) & _M32) \
            < _rate_threshold(spec.hot_frac)
        hp = spec.hot_set_pages
        return h % hp if hot else hp + h % (spec.num_pages - hp)
    if spec.kind == "bursty":
        on = i % (spec.on_len + spec.off_len) < spec.on_len
        return (h % spec.hot_set_pages if on
                else (i * spec.cold_stride) % spec.num_pages)
    return (i * spec.stride_pages) % spec.num_pages          # scan


# ------------------------------------------------------------- numpy twin
def host_trace_np(spec: WorkloadSpec, seed: int, host: int, n: int):
    """``(addrs int64 (n,), writes bool (n,))`` for one host — vectorized
    numpy, bit-equal to :func:`access_at` per element."""
    idx = np.arange(n, dtype=np.int64)
    h = fault_hash_np(seed, SALT_PAGE, host, idx)
    if spec.kind == "zipfian":
        cdf = zipf_cdf(spec.num_pages, spec.zipf_s)
        page = np.minimum(
            np.searchsorted(cdf, _u01(h), side="right"),
            spec.num_pages - 1).astype(np.int64)
    elif spec.kind == "hotspot":
        hot = (fault_hash_np(seed, SALT_GATE, host, idx)
               & np.uint64(_M32)) < np.uint64(_rate_threshold(spec.hot_frac))
        hp = spec.hot_set_pages
        page = np.where(hot, h % np.uint64(hp),
                        np.uint64(hp) + h % np.uint64(spec.num_pages - hp)
                        ).astype(np.int64)
    elif spec.kind == "bursty":
        on = idx % (spec.on_len + spec.off_len) < spec.on_len
        page = np.where(on, (h % np.uint64(spec.hot_set_pages)).astype(
            np.int64), (idx * spec.cold_stride) % spec.num_pages)
    else:                                                    # scan
        page = (idx * spec.stride_pages) % spec.num_pages
    off = (fault_hash_np(seed, SALT_OFF, host, idx)
           % np.uint64(spec.line_offsets)).astype(np.int64)
    wr = (fault_hash_np(seed, SALT_WRITE, host, idx) & np.uint64(_M32)) \
        < np.uint64(_rate_threshold(spec.write_frac))
    return page * spec.page_bytes + off * 64, wr


def traces_np(spec: WorkloadSpec, seed: int, num_hosts: int, n: int):
    """Stacked per-host columns ``(addrs (H, n), writes (H, n))`` — the
    exact input shape of :meth:`MultiHostReplay.run_arrays`."""
    cols = [host_trace_np(spec, seed, h, n) for h in range(num_hosts)]
    return (np.stack([a for a, _ in cols]), np.stack([w for _, w in cols]))


def make_traces(spec: WorkloadSpec, seed: int, num_hosts: int, n: int,
                size: int = 64):
    """Python tuple-list traces for the *interpreted* drivers (golden pins,
    small-scale parity checks) — same accesses as the array twins."""
    addrs, writes = traces_np(spec, seed, num_hosts, n)
    return [[(int(a), size, bool(w)) for a, w in zip(addrs[h], writes[h])]
            for h in range(num_hosts)]


# --------------------------------------------------------------- jnp twin
def _hash_jnp(seed: int, salt: int, host: int, idx):
    """Traced ``fault_hash(seed, salt, host, i)``: the two seed/host-side
    splitmix rounds fold to a python constant at trace time (exactly like
    :func:`repro.core.faults.plan._mix_jnp_scalar`); only the per-index
    round is traced."""
    import jax.numpy as jnp

    from repro.core.faults.plan import _GOLDEN, _MULT1, _MULT2, _mix

    h1 = _mix(_mix((seed + salt) & _M64) ^ (host & _M64))
    x = jnp.uint64(h1) ^ jnp.asarray(idx).astype(jnp.uint64)
    x = x + jnp.uint64(_GOLDEN)
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(_MULT1)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(_MULT2)
    return x ^ (x >> jnp.uint64(31))


def host_trace_jnp(spec: WorkloadSpec, seed: int, host: int, n: int):
    """Traced twin of :func:`host_trace_np` — synthesizes one host's
    ``(addrs, writes)`` entirely on-device (jit-friendly: ``spec``/``n``
    static, output ``(int64 (n,), bool (n,))``).  Needs x64."""
    import jax.numpy as jnp

    idx = jnp.arange(n, dtype=jnp.int64)
    h = _hash_jnp(seed, SALT_PAGE, host, idx)
    if spec.kind == "zipfian":
        cdf = jnp.asarray(zipf_cdf(spec.num_pages, spec.zipf_s))
        page = jnp.minimum(
            jnp.searchsorted(cdf, _u01(h), side="right"),
            spec.num_pages - 1).astype(jnp.int64)
    elif spec.kind == "hotspot":
        hot = (_hash_jnp(seed, SALT_GATE, host, idx)
               & jnp.uint64(_M32)) < jnp.uint64(
                   _rate_threshold(spec.hot_frac))
        hp = spec.hot_set_pages
        page = jnp.where(hot, h % jnp.uint64(hp),
                         jnp.uint64(hp) + h % jnp.uint64(spec.num_pages - hp)
                         ).astype(jnp.int64)
    elif spec.kind == "bursty":
        on = idx % (spec.on_len + spec.off_len) < spec.on_len
        page = jnp.where(on, (h % jnp.uint64(spec.hot_set_pages)).astype(
            jnp.int64), (idx * spec.cold_stride) % spec.num_pages)
    else:                                                    # scan
        page = (idx * spec.stride_pages) % spec.num_pages
    off = (_hash_jnp(seed, SALT_OFF, host, idx)
           % jnp.uint64(spec.line_offsets)).astype(jnp.int64)
    wr = (_hash_jnp(seed, SALT_WRITE, host, idx) & jnp.uint64(_M32)) \
        < jnp.uint64(_rate_threshold(spec.write_frac))
    return page * spec.page_bytes + off * 64, wr
