"""Columnar on-disk trace format for streaming replay.

A ``TraceStore`` is a directory with one ``.npy`` file per access field
plus a small JSON header::

    trace.store/
        header.json     {"format": 1, "n": ..., "size": 64,
                         "max_addr": ..., "columns": {"addr": "int64",
                         "op": "uint8", ...}}
        addr.npy        int64   byte address per access
        op.npy          uint8   1 = write, 0 = read
        tick.npy        int64   optional issue-tick hints
        host.npy        int32   optional originating host index
        route.npy       int32   optional pinned ECMP route choice

Columns are standard ``np.save`` files, so readers open them with
``np.load(mmap_mode="r")`` and never materialize the full trace: slicing
a memmap copies only the requested rows.  ``addr`` and ``op`` are
required; the rest are optional annotations that replay front ends may
consume or ignore.

The header pins the replay-relevant scalars — uniform access ``size``
(validated to stay inside one 64 B line, mirroring
``spec.trace_to_arrays``) and ``max_addr`` — so ``ReplayEngine`` can
size its stack without scanning the address column first.
"""

from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

HEADER = "header.json"
FORMAT = 1
LINE_BYTES = 64


class TraceStoreCorrupt(ValueError):
    """A column file failed integrity validation — truncated, bit-flipped
    (checksum mismatch), or otherwise unreadable.  Typed so streaming
    consumers (:class:`~repro.data.pipeline.Prefetcher` forwards producer
    exceptions) can distinguish data corruption from configuration
    errors."""

#: column name -> required dtype (anything else in the header is rejected)
_COLUMN_DTYPES = {
    "addr": "int64",
    "op": "uint8",
    "tick": "int64",
    "host": "int32",
    "route": "int32",
}
_REQUIRED = ("addr", "op")


class TraceStore:
    """Read-side handle on a columnar trace directory.

    Columns are opened lazily as read-only memmaps and cached; ``slice``
    and ``chunks`` hand out *copies* of the requested window, so the
    caller's working set is O(chunk) regardless of trace length.
    """

    def __init__(self, path) -> None:
        self.path = Path(path)
        hdr_path = self.path / HEADER
        if not hdr_path.is_file():
            raise FileNotFoundError(f"not a TraceStore (no {HEADER}): "
                                    f"{self.path}")
        with open(hdr_path) as fh:
            hdr = json.load(fh)
        if hdr.get("format") != FORMAT:
            raise ValueError(f"unsupported TraceStore format "
                             f"{hdr.get('format')!r} (expected {FORMAT})")
        self._n = int(hdr["n"])
        self._size = int(hdr["size"])
        self._max_addr = int(hdr["max_addr"])
        self._columns: Dict[str, str] = dict(hdr["columns"])
        # optional (absent in stores written before integrity landed):
        # sha256 over each column's full .npy file bytes
        self._checksums: Dict[str, str] = dict(hdr.get("checksums", {}))
        for name in _REQUIRED:
            if name not in self._columns:
                raise ValueError(f"TraceStore missing required column "
                                 f"{name!r}")
        for name, dtype in self._columns.items():
            want = _COLUMN_DTYPES.get(name)
            if want is None:
                raise ValueError(f"unknown TraceStore column {name!r}")
            if dtype != want:
                raise ValueError(f"column {name!r} has dtype {dtype}, "
                                 f"expected {want}")
            if not (self.path / f"{name}.npy").is_file():
                raise FileNotFoundError(f"missing column file {name}.npy "
                                        f"in {self.path}")
        self._mm: Dict[str, np.ndarray] = {}

    # ------------------------------------------------------------ metadata
    @property
    def n(self) -> int:
        """Number of accesses."""
        return self._n

    @property
    def size(self) -> int:
        """Uniform per-access size in bytes."""
        return self._size

    @property
    def max_addr(self) -> int:
        """Largest byte address in the trace (pinned in the header)."""
        return self._max_addr

    @property
    def row_bytes(self) -> int:
        """Bytes per access across the columns ``chunks`` yields."""
        return (np.dtype(np.int64).itemsize
                + np.dtype(np.uint8).itemsize)

    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._columns))

    def __len__(self) -> int:
        return self._n

    # ------------------------------------------------------------- reading
    def column(self, name: str) -> np.ndarray:
        """The full column as a read-only memmap (no copy)."""
        if name not in self._columns:
            raise KeyError(f"TraceStore has no column {name!r}")
        mm = self._mm.get(name)
        if mm is None:
            mm = np.load(self.path / f"{name}.npy", mmap_mode="r")
            self._mm[name] = mm
        return mm

    def writes(self) -> np.ndarray:
        """The full op column as a fresh bool array (one pass, O(n))."""
        return np.asarray(self.column("op")) != 0

    def slice(self, lo: int, hi: int) -> Dict[str, np.ndarray]:
        """Copy rows ``[lo, hi)`` of the replay columns into host arrays."""
        if not 0 <= lo <= hi <= self._n:
            raise IndexError(f"slice [{lo}, {hi}) out of range for "
                             f"n={self._n}")
        return {
            "addr": np.array(self.column("addr")[lo:hi], np.int64),
            "wr": np.array(self.column("op")[lo:hi], np.uint8) != 0,
        }

    def chunks(self, chunk_size: int,
               start: int = 0) -> Iterator[Tuple[int, int, Dict]]:
        """Yield ``(lo, hi, columns)`` windows of at most ``chunk_size``
        rows, in order, beginning at row ``start`` (a chunk-aligned resume
        cursor: a checkpointed run re-enters the stream exactly where the
        snapshot left off).  Each window is an independent copy, safe to
        hand to a prefetch thread."""
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        if not 0 <= start <= self._n:
            raise IndexError(f"start {start} out of range for n={self._n}")
        for lo in range(int(start), self._n, chunk_size):
            hi = min(lo + chunk_size, self._n)
            yield lo, hi, self.slice(lo, hi)

    # ----------------------------------------------------------- integrity
    def validate(self) -> None:
        """Verify every column file against the header: readable as an
        ``.npy``, row count matching ``n``, and (when the header carries
        per-column checksums) byte-exact SHA-256.  Raises
        :class:`TraceStoreCorrupt` naming the first bad column — truncated
        files fail the load/length checks even on stores written before
        checksums landed."""
        for name in sorted(self._columns):
            fpath = self.path / f"{name}.npy"
            try:
                raw = fpath.read_bytes()
            except OSError as exc:
                raise TraceStoreCorrupt(
                    f"column {name!r} unreadable: {exc}") from exc
            digest = self._checksums.get(name)
            if digest is not None:
                got = hashlib.sha256(raw).hexdigest()
                if got != digest:
                    raise TraceStoreCorrupt(
                        f"column {name!r} checksum mismatch "
                        f"(bit-flip or partial write): header pins "
                        f"{digest[:12]}…, file hashes {got[:12]}…")
            try:
                import io
                arr = np.load(io.BytesIO(raw))
            except Exception as exc:
                raise TraceStoreCorrupt(
                    f"column {name!r} is not a readable .npy "
                    f"(truncated?): {exc}") from exc
            if arr.shape != (self._n,):
                raise TraceStoreCorrupt(
                    f"column {name!r} has {arr.shape[0] if arr.ndim else 0} "
                    f"rows, header pins n={self._n} (truncated or "
                    f"mismatched header)")

    # ------------------------------------------------------------- writing
    @classmethod
    def write(cls, path, addrs, writes, *, size: int = 64,
              ticks=None, hosts=None, routes=None) -> "TraceStore":
        """Create a store from in-memory arrays.

        Validation mirrors ``spec.trace_to_arrays``: uniform ``size``
        inside one 64 B line, non-negative addresses — so anything a
        store holds is replayable without re-validation surprises."""
        addrs = np.ascontiguousarray(np.asarray(addrs, np.int64))
        wr = np.ascontiguousarray(
            np.asarray(writes, bool).astype(np.uint8))
        if addrs.ndim != 1 or wr.shape != addrs.shape:
            raise ValueError("addrs and writes must be 1-D and equal "
                             "length")
        if addrs.size == 0:
            raise ValueError("refusing to write an empty TraceStore")
        if size < 1 or int(((addrs % LINE_BYTES) + size).max()) > LINE_BYTES:
            raise ValueError("accesses must stay inside one 64 B line")
        if int(addrs.min()) < 0:
            raise ValueError("negative addresses")

        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        cols: Dict[str, np.ndarray] = {"addr": addrs, "op": wr}
        for name, val in (("tick", ticks), ("host", hosts),
                          ("route", routes)):
            if val is None:
                continue
            arr = np.ascontiguousarray(
                np.asarray(val).astype(_COLUMN_DTYPES[name]))
            if arr.shape != addrs.shape:
                raise ValueError(f"column {name!r} length mismatch")
            cols[name] = arr
        checksums = {}
        for name, arr in cols.items():
            np.save(path / f"{name}.npy", arr)
            checksums[name] = hashlib.sha256(
                (path / f"{name}.npy").read_bytes()).hexdigest()
        header = {
            "format": FORMAT,
            "n": int(addrs.size),
            "size": int(size),
            "max_addr": int(addrs.max()),
            "columns": {name: str(arr.dtype)
                        for name, arr in sorted(cols.items())},
            "checksums": dict(sorted(checksums.items())),
        }
        with open(path / HEADER, "w") as fh:
            json.dump(header, fh, indent=1, sort_keys=True)
            fh.write("\n")
        return cls(path)

    @classmethod
    def from_trace(cls, path, trace, *,
                   hosts=None, routes=None) -> "TraceStore":
        """Create a store from a driver-style ``[(addr, size, write)]``
        trace, reusing the replay layer's validation."""
        from repro.core.replay.spec import trace_to_arrays

        addrs, writes, size = trace_to_arrays(trace)
        return cls.write(path, addrs, writes, size=size,
                         hosts=hosts, routes=routes)
