from repro.data.pipeline import ShardedLoader, make_batch_spec

__all__ = ["ShardedLoader", "make_batch_spec"]
