from repro.data.pipeline import ShardedLoader, make_batch_spec
from repro.data.workloads import (
    WORKLOAD_KINDS,
    WorkloadSpec,
    access_at,
    host_trace_jnp,
    host_trace_np,
    make_traces,
    traces_np,
    zipf_cdf,
)

__all__ = [
    "ShardedLoader",
    "WORKLOAD_KINDS",
    "WorkloadSpec",
    "access_at",
    "host_trace_jnp",
    "host_trace_np",
    "make_batch_spec",
    "make_traces",
    "traces_np",
    "zipf_cdf",
]
