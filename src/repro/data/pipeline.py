"""Data pipeline: deterministic synthetic corpus, sharded per DP rank, with
checkpointable iterator state (preemption-safe restart).

The synthetic corpus is a seeded Markov-ish token stream (not uniform noise:
transition structure gives the model something learnable so the example
training runs show loss going down).  Every (seed, shard, step) triple is
reproducible, so restoring ``{"step": n}`` resumes the exact stream — the
fault-tolerance tests rely on this.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def make_batch_spec(cfg: ArchConfig, seq_len: int, batch: int) -> Dict:
    spec = {"tokens": ((batch, seq_len, cfg.n_codebooks) if cfg.n_codebooks
                       else (batch, seq_len))}
    if cfg.cross_attn_every:
        spec["frontend"] = (batch, cfg.n_frontend_tokens, cfg.d_model)
    return spec


class ShardedLoader:
    """Per-DP-rank loader.  ``state()``/``restore()`` capture the cursor."""

    def __init__(self, cfg: ArchConfig, seq_len: int, per_shard_batch: int,
                 shard: int = 0, num_shards: int = 1, seed: int = 1234) -> None:
        self.cfg = cfg
        self.seq_len = seq_len
        self.batch = per_shard_batch
        self.shard = shard
        self.num_shards = num_shards
        self.seed = seed
        self._step = 0
        # fixed Markov transition table (shared across shards)
        rng = np.random.default_rng(seed)
        self._n_states = 64
        v = min(cfg.vocab, 1 << 15)
        self._emit = rng.integers(0, v, size=(self._n_states, 8))
        self._trans = rng.integers(0, self._n_states, size=(self._n_states, 8))

    # ------------------------------------------------------------- batches
    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        # Vectorized Markov walk, byte-identical to the original
        # per-element loop (the rng draw order — one state draw, then one
        # batched choice draw — is part of the contract).  Each choice c
        # induces a state map s -> trans[s, c]; the state *before* step i
        # is the composition of the first i maps applied to the start
        # state, computed in O(log n) doubling passes over (n, 64) maps.
        flat = int(np.prod(shape))
        state = int(rng.integers(0, self._n_states))
        choices = rng.integers(0, 8, size=flat)
        if flat == 0:
            return np.empty(shape, np.int32)
        states = np.empty(flat, np.intp)
        states[0] = state
        if flat > 1:
            # maps[i] = the map applied after emitting token i
            # (state_{i+1} = maps[i][state_i]); inclusive prefix compose.
            maps = self._trans.T[choices[:-1]]
            d = 1
            while d < maps.shape[0]:
                maps[d:] = np.take_along_axis(maps[d:], maps[:-d], axis=1)
                d *= 2
            states[1:] = maps[:, state]
        out = self._emit[states, choices].astype(np.int32)
        return out.reshape(shape)

    def next(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, self.shard, self._step, 0xD00D))
        self._step += 1
        batch = {"tokens": self._tokens(
            rng, make_batch_spec(self.cfg, self.seq_len, self.batch)["tokens"])}
        if self.cfg.cross_attn_every:
            batch["frontend"] = rng.standard_normal(
                (self.batch, self.cfg.n_frontend_tokens, self.cfg.d_model)
            ).astype(np.float32) * 0.1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # --------------------------------------------------------------- state
    def state(self) -> Dict:
        return {"step": self._step, "shard": self.shard, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        if state.get("seed", self.seed) != self.seed:
            raise ValueError("restoring loader with a different seed")
        self._step = int(state["step"])
        self.shard = int(state.get("shard", self.shard))


_DONE = object()


class Prefetcher:
    """Double-buffered background prefetch over any iterator.

    A producer thread pulls items from ``it`` into a bounded queue of
    ``depth`` slots, so the consumer (e.g. a replay chunk loop) overlaps
    the next window's disk read with the current window's compute while
    holding at most ``depth + 1`` items alive — the streaming-replay
    memory bound.  Producer exceptions are re-raised in the consumer at
    the point of ``next()``; ``close()`` stops the producer and drains
    the queue (safe to call twice, and from ``finally``).
    """

    def __init__(self, it, depth: int = 2) -> None:
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._err: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._buffered = 0
        self.peak_buffered_bytes = 0
        self._thread = threading.Thread(
            target=self._produce, args=(iter(it),), daemon=True)
        self._thread.start()

    @staticmethod
    def _nbytes(item) -> int:
        if isinstance(item, np.ndarray):
            return int(item.nbytes)
        if isinstance(item, dict):
            return sum(Prefetcher._nbytes(v) for v in item.values())
        if isinstance(item, (tuple, list)):
            return sum(Prefetcher._nbytes(v) for v in item)
        return 0

    def _produce(self, it) -> None:
        try:
            for item in it:
                if self._stop.is_set():
                    return
                nb = self._nbytes(item)
                with self._lock:
                    self._buffered += nb
                    self.peak_buffered_bytes = max(
                        self.peak_buffered_bytes, self._buffered)
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                else:
                    return
        except BaseException as exc:  # forwarded to the consumer
            self._err = exc
        finally:
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    return
                except queue.Full:
                    continue

    def __iter__(self) -> "Prefetcher":
        return self

    def __next__(self):
        item = self._q.get()
        if item is _DONE:
            self._q.put(_DONE)  # keep exhaustion idempotent
            if self._err is not None:
                err, self._err = self._err, None
                raise err
            raise StopIteration
        with self._lock:
            self._buffered -= self._nbytes(item)
        return item

    def close(self) -> None:
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)
