"""Data pipeline: deterministic synthetic corpus, sharded per DP rank, with
checkpointable iterator state (preemption-safe restart).

The synthetic corpus is a seeded Markov-ish token stream (not uniform noise:
transition structure gives the model something learnable so the example
training runs show loss going down).  Every (seed, shard, step) triple is
reproducible, so restoring ``{"step": n}`` resumes the exact stream — the
fault-tolerance tests rely on this.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


def make_batch_spec(cfg: ArchConfig, seq_len: int, batch: int) -> Dict:
    spec = {"tokens": ((batch, seq_len, cfg.n_codebooks) if cfg.n_codebooks
                       else (batch, seq_len))}
    if cfg.cross_attn_every:
        spec["frontend"] = (batch, cfg.n_frontend_tokens, cfg.d_model)
    return spec


class ShardedLoader:
    """Per-DP-rank loader.  ``state()``/``restore()`` capture the cursor."""

    def __init__(self, cfg: ArchConfig, seq_len: int, per_shard_batch: int,
                 shard: int = 0, num_shards: int = 1, seed: int = 1234) -> None:
        self.cfg = cfg
        self.seq_len = seq_len
        self.batch = per_shard_batch
        self.shard = shard
        self.num_shards = num_shards
        self.seed = seed
        self._step = 0
        # fixed Markov transition table (shared across shards)
        rng = np.random.default_rng(seed)
        self._n_states = 64
        v = min(cfg.vocab, 1 << 15)
        self._emit = rng.integers(0, v, size=(self._n_states, 8))
        self._trans = rng.integers(0, self._n_states, size=(self._n_states, 8))

    # ------------------------------------------------------------- batches
    def _tokens(self, rng: np.random.Generator, shape) -> np.ndarray:
        flat = int(np.prod(shape))
        state = int(rng.integers(0, self._n_states))
        choices = rng.integers(0, 8, size=flat)
        out = np.empty(flat, np.int32)
        for i in range(flat):
            out[i] = self._emit[state, choices[i]]
            state = self._trans[state, choices[i]]
        return out.reshape(shape)

    def next(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, self.shard, self._step, 0xD00D))
        self._step += 1
        batch = {"tokens": self._tokens(
            rng, make_batch_spec(self.cfg, self.seq_len, self.batch)["tokens"])}
        if self.cfg.cross_attn_every:
            batch["frontend"] = rng.standard_normal(
                (self.batch, self.cfg.n_frontend_tokens, self.cfg.d_model)
            ).astype(np.float32) * 0.1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()

    # --------------------------------------------------------------- state
    def state(self) -> Dict:
        return {"step": self._step, "shard": self.shard, "seed": self.seed}

    def restore(self, state: Dict) -> None:
        if state.get("seed", self.seed) != self.seed:
            raise ValueError("restoring loader with a different seed")
        self._step = int(state["step"])
        self.shard = int(state.get("shard", self.shard))
