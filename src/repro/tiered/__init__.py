from repro.tiered.store import TieredStore, TieredStoreConfig

__all__ = ["TieredStore", "TieredStoreConfig"]
