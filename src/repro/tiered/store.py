"""TieredStore — the paper's DRAM-cache-over-CXL-SSD, realized for TPU
serving: an HBM page pool in front of a large capacity tier.

This is the load-bearing reuse of the reproduction: the *same* replacement
policies that run inside the CXL-SSD-Sim DRAM cache
(:mod:`repro.core.cache.policies` — Direct/LRU/FIFO/2Q/LFRU) manage HBM
residency of model pages:

  * KV pages of long-context decode (a "page" = one ring-buffer segment's
    tokens for one layer), evicted from HBM when cold, kept in the capacity
    tier for re-prefill;
  * MoE expert weights (kimi-k2: 384 experts x 61 layers — ~2 TB in bf16 —
    against ~16 GB of HBM per chip).

The capacity tier is host memory here; on a real deployment it is the
CXL-attached SSD the paper simulates.  When a ``backing device`` from
:mod:`repro.core.devices` is attached, every miss/writeback also advances a
*simulated* device clock, so experiments report both real hit-rates and the
simulated CXL-SSD time the cache layer saved — tying the serving runtime
back to the paper's Figs. 3-6.

Duplicate in-flight fetches within one request batch are coalesced
(the MSHR analogue).  HBM-side page movement uses the Pallas
``page_gather``/``page_scatter`` kernels.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache.policies import CachePolicy, make_policy
from repro.core.devices import MemDevice
from repro.core.engine import to_us
from repro.kernels.ops import page_gather_op, page_scatter_op


@dataclass
class TieredStoreConfig:
    n_logical_pages: int
    page_shape: Tuple[int, ...]
    hbm_pages: int
    policy: str = "lru"
    dtype: str = "float32"
    writeback: bool = True          # dirty pages flush to the capacity tier


class TieredStore:
    def __init__(self, cfg: TieredStoreConfig,
                 backing: Optional[MemDevice] = None) -> None:
        if cfg.hbm_pages < 1:
            raise ValueError("need at least one HBM page")
        self.cfg = cfg
        dtype = jnp.dtype(cfg.dtype)
        self.page_elems = int(np.prod(cfg.page_shape))
        self.page_bytes = self.page_elems * dtype.itemsize
        # capacity tier ("CXL-SSD"): host numpy
        self._capacity = np.zeros((cfg.n_logical_pages,) + tuple(cfg.page_shape),
                                  dtype)
        # HBM pool + mapping
        self.pool = jnp.zeros((cfg.hbm_pages,) + tuple(cfg.page_shape), dtype)
        self.policy: CachePolicy = make_policy(cfg.policy, cfg.hbm_pages)
        self._slot_of: Dict[int, int] = {}
        self._free_slots: List[int] = list(range(cfg.hbm_pages))
        self.backing = backing
        self.sim_ticks = 0            # simulated capacity-tier clock
        self.stats = {"reads": 0, "hits": 0, "misses": 0, "coalesced": 0,
                      "fills": 0, "writebacks": 0,
                      "bytes_in": 0, "bytes_out": 0}

    # ------------------------------------------------------------ internals
    def _sim_access(self, lpn: int, write: bool) -> None:
        if self.backing is not None:
            self.sim_ticks = max(self.sim_ticks, self.backing.service(
                self.sim_ticks, lpn * self.page_bytes, self.page_bytes, write))

    def _evict_for(self, lpn: int, dirty: bool) -> int:
        """Insert lpn into the policy; return the HBM slot it may use."""
        ev = self.policy.insert(lpn, dirty=dirty)
        if ev is not None:
            slot = self._slot_of.pop(ev.page)
            if ev.dirty and self.cfg.writeback:
                # flush the evicted page back to the capacity tier
                self._capacity[ev.page] = np.asarray(self.pool[slot])
                self._sim_access(ev.page, write=True)
                self.stats["writebacks"] += 1
                self.stats["bytes_out"] += self.page_bytes
        else:
            slot = self._free_slots.pop()
        return slot

    # ------------------------------------------------------------------ api
    def write_page(self, lpn: int, data: np.ndarray, through: bool = False) -> None:
        """Store a page into the capacity tier (e.g. an evicted KV segment
        or an expert's weights).  ``through=True`` also caches it in HBM."""
        self._capacity[lpn] = np.asarray(data, self._capacity.dtype)
        self._sim_access(lpn, write=True)
        if through:
            self.ensure_resident([lpn], dirty=False)

    def ensure_resident(self, lpns: Sequence[int], dirty: bool = False
                        ) -> jnp.ndarray:
        """Make pages HBM-resident; returns their pool slots (int32 array).

        Duplicates within the request are coalesced (MSHR analogue): a page
        is fetched from the capacity tier at most once.
        """
        slots = np.zeros(len(lpns), np.int32)
        seen: Dict[int, int] = {}
        fill_slots: List[int] = []
        fill_pages: List[np.ndarray] = []
        for i, lpn in enumerate(lpns):
            lpn = int(lpn)
            self.stats["reads"] += 1
            if lpn in seen:
                self.stats["coalesced"] += 1
                slots[i] = seen[lpn]
                continue
            if self.policy.lookup(lpn):
                self.stats["hits"] += 1
                self.policy.touch(lpn, dirty=dirty)
                slot = self._slot_of[lpn]
            else:
                self.stats["misses"] += 1
                self.stats["fills"] += 1
                self.stats["bytes_in"] += self.page_bytes
                self._sim_access(lpn, write=False)
                slot = self._evict_for(lpn, dirty)
                self._slot_of[lpn] = slot
                fill_slots.append(slot)
                fill_pages.append(self._capacity[lpn])
            seen[lpn] = slot
            slots[i] = slot
        if fill_slots:
            pages = jnp.asarray(np.stack(fill_pages))
            self.pool = page_scatter_op(self.pool,
                                        jnp.asarray(fill_slots, jnp.int32),
                                        pages)
        return jnp.asarray(slots)

    def read_pages(self, lpns: Sequence[int]) -> jnp.ndarray:
        """Resident-or-fetched gather: returns (n, *page_shape) from HBM."""
        slots = self.ensure_resident(lpns)
        return page_gather_op(self.pool, slots)

    def update_page(self, lpn: int, data: jnp.ndarray) -> None:
        """Write-back update of a resident page (dirty bit set)."""
        slots = self.ensure_resident([lpn], dirty=True)
        self.pool = page_scatter_op(self.pool, slots,
                                    jnp.asarray(data)[None])
        self.policy.touch(int(lpn), dirty=True)

    def flush(self) -> None:
        for lpn in sorted(self.policy.resident_pages()):
            if self.policy.is_dirty(lpn):
                slot = self._slot_of[lpn]
                self._capacity[lpn] = np.asarray(self.pool[slot])
                self._sim_access(lpn, write=True)
                self.stats["writebacks"] += 1

    # ------------------------------------------------------------- metrics
    @property
    def hit_rate(self) -> float:
        tot = self.stats["hits"] + self.stats["misses"]
        return self.stats["hits"] / tot if tot else 0.0

    @property
    def sim_time_us(self) -> float:
        """Simulated capacity-tier (CXL-SSD) time spent on misses/flushes."""
        return to_us(self.sim_ticks)

    def capacity_page(self, lpn: int) -> np.ndarray:
        return self._capacity[lpn]
