# Model zoo: layers, moe, ssm, transformer (top-level dispatch).
