"""Core transformer layers in pure JAX: RMSNorm, RoPE, GQA attention with
chunked (flash-style) online softmax, SWA masking, SwiGLU MLP.

Attention is written as a double ``lax.scan`` over query/key blocks so the
HLO stays O(1) in sequence length and peak memory stays
O(q_block x kv_block) — the property the multi-pod dry-run needs at 32 k
context.  The Pallas kernel in :mod:`repro.kernels.flash_attention` is the
TPU performance path; this is the reference/fallback used by default in the
pure-JAX model (numerics validated against each other in tests).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dtype)


# -------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def _block_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int) -> jnp.ndarray:
    """(q_block, kv_block) causal (+ optional sliding-window) mask."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        causal &= q_pos[:, None] - k_pos[None, :] < window
    return causal


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    q_block: int = 256, kv_block: int = 256,
                    impl: str = "masked") -> jnp.ndarray:
    """Chunked attention with online softmax and an O(S*d)-residual custom
    VJP (see repro.models.flash_vjp) — the differentiable production path.
    ``impl='triangular'`` skips causally-unreachable block pairs."""
    from repro.models.flash_vjp import flash_attention_tri, flash_attention_vjp
    if impl == "triangular" and causal:
        return flash_attention_tri(q, k, v, causal, window, q_block, kv_block)
    return flash_attention_vjp(q, k, v, causal, window, q_block, kv_block)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                             "kv_block"))
def flash_attention_scan(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, window: int = 0,
                         q_block: int = 256, kv_block: int = 256) -> jnp.ndarray:
    """Chunked attention with online softmax (autodiff-naive variant kept as
    a cross-check oracle; backward stashes per-block scores).

    q: (B, S, H, hd);  k, v: (B, S, KV, hd) with H % KV == 0 (GQA).
    Returns (B, S, H, hd).  Peak memory O(B*H*q_block*kv_block).
    """
    B, S, H, hd = q.shape
    Skv = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5

    q_block = min(q_block, S)
    kv_block = min(kv_block, Skv)
    pad_q = (-S) % q_block
    pad_k = (-Skv) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq, Sk = S + pad_q, Skv + pad_k
    nq, nk = Sq // q_block, Sk // kv_block

    # (nq, B, KV, G, qb, hd)
    qb = q.reshape(B, nq, q_block, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    # (nk, B, KV, kb, hd)
    kb = k.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_block, KV, hd).transpose(1, 0, 3, 2, 4)

    def outer(_, qi):
        qblk, qidx = qi                                  # (B,KV,G,qb,hd), scalar
        q_pos = qidx * q_block + jnp.arange(q_block)

        def inner(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            if causal:
                mask = _block_mask(q_pos, k_pos, window)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            # padding keys masked out
            s = jnp.where((k_pos < Skv)[None, None, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0), (kb, vb, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return None, out

    _, ob = jax.lax.scan(outer, None, (qb, jnp.arange(nq)))
    # (nq, B, KV, G, qb, hd) -> (B, S, H, hd)
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)[:, :S]
    return out.astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, window=0):
    """O(S^2)-memory reference attention (tests / tiny shapes only)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        pos = jnp.arange(S)
        mask = _block_mask(pos, pos, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cur_len, *, window: int = 0):
    """Single-token decode attention against a (possibly padded) KV cache.

    q: (B, H, hd); k_cache/v_cache: (B, Smax, KV, hd); cur_len: () or (B,)
    int32 — number of valid cache entries (the new token's KV must already
    be written at index cur_len-1).  Returns (B, H, hd).
    """
    B, Smax, KV, hd = k_cache.shape
    H = q.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bckd->bkgc", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    pos = jnp.arange(Smax)
    cur = jnp.asarray(cur_len, jnp.int32)
    if cur.ndim == 0:
        cur = jnp.full((B,), cur)
    valid = pos[None, :] < cur[:, None]
    if window > 0:
        valid &= pos[None, :] >= cur[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


# --------------------------------------------------------------------- mlp
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ------------------------------------------------------------------ embeds
def embed_tokens(table: jnp.ndarray, token_ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, token_ids, axis=0)


def embed_codebooks(tables: jnp.ndarray, token_grid: jnp.ndarray) -> jnp.ndarray:
    """MusicGen-style: tables (nq, V, D), token_grid (B, S, nq) -> summed."""
    nq = tables.shape[0]
    embs = [jnp.take(tables[i], token_grid[..., i], axis=0) for i in range(nq)]
    return functools.reduce(jnp.add, embs)
