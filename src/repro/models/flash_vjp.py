"""Flash attention with a custom VJP — O(S·d) residuals.

Without this, differentiating the double-scan attention stashes every
per-block f32 score matrix (the full S x S attention matrix): ~39 GB/device
for a 4k x 16-batch minicpm layer.  The custom VJP saves only
``(q, k, v, out, lse)`` and recomputes score blocks inside the backward
scans — the standard flash-attention backward, here in pure JAX so it works
under pjit/GSPMD on any mesh (the Pallas forward kernel shares its numerics).

Layout mirrors :func:`repro.models.layers.flash_attention`:
q (B, S, H, hd); k, v (B, Skv, KV, hd); GQA via H = KV * G.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masks(q_pos, k_pos, Skv, causal, window):
    m = (k_pos < Skv)[None, :]
    if causal:
        c = q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            c &= q_pos[:, None] - k_pos[None, :] < window
        m = m & c
    return m


def _fwd_scan(q, k, v, causal, window, qb, kb, Skv):
    """Returns (out, lse) with out (nq,B,KV,G,qb,hd), lse (nq,B,KV,G,qb)."""
    nq = q.shape[0]
    nk = k.shape[0]
    B, KV, G, _, hd = q.shape[1:]
    scale = hd ** -0.5

    def outer(_, qi):
        qblk, qidx = qi
        q_pos = qidx * qb + jnp.arange(qb)

        def inner(carry, ki):
            m, l, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kb + jnp.arange(kb)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk) * scale
            s = jnp.where(_masks(q_pos, k_pos, Skv, causal, window)
                          [None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0),
                                      (k, v, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-37))
        return None, (out, lse)

    _, (out, lse) = jax.lax.scan(outer, None, (q, jnp.arange(nq)))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_vjp(q, k, v, causal: bool = True, window: int = 0,
                        q_block: int = 256, kv_block: int = 256):
    out, _ = _flash_fwd(q, k, v, causal, window, q_block, kv_block)
    return out


# --------------------------------------------------------------------------
# Triangular variant: iterate only the (q-block, kv-block) pairs the causal
# (+ sliding-window) mask can reach, instead of masking a full nq x nk grid.
# Halves causal attention FLOPs; makes SWA attention O(S * window).  The
# pair list is static (host-computed); one scan runs over it with the
# per-q-block (m, l, acc) stats as a full-size carry updated by
# dynamic-slice.  See EXPERIMENTS.md §Perf (hillclimb #1).
# --------------------------------------------------------------------------
def _valid_pairs(nq: int, nk: int, qb: int, kb: int, causal: bool,
                 window: int, S: int, Skv: int):
    import numpy as _np
    pairs = []
    for qi in range(nq):
        q_lo, q_hi = qi * qb, min(qi * qb + qb - 1, S - 1)
        for ki in range(nk):
            k_lo, k_hi = ki * kb, ki * kb + kb - 1
            if k_lo >= Skv:
                continue
            if causal and k_lo > q_hi:
                continue                    # fully above the diagonal
            if causal and window > 0 and k_hi < q_lo - window + 1:
                continue                    # fully outside the window
            pairs.append((qi, ki))
    arr = _np.asarray(pairs, _np.int32)
    return arr[:, 0], arr[:, 1]


def _tri_fwd_scan(q, k, v, causal, window, qb, kb, S, Skv):
    nq, nk = q.shape[0], k.shape[0]
    B, KV, G, _, hd = q.shape[1:]
    scale = hd ** -0.5
    qi_arr, ki_arr = _valid_pairs(nq, nk, qb, kb, causal, window, S, Skv)

    def step(carry, pair):
        m, l, acc = carry                      # (nq, B,KV,G,qb[,hd])
        qi, ki = pair
        qblk = jax.lax.dynamic_index_in_dim(q, qi, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(k, ki, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(v, ki, 0, keepdims=False)
        q_pos = qi * qb + jnp.arange(qb)
        k_pos = ki * kb + jnp.arange(kb)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk) * scale
        s = jnp.where(_masks(q_pos, k_pos, Skv, causal, window)
                      [None, None, None], s, NEG_INF)
        mi = jax.lax.dynamic_index_in_dim(m, qi, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, qi, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, qi, 0, keepdims=False)
        m_new = jnp.maximum(mi, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + p.sum(-1)
        a_new = ai * corr[..., None] + jnp.einsum("bkgqc,bkcd->bkgqd", p, vblk)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 0)
        return (m, l, acc), None

    m0 = jnp.full((nq, B, KV, G, qb), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, B, KV, G, qb), jnp.float32)
    a0 = jnp.zeros((nq, B, KV, G, qb, hd), jnp.float32)
    if len(qi_arr) <= 64:
        # unrolled: every block pair appears explicitly in the HLO, so the
        # dry-run probe compiles count triangular FLOPs exactly
        carry = (m0, l0, a0)
        for qi, ki in zip(qi_arr.tolist(), ki_arr.tolist()):
            carry, _ = step(carry, (jnp.int32(qi), jnp.int32(ki)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (jnp.asarray(qi_arr), jnp.asarray(ki_arr)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-37))
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_tri(q, k, v, causal: bool = True, window: int = 0,
                        q_block: int = 256, kv_block: int = 256):
    out, _ = _tri_fwd(q, k, v, causal, window, q_block, kv_block)
    return out


def _tri_fwd(q, k, v, causal, window, q_block, kv_block):
    qf, kf, vf, dims = _prep(q, k, v, q_block, kv_block)
    B, S, Skv, H, KV, G, hd, qb, kb, nq, nk = dims
    out_b, lse_b = _tri_fwd_scan(qf, kf, vf, causal, window, qb, kb, S, Skv)
    out = out_b.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, hd)[:, :S]
    return out.astype(q.dtype), (q, k, v, out_b, lse_b)


def _tri_bwd(causal, window, q_block, kv_block, res, dout):
    q, k, v, out_b, lse_b = res
    qf, kf, vf, dims = _prep(q, k, v, q_block, kv_block)
    B, S, Skv, H, KV, G, hd, qb, kb, nq, nk = dims
    scale = hd ** -0.5
    pad_q = nq * qb - S
    dof = jnp.pad(dout, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else dout
    dof = dof.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5) \
             .astype(jnp.float32)
    delta = jnp.sum(dof * out_b, axis=-1)
    qi_arr, ki_arr = _valid_pairs(nq, nk, qb, kb, causal, window, S, Skv)

    def step(carry, pair):
        dq, dk, dv = carry
        qi, ki = pair
        qblk = jax.lax.dynamic_index_in_dim(qf, qi, 0, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kf, ki, 0, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vf, ki, 0, keepdims=False)
        doblk = jax.lax.dynamic_index_in_dim(dof, qi, 0, keepdims=False)
        lseblk = jax.lax.dynamic_index_in_dim(lse_b, qi, 0, keepdims=False)
        dblk = jax.lax.dynamic_index_in_dim(delta, qi, 0, keepdims=False)
        q_pos = qi * qb + jnp.arange(qb)
        k_pos = ki * kb + jnp.arange(kb)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk) * scale
        mask = _masks(q_pos, k_pos, Skv, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lseblk[..., None])
        dvi = jnp.einsum("bkgqc,bkgqd->bkcd", p, doblk)
        dp = jnp.einsum("bkgqd,bkcd->bkgqc", doblk, vblk)
        ds = p * (dp - dblk[..., None]) * scale
        dki = jnp.einsum("bkgqc,bkgqd->bkcd", ds, qblk)
        dqi = jnp.einsum("bkgqc,bkcd->bkgqd", ds, kblk)
        dq = dq.at[qi].add(dqi)
        dk = dk.at[ki].add(dki)
        dv = dv.at[ki].add(dvi)
        return (dq, dk, dv), None

    dq0 = jnp.zeros_like(qf)
    dk0 = jnp.zeros((nk, B, KV, kb, hd), jnp.float32)
    dv0 = jnp.zeros((nk, B, KV, kb, hd), jnp.float32)
    if len(qi_arr) <= 64:
        carry = (dq0, dk0, dv0)
        for qi, ki in zip(qi_arr.tolist(), ki_arr.tolist()):
            carry, _ = step(carry, (jnp.int32(qi), jnp.int32(ki)))
        dq, dk, dv = carry
    else:
        (dq, dk, dv), _ = jax.lax.scan(
            step, (dq0, dk0, dv0), (jnp.asarray(qi_arr), jnp.asarray(ki_arr)))

    dq = dq.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, hd)[:, :S]
    dk = dk.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, KV, hd)[:, :Skv]
    dv = dv.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, KV, hd)[:, :Skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention_tri.defvjp(_tri_fwd, _tri_bwd)


def _prep(q, k, v, qb, kb):
    B, S, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    qb = min(qb, S)
    kb = min(kb, Skv)
    pad_q = (-S) % qb
    pad_k = (-Skv) % kb
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = (S + pad_q) // qb, (Skv + pad_k) // kb
    qf = qp.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5) \
           .astype(jnp.float32)
    kf = kp.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4) \
           .astype(jnp.float32)
    vf = vp.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4) \
           .astype(jnp.float32)
    return qf, kf, vf, (B, S, Skv, H, KV, G, hd, qb, kb, nq, nk)


def _flash_fwd(q, k, v, causal, window, q_block, kv_block):
    qf, kf, vf, dims = _prep(q, k, v, q_block, kv_block)
    B, S, Skv, H, KV, G, hd, qb, kb, nq, nk = dims
    out_b, lse_b = _fwd_scan(qf, kf, vf, causal, window, qb, kb, Skv)
    out = out_b.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, hd)[:, :S]
    return out.astype(q.dtype), (q, k, v, out_b, lse_b)


def _flash_bwd(causal, window, q_block, kv_block, res, dout):
    q, k, v, out_b, lse_b = res
    qf, kf, vf, dims = _prep(q, k, v, q_block, kv_block)
    B, S, Skv, H, KV, G, hd, qb, kb, nq, nk = dims
    scale = hd ** -0.5
    pad_q = nq * qb - S
    dof = jnp.pad(dout, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else dout
    dof = dof.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5) \
             .astype(jnp.float32)
    # delta_i = sum_d dout_i * out_i
    delta = jnp.sum(dof * out_b, axis=-1)              # (nq,B,KV,G,qb)

    def kv_step(dq_acc, ki):
        kblk, vblk, kidx = ki
        k_pos = kidx * kb + jnp.arange(kb)

        def q_step(carry, qi):
            dkb, dvb = carry
            qblk, doblk, lseblk, dblk, dqblk, qidx = qi
            q_pos = qidx * qb + jnp.arange(qb)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk) * scale
            mask = _masks(q_pos, k_pos, Skv, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lseblk[..., None])         # (B,KV,G,qb,kb)
            dvb = dvb + jnp.einsum("bkgqc,bkgqd->bkcd", p, doblk)
            dp = jnp.einsum("bkgqd,bkcd->bkgqc", doblk, vblk)
            ds = p * (dp - dblk[..., None]) * scale
            dkb = dkb + jnp.einsum("bkgqc,bkgqd->bkcd", ds, qblk)
            dqblk = dqblk + jnp.einsum("bkgqc,bkcd->bkgqd", ds, kblk)
            return (dkb, dvb), dqblk

        dk0 = jnp.zeros((B, KV, kb, hd), jnp.float32)
        dv0 = jnp.zeros((B, KV, kb, hd), jnp.float32)
        (dkb, dvb), dq_acc = jax.lax.scan(
            q_step, (dk0, dv0),
            (qf, dof, lse_b, delta, dq_acc, jnp.arange(nq)))
        return dq_acc, (dkb, dvb)

    dq0 = jnp.zeros_like(qf)
    dq, (dk, dv) = jax.lax.scan(kv_step, dq0, (kf, vf, jnp.arange(nk)))

    dq = dq.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * qb, H, hd)[:, :S]
    dk = dk.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, KV, hd)[:, :Skv]
    dv = dv.transpose(1, 0, 3, 2, 4).reshape(B, nk * kb, KV, hd)[:, :Skv]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention_vjp.defvjp(_flash_fwd, _flash_bwd)
