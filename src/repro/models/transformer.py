"""Top-level model: init / forward / decode for all six architecture
families (dense, moe, ssm, hybrid, vlm, audio).

Layer parameters are STACKED along a leading ``n_layers`` axis and the
forward pass is a ``jax.lax.scan`` over that axis, so the lowered HLO is
O(1) in depth — a hard requirement for compiling 100-layer 90 B configs on
this machine and for keeping dry-run compile times sane.  VLM models scan
over *super-blocks* (``cross_attn_every`` self layers + 1 cross layer) to
stay homogeneous.

The KV cache for decode is a ring buffer of ``min(context, window)`` slots:
sliding-window archs therefore hold O(window) KV in HBM while the full
history lives in the tiered store (the paper's DRAM-cache-over-SSD pattern;
see repro.tiered).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.moe import MoEParams, init_moe_params, moe_ffn_local, moe_ffn_sharded
from repro.models.ssm import (SSMParams, SSMState, init_ssm_params,
                              init_ssm_state, ssd_decode_step, ssd_forward)


@dataclass(frozen=True)
class MeshCtx:
    """Distribution context for shard_map islands (None => single device)."""
    mesh: Any
    dp_axes: Tuple[str, ...]
    tp_axis: str
    # long-context decode with tiny batch: replicate batch over dp, shard
    # only the KV sequence axis over tp
    batch_replicated: bool = False
    # decode-time MoE layout: expert weights resident (tp x dp sharded),
    # tokens gathered — see repro.models.moe.moe_ffn_sharded
    resident_experts: bool = False


# ----------------------------------------------------------------- init
def _init_attn(key, cfg: ArchConfig, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(kq, (d, cfg.n_heads * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, cfg.n_kv_heads * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (cfg.n_heads * hd, d))
               * (cfg.n_heads * hd) ** -0.5).astype(dtype),
    }


def _init_mlp(key, cfg: ArchConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(kg, (d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ku, (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(kd, (f, d)) * f ** -0.5).astype(dtype),
    }


def _init_block(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    blk: Dict[str, Any] = {}
    if cfg.family == "ssm":
        blk["ln1"] = jnp.ones((d,), dtype)
        blk["ssm"] = init_ssm_params(key, d, cfg.ssm, dtype)
        return blk
    k1, k2, k3 = jax.random.split(key, 3)
    blk["ln1"] = jnp.ones((d,), dtype)
    blk["ln2"] = jnp.ones((d,), dtype)
    blk.update(_init_attn(k1, cfg, dtype))
    if cfg.family == "hybrid":
        blk["ssm"] = init_ssm_params(k3, d, cfg.ssm, dtype)
        blk["norm_attn"] = jnp.ones((d,), dtype)
        blk["norm_ssm"] = jnp.ones((d,), dtype)
    if cfg.moe is not None:
        blk["moe"] = init_moe_params(k2, d, cfg.moe, dtype)
    elif cfg.d_ff:
        blk.update(_init_mlp(k2, cfg, dtype))
    return blk


def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.padded_vocab
    ke, kl, kh, kc = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    if cfg.n_codebooks:
        params["embed"] = (jax.random.normal(
            ke, (cfg.n_codebooks, V, d)) * 0.02).astype(dtype)
        params["lm_head"] = (jax.random.normal(
            kh, (cfg.n_codebooks, d, V)) * d ** -0.5).astype(dtype)
    else:
        params["embed"] = (jax.random.normal(ke, (V, d)) * 0.02).astype(dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = (jax.random.normal(kh, (d, V)) * d ** -0.5).astype(dtype)
    params["final_norm"] = jnp.ones((d,), dtype)

    layer_keys = jax.random.split(kl, cfg.n_layers)
    params["blocks"] = jax.vmap(
        lambda k: _init_block(k, cfg, dtype))(layer_keys)

    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        cross_keys = jax.random.split(kc, n_cross)

        def _init_cross(k):
            blk = _init_attn(k, cfg, dtype)
            blk["ln"] = jnp.ones((d,), dtype)
            blk["gate"] = jnp.zeros((1,), dtype)  # gated cross-attn (llama3.2)
            return blk

        params["cross"] = jax.vmap(_init_cross)(cross_keys)
        # reshape self blocks into (n_cross, cross_every, ...) super-blocks
        params["blocks"] = jax.tree.map(
            lambda x: x.reshape((n_cross, cfg.cross_attn_every) + x.shape[1:]),
            params["blocks"])
    return params


# -------------------------------------------------------------- forward
def _attn_forward(x, blk, cfg: ArchConfig, positions):
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ blk["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ blk["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ blk["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = L.flash_attention(q, k, v, causal=True, window=cfg.swa_window,
                          q_block=cfg.attn_block, kv_block=cfg.attn_block,
                          impl=cfg.attn_impl)
    return o.reshape(B, S, cfg.n_heads * hd) @ blk["wo"]


def _cross_attn_forward(x, blk, cfg: ArchConfig, frontend):
    """x: (B, S, D) attends over frontend embeds (B, T_img, D)."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    xn = L.rms_norm(x, blk["ln"], cfg.norm_eps)
    q = (xn @ blk["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (frontend @ blk["wk"]).reshape(B, -1, cfg.n_kv_heads, hd)
    v = (frontend @ blk["wv"]).reshape(B, -1, cfg.n_kv_heads, hd)
    o = L.flash_attention(q, k, v, causal=False,
                          q_block=cfg.attn_block, kv_block=cfg.attn_block)
    o = o.reshape(B, S, cfg.n_heads * hd) @ blk["wo"]
    return x + jnp.tanh(blk["gate"]) * o


def _ffn_forward(x, blk, cfg: ArchConfig, ctx: Optional[MeshCtx]):
    if cfg.moe is not None:
        if ctx is not None:
            y, aux = moe_ffn_sharded(x, blk["moe"], cfg.moe,
                                     ctx.mesh, ctx.dp_axes, ctx.tp_axis,
                                     batch_replicated=ctx.batch_replicated,
                                     resident_experts=ctx.resident_experts)
        else:
            B, S, D = x.shape
            y, aux = moe_ffn_local(x.reshape(-1, D), blk["moe"], cfg.moe)
            y = y.reshape(B, S, D)
        return y, aux
    if cfg.d_ff:
        return L.swiglu(x, blk["w_gate"], blk["w_up"], blk["w_down"]), 0.0
    return jnp.zeros_like(x), 0.0


def _block_forward(x, blk, cfg: ArchConfig, positions, ctx: Optional[MeshCtx]):
    """One decoder block (self-attn/ssm/hybrid + FFN). Returns (x, aux)."""
    aux = 0.0
    if cfg.family == "ssm":
        h = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
        x = x + ssd_forward(h, blk["ssm"], cfg.ssm)
        return x, aux
    h = L.rms_norm(x, blk["ln1"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a = _attn_forward(h, blk, cfg, positions)
        s = ssd_forward(h, blk["ssm"], cfg.ssm)
        mixed = 0.5 * (L.rms_norm(a, blk["norm_attn"], cfg.norm_eps)
                       + L.rms_norm(s, blk["norm_ssm"], cfg.norm_eps))
        x = x + mixed
    else:
        x = x + _attn_forward(h, blk, cfg, positions)
    h2 = L.rms_norm(x, blk["ln2"], cfg.norm_eps)
    y, aux = _ffn_forward(h2, blk, cfg, ctx)
    return x + y, aux


def _embed(params, cfg: ArchConfig, tokens):
    if cfg.n_codebooks:
        return L.embed_codebooks(params["embed"], tokens)
    return L.embed_tokens(params["embed"], tokens)


def _unembed(params, cfg: ArchConfig, x):
    if cfg.n_codebooks:
        return jnp.einsum("bsd,qdv->bsqv", x, params["lm_head"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def forward(params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            ctx: Optional[MeshCtx] = None, remat: bool = True,
            unroll: bool = False, remat_policy: Optional[str] = None):
    """Full-sequence forward. batch['tokens']: (B, S[,nq]) int32; vlm batches
    also carry batch['frontend'] (B, T_img, D).  Returns (logits, aux_loss).

    ``unroll=True`` replaces the layer scans with Python loops — used by the
    dry-run probe compiles, because XLA cost analysis counts a while-loop
    body once regardless of trip count."""
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]

    def _constrain(x):
        if ctx is None:
            return x
        from jax.sharding import PartitionSpec as P
        b = None if ctx.batch_replicated else ctx.dp_axes
        return jax.lax.with_sharding_constraint(x, P(b, None, None))

    x = _constrain(x)

    def self_block(x, blk):
        x, aux = _block_forward(x, blk, cfg, positions, ctx)
        return _constrain(x), aux

    policy = None
    if remat_policy == "dots":
        # save matmul results without batch dims (weight-stationary values):
        # the backward pass then re-uses them instead of recomputing — which
        # under FSDP also skips the remat-time weight re-gather (§Perf A#5)
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    body = (jax.checkpoint(self_block, policy=policy) if remat
            else self_block)

    if cfg.cross_attn_every:
        frontend = batch["frontend"]

        def super_block(x, blks):
            self_stack, cross_blk = blks
            if unroll:
                auxs = []
                for i in range(cfg.cross_attn_every):
                    x, a = body(x, jax.tree.map(lambda p: p[i], self_stack))
                    auxs.append(a)
                aux = jnp.asarray(auxs).sum()
            else:
                x, aux = jax.lax.scan(body, x, self_stack)
                aux = aux.sum()
            x = _cross_attn_forward(x, cross_blk, cfg, frontend)
            return x, aux

        sb = (jax.checkpoint(super_block, policy=policy) if remat
              else super_block)
        if unroll:
            n_groups = jax.tree.leaves(params["blocks"])[0].shape[0]
            auxs = []
            for g in range(n_groups):
                x, a = sb(x, (jax.tree.map(lambda p: p[g], params["blocks"]),
                              jax.tree.map(lambda p: p[g], params["cross"])))
                auxs.append(a)
            auxs = jnp.asarray(auxs)
        else:
            x, auxs = jax.lax.scan(sb, x, (params["blocks"], params["cross"]))
    elif unroll:
        auxs = []
        for i in range(cfg.n_layers):
            x, a = body(x, jax.tree.map(lambda p: p[i], params["blocks"]))
            auxs.append(a)
        auxs = jnp.asarray(auxs)
    else:
        x, auxs = jax.lax.scan(body, x, params["blocks"])

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(params, cfg, x), jnp.sum(auxs)


# --------------------------------------------------------------- decode
def kv_cache_len(cfg: ArchConfig, context_len: int) -> int:
    if cfg.swa_window:
        return min(context_len, cfg.swa_window)
    return context_len


def init_decode_state(params, cfg: ArchConfig, batch: int, context_len: int,
                      dtype=jnp.float32,
                      frontend: Optional[jnp.ndarray] = None) -> Dict[str, Any]:
    """Allocate decode state: ring-buffer KV caches, SSM states, cross-KV."""
    state: Dict[str, Any] = {"cur": jnp.zeros((), jnp.int32)}
    hd = cfg.resolved_head_dim
    Sc = kv_cache_len(cfg, context_len)
    nl = cfg.n_layers
    if cfg.n_heads:
        kv_dt = jnp.int8 if cfg.kv_dtype == "int8" else dtype
        state["k"] = jnp.zeros((nl, batch, Sc, cfg.n_kv_heads, hd), kv_dt)
        state["v"] = jnp.zeros((nl, batch, Sc, cfg.n_kv_heads, hd), kv_dt)
        if cfg.kv_dtype == "int8":
            # per-(slot, kv-head) scales, fp16 (0.4% of the cache bytes)
            state["k_scale"] = jnp.zeros((nl, batch, Sc, cfg.n_kv_heads),
                                         jnp.float16)
            state["v_scale"] = jnp.zeros((nl, batch, Sc, cfg.n_kv_heads),
                                         jnp.float16)
    if cfg.family in ("ssm", "hybrid"):
        def mk(_):
            return init_ssm_state(batch, cfg.d_model, cfg.ssm, dtype)
        state["ssm"] = jax.vmap(mk)(jnp.arange(nl))
    if cfg.cross_attn_every and frontend is not None:
        n_cross = cfg.n_layers // cfg.cross_attn_every

        def cross_kv(blk):
            k = (frontend @ blk["wk"]).reshape(batch, -1, cfg.n_kv_heads, hd)
            v = (frontend @ blk["wv"]).reshape(batch, -1, cfg.n_kv_heads, hd)
            return k, v

        ck, cv = jax.vmap(cross_kv)(params["cross"])
        state["cross_k"], state["cross_v"] = ck, cv
    return state


def _quantize_kv(t):
    """t: (B, KV, hd) -> (int8 values, fp16 per-(B,KV) scales)."""
    scale = jnp.maximum(jnp.max(jnp.abs(t), axis=-1), 1e-6) / 127.0
    q = jnp.clip(jnp.round(t / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def _attn_decode(x, blk, cfg, k_cache, v_cache, cur, ctx=None,
                 k_scale=None, v_scale=None):
    """x: (B, D). Writes this token's KV at slot cur % ring, attends.
    With a MeshCtx whose model axis is >1, uses the sequence-sharded
    flash-decoding path (repro.distributed.decode).  int8 caches carry
    per-(slot, head) scales alongside."""
    if ctx is not None and ctx.mesh.shape[ctx.tp_axis] > 1:
        from repro.distributed.decode import decode_attn_sharded
        return decode_attn_sharded(x, blk, cfg, k_cache, v_cache, cur, ctx,
                                   k_scale=k_scale, v_scale=v_scale)
    B, d = x.shape
    hd = cfg.resolved_head_dim
    Sc = k_cache.shape[1]
    q = (x @ blk["wq"]).reshape(B, cfg.n_heads, hd)
    k = (x @ blk["wk"]).reshape(B, cfg.n_kv_heads, hd)
    v = (x @ blk["wv"]).reshape(B, cfg.n_kv_heads, hd)
    pos = jnp.full((B,), cur)
    q = L.apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = L.apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    slot = cur % Sc
    quant = k_scale is not None
    if quant:
        kq, ks = _quantize_kv(k.astype(jnp.float32))
        vq, vs = _quantize_kv(v.astype(jnp.float32))
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, kq[:, None], slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vq[:, None], slot, 1)
        k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks[:, None], slot, 1)
        v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs[:, None], slot, 1)
        k_eff = k_cache.astype(jnp.float32) * k_scale.astype(jnp.float32)[..., None]
        v_eff = v_cache.astype(jnp.float32) * v_scale.astype(jnp.float32)[..., None]
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k[:, None], slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v[:, None], slot, 1)
        k_eff, v_eff = k_cache, v_cache
    # ring buffer: number of valid slots
    n_valid = jnp.minimum(cur + 1, Sc)
    o = L.decode_attention(q, k_eff, v_eff, n_valid, window=0)
    o = o.astype(x.dtype)
    out = (o.reshape(B, cfg.n_heads * hd) @ blk["wo"])
    if quant:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


def decode_step(params, cfg: ArchConfig, state: Dict[str, Any],
                tokens: jnp.ndarray, ctx: Optional[MeshCtx] = None,
                unroll: bool = False):
    """One decode step. tokens: (B,) int32 (or (B, nq) for audio).
    Returns (logits (B, V[, nq]), new_state)."""
    x = _embed(params, cfg, tokens[:, None] if tokens.ndim == 1
               else tokens[:, None, :])[:, 0]
    B, d = x.shape
    cur = state["cur"]

    has_kv = cfg.n_heads > 0
    has_ssm = cfg.family in ("ssm", "hybrid")

    if cfg.cross_attn_every:
        # unroll super-blocks: scan over self layers inside each group
        n_cross = cfg.n_layers // cfg.cross_attn_every
        new_k, new_v = [], []
        for g in range(n_cross):
            blks = jax.tree.map(lambda p: p[g], params["blocks"])
            caches = (
                jax.tree.map(lambda p: jax.lax.dynamic_slice_in_dim(
                    p, g * cfg.cross_attn_every, cfg.cross_attn_every, 0),
                    (state["k"], state["v"])))

            def body(carry, xs):
                h, = carry
                blk, kc, vc = xs
                hn = L.rms_norm(h, blk["ln1"], cfg.norm_eps)
                o, kc, vc = _attn_decode(hn, blk, cfg, kc, vc, cur, ctx)
                h = h + o
                h2 = L.rms_norm(h, blk["ln2"], cfg.norm_eps)
                y, _ = _ffn_forward(h2[:, None], blk, cfg, ctx)
                return (h + y[:, 0],), (kc, vc)

            (x,), (kcs, vcs) = jax.lax.scan(body, (x,), (blks, *caches))
            new_k.append(kcs)
            new_v.append(vcs)
            cblk = jax.tree.map(lambda p: p[g], params["cross"])
            q = (L.rms_norm(x, cblk["ln"], cfg.norm_eps) @ cblk["wq"]) \
                .reshape(B, cfg.n_heads, cfg.resolved_head_dim)
            ck, cv = state["cross_k"][g], state["cross_v"][g]
            o = L.decode_attention(q, ck, cv, ck.shape[1])
            x = x + jnp.tanh(cblk["gate"]) * (
                o.reshape(B, -1) @ cblk["wo"])
        new_state = dict(state)
        new_state["k"] = jnp.concatenate(new_k, axis=0)
        new_state["v"] = jnp.concatenate(new_v, axis=0)
        new_state["cur"] = cur + 1
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return _unembed(params, cfg, x[:, None])[:, 0], new_state

    def body(carry, xs):
        (h,) = carry
        blk = xs["blk"]
        outs = {}
        if cfg.family == "ssm":
            hn = L.rms_norm(h, blk["ln1"], cfg.norm_eps)
            y, new_ssm = ssd_decode_step(hn, xs["ssm"], blk["ssm"], cfg.ssm)
            outs["ssm"] = new_ssm
            return (h + y,), outs
        hn = L.rms_norm(h, blk["ln1"], cfg.norm_eps)
        quant = "k_scale" in xs
        extra = ({"k_scale": xs["k_scale"], "v_scale": xs["v_scale"]}
                 if quant else {})
        if cfg.family == "hybrid":
            res = _attn_decode(hn, blk, cfg, xs["k"], xs["v"], cur, ctx, **extra)
            a, kc, vc = res[:3]
            s, new_ssm = ssd_decode_step(hn, xs["ssm"], blk["ssm"], cfg.ssm)
            outs["ssm"] = new_ssm
            mixed = 0.5 * (L.rms_norm(a, blk["norm_attn"], cfg.norm_eps)
                           + L.rms_norm(s, blk["norm_ssm"], cfg.norm_eps))
            h = h + mixed
        else:
            res = _attn_decode(hn, blk, cfg, xs["k"], xs["v"], cur, ctx, **extra)
            a, kc, vc = res[:3]
            h = h + a
        outs["k"], outs["v"] = kc, vc
        if quant:
            outs["k_scale"], outs["v_scale"] = res[3], res[4]
        h2 = L.rms_norm(h, blk["ln2"], cfg.norm_eps)
        y, _ = _ffn_forward(h2[:, None], blk, cfg, ctx)
        return (h + y[:, 0],), outs

    xs = {"blk": params["blocks"]}
    if has_kv:
        xs["k"], xs["v"] = state["k"], state["v"]
        if "k_scale" in state:
            xs["k_scale"], xs["v_scale"] = state["k_scale"], state["v_scale"]
    if has_ssm:
        xs["ssm"] = state["ssm"]
    if unroll:
        outs_list = []
        for i in range(cfg.n_layers):
            (x,), o = body((x,), jax.tree.map(lambda p: p[i], xs))
            outs_list.append(o)
        outs = jax.tree.map(lambda *ls: jnp.stack(ls), *outs_list)
    else:
        (x,), outs = jax.lax.scan(body, (x,), xs)

    new_state = dict(state)
    if has_kv:
        new_state["k"], new_state["v"] = outs["k"], outs["v"]
        if "k_scale" in outs:
            new_state["k_scale"] = outs["k_scale"]
            new_state["v_scale"] = outs["v_scale"]
    if has_ssm:
        new_state["ssm"] = outs["ssm"]
    new_state["cur"] = cur + 1
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, None])[:, 0]
    return logits, new_state
