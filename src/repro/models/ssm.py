"""Mamba2 — SSD (state-space duality) layer in pure JAX.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060): the
sequence is split into chunks; within a chunk the recurrence is computed as
a masked (attention-like) matmul, across chunks a short ``lax.scan`` carries
the (B, H, P, N) state.  This is the TPU-friendly formulation — the chunk
matmuls hit the MXU, the scan is O(S/chunk).

Decode is the O(1) recurrence:
    state = exp(dt*A) * state + dt * B ⊗ x ;  y = C·state + D*x
which is why SSM archs are the ones eligible for the 500k-context shape.

Layout conventions:
    x (inner activations): (B, S, H, P)   H = d_inner/P heads, P = head_dim
    B/C (input/output proj of the state): (B, S, N)   (n_groups == 1)
    dt: (B, S, H);  A: (H,) (negative);  state: (B, H, P, N)
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import rms_norm

NEG_INF = -1e30


class SSMParams(NamedTuple):
    """Projections are SPLIT (z/x/B/C/dt separately) rather than one fused
    in_proj so tensor parallelism can shard the d_in-sized pieces over the
    model axis while keeping the small B/C/dt pieces replicated."""

    in_z: jnp.ndarray          # (D, d_in)
    in_x: jnp.ndarray          # (D, d_in)
    in_B: jnp.ndarray          # (D, N)
    in_C: jnp.ndarray          # (D, N)
    in_dt: jnp.ndarray         # (D, H)
    conv_x: jnp.ndarray        # (K, d_in) depthwise causal conv
    conv_B: jnp.ndarray        # (K, N)
    conv_C: jnp.ndarray        # (K, N)
    conv_bx: jnp.ndarray       # (d_in,)
    conv_bB: jnp.ndarray       # (N,)
    conv_bC: jnp.ndarray       # (N,)
    A_log: jnp.ndarray         # (H,)
    D_skip: jnp.ndarray        # (H,)
    dt_bias: jnp.ndarray       # (H,)
    norm_w: jnp.ndarray        # (d_in,) gated RMSNorm
    out_proj: jnp.ndarray      # (d_in, D)


def init_ssm_params(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> SSMParams:
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    N = cfg.d_state
    ks = jax.random.split(key, 6)
    scale = d_model ** -0.5
    rnd = lambda k, shape, s: (jax.random.normal(k, shape) * s).astype(dtype)
    return SSMParams(
        in_z=rnd(ks[0], (d_model, d_in), scale),
        in_x=rnd(ks[1], (d_model, d_in), scale),
        in_B=rnd(ks[2], (d_model, N), scale),
        in_C=rnd(ks[3], (d_model, N), scale),
        in_dt=rnd(ks[4], (d_model, H), scale),
        conv_x=rnd(ks[5], (cfg.conv_kernel, d_in), 0.1),
        conv_B=rnd(jax.random.fold_in(key, 7), (cfg.conv_kernel, N), 0.1),
        conv_C=rnd(jax.random.fold_in(key, 8), (cfg.conv_kernel, N), 0.1),
        conv_bx=jnp.zeros((d_in,), dtype),
        conv_bB=jnp.zeros((N,), dtype),
        conv_bC=jnp.zeros((N,), dtype),
        A_log=jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)).astype(dtype),
        D_skip=jnp.ones((H,), dtype),
        dt_bias=jnp.full((H,), -2.0, dtype),   # softplus(-2) ~ 0.12
        norm_w=jnp.ones((d_in,), dtype),
        out_proj=rnd(jax.random.fold_in(key, 9), (d_in, d_model), d_in ** -0.5),
    )


def _split_proj(u, p: SSMParams, d_in: int, N: int, H: int):
    return (u @ p.in_z, u @ p.in_x, u @ p.in_B, u @ p.in_C, u @ p.in_dt)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along S.  x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def _segsum_exp(a: jnp.ndarray) -> jnp.ndarray:
    """a: (B, L, H) -> (B, H, L, L) with [l, s] = exp(sum_{r=s+1..l} a_r),
    masked to s <= l."""
    cs = jnp.cumsum(a, axis=1)                       # (B, L, H)
    diff = cs[:, :, None, :] - cs[:, None, :, :]     # (B, L, S, H): cs[l]-cs[s]
    L = a.shape[1]
    mask = jnp.tril(jnp.ones((L, L), bool))
    diff = jnp.where(mask[None, :, :, None], diff, NEG_INF)
    return jnp.exp(diff).transpose(0, 3, 1, 2)       # (B, H, L, L)


@functools.partial(jax.jit, static_argnames=("cfg",))
def ssd_forward(u: jnp.ndarray, p: SSMParams, cfg: SSMConfig) -> jnp.ndarray:
    """Chunked SSD over a full sequence. u: (B, S, D) -> (B, S, D)."""
    Bsz, S, D = u.shape
    d_in = cfg.expand * D
    P = cfg.head_dim
    H = d_in // P
    N = cfg.d_state
    L = min(cfg.chunk, S)
    pad = (-S) % L
    z, x, Bm, Cm, dt = _split_proj(u, p, d_in, N, H)

    x = jax.nn.silu(_causal_conv(x, p.conv_x, p.conv_bx))
    Bm = jax.nn.silu(_causal_conv(Bm, p.conv_B, p.conv_bB))
    Cm = jax.nn.silu(_causal_conv(Cm, p.conv_C, p.conv_bC))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias.astype(jnp.float32))
    A = -jnp.exp(p.A_log.astype(jnp.float32))        # (H,)

    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // L

    xh = x.reshape(Bsz, nc, L, H, P).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, L, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, L, H)
    a = dtc * A                                      # (B, nc, L, H)

    def chunk_step(h_prev, inputs):
        xk, Bk, Ck, ak, dk = inputs                  # (B,L,H,P),(B,L,N),(B,L,N),(B,L,H),(B,L,H)
        cs = jnp.cumsum(ak, axis=1)                  # (B,L,H)
        decay = _segsum_exp(ak)                      # (B,H,L,S)
        CB = jnp.einsum("bln,bsn->bls", Ck, Bk)      # (B,L,S)
        W = CB[:, None] * decay * dk.transpose(0, 2, 1)[:, :, None, :]  # (B,H,L,S)
        y_diag = jnp.einsum("bhls,bshp->blhp", W, xk)
        # contribution of the carried state
        state_decay = jnp.exp(cs)                    # (B,L,H)
        y_off = jnp.einsum("bln,bhpn->blhp", Ck, h_prev) * state_decay[..., None]
        # new chunk state
        end_decay = jnp.exp(cs[:, -1:, :] - cs)      # (B,L,H)
        S_new = jnp.einsum("blh,bln,blhp->bhpn", end_decay * dk, Bk, xk)
        h = h_prev * jnp.exp(cs[:, -1])[:, :, None, None] + S_new
        return h, y_diag + y_off

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (xh.transpose(1, 0, 2, 3, 4), Bc.transpose(1, 0, 2, 3),
          Cc.transpose(1, 0, 2, 3), a.transpose(1, 0, 2, 3),
          dtc.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(chunk_step, h0, xs)         # (nc, B, L, H, P)

    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, Sp, H, P)[:, :S]
    y = y + x.reshape(Bsz, Sp, H, P)[:, :S] * p.D_skip.astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, S, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p.norm_w)
    return (y @ p.out_proj.astype(y.dtype)).astype(u.dtype)


class SSMState(NamedTuple):
    h: jnp.ndarray             # (B, H, P, N)
    conv_buf: jnp.ndarray      # (B, K-1, d_in + 2N) trailing conv inputs
                               # (x channels first, then B, then C)


def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.float32) -> SSMState:
    d_in = cfg.expand * d_model
    H = d_in // cfg.head_dim
    return SSMState(
        h=jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), jnp.float32),
        conv_buf=jnp.zeros((batch, cfg.conv_kernel - 1, d_in + 2 * cfg.d_state),
                           dtype),
    )


def ssd_decode_step(u: jnp.ndarray, state: SSMState, p: SSMParams,
                    cfg: SSMConfig) -> Tuple[jnp.ndarray, SSMState]:
    """One-token recurrence. u: (B, D) -> (B, D), new state."""
    Bsz, D = u.shape
    d_in = cfg.expand * D
    P = cfg.head_dim
    H = d_in // P
    N = cfg.d_state

    z, x, Bm, Cm, dt = _split_proj(u, p, d_in, N, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)      # (B, C)
    window = jnp.concatenate([state.conv_buf, xbc[:, None]], axis=1)  # (B,K,C)
    conv_w = jnp.concatenate([p.conv_x, p.conv_B, p.conv_C], axis=-1)
    conv_b = jnp.concatenate([p.conv_bx, p.conv_bB, p.conv_bC], axis=-1)
    conv_out = jnp.einsum("bkc,kc->bc", window, conv_w) + conv_b
    xbc = jax.nn.silu(conv_out)
    x, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p.dt_bias.astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p.A_log.astype(jnp.float32))
    xh = x.reshape(Bsz, H, P).astype(jnp.float32)
    dA = jnp.exp(dt * A)                             # (B,H)
    h = state.h * dA[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * p.D_skip.astype(jnp.float32)[:, None]
    y = y.reshape(Bsz, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p.norm_w)
    out = (y @ p.out_proj.astype(y.dtype)).astype(u.dtype)
    new_state = SSMState(h=h, conv_buf=window[:, 1:])
    return out, new_state
