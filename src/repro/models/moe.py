"""Mixture-of-Experts layer: top-k routing, capacity-based sort-free
dispatch, and two sharding modes.

``ep``  (kimi-k2: 384 experts): experts sharded over the ``model`` axis;
        tokens routed with a tiled ``all_to_all`` inside ``shard_map``
        (24 experts/device on a 16-wide model axis).
``tp``  (mixtral: 8 experts < axis): every device holds all experts but only
        a ``d_expert/axis`` slice; partial outputs are ``psum``-reduced.
        No all_to_all — the dispatch stays device-local.

Dispatch is gather-based with a fixed per-expert capacity
(``ceil(T*K/E * capacity_factor)``); overflow tokens are dropped (they ride
the residual), underflow slots are masked.  This keeps every shape static —
a requirement for the multi-pod dry-run — and matches standard TPU MoE
practice (Switch/GShard capacity dispatch).
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import MoEConfig


class MoEParams(NamedTuple):
    router: jnp.ndarray        # (D, E)
    w_gate: jnp.ndarray        # (E, D, F)
    w_up: jnp.ndarray          # (E, D, F)
    w_down: jnp.ndarray        # (E, F, D)


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> MoEParams:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_expert
    s_in = d_model ** -0.5
    s_out = F ** -0.5
    return MoEParams(
        router=(jax.random.normal(k1, (d_model, E)) * s_in).astype(dtype),
        w_gate=(jax.random.normal(k2, (E, d_model, F)) * s_in).astype(dtype),
        w_up=(jax.random.normal(k3, (E, d_model, F)) * s_in).astype(dtype),
        w_down=(jax.random.normal(k4, (E, F, d_model)) * s_out).astype(dtype),
    )


def capacity_for(tokens: int, cfg: MoEConfig,
                 factor: Optional[float] = None) -> int:
    f = cfg.capacity_factor if factor is None else factor
    return max(1, math.ceil(tokens * cfg.top_k / cfg.n_experts * f))


def _route(x, router, top_k: int):
    """x: (T, D) -> (weights (T,K), expert_idx (T,K), aux_loss scalar)."""
    logits = (x.astype(jnp.float32)) @ router.astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    E = router.shape[1]
    density = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * mean_prob)
    return weights, idx, aux


def _dispatch_indices(expert_idx, n_experts: int, capacity: int):
    """Flat assignment list -> (per-expert slot matrix, validity mask).

    Returns ``slots (E, C)`` holding flat assignment ids (t*K + k) and
    ``valid (E, C)``.  Sort-free: assignments are ranked within their expert
    by a stable argsort of expert id."""
    TK = expert_idx.size
    flat = expert_idx.reshape(-1)                      # (T*K,)
    order = jnp.argsort(flat, stable=True)             # grouped by expert
    counts = jnp.bincount(flat, length=n_experts)      # (E,)
    start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                             jnp.cumsum(counts)[:-1]])
    pos = start[:, None] + jnp.arange(capacity)[None, :]        # (E, C)
    valid = jnp.arange(capacity)[None, :] < jnp.minimum(counts, capacity)[:, None]
    slots = jnp.take(order, jnp.clip(pos, 0, TK - 1), axis=0)
    return slots, valid


def _expert_ffn(xe, w_gate, w_up, w_down):
    """xe: (E, C, D) grouped tokens -> (E, C, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_ffn_local(x, p: MoEParams, cfg: MoEConfig,
                  capacity_factor: Optional[float] = None):
    """Single-device MoE: x (T, D) -> (T, D), aux_loss."""
    T, D = x.shape
    weights, idx, aux = _route(x, p.router, cfg.top_k)
    C = capacity_for(T, cfg, capacity_factor)
    slots, valid = _dispatch_indices(idx, cfg.n_experts, C)
    token_of = slots // cfg.top_k                                  # (E, C)
    xe = jnp.take(x, token_of, axis=0) * valid[..., None]          # (E, C, D)
    ye = _expert_ffn(xe.astype(x.dtype), p.w_gate, p.w_up, p.w_down)
    w_flat = weights.reshape(-1)                                   # (T*K,)
    wslot = jnp.take(w_flat, slots) * valid                        # (E, C)
    out = jnp.zeros((T, D), ye.dtype).at[token_of.reshape(-1)].add(
        (ye * wslot[..., None]).reshape(-1, D))
    return out.astype(x.dtype), aux


def moe_ffn_sharded(x, p: MoEParams, cfg: MoEConfig, mesh,
                    dp_axes: Tuple[str, ...], tp_axis: str,
                    capacity_factor: Optional[float] = None,
                    batch_replicated: bool = False,
                    resident_experts: bool = False):
    """Sharded MoE over a (dp..., tp) mesh.  x: (B, S, D) with B sharded over
    ``dp_axes`` (or replicated).  Expert placement per ``cfg.sharding``.

    ``resident_experts=True`` is the DECODE layout (§Perf hillclimb): expert
    weights stay resident, sharded (experts over tp) x (expert-hidden over
    dp); the few decode tokens are all-gathered instead of the multi-GB
    expert weights — the collective per layer drops from O(expert bytes) to
    O(token bytes)."""
    B, S, D = x.shape
    n_tp = mesh.shape[tp_axis]
    dp_spec = None if batch_replicated else dp_axes
    dp_axes = () if batch_replicated else dp_axes

    if resident_experts and cfg.sharding == "ep" and n_tp > 1:
        e_spec_f = P(tp_axis, None, None)  # placeholder replaced below

        def body_res(xl, router, w_gate, w_up, w_down):
            # xl: (B_loc, S, D); weights: (E_loc, D, F_loc)
            T_loc = xl.shape[0] * xl.shape[1]
            xf = xl.reshape(T_loc, D)
            # gather ALL tokens (tiny at decode) so every device can serve
            # its resident expert shard
            for axn in dp_axes:
                xf = jax.lax.all_gather(xf, axn, axis=0, tiled=True)
            T = xf.shape[0]
            weights, idx, aux = _route(xf, router, cfg.top_k)
            C = capacity_for(T, cfg, capacity_factor)
            slots, valid = _dispatch_indices(idx, cfg.n_experts, C)
            token_of = slots // cfg.top_k
            e_loc = w_gate.shape[0]
            tpi = jax.lax.axis_index(tp_axis)
            my_slots = jax.lax.dynamic_slice_in_dim(slots, tpi * e_loc, e_loc, 0)
            my_valid = jax.lax.dynamic_slice_in_dim(valid, tpi * e_loc, e_loc, 0)
            my_tok = my_slots // cfg.top_k
            xe = jnp.take(xf, my_tok, axis=0) * my_valid[..., None]
            ye = _expert_ffn(xe.astype(xf.dtype), w_gate, w_up, w_down)
            # F is sharded over dp -> partial sums; tokens identical on all
            # dp shards, so psum over dp completes the contraction
            for axn in dp_axes:
                ye = jax.lax.psum(ye, axn)
            w_flat = weights.reshape(-1)
            wslot = jnp.take(w_flat, my_slots) * my_valid
            out = jnp.zeros((T, D), jnp.float32).at[my_tok.reshape(-1)].add(
                (ye.astype(jnp.float32) * wslot[..., None]).reshape(-1, D))
            out = jax.lax.psum(out, tp_axis)   # combine expert shards
            # keep my dp slice of the tokens
            if dp_axes:
                dpi = jax.lax.axis_index(dp_axes[0])
                for axn in dp_axes[1:]:
                    dpi = dpi * mesh.shape[axn] + jax.lax.axis_index(axn)
                out = jax.lax.dynamic_slice_in_dim(out, dpi * T_loc, T_loc, 0)
            aux = jax.lax.pmean(aux, tp_axis)
            return out.reshape(xl.shape).astype(xl.dtype), aux

        return shard_map(
            body_res, mesh=mesh,
            in_specs=(P(dp_spec, None, None), P(None, None),
                      P(tp_axis, None, dp_axes or None),
                      P(tp_axis, None, dp_axes or None),
                      P(tp_axis, dp_axes or None, None)),
            out_specs=(P(dp_spec, None, None), P()),
            check_rep=False,
        )(x, p.router, p.w_gate, p.w_up, p.w_down)

    if cfg.sharding == "ep" and cfg.n_experts % n_tp == 0 and n_tp > 1:
        e_spec = P(tp_axis, None, None)

        def body(xl, router, w_gate, w_up, w_down):
            T = xl.shape[0] * xl.shape[1]
            xf = xl.reshape(T, D)
            weights, idx, aux = _route(xf, router, cfg.top_k)
            C = capacity_for(T, cfg, capacity_factor)
            slots, valid = _dispatch_indices(idx, cfg.n_experts, C)
            token_of = slots // cfg.top_k
            xe = jnp.take(xf, token_of, axis=0) * valid[..., None]  # (E, C, D)
            # send each expert block to its owner: (E, C, D) -> (E, C, D)
            # where rows now hold **my local experts'** tokens from every src
            xr = jax.lax.all_to_all(xe.astype(xf.dtype), tp_axis, 0, 0, tiled=True)
            e_loc = cfg.n_experts // n_tp
            xr = xr.reshape(n_tp, e_loc, C, D).transpose(1, 0, 2, 3) \
                   .reshape(e_loc, n_tp * C, D)
            yr = _expert_ffn(xr, w_gate, w_up, w_down)
            yr = yr.reshape(e_loc, n_tp, C, D).transpose(1, 0, 2, 3) \
                   .reshape(cfg.n_experts, C, D)
            ye = jax.lax.all_to_all(yr, tp_axis, 0, 0, tiled=True)
            w_flat = weights.reshape(-1)
            wslot = jnp.take(w_flat, slots) * valid
            out = jnp.zeros((T, D), jnp.float32).at[token_of.reshape(-1)].add(
                (ye.astype(jnp.float32) * wslot[..., None]).reshape(-1, D))
            aux = jax.lax.pmean(aux, tp_axis)
            for ax in dp_axes:
                aux = jax.lax.pmean(aux, ax)
            return out.reshape(xl.shape).astype(xl.dtype), aux

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(dp_spec, None, None), P(None, None),
                      e_spec, e_spec, P(tp_axis, None, None)),
            out_specs=(P(dp_spec, None, None), P()),
            check_rep=False,
        )(x, p.router, p.w_gate, p.w_up, p.w_down)

    # 'tp' mode: experts replicated, d_expert sharded; psum partial outputs.
    def body_tp(xl, router, w_gate, w_up, w_down):
        T = xl.shape[0] * xl.shape[1]
        xf = xl.reshape(T, D)
        weights, idx, aux = _route(xf, router, cfg.top_k)
        C = capacity_for(T, cfg, capacity_factor)
        slots, valid = _dispatch_indices(idx, cfg.n_experts, C)
        token_of = slots // cfg.top_k
        xe = jnp.take(xf, token_of, axis=0) * valid[..., None]
        ye = _expert_ffn(xe.astype(xf.dtype), w_gate, w_up, w_down)
        ye = jax.lax.psum(ye, tp_axis)               # reduce over F shards
        w_flat = weights.reshape(-1)
        wslot = jnp.take(w_flat, slots) * valid
        out = jnp.zeros((T, D), jnp.float32).at[token_of.reshape(-1)].add(
            (ye.astype(jnp.float32) * wslot[..., None]).reshape(-1, D))
        aux = jax.lax.pmean(aux, tp_axis)
        for ax in dp_axes:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(xl.shape).astype(xl.dtype), aux

    return shard_map(
        body_tp, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(None, None),
                  P(None, None, tp_axis), P(None, None, tp_axis),
                  P(None, tp_axis, None)),
        out_specs=(P(dp_spec, None, None), P()),
        check_rep=False,
    )(x, p.router, p.w_gate, p.w_up, p.w_down)
