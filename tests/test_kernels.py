"""Pallas kernel validation: shape/dtype sweeps, allclose vs ref.py oracles
(interpret mode executes kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import (cache_sim_op, combine_partials,
                               flash_attention_op, flash_decode_op,
                               page_gather_op, page_scatter_op)

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------- cache_sim
class TestCacheSimKernel:
    @pytest.mark.parametrize("policy,num_sets,ways",
                             [("lru", 32, 4), ("lru", 64, 8), ("lru", 1, 16),
                              ("fifo", 32, 4), ("fifo", 16, 2),
                              ("direct", 64, 1)])
    def test_matches_oracle(self, policy, num_sets, ways):
        rng = np.random.default_rng(11)
        n = 1500
        pages = jnp.asarray(rng.integers(0, num_sets * ways * 3, size=n),
                            jnp.int32)
        writes = jnp.asarray(rng.random(n) < 0.4)
        h, e = cache_sim_op(pages, writes, num_sets=num_sets, ways=ways,
                            policy=policy, chunk=256)
        hr, er = ref.cache_sim_ref(pages, writes, num_sets=num_sets,
                                   ways=ways, policy=policy)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(er))

    def test_non_multiple_chunk_padding(self):
        rng = np.random.default_rng(5)
        n = 777  # not a multiple of chunk
        pages = jnp.asarray(rng.integers(0, 256, size=n), jnp.int32)
        writes = jnp.asarray(rng.random(n) < 0.5)
        h, e = cache_sim_op(pages, writes, num_sets=16, ways=4, chunk=256)
        hr, er = ref.cache_sim_ref(pages, writes, num_sets=16, ways=4)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))

    def test_rejects_unsupported(self):
        pages = jnp.zeros((8,), jnp.int32)
        with pytest.raises(ValueError):
            cache_sim_op(pages, pages, num_sets=4, ways=2, policy="2q")
        with pytest.raises(ValueError):
            cache_sim_op(pages, pages, num_sets=4, ways=2, policy="direct")


# -------------------------------------------------------- flash_attention
class TestFlashAttentionKernel:
    @pytest.mark.parametrize("S,H,KV,hd,win,dtype", [
        (64, 4, 4, 32, 0, jnp.float32),
        (96, 8, 2, 16, 0, jnp.float32),
        (64, 4, 4, 32, 24, jnp.float32),
        (70, 4, 2, 32, 0, jnp.float32),       # padded seq
        (64, 4, 4, 32, 0, jnp.bfloat16),
    ])
    def test_causal_matches_ref(self, S, H, KV, hd, win, dtype):
        q = jax.random.normal(KEY, (2, S, H, hd), dtype)
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, KV, hd), dtype)
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, KV, hd), dtype)
        out = flash_attention_op(q, k, v, causal=True, window=win, bq=32, bk=32)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=win)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)

    def test_cross_attention_lengths(self):
        q = jax.random.normal(KEY, (2, 48, 4, 32))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 20, 2, 32))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 20, 2, 32))
        out = flash_attention_op(q, k, v, causal=False, bq=16, bk=16)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_model_layer_path(self):
        """Kernel and the pure-JAX scan attention agree (same numerics)."""
        from repro.models.layers import flash_attention as scan_attn
        q = jax.random.normal(KEY, (1, 64, 8, 32))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 8, 32))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 8, 32))
        a = flash_attention_op(q, k, v, bq=32, bk=32)
        b = scan_attn(q, k, v, causal=True, q_block=32, kv_block=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------- flash_decode
class TestFlashDecodeKernel:
    @pytest.mark.parametrize("Smax,H,KV,hd,n_valid", [
        (128, 8, 8, 32, 128), (128, 8, 2, 32, 77), (256, 4, 4, 16, 1),
        (96, 16, 4, 64, 50),
    ])
    def test_matches_ref(self, Smax, H, KV, hd, n_valid):
        B = 2
        q = jax.random.normal(KEY, (B, H, hd))
        kc = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Smax, KV, hd))
        vc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Smax, KV, hd))
        out, m, l = flash_decode_op(q, kc, vc, n_valid, bk=32)
        want, mw, lw = ref.flash_decode_ref(q, kc, vc, n_valid)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(m), np.asarray(mw), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(l), np.asarray(lw), rtol=1e-4, atol=1e-5)

    def test_sharded_combine_exact(self):
        """Splitting the KV cache into shards + combine == unsharded result."""
        B, Smax, H, KV, hd, n_shards = 2, 128, 8, 4, 32, 4
        q = jax.random.normal(KEY, (B, H, hd))
        kc = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Smax, KV, hd))
        vc = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Smax, KV, hd))
        full, _, _ = flash_decode_op(q, kc, vc, Smax, bk=32)
        S_loc = Smax // n_shards
        outs, ms, ls = [], [], []
        for i in range(n_shards):
            o, m, l = flash_decode_op(q, kc[:, i*S_loc:(i+1)*S_loc],
                                      vc[:, i*S_loc:(i+1)*S_loc], S_loc, bk=32)
            outs.append(o); ms.append(m); ls.append(l)
        merged = combine_partials(jnp.stack(outs), jnp.stack(ms), jnp.stack(ls))
        np.testing.assert_allclose(np.asarray(merged), np.asarray(full),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ page gather
class TestPageGatherKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    def test_gather(self, dtype):
        pool = jnp.arange(16 * 8 * 32).reshape(16, 8, 32).astype(dtype)
        table = jnp.asarray([3, 0, 15, 7, 7], jnp.int32)
        out = page_gather_op(pool, table)
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      np.asarray(ref.page_gather_ref(pool, table), np.float32))

    def test_scatter(self):
        pool = jnp.zeros((8, 4, 16), jnp.float32)
        table = jnp.asarray([2, 5], jnp.int32)
        pages = jnp.ones((2, 4, 16), jnp.float32)
        out = page_scatter_op(pool, table, pages)
        want = ref.page_scatter_ref(jnp.zeros((8, 4, 16)), table, pages)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_gather_roundtrip_scatter(self):
        pool = jax.random.normal(KEY, (12, 4, 8))
        table = jnp.asarray([1, 4, 9], jnp.int32)
        pages = page_gather_op(pool, table)
        restored = page_scatter_op(pool, table, pages)
        np.testing.assert_allclose(np.asarray(restored), np.asarray(pool))
