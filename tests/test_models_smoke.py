"""Per-architecture smoke tests (reduced configs) + numerical parity tests.

Each assigned architecture instantiates a REDUCED config of the same family
and runs one forward + one train-style step + one decode step on CPU,
asserting output shapes and no NaNs (deliverable f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_archs, get_arch
from repro.configs.base import SSMConfig
from repro.models import layers as L
from repro.models.ssm import (init_ssm_params, init_ssm_state,
                              ssd_decode_step, ssd_forward)
from repro.models.transformer import (decode_step, forward,
                                      init_decode_state, init_params)

KEY = jax.random.PRNGKey(0)
ARCHS = all_archs()


def _batch(cfg, B=2, S=16):
    batch = {}
    if cfg.n_codebooks:
        batch["tokens"] = jax.random.randint(KEY, (B, S, cfg.n_codebooks), 0, cfg.vocab)
    else:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.cross_attn_every:
        batch["frontend"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_decode(arch_id):
    cfg = ARCHS[arch_id].reduced()
    params = init_params(KEY, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = forward(params, cfg, batch)
    exp = (B, S, cfg.n_codebooks, cfg.vocab) if cfg.n_codebooks else (B, S, cfg.vocab)
    assert logits.shape == exp
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))

    state = init_decode_state(params, cfg, B, context_len=64,
                              frontend=batch.get("frontend"))
    tok = batch["tokens"][:, 0]
    lg, state2 = decode_step(params, cfg, state, tok)
    exp_d = (B, cfg.n_codebooks, cfg.vocab) if cfg.n_codebooks else (B, cfg.vocab)
    assert lg.shape == exp_d
    assert np.isfinite(np.asarray(lg)).all()
    assert int(state2["cur"]) == 1


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_grad_step(arch_id):
    """One loss+grad step: finite loss, finite grads, params update."""
    cfg = ARCHS[arch_id].reduced()
    params = init_params(KEY, cfg)
    batch = _batch(cfg, B=2, S=8)

    def loss_fn(p):
        logits, aux = forward(p, cfg, batch, remat=True)
        tgt = batch["tokens"]
        if cfg.n_codebooks:
            lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            nll = -jnp.take_along_axis(lp, tgt[:, 1:, :, None], axis=-1).mean()
        else:
            lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            nll = -jnp.take_along_axis(lp, tgt[:, 1:, None], axis=-1).mean()
        return nll + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in flat)
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch_id", ["minicpm-2b", "glm4-9b", "mamba2-2_7b",
                                     "hymba-1_5b", "musicgen-large",
                                     "mixtral-8x7b"])
def test_prefill_decode_parity(arch_id):
    """Decoding token-by-token must reproduce the full-sequence forward."""
    cfg = ARCHS[arch_id].reduced()
    params = init_params(KEY, cfg)
    B, S = 2, 10
    batch = _batch(cfg, B, S)
    full_logits, _ = forward(params, cfg, batch, remat=False)

    state = init_decode_state(params, cfg, B, context_len=S,
                              frontend=batch.get("frontend"))
    outs = []
    for t in range(S):
        tok = batch["tokens"][:, t]
        lg, state = decode_step(params, cfg, state, tok)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_decode_matches_windowed_forward():
    """SWA arch with context > window: ring-buffer decode == windowed attn."""
    cfg = ARCHS["h2o-danube-3-4b"].reduced(swa_window=6)
    params = init_params(KEY, cfg)
    B, S = 1, 12  # S > window
    batch = _batch(cfg, B, S)
    full_logits, _ = forward(params, cfg, batch, remat=False)
    state = init_decode_state(params, cfg, B, context_len=S)
    assert state["k"].shape[2] == 6  # ring limited to window
    outs = []
    for t in range(S):
        lg, state = decode_step(params, cfg, state, batch["tokens"][:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_flash_vs_reference_attention():
    for (S, Skv, H, KV, hd, win) in [(33, 33, 8, 8, 16, 0), (64, 64, 8, 2, 32, 0),
                                     (40, 40, 4, 4, 16, 8), (16, 48, 4, 2, 16, 0)]:
        q = jax.random.normal(KEY, (2, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, Skv, KV, hd))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, Skv, KV, hd))
        causal = S == Skv
        a = L.flash_attention(q, k, v, causal=causal, window=win,
                              q_block=16, kv_block=8)
        r = L.attention_ref(q, k, v, causal=causal, window=win) if causal else None
        if r is None:
            # cross-attention: compare against explicit softmax
            qg = q.reshape(2, S, KV, H // KV, hd)
            s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k) * hd ** -0.5
            p = jax.nn.softmax(s, -1)
            r = jnp.einsum("bkgqc,bckd->bqkgd", p, v).reshape(2, S, H, hd)
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=2e-5, atol=2e-5)


def test_ssd_chunked_equals_recurrence():
    cfg = SSMConfig(d_state=8, head_dim=8, expand=2, chunk=8, conv_kernel=4)
    D, S, B = 16, 21, 2
    p = init_ssm_params(KEY, D, cfg)
    u = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, D)) * 0.5
    y_chunk = ssd_forward(u, p, cfg)
    st = init_ssm_state(B, D, cfg)
    ys = []
    for t in range(S):
        y, st = ssd_decode_step(u[:, t], st, p, cfg)
        ys.append(y)
    y_step = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=1e-4, atol=1e-5)


def test_param_counts_match_public_scale():
    """Sanity: analytic N matches each model's public name/scale."""
    expect = {
        "minicpm-2b": (2.0e9, 4.0e9),
        "codeqwen1_5-7b": (6.5e9, 9.0e9),
        "glm4-9b": (8.5e9, 10.5e9),
        "h2o-danube-3-4b": (3.2e9, 4.6e9),
        "hymba-1_5b": (1.2e9, 2.0e9),
        "llama-3_2-vision-90b": (80e9, 100e9),
        "mamba2-2_7b": (2.4e9, 3.1e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
        "mixtral-8x7b": (44e9, 49e9),
        "musicgen-large": (2.8e9, 3.6e9),
    }
    for aid, (lo, hi) in expect.items():
        n = get_arch(aid).param_count()
        assert lo <= n <= hi, f"{aid}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
    kimi = get_arch("kimi-k2-1t-a32b")
    assert 28e9 <= kimi.active_param_count() <= 36e9
    mix = get_arch("mixtral-8x7b")
    assert 11e9 <= mix.active_param_count() <= 15e9


def test_triangular_attention_matches_masked():
    """§Perf hillclimb #1: triangular flash == masked flash (and ref)."""
    import dataclasses
    cfg = ARCHS["glm4-9b"].reduced()
    cfg_tri = dataclasses.replace(cfg, attn_impl="triangular")
    params = init_params(KEY, cfg)
    batch = _batch(cfg, 2, 24)
    a, _ = forward(params, cfg, batch, remat=False)
    b, _ = forward(params, cfg_tri, batch, remat=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_triangular_attention_grads_match():
    import dataclasses
    from repro.distributed.step import make_loss_fn
    cfg = ARCHS["h2o-danube-3-4b"].reduced(swa_window=8)
    cfg_tri = dataclasses.replace(cfg, attn_impl="triangular")
    params = init_params(KEY, cfg)
    batch = _batch(cfg, 2, 16)
    g1 = jax.grad(make_loss_fn(cfg, None, remat=True))(params, batch)
    g2 = jax.grad(make_loss_fn(cfg_tri, None, remat=True))(params, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)


def test_int8_kv_cache_decode_close():
    """§Perf hillclimb: int8 KV cache stays within 5% of full precision."""
    import dataclasses
    cfg = ARCHS["glm4-9b"].reduced()
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    params = init_params(KEY, cfg)
    B, S = 2, 10
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)

    def run(c):
        st = init_decode_state(params, c, B, context_len=16)
        outs = []
        for t in range(S):
            lg, st = decode_step(params, c, st, toks[:, t])
            outs.append(lg)
        return jnp.stack(outs, 1)

    ref, q8 = run(cfg), run(cfg8)
    rel = float(jnp.abs(q8 - ref).max() / jnp.abs(ref).max())
    assert rel < 0.05, rel


def test_int8_kv_state_is_half_size():
    import dataclasses
    cfg = ARCHS["glm4-9b"].reduced()
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    params = init_params(KEY, cfg)
    st16 = init_decode_state(params, cfg, 2, 64, dtype=jnp.bfloat16)
    st8 = init_decode_state(params, cfg8, 2, 64, dtype=jnp.bfloat16)
    b16 = st16["k"].nbytes + st16["v"].nbytes
    b8 = st8["k"].nbytes + st8["v"].nbytes + st8["k_scale"].nbytes \
        + st8["v_scale"].nbytes
    assert b8 < 0.6 * b16
