"""Continuous-batching scheduler over the real decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.transformer import decode_step, init_decode_state, init_params
from repro.serving.scheduler import BatchScheduler, Request, SchedulerConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def engine():
    cfg = get_arch("minicpm-2b").reduced()
    params = init_params(KEY, cfg)
    step = jax.jit(lambda st, toks: decode_step(params, cfg, st, toks))

    def init_state(batch):
        return init_decode_state(params, cfg, batch, context_len=64)

    return cfg, step, init_state


def _mk(engine, slots=2):
    cfg, step, init_state = engine
    return cfg, BatchScheduler(step, init_state,
                               SchedulerConfig(batch_slots=slots), cfg.vocab)


def test_single_request_completes(engine):
    cfg, sched = _mk(engine)
    sched.submit(Request(rid=1, prompt=np.asarray([5, 6, 7], np.int32),
                         max_new_tokens=4))
    done = sched.run()
    assert 1 in done
    assert len(done[1].output) == 4
    assert all(0 <= t < cfg.vocab for t in done[1].output)


def test_more_requests_than_slots(engine):
    cfg, sched = _mk(engine, slots=2)
    for rid in range(5):
        sched.submit(Request(rid=rid,
                             prompt=np.asarray([rid + 1, rid + 2], np.int32),
                             max_new_tokens=3))
    done = sched.run()
    assert sorted(done) == [0, 1, 2, 3, 4]
    assert all(len(r.output) == 3 for r in done.values())


def test_mid_flight_join(engine):
    """A request submitted after ticking starts still completes."""
    cfg, sched = _mk(engine, slots=2)
    sched.submit(Request(rid=1, prompt=np.asarray([3], np.int32),
                         max_new_tokens=6))
    # tick a few times manually, then add a second request
    sched.run(max_ticks=3)
    sched.submit(Request(rid=2, prompt=np.asarray([9, 9], np.int32),
                         max_new_tokens=2))
    done = sched.run()
    assert sorted(done) == [1, 2]


def test_eos_stops_early(engine):
    cfg, sched = _mk(engine)
    # greedy decode is deterministic: discover the first generated token,
    # then use it as the EOS for a second identical request
    sched.submit(Request(rid=1, prompt=np.asarray([5, 6, 7], np.int32),
                         max_new_tokens=4))
    done = sched.run()
    first_tok = done[1].output[0]

    cfg2, sched2 = _mk(engine)
    sched2.submit(Request(rid=2, prompt=np.asarray([5, 6, 7], np.int32),
                          max_new_tokens=8, eos_id=first_tok))
    done2 = sched2.run()
    assert done2[2].output[-1] == first_tok
    assert len(done2[2].output) < 8


def test_deterministic_vs_slot_assignment(engine):
    """The same request produces the same tokens regardless of which other
    requests share the batch (slot isolation)."""
    cfg, sched_a = _mk(engine, slots=2)
    sched_a.submit(Request(rid=1, prompt=np.asarray([11, 12], np.int32),
                           max_new_tokens=3))
    out_alone = sched_a.run()[1].output

    cfg, sched_b = _mk(engine, slots=2)
    sched_b.submit(Request(rid=1, prompt=np.asarray([11, 12], np.int32),
                           max_new_tokens=3))
    sched_b.submit(Request(rid=2, prompt=np.asarray([40, 41, 42], np.int32),
                           max_new_tokens=3))
    out_shared = sched_b.run()[1].output
    assert out_alone == out_shared
