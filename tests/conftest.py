"""Shared test configuration.

Registers a pinned hypothesis profile for CI: ``derandomize=True`` makes
every property suite (the fault plans, chunk parity, cache policies, …)
draw the same example sequence on every run, so a red CI job reproduces
locally from the log with::

    HYPOTHESIS_PROFILE=ci PYTHONPATH=src python -m pytest tests/...

Local runs keep the default profile (randomized exploration keeps
finding new counterexamples); CI exports ``HYPOTHESIS_PROFILE=ci``.
"""

import os

try:
    from hypothesis import settings

    settings.register_profile("ci", derandomize=True, deadline=None,
                              print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:      # hypothesis is a dev extra; suites skip cleanly
    pass
