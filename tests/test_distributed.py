"""Multi-device distribution tests.

These need >1 device, so each test launches a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process must keep seeing 1 CPU device for everything else).  Each
subprocess asserts numerical equality between the sharded step (2x4 or
2x2x2 mesh, shard_map MoE / flash-decode / pipeline) and the single-device
reference.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    script = textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
assert jax.device_count() == 8, jax.device_count()
from repro.configs import get_arch
from repro.distributed.sharding import MeshAxes, param_specs, batch_spec, decode_state_specs
from repro.distributed.step import make_train_step, make_serve_step, make_mesh_ctx
from repro.models.transformer import init_params, init_decode_state, decode_step, forward
from repro.launch.mesh import make_debug_mesh
key = jax.random.PRNGKey(0)
"""


@pytest.mark.slow
def test_sharded_forward_matches_single_device():
    _run(COMMON + """
for arch in ("minicpm-2b", "mixtral-8x7b", "kimi-k2-1t-a32b", "mamba2-2_7b"):
    cfg = get_arch(arch).reduced(n_kv_heads=4 if get_arch(arch).n_heads else 0)
    if cfg.moe is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, n_experts=8, capacity_factor=8.0))
    params = init_params(key, cfg)
    B, S = 4, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    ref, _ = forward(params, cfg, batch, ctx=None, remat=False)

    mesh = make_debug_mesh(2, 4)
    ax = MeshAxes.for_mesh(mesh)
    pspecs = param_specs(params, cfg, mesh, ax)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    params_s = jax.tree.map(lambda x, sh: jax.device_put(x, sh), params, psh)
    batch_s = {"tokens": jax.device_put(batch["tokens"],
                                        NamedSharding(mesh, P(ax.dp, None)))}
    ctx = make_mesh_ctx(mesh)
    with mesh:
        out, _ = jax.jit(lambda p, b: forward(p, cfg, b, ctx=ctx, remat=False))(
            params_s, batch_s)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    scale = float(jnp.abs(ref).max())
    assert err < 2e-2 * max(scale, 1.0), (arch, err, scale)
    print(arch, "ok", err)
""")


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    _run(COMMON + """
for arch in ("glm4-9b", "h2o-danube-3-4b"):
    cfg = get_arch(arch).reduced()
    params = init_params(key, cfg)
    B, S = 4, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)

    # single-device decode reference
    st = init_decode_state(params, cfg, B, context_len=16)
    outs = []
    for t in range(S):
        lg, st = decode_step(params, cfg, st, toks[:, t])
        outs.append(lg)
    ref = jnp.stack(outs, 1)

    mesh = make_debug_mesh(2, 4)
    ax = MeshAxes.for_mesh(mesh)
    pspecs = param_specs(params, cfg, mesh, ax)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    params_s = jax.tree.map(lambda x, sh: jax.device_put(x, sh), params, psh)
    state = init_decode_state(params, cfg, B, context_len=16)
    dspecs = decode_state_specs(state, cfg, mesh, ax)
    dsh = jax.tree.map(lambda s: NamedSharding(mesh, s), dspecs,
                       is_leaf=lambda x: isinstance(x, P))
    state_s = jax.tree.map(lambda x, sh: jax.device_put(x, sh), state, dsh)
    step = jax.jit(make_serve_step(cfg, mesh))
    outs = []
    with mesh:
        for t in range(S):
            lg, state_s = step(params_s, state_s,
                               jax.device_put(toks[:, t],
                                              NamedSharding(mesh, P(ax.dp))))
            outs.append(lg)
    got = jnp.stack(outs, 1)
    err = float(jnp.abs(got.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    assert err < 2e-2, (arch, err)
    print(arch, "decode ok", err)
""")


@pytest.mark.slow
def test_train_step_runs_on_multipod_debug_mesh():
    _run(COMMON + """
from repro.optim.adamw import adamw_init
from repro.optim.schedules import wsd_schedule
from repro.distributed.sharding import opt_state_specs
cfg = get_arch("minicpm-2b").reduced()
params = init_params(key, cfg)
opt = adamw_init(params)
mesh = make_debug_mesh(2, 2, n_pod=2)    # (pod, data, model) = 2x2x2
ax = MeshAxes.for_mesh(mesh)
assert ax.dp == ("pod", "data")
pspecs = param_specs(params, cfg, mesh, ax)
ospecs = opt_state_specs(opt, pspecs, mesh, ax)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
osh = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                   is_leaf=lambda x: isinstance(x, P))
params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
opt = jax.tree.map(lambda x, s: jax.device_put(x, s), opt, osh)
step_fn = jax.jit(make_train_step(cfg, mesh, lr_fn=wsd_schedule(1e-3, 2, 5, 5)))
batch = {"tokens": jax.device_put(
    jax.random.randint(key, (8, 16), 0, cfg.vocab),
    NamedSharding(mesh, P(ax.dp, None)))}
with mesh:
    losses = []
    for s in range(3):
        params, opt, loss = step_fn(params, opt, batch, jnp.asarray(s))
        losses.append(float(loss))
assert all(np.isfinite(losses)), losses
assert losses[2] < losses[0]  # overfits one batch
print("multipod train ok", losses)
""")


@pytest.mark.slow
def test_elastic_remesh_restore():
    _run(COMMON + """
import tempfile
from repro.checkpoint.manager import CheckpointManager
cfg = get_arch("minicpm-2b").reduced()
params = init_params(key, cfg)
mesh_a = make_debug_mesh(2, 4)           # "big" mesh
ax_a = MeshAxes.for_mesh(mesh_a)
pspecs_a = param_specs(params, cfg, mesh_a, ax_a)
psh_a = jax.tree.map(lambda s: NamedSharding(mesh_a, s), pspecs_a,
                     is_leaf=lambda x: isinstance(x, P))
params_a = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh_a)
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, params_a)
    # restore onto a smaller mesh (node failure -> elastic downscale)
    mesh_b = make_debug_mesh(2, 2)
    ax_b = MeshAxes.for_mesh(mesh_b)
    pspecs_b = param_specs(params, cfg, mesh_b, ax_b)
    restored, _, _ = mgr.restore(params, mesh=mesh_b, specs=pspecs_b)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("elastic remesh ok")
""")


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    _run(COMMON + """
from repro.distributed.pipeline import pipeline_forward
mesh = jax.make_mesh((4,), ("pod",))
D = 16
n_layers = 8
keys = jax.random.split(key, n_layers)
blocks = {"w": jnp.stack([jax.random.normal(k, (D, D)) * 0.2 for k in keys])}
def block_fn(h, blk):
    return jnp.tanh(h @ blk["w"])
x = jax.random.normal(key, (8, D))
# sequential reference
ref = x
for i in range(n_layers):
    ref = block_fn(ref, {"w": blocks["w"][i]})
fn = pipeline_forward(block_fn, mesh, stage_axis="pod", microbatches=4)
with mesh:
    got = jax.jit(fn)(x, blocks)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("pipeline ok")
""")


@pytest.mark.slow
def test_resident_expert_decode_matches_single_device():
    """§Perf hillclimb B: resident-expert MoE decode layout is exact."""
    _run(COMMON + """
import dataclasses
cfg = get_arch("kimi-k2-1t-a32b").reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, n_experts=8, capacity_factor=8.0))
params = init_params(key, cfg)
B = 4
toks = jax.random.randint(key, (B, 6), 0, cfg.vocab)
st = init_decode_state(params, cfg, B, 16)
outs = []
for t in range(6):
    lg, st = decode_step(params, cfg, st, toks[:, t])
    outs.append(lg)
ref = jnp.stack(outs, 1)

mesh = make_debug_mesh(2, 4)
ax = MeshAxes.for_mesh(mesh)
pspecs = param_specs(params, cfg, mesh, ax, kind="decode")
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
params_s = jax.tree.map(lambda x, sh: jax.device_put(x, sh), params, psh)
st = init_decode_state(params, cfg, B, 16)
dspecs = decode_state_specs(st, cfg, mesh, ax)
dsh = jax.tree.map(lambda s: NamedSharding(mesh, s), dspecs,
                   is_leaf=lambda x: isinstance(x, P))
st = jax.tree.map(lambda x, sh: jax.device_put(x, sh), st, dsh)
step = jax.jit(make_serve_step(cfg, mesh, resident_experts=True))
outs = []
with mesh:
    for t in range(6):
        lg, st = step(params_s, st,
                      jax.device_put(toks[:, t], NamedSharding(mesh, P(("data",)))))
        outs.append(lg)
got = jnp.stack(outs, 1)
err = float(jnp.abs(got - ref).max())
assert err < 2e-2, err
print("resident-expert decode ok", err)
""")
