"""Benchmark determinism: the fabric sweep's *derived* (simulated) metrics
must be bit-identical across runs, so BENCH comparisons across PRs compare
simulation results, never run-to-run noise.

``collect_derived`` is the pure half of ``benchmarks/fabric_sweep.py`` —
every trace generator is explicitly seeded and no wall-clock numbers leak
into it.  A scaled-down configuration keeps this in the default test tier.
"""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))

import fabric_sweep  # noqa: E402


def test_fabric_sweep_derived_json_identical_across_runs():
    a = fabric_sweep.collect_derived(accesses=2500, host_counts=[1, 2])
    b = fabric_sweep.collect_derived(accesses=2500, host_counts=[1, 2])
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_fabric_sweep_derived_covers_qos_and_ecmp():
    d = fabric_sweep.collect_derived(accesses=2500, host_counts=[1])
    # QoS: weighted run reorders completion — heavy host ends first
    qos = d["qos"]["qos3to1"]
    assert qos["end_ticks"][0] < qos["end_ticks"][1]
    assert qos["own_window_gbps"][0] > d["qos"]["fcfs"]["own_window_gbps"][0]
    # ECMP: both spines carry bytes and aggregate beats single-path
    ecmp, single = d["ecmp"]["ecmp"], d["ecmp"]["single_path"]
    assert all(b > 0 for b in ecmp["spine_bytes"].values())
    assert sum(1 for b in single["spine_bytes"].values() if b == 0) >= 1
    assert ecmp["aggregate_gbps"] > single["aggregate_gbps"]


def test_trace_generator_explicitly_seeded():
    t1 = fabric_sweep._stream_trace(3, n=500)
    t2 = fabric_sweep._stream_trace(3, n=500)
    assert t1 == t2
    assert t1 != fabric_sweep._stream_trace(4, n=500)


# Perf-floor guard over the RECORDED replay benchmark (deterministic — it
# reads the committed results/BENCH_replay.json, so CI compares simulation
# artifacts, never runner-to-runner wall-clock noise).  A PR that commits a
# regressed artifact — a lost exactness bit or a DRAM-lane speedup below
# the pinned floor — fails here.
SPEEDUP_FLOORS = {"dram": 20.0, "pmem": 20.0, "cxl-ssd-cache": 10.0}


def _load_replay_report():
    path = Path(__file__).resolve().parents[1] / "results" / "BENCH_replay.json"
    assert path.exists(), \
        "missing results/BENCH_replay.json; run benchmarks/replay_bench.py"
    with open(path) as fh:
        return json.load(fh)


def test_replay_bench_exactness_flags_recorded_true():
    report = _load_replay_report()
    for dev, lanes in report["devices"].items():
        for lane, v in lanes.items():
            if isinstance(v, dict) and "tick_exact_vs_python" in v:
                assert v["tick_exact_vs_python"], \
                    f"{dev}/{lane} recorded as not tick-exact"
    assert report["devices"]["cxl-ssd-cache"]["pallas"]["decisions_exact"]


# The multi-host stacked-state lane (cached CXL-SSD x 2/4 hosts) carries a
# more modest floor than the single-host lanes: the per-step host race adds
# gather/scatter over the lane axis, and the interpreted baseline is the
# same per-access python cost.
MULTI_SPEEDUP_FLOOR = 5.0


def test_replay_bench_multihost_lane_recorded():
    report = _load_replay_report()
    lanes = report["multihost"]
    assert set(lanes) == {"cxl-ssd-cache x2", "cxl-ssd-cache x4"}
    assert report["multihost_target_speedup"] == MULTI_SPEEDUP_FLOOR
    assert report["multihost_meets_target"] is True
    for name, v in lanes.items():
        assert v["tick_exact_vs_python"], f"{name} recorded as not tick-exact"
        assert v["speedup_vs_python"] >= MULTI_SPEEDUP_FLOOR, \
            f"{name}: recorded fused speedup {v['speedup_vs_python']:.1f}x " \
            f"fell below the pinned {MULTI_SPEEDUP_FLOOR:.0f}x floor"


# Observability must be close to free: the scan_metrics lane (same scan,
# plus the in-scan MetricsSpec carry) may cost at most 10% of the bare
# scan's recorded steady-state throughput.
METRICS_OVERHEAD_CEILING = 0.10


def test_replay_bench_metrics_overhead_recorded_under_ceiling():
    report = _load_replay_report()
    for dev in SPEEDUP_FLOORS:
        lane = report["devices"][dev].get("scan_metrics")
        assert lane is not None, \
            f"{dev}: scan_metrics lane missing from the recorded artifact"
        assert lane["tick_exact_vs_python"], \
            f"{dev}: metrics lane recorded as not tick-exact"
        assert lane["overhead_vs_scan"] < METRICS_OVERHEAD_CEILING, \
            f"{dev}: recorded metrics overhead " \
            f"{lane['overhead_vs_scan'] * 100:.1f}% breaches the " \
            f"{METRICS_OVERHEAD_CEILING * 100:.0f}% ceiling"


def test_replay_bench_metrics_summaries_recorded():
    """The artifact carries the counter/percentile summaries the
    observability layer promises (and they are internally consistent)."""
    report = _load_replay_report()
    for dev in SPEEDUP_FLOORS:
        lane = report["devices"][dev]["scan_metrics"]
        assert lane["p50_ticks"] is not None
        assert lane["p99_ticks"] is not None
        assert lane["p50_ticks"] <= lane["p99_ticks"]
        assert lane["counters"]["accesses"] == report["n_accesses"]
        assert 0.0 <= lane["hit_rate"] <= 1.0
        assert lane["write_amplification"] >= 1.0
    assert report["devices"]["cxl-ssd-cache"]["scan_metrics"]["hit_rate"] > 0


def test_fault_lane_derived_json_identical_across_runs():
    """The fault-injected replay lane is a pure function of its seeds: two
    runs must produce byte-identical derived JSON (counters, latency
    totals, exactness bits — no wall-clock numbers)."""
    import replay_bench

    a = replay_bench.collect_fault_derived(accesses=2000)
    b = replay_bench.collect_fault_derived(accesses=2000)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_replay_bench_fault_lane_recorded():
    """The committed artifact carries the fault-injected lane: tick-exact,
    metrics-equal, and with every injected fault class actually firing."""
    report = _load_replay_report()
    faults = report.get("faults")
    assert faults is not None, \
        "faults section missing from results/BENCH_replay.json"
    transport = faults["transport@spine_leaf_ecmp"]
    assert transport["tick_exact_vs_python"] is True
    assert transport["metrics_equal"] is True
    assert transport["faults"]["link_retries"] > 0
    assert transport["faults"]["degraded_accesses"] > 0
    assert transport["faults"]["poisoned_reads"] > 0
    nand = faults["nand@multihost_x2"]
    assert nand["tick_exact_vs_python"] is True
    assert nand["metrics_equal"] is True
    assert nand["faults"]["nand_read_retries"] > 0


def test_streaming_lane_derived_json_identical_across_runs():
    """The streaming lane's derived results are a pure function of the
    seeds (exactness bits, metrics parity, the analytic memory model —
    no wall-clock or measured-peak numbers)."""
    import replay_bench

    a = replay_bench.collect_streaming_derived(accesses=2000)
    b = replay_bench.collect_streaming_derived(accesses=2000)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_streaming_derived_exact_and_bounded():
    """Streamed == one-shot on the derived lane, and the analytic input
    bound is O(chunk): it scales with the chunk size, not the trace."""
    import replay_bench

    d = replay_bench.collect_streaming_derived(accesses=2000,
                                               chunk_sizes=(64, 256))
    c64, c256 = d["chunk_64"], d["chunk_256"]
    for lane in (c64, c256):
        assert lane["tick_exact_vs_oneshot"] is True
        assert lane["metrics_equal"] is True
    assert c256["peak_input_bound_bytes"] == \
        4 * c64["peak_input_bound_bytes"]
    assert c64["peak_input_bound_bytes"] < d["trace_input_bytes"]


def test_replay_bench_streaming_lane_recorded():
    """The committed artifact carries the >=1M-access streaming lane:
    tick-exact at every chunk size, with peak input residency growing
    with the chunk — not the trace."""
    report = _load_replay_report()
    lane = report.get("streaming")
    assert lane is not None, \
        "streaming section missing from results/BENCH_replay.json"
    assert lane["n_accesses"] >= 1_000_000
    assert len(lane["chunks"]) >= 2
    bounds = {}
    for ch, v in lane["chunks"].items():
        assert v["tick_exact_vs_oneshot"] is True, \
            f"chunk {ch} recorded as not tick-exact"
        # the analytic O(chunk) model: (depth + 1) windows of
        # chunk * row_bytes, far below the full trace's input bytes
        assert v["peak_input_bound_bytes"] == \
            (lane["prefetch_depth"] + 1) * v["chunk_input_bytes"]
        assert v["peak_input_bound_bytes"] < lane["trace_input_bytes"]
        assert v["peak_buffered_bytes"] <= v["peak_input_bound_bytes"]
        bounds[int(ch)] = v["peak_input_bound_bytes"]
    small, big = min(bounds), max(bounds)
    assert bounds[big] * small == bounds[small] * big, \
        "input bound must scale linearly with chunk size"
    # streamed == one-shot scalar summaries, recorded in the artifact
    assert all(v["tick_exact_vs_oneshot"]
               for v in lane["derived"].values()
               if isinstance(v, dict) and "tick_exact_vs_oneshot" in v)


def test_replay_bench_availability_derived_identical_across_runs():
    """The fleet availability sweep (vmapped fault-seed lane) is a pure
    function of its seeds: two runs emit byte-identical derived JSON
    (tail percentiles, availability curves, fault counters — no
    wall-clock numbers), so BENCH availability diffs across PRs are
    always simulation changes."""
    import replay_bench

    kw = dict(host_counts=(2,), n_seeds=3, accesses=96)
    a = replay_bench.collect_availability_derived(**kw)
    b = replay_bench.collect_availability_derived(**kw)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert a["hosts_x2"]["tick_exact_vs_python"] is True


def test_replay_bench_availability_lane_recorded():
    """The committed artifact carries the 4- and 8-host availability
    sweeps: every per-seed lane verified tick-exact against the
    interpreted driver, the shared down window visible as a dip in the
    seed-averaged reachable-fraction curve, and live fault activity."""
    report = _load_replay_report()
    avail = report.get("availability")
    assert avail is not None, \
        "availability section missing from results/BENCH_replay.json"
    for key in ("hosts_x4", "hosts_x8"):
        lane = avail[key]
        assert lane["tick_exact_vs_python"] is True, \
            f"{key} recorded as not tick-exact vs the interpreted driver"
        assert len(lane["seeds"]) == avail["n_seeds"]
        curve = [lane["availability_curve"][str(w)]
                 for w in range(lane["num_windows"])]
        assert min(curve) < 1.0, \
            f"{key}: down window left no dip in the availability curve"
        assert lane["degraded_fraction"]["max"] > 0
        assert lane["tail_p99_ticks"]["min"] <= lane["tail_p99_ticks"]["max"]
        assert any(s["link_retries"] > 0 for s in lane["seeds"].values())


def test_fleet_lane_derived_json_identical_across_runs():
    """The rack-scale fleet lane is a pure function of its workload seed:
    two runs must produce byte-identical derived JSON (exactness bits,
    mesh shape, tail percentiles — no wall-clock numbers)."""
    import replay_bench

    kw = dict(num_hosts=8, accesses=120, num_pods=2)
    a = replay_bench.collect_fleet_derived(**kw)
    b = replay_bench.collect_fleet_derived(**kw)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert a["tick_exact_sharded_vs_unsharded"] is True
    assert a["tick_exact_vs_python"] is True


def test_replay_bench_fleet_lane_recorded():
    """The committed artifact carries the rack-scale fleet lane: >=64
    hosts, >=100k on-device-synthesized accesses on a multi-pod fabric,
    the sharded lane recorded tick-exact against the unsharded lane and
    the interpreted driver at that scale."""
    report = _load_replay_report()
    fleet = report.get("fleet")
    assert fleet is not None, \
        "fleet section missing from results/BENCH_replay.json"
    assert fleet["hosts"] >= 64
    assert fleet["n_accesses"] >= 100_000
    assert fleet["n_accesses"] == fleet["hosts"] * fleet["accesses_per_host"]
    assert fleet["workload"]["synthesis"].startswith("jnp")
    assert fleet["fabric"]["kind"] == "multi_pod"
    assert fleet["fabric"]["num_pods"] >= 2
    assert fleet["tick_exact_sharded_vs_unsharded"] is True
    assert fleet["metrics_equal_sharded_vs_unsharded"] is True
    assert fleet["tick_exact_vs_python"] is True
    mesh = fleet["mesh"]
    assert mesh["device_count"] * mesh["hosts_per_device"] == fleet["hosts"]


def test_replay_bench_lane_merge_map_covers_fleet():
    """--lanes re-records single derived lanes append-only; the map must
    cover every derived-only section of the artifact."""
    import replay_bench

    assert set(replay_bench.LANE_COLLECTORS) == \
        {"faults", "availability", "fleet"}
    for key, (section, fn) in replay_bench.LANE_COLLECTORS.items():
        assert callable(fn)
        assert section in _load_replay_report()


def test_replay_bench_speedups_meet_pinned_floor():
    report = _load_replay_report()
    assert report["meets_target"] is True
    # the benchmark's own targets must match this guard's pins — a target
    # bumped in replay_bench.py without updating the floor (or vice versa)
    # would make meets_target and CI test different thresholds
    assert report["target_speedup"] == SPEEDUP_FLOORS
    for dev, floor in SPEEDUP_FLOORS.items():
        best = report["devices"][dev]["best_exact_speedup"]
        assert best >= floor, \
            f"{dev}: recorded best exact-lane speedup {best:.1f}x fell " \
            f"below the pinned {floor:.0f}x floor"
