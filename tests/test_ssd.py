"""SimpleSSD-like backend: PAL timing, FTL mapping + GC, HIL interface."""

import pytest

from repro.core.engine import us, to_us
from repro.core.ssd.ftl import FTL
from repro.core.ssd.hil import HIL, InitSimpleSSDEngine, SSDConfig
from repro.core.ssd.pal import NANDTiming, PAL


class TestPAL:
    def test_read_latency_is_tr_plus_xfer(self):
        pal = PAL(channels=1, dies_per_channel=1)
        done = pal.read_page(0, ppn=0)
        expect = pal.timing.read_ticks + pal.timing.xfer_ticks(4096)
        assert done == expect

    def test_program_slower_than_read(self):
        pal = PAL()
        r = pal.read_page(0, 0)
        pal2 = PAL()
        w = pal2.program_page(0, 0)
        assert w > r

    def test_same_die_serializes(self):
        pal = PAL(channels=1, dies_per_channel=1)
        d1 = pal.read_page(0, 0)
        d2 = pal.read_page(0, 0)
        assert d2 >= 2 * pal.timing.read_ticks

    def test_channel_parallelism(self):
        # Two reads to different channels overlap; same channel serializes
        # on the bus but overlaps array time.
        par = PAL(channels=2, dies_per_channel=1)
        a = par.read_page(0, 0)   # channel 0
        b = par.read_page(0, 1)   # channel 1
        assert max(a, b) < 2 * par.timing.read_ticks + 2 * par.timing.xfer_ticks(4096)
        ser = PAL(channels=1, dies_per_channel=2)
        c = ser.read_page(0, 0)
        d = ser.read_page(0, 1)  # same channel, different die
        assert abs(max(c, d) - (ser.timing.read_ticks + 2 * ser.timing.xfer_ticks(4096))) \
            <= ser.timing.xfer_ticks(4096)

    def test_program_suspend_lets_reads_preempt(self):
        pal = PAL(channels=1, dies_per_channel=1)
        pal.program_page(0, 0)
        t_read = pal.read_page(pal.timing.xfer_ticks(4096), 0)
        # Without suspend the read would wait tPROG (660us); with suspend it
        # completes in ~t_suspend + tR + xfer.
        assert to_us(t_read) < pal.timing.t_prog_us / 2

    def test_low_latency_profile(self):
        lo, hi = NANDTiming.low_latency(), NANDTiming.mlc()
        assert lo.t_read_us < hi.t_read_us
        assert lo.t_prog_us < hi.t_prog_us


class TestFTL:
    def _ftl(self, blocks=8, ppb=16):
        pal = PAL(channels=1, dies_per_channel=1)
        return FTL(pal, total_pages=blocks * ppb, pages_per_block=ppb, op_ratio=0.25)

    def test_read_unwritten_is_cheap(self):
        ftl = self._ftl()
        t = ftl.read(0, lpn=5)
        assert t < ftl.pal.timing.read_ticks  # no NAND array access

    def test_write_then_read(self):
        ftl = self._ftl()
        t = ftl.write(0, lpn=5)
        assert t >= ftl.pal.timing.prog_ticks
        t2 = ftl.read(t, lpn=5)
        assert t2 > t

    def test_overwrite_invalidates(self):
        ftl = self._ftl()
        ftl.write(0, lpn=1)
        ppn_old = ftl.l2p[1]
        ftl.write(0, lpn=1)
        assert ftl.l2p[1] != ppn_old
        assert ppn_old not in ftl.p2l

    def test_gc_reclaims_space_and_counts_wa(self):
        ftl = self._ftl(blocks=8, ppb=16)
        t = 0
        # hammer a small LPN set so most pages are invalid garbage
        for i in range(600):
            t = ftl.write(t, lpn=i % 10)
        assert ftl.stats["gc_runs"] > 0
        assert ftl.stats["gc_erases"] > 0
        assert ftl.write_amplification >= 1.0
        # all live mappings intact
        for lpn in range(10):
            assert lpn in ftl.l2p

    def test_overfill_raises(self):
        ftl = self._ftl(blocks=4, ppb=4)
        with pytest.raises(RuntimeError):
            t = 0
            for i in range(1000):  # way beyond capacity with all-unique LPNs
                t = ftl.write(t, lpn=i)


class TestHIL:
    def test_page_split(self):
        hil = HIL(SSDConfig(capacity_bytes=1 << 20))
        hil.read(0, addr=4000, size=200)  # straddles pages 0 and 1
        assert hil.stats["read_pages"] == 2

    def test_write_then_is_written(self):
        hil = HIL(SSDConfig(capacity_bytes=1 << 20))
        assert not hil.is_written(8192)
        hil.write(0, addr=8192, size=100)
        assert hil.is_written(8192)

    def test_tick_contract_monotonic(self):
        hil = InitSimpleSSDEngine(SSDConfig(capacity_bytes=1 << 20))
        t1 = hil.write(0, 0, 4096)
        t2 = hil.read(t1, 0, 4096)
        assert t2 > t1 > 0
