"""Deterministic fault injection and graceful degradation (PR 7).

Covers the four fault classes end to end: the :class:`FaultPlan` schedule
is a pure function of ``(seed, config)`` (hash twins agree across the
scalar / numpy / traced-jnp implementations), routing degrades gracefully
under down windows (ECMP exclusion, failover reroutes, typed
:class:`DeviceUnreachable` when a device is isolated), poison rides the
flit encode/decode roundtrip as status, and — the tick-identity contract —
the fused scan replays fault-injected traces access-for-access equal to
the interpreted drivers, or refuses with :class:`ReplayUnsupported`.
"""

import numpy as np
import pytest
from jax.experimental import enable_x64

from golden.scenarios import ServiceTap
from repro.core.cache.dram_cache import DRAMCacheConfig
from repro.core.cxl.flit import CXLCommand, CXLFlit, decode_flit, encode_flit
from repro.core.devices import make_device
from repro.core.fabric import Fabric, MemoryPool
from repro.core.faults import (DeviceUnreachable, FaultConfig, FaultPlan,
                               erase_fails_jnp, fault_hash, fault_hash_np,
                               install, nand_read_retries_jnp)
from repro.core.replay import (AssocReplayEngine, MultiHostReplay,
                               ReplayEngine, ReplayUnsupported)
from repro.core.replay.metrics import MetricsSpec
from repro.core.workloads.driver import MultiHostDriver, TraceDriver

CACHE_KW = dict(capacity_bytes=16 * 4096, mshr_entries=4, writeback_buffer=2)
DEVICES = ["dram", "cxl-dram", "pmem", "cxl-ssd", "cxl-ssd-cache"]
OUT = 8


def _mk_device(name):
    if name == "cxl-ssd-cache":
        return make_device(name,
                           cache_cfg=DRAMCacheConfig(policy="lru", **CACHE_KW))
    return make_device(name)


def _mount(name, topo="spine_leaf", ecmp=False, qos=None):
    kw = dict(num_hosts=2, num_devices=2)
    if topo == "spine_leaf":
        kw.update(num_leaves=2, num_spines=2)
    if qos:
        kw["qos_weights"] = qos
    fab = Fabric.build(topo, ecmp=ecmp, **kw)
    return fab.mount("h0", "d0", _mk_device(name))


def _trace(seed, n=160, pages=24, write_frac=0.3):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, pages, n) * 4096 + rng.integers(0, 64, n) * 64
    writes = rng.random(n) < write_frac
    return [(int(a), 64, bool(w)) for a, w in zip(addrs, writes)]


def _parity(mk, cfg, seed=7, trace=None, counters_only=False):
    """python tap latencies == fused scan latencies, and (unless QoS's
    pre-existing single-host throttle-count divergence is in play) the
    full metrics bundles — fault counters included — byte-equal."""
    trace = trace or _trace(11)
    t1 = mk()
    install(FaultPlan(cfg, seed=seed), [t1])
    tap = ServiceTap(t1)
    TraceDriver(tap, outstanding=OUT).run(trace)
    t2 = mk()
    install(FaultPlan(cfg, seed=seed), [t2])
    res = ReplayEngine(t2, outstanding=OUT, metrics=MetricsSpec()).run(trace)
    assert np.array_equal(np.asarray(tap.latencies),
                          np.asarray(res.latency_ticks))
    t3 = mk()
    install(FaultPlan(cfg, seed=seed), [t3])
    py = TraceDriver(t3, outstanding=OUT, engine="python",
                     metrics=MetricsSpec()).run(trace)
    jp, js = py.metrics.to_jsonable(), res.metrics.to_jsonable()
    if counters_only:
        assert jp["faults"] == js["faults"]
    else:
        assert jp == js
    return res, js


# ------------------------------------------------------------ hash twins
def test_fault_hash_twins_agree():
    ords = np.arange(512, dtype=np.int64)
    for salt in (0xA1A1, 0xC3C3, 0xE5E5):
        scalar = np.asarray([fault_hash(9, salt, 5, int(o)) for o in ords],
                            np.uint64)
        assert np.array_equal(scalar, fault_hash_np(9, salt, 5, ords))


def test_nand_jnp_twins_agree():
    plan = FaultPlan(FaultConfig(nand_read_retry_rate=0.35,
                                 nand_read_retry_max=2,
                                 erase_fail_rate=0.4), seed=3)
    statics = plan.nand_statics()
    with enable_x64():
        import jax.numpy as jnp
        for seq in range(200):
            assert plan.nand_read_retries(seq) == int(
                nand_read_retries_jnp(statics, jnp.int64(seq)))
            assert plan.erase_fails(seq) == bool(
                erase_fails_jnp(statics, jnp.int64(seq)))


def test_link_and_poison_vector_twins_agree():
    plan = FaultPlan(FaultConfig(link_retry_rate=0.3, link_retry_max=3,
                                 poison_rate=0.2), seed=5)
    ords = np.arange(400, dtype=np.int64)
    scalar = [plan.link_retries(("s0", "sp1"), int(o)) for o in ords]
    assert np.array_equal(np.asarray(scalar),
                          plan.link_retries_np(("s0", "sp1"), ords))
    writes = (ords % 3) == 0
    scalar_p = [plan.poisoned(0, int(o), bool(w))
                for o, w in zip(ords, writes)]
    assert np.array_equal(np.asarray(scalar_p),
                          plan.poisoned_np(0, ords, writes))


def test_plan_is_pure_function_of_seed_and_config():
    cfg = FaultConfig(link_retry_rate=0.25, nand_read_retry_rate=0.3,
                      poison_rate=0.1)
    a, b = FaultPlan(cfg, seed=42), FaultPlan(cfg, seed=42)
    ords = np.arange(300, dtype=np.int64)
    assert np.array_equal(a.link_retries_np(("u", "v"), ords),
                          b.link_retries_np(("u", "v"), ords))
    assert np.array_equal(a.poisoned_np(1, ords, ords % 2 == 0),
                          b.poisoned_np(1, ords, ords % 2 == 0))
    assert [a.nand_read_retries(s) for s in range(100)] \
        == [b.nand_read_retries(s) for s in range(100)]
    other = FaultPlan(cfg, seed=43)
    assert not np.array_equal(a.link_retries_np(("u", "v"), ords),
                              other.link_retries_np(("u", "v"), ords))


# ----------------------------------------------------- down-window routing
def test_down_window_is_directed_both_ways_and_bounded():
    plan = FaultPlan(FaultConfig(down_links=(("a", "b", 10, 20),)), seed=0)
    assert plan.down_links_at(9) == frozenset()
    assert plan.down_links_at(10) == frozenset({("a", "b"), ("b", "a")})
    assert plan.down_links_at(19) == frozenset({("a", "b"), ("b", "a")})
    assert plan.down_links_at(20) == frozenset()


def test_routing_select_degrades_then_raises_spine_leaf():
    fab = Fabric.build("spine_leaf", num_hosts=2, num_devices=2,
                       num_leaves=2, num_spines=2, ecmp=True)
    rt = fab.routing
    # two equal-cost spine paths h0 -> d0; one spine down -> the other
    one = frozenset({("s0", "sp0"), ("sp0", "s0")})
    paths = rt.paths("h0", "d0", down=one)
    assert len(paths) == 1 and "sp1" in paths[0]
    assert "sp1" in rt.select("h0", "d0", 0, down=one)
    # both spines down from the leaf -> no route at all
    both = frozenset({("s0", "sp0"), ("s0", "sp1")})
    with pytest.raises(DeviceUnreachable):
        rt.select("h0", "d0", 0, down=both)


def test_routing_failover_then_raises_mesh():
    fab = Fabric.build("mesh", num_hosts=2, num_devices=2)
    rt = fab.routing
    nominal = rt.path("h0", "d0")
    sw = [n for n in nominal if n.startswith("s")]
    # cut the first switch-to-switch hop of the nominal path: a longer
    # recomputed route must take over
    cut = frozenset({(sw[0], sw[1]), (sw[1], sw[0])})
    alt = rt.select("h0", "d0", 0, down=cut)
    assert alt != nominal and alt[0] == "h0" and alt[-1] == "d0"
    # sever every edge out of h0's switch -> isolated
    edges = {(u, v) for (u, v) in fab.ports if u == sw[0] or v == sw[0]}
    with pytest.raises(DeviceUnreachable):
        rt.select("h0", "d0", 0, down=frozenset(edges))


def test_isolated_device_raises_through_service():
    fab = Fabric.build("direct", num_pairs=2)
    tgt = fab.mount("h0", "d0", _mk_device("dram"))
    install(FaultPlan(FaultConfig(down_links=(("h0", "d0", 0, 1000),)),
                      seed=1), [tgt])
    with pytest.raises(DeviceUnreachable):
        TraceDriver(tgt, outstanding=OUT).run(_trace(3, n=8))


# --------------------------------------------------- poison flit roundtrip
def test_poison_flit_roundtrip_property():
    rng = np.random.default_rng(0)
    for _ in range(200):
        flit = CXLFlit(opcode=CXLCommand.S2MDRS,
                       addr=int(rng.integers(0, 1 << 40)) * 64,
                       tag=int(rng.integers(0, 1 << 16)),
                       poison=bool(rng.integers(0, 2)),
                       dirty_evict=bool(rng.integers(0, 2)))
        back = decode_flit(encode_flit(flit))
        assert back.poison == flit.poison
        assert back.dirty_evict == flit.dirty_evict
        assert (back.addr, back.tag) == (flit.addr, flit.tag)


def test_decode_rejects_reserved_flag_bits():
    raw = bytearray(encode_flit(CXLFlit(opcode=CXLCommand.S2MDRS,
                                        addr=0, tag=1)))
    # flags byte is at offset 15 (<BBBHQH is unpadded: 1+1+1+2+8+2)
    raw[15] |= 0b100
    with pytest.raises(ValueError, match="reserved flag bits"):
        decode_flit(bytes(raw))
    raw[15] = 0b01      # poison alone still decodes
    assert decode_flit(bytes(raw)).poison


# ------------------------------------------------- python == scan parity
@pytest.mark.parametrize("name", DEVICES)
def test_parity_random_plan_per_device(name):
    """Every paper device under a randomized (but seeded) mixed fault plan
    on an ECMP spine-leaf mount: per-access latencies and fault counters
    must be tick/byte-identical between the interpreted driver and the
    fused scan."""
    rng = np.random.default_rng(sum(ord(c) for c in name))
    kw = dict(link_retry_rate=float(rng.uniform(0.05, 0.4)),
              link_retry_max=int(rng.integers(1, 4)),
              poison_rate=float(rng.uniform(0.0, 0.2)))
    if rng.random() < 0.5:
        first = int(rng.integers(0, 60))
        kw["down_links"] = (("s0", "sp0", first,
                             first + int(rng.integers(20, 80))),)
    if name in ("cxl-ssd", "cxl-ssd-cache"):
        kw["nand_read_retry_rate"] = float(rng.uniform(0.1, 0.4))
    _parity(lambda: _mount(name, ecmp=True), FaultConfig(**kw),
            seed=int(rng.integers(0, 1 << 16)))


def test_parity_failover_reroute_mesh():
    res, js = _parity(lambda: _mount("cxl-dram", topo="mesh"),
                      FaultConfig(down_links=(("s0_0", "s0_1", 10, 70),)))
    assert js["faults"]["failovers"] > 0


def test_parity_qos_latencies_and_fault_counters():
    # full-bundle equality is excluded on single-host QoS mounts: the
    # interpreted qos_throttle_events counter diverges there even without
    # faults (pre-existing, unpinned); latencies + fault counters must agree
    _parity(lambda: _mount("dram", ecmp=True,
                           qos={"h0": 3.0, "h1": 1.0}),
            FaultConfig(link_retry_rate=0.2,
                        down_links=(("s0", "sp1", 30, 100),)),
            counters_only=True)


def test_poison_surfaces_as_status_not_latency():
    cfg = FaultConfig(poison_rate=0.25)
    res, js = _parity(lambda: _mount("pmem"), cfg, seed=9)
    plan = FaultPlan(cfg, seed=9)
    trace = _trace(11)
    writes = np.asarray([w for _, _, w in trace])
    expect = plan.poisoned_np(0, np.arange(len(trace), dtype=np.int64),
                              writes)
    assert np.array_equal(res.poison_flags, expect)
    assert js["faults"]["poisoned_reads"] == int(expect.sum())
    # clean twin: identical latencies — poison is status, never latency
    t_clean = _mount("pmem")
    clean = ReplayEngine(t_clean, outstanding=OUT).run(trace)
    t_f = _mount("pmem")
    install(plan, [t_f])
    faulted = ReplayEngine(t_f, outstanding=OUT).run(trace)
    assert np.array_equal(clean.latency_ticks, faulted.latency_ticks)


def _mh_targets(plan_cfg=None, seed=5, qos=False, ecmp=False):
    kw = dict(num_hosts=2, num_devices=2, num_leaves=2, num_spines=2)
    if qos:
        kw["qos_weights"] = {"h0": 2.0, "h1": 1.0}
    fab = Fabric.build("spine_leaf", ecmp=ecmp, **kw)
    tgts = [fab.mount(f"h{i}", f"d{i}", _mk_device("cxl-ssd-cache"))
            for i in range(2)]
    if plan_cfg is not None:
        install(FaultPlan(plan_cfg, seed=seed), tgts)
    return tgts


def test_parity_multihost_nand_qos_ecmp():
    cfg = FaultConfig(nand_read_retry_rate=0.35)
    traces = [_trace(21, n=200, write_frac=0.5), _trace(22, n=200,
                                                        write_frac=0.5)]
    py = MultiHostDriver(_mh_targets(cfg), outstanding=OUT,
                         metrics=MetricsSpec()).run(traces)
    eng = MultiHostReplay(_mh_targets(cfg), outstanding=OUT,
                          metrics=MetricsSpec())
    rp, lat = eng.run_recorded(traces)
    taps = [ServiceTap(t) for t in _mh_targets(cfg)]
    MultiHostDriver(taps, outstanding=OUT).run(traces)
    for tap, l in zip(taps, lat):
        assert np.array_equal(np.asarray(tap.latencies), np.asarray(l))
    jp, js = py.metrics.to_jsonable(), rp.metrics.to_jsonable()
    assert jp == js
    assert js["faults"]["nand_read_retries"] > 0
    # QoS + ECMP multihost mounts fuse too (NAND-only plan)
    py2 = MultiHostDriver(_mh_targets(cfg, qos=True, ecmp=True),
                          outstanding=OUT, metrics=MetricsSpec()).run(traces)
    rp2 = MultiHostReplay(_mh_targets(cfg, qos=True, ecmp=True),
                          outstanding=OUT, metrics=MetricsSpec()).run(traces)
    assert py2.metrics.to_jsonable() == rp2.metrics.to_jsonable()
    assert py2.elapsed_ticks == rp2.elapsed_ticks


# ---------------------------------------- multi-host transport parity
def _mh_parity(cfg, seed=5, qos=False, ecmp=False, chunk=None):
    """Fused multi-host replay under transport faults: per-host latency
    streams and the full metrics bundle (fault counters included) must be
    tick/byte-identical to the interpreted MultiHostDriver."""
    traces = [_trace(21, n=120, write_frac=0.5),
              _trace(22, n=120, write_frac=0.5)]
    py = MultiHostDriver(_mh_targets(cfg, seed, qos, ecmp),
                         outstanding=OUT, metrics=MetricsSpec()).run(traces)
    eng = MultiHostReplay(_mh_targets(cfg, seed, qos, ecmp),
                          outstanding=OUT, metrics=MetricsSpec())
    rp, lat = eng.run_recorded(traces, chunk_size=chunk)
    taps = [ServiceTap(t) for t in _mh_targets(cfg, seed, qos, ecmp)]
    MultiHostDriver(taps, outstanding=OUT).run(traces)
    for tap, l in zip(taps, lat):
        assert np.array_equal(np.asarray(tap.latencies), np.asarray(l))
    js = rp.metrics.to_jsonable()
    assert py.metrics.to_jsonable() == js
    return rp, js


def test_parity_multihost_link_retries():
    rp, js = _mh_parity(FaultConfig(link_retry_rate=0.3))
    assert js["faults"]["link_retries"] > 0


def test_parity_multihost_port_down_ecmp_and_failover():
    rp, js = _mh_parity(FaultConfig(down_links=(("s0", "sp0", 20, 90),)),
                        ecmp=True)
    assert js["faults"]["degraded_accesses"] > 0
    # non-ECMP spine-leaf: the same window forces failover reroutes
    rp2, js2 = _mh_parity(FaultConfig(down_links=(("s0", "sp0", 20, 90),)))
    assert js2["faults"]["failovers"] > 0


def test_parity_multihost_poison_status():
    rp, js = _mh_parity(FaultConfig(poison_rate=0.2))
    assert js["faults"]["poisoned_reads"] > 0


def test_parity_multihost_mixed_qos_ecmp():
    rp, js = _mh_parity(FaultConfig(link_retry_rate=0.2,
                                    down_links=(("s0", "sp1", 30, 100),),
                                    poison_rate=0.1),
                        qos=True, ecmp=True)
    for k in ("link_retries", "degraded_accesses", "poisoned_reads"):
        assert js["faults"][k] > 0


def test_multihost_fault_flags_exposed_for_availability():
    cfg = FaultConfig(down_links=(("s0", "sp0", 20, 90),))
    traces = [_trace(21, n=120), _trace(22, n=120)]
    eng = MultiHostReplay(_mh_targets(cfg, ecmp=True), outstanding=OUT)
    eng.run(traces)
    deg, fo = eng.fault_flags
    assert deg.shape == (2, 120) and fo.shape == (2, 120)
    assert deg[:, :20].sum() == 0 and deg[:, 20:90].any()


def test_multihost_unreachable_raises_at_prepare():
    # both spines down for the whole run: no surviving route, typed error
    cfg = FaultConfig(down_links=(("s0", "sp0", 0, 1000),
                                  ("s0", "sp1", 0, 1000)))
    traces = [_trace(31, n=16), _trace(32, n=16)]
    with pytest.raises(DeviceUnreachable):
        MultiHostReplay(_mh_targets(cfg, ecmp=True)).run(traces)


# ------------------------------------------------------ typed refusals
def test_multihost_pool_refuses_transport_faults_naming_classes():
    from repro.core.devices import DRAMDevice
    fab = Fabric.build("two_level", num_hosts=2, num_devices=2,
                       num_leaves=2)
    pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
    views = pool.views(["h0", "h1"])
    # install() refuses pool views, so wire the plan onto the fabric the
    # way a mounted topology would carry it
    fab.fault_plan = FaultPlan(FaultConfig(link_retry_rate=0.3,
                                           down_links=(("s0", "l0", 0,
                                                        50),)), seed=2)
    traces = [_trace(31, n=16), _trace(32, n=16)]
    with pytest.raises(ReplayUnsupported,
                       match="link-retry, port-down.*pool address "
                             "interleaving.*engine='python'"):
        MultiHostReplay(views).run(traces)


def test_assoc_and_pallas_refusals_name_fault_class_and_lane():
    tgt = _mount("dram")
    install(FaultPlan(FaultConfig(link_retry_rate=0.3,
                                  poison_rate=0.1), seed=2), [tgt])
    with pytest.raises(ReplayUnsupported,
                       match="link-retry, poison.*engine='scan'"):
        AssocReplayEngine(tgt, outstanding=OUT).run(_trace(4, n=32))
    from repro.core.replay.pallas_engine import run_pallas
    dev = _mk_device("cxl-ssd-cache")
    install(FaultPlan(FaultConfig(nand_read_retry_rate=0.3), seed=2), [dev])
    addrs = np.asarray([a for a, _, _ in _trace(4, n=32)], np.int64)
    writes = np.asarray([w for _, _, w in _trace(4, n=32)], bool)
    with pytest.raises(ReplayUnsupported, match="NAND.*engine='scan'"):
        run_pallas(dev, addrs, writes)
    # an inert plan (all rates zero) constrains nothing
    t2 = _mount("dram")
    install(FaultPlan(FaultConfig(), seed=2), [t2])
    AssocReplayEngine(t2, outstanding=OUT).run(_trace(4, n=32))


def test_pool_views_refuse_fault_install():
    from repro.core.devices import DRAMDevice
    fab = Fabric.build("two_level", num_hosts=2, num_devices=2, num_leaves=2)
    pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
    views = pool.views(["h0", "h1"])
    with pytest.raises(TypeError):
        install(FaultPlan(FaultConfig(link_retry_rate=0.1), seed=0), views)


# ------------------------------------------------------- perfetto export
def test_perfetto_export_carries_fault_instants(tmp_path):
    import json

    from repro.obs import write_perfetto

    tgt = _mount("dram", ecmp=True)
    install(FaultPlan(FaultConfig(link_retry_rate=0.3,
                                  poison_rate=0.1), seed=4), [tgt])
    res = ReplayEngine(tgt, outstanding=OUT,
                       metrics=MetricsSpec()).run(_trace(11))
    doc = json.load(open(write_perfetto(res, str(tmp_path / "t.json"))))
    events = doc["traceEvents"]
    procs = {e["args"]["name"] for e in events
             if e["name"] == "process_name"}
    assert "faults" in procs
    instants = [e for e in events if e["ph"] == "i"]
    assert any(e["name"].startswith("link_retries=") for e in instants)
    assert any(e["name"].startswith("poisoned_reads=") for e in instants)
    summary = [e for e in events if e["name"] == "fault_counters"]
    assert summary and summary[0]["args"]["link_retries"] > 0
    # fault-free runs export no faults process (schema unchanged)
    clean = ReplayEngine(_mount("dram"), outstanding=OUT,
                         metrics=MetricsSpec()).run(_trace(11))
    doc2 = json.load(open(write_perfetto(clean, str(tmp_path / "c.json"))))
    procs2 = {e["args"]["name"] for e in doc2["traceEvents"]
              if e["name"] == "process_name"}
    assert "faults" not in procs2


def test_perfetto_export_renders_down_window_spans(tmp_path):
    import json

    from repro.core.replay.metrics import down_window_spans
    from repro.obs import write_perfetto

    cfg = FaultConfig(down_links=(("s0", "sp0", 30, 90),))
    tgt = _mount("dram", ecmp=True)
    plan = install(FaultPlan(cfg, seed=4), [tgt])
    res = ReplayEngine(tgt, outstanding=OUT,
                       metrics=MetricsSpec()).run(_trace(11))
    iss = np.cumsum(np.full(160, 100, np.int64))
    spans = down_window_spans(plan, [iss], hosts=["h0"])
    assert spans and spans[0]["link"] == "s0<->sp0"
    assert spans[0]["start_tick"] == int(iss[30])
    doc = json.load(open(write_perfetto(res, str(tmp_path / "d.json"),
                                        down_windows=spans)))
    xs = [e for e in doc["traceEvents"]
          if e["ph"] == "X" and e["name"].startswith("down ")]
    assert len(xs) == len(spans)
    assert xs[0]["args"]["link"] == "s0<->sp0"
    assert xs[0]["dur"] > 0
    # spans land in the faults process group
    pids = {e["pid"]: e["args"]["name"] for e in doc["traceEvents"]
            if e["name"] == "process_name"}
    assert pids[xs[0]["pid"]] == "faults"


# --------------------------------------------- property suite (hypothesis)
# Random seeded FaultPlans; skips cleanly when the dev extra is absent.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    PLANS = st.fixed_dictionaries({
        "link_retry_rate": st.floats(0.0, 0.5),
        "link_retry_max": st.integers(1, 4),
        "nand_read_retry_rate": st.floats(0.0, 0.5),
        "poison_rate": st.floats(0.0, 0.3),
    })

    @settings(max_examples=8, deadline=None)
    @given(kw=PLANS, seed=st.integers(0, 2**31 - 1),
           device=st.sampled_from(DEVICES))
    def test_random_fault_plans_replay_tick_exact(kw, seed, device):
        _parity(lambda: _mount(device, ecmp=True), FaultConfig(**kw),
                seed=seed, trace=_trace(13))

    MH_PLANS = st.fixed_dictionaries({
        "link_retry_rate": st.floats(0.0, 0.4),
        "link_retry_max": st.integers(1, 3),
        "poison_rate": st.floats(0.0, 0.2),
    })

    @settings(max_examples=6, deadline=None)
    @given(kw=MH_PLANS, seed=st.integers(0, 2**31 - 1),
           qos=st.booleans(), ecmp=st.booleans(), down=st.booleans())
    def test_random_multihost_transport_plans_tick_exact(kw, seed, qos,
                                                         ecmp, down):
        """Fused multi-host transport faults across the QoS x ECMP grid on
        spine-leaf: tick/byte-identical to the interpreted driver for any
        seeded plan mix (down windows included)."""
        if down:
            kw = dict(kw, down_links=(("s0", "sp0", 20, 90),))
        _mh_parity(FaultConfig(**kw), seed=seed, qos=qos, ecmp=ecmp)
