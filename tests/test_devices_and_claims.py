"""Five-device behavior + the paper's headline experimental claims (C1-C8).

Bands are taken from the paper's own numbers (see DESIGN.md §1); the point of
these tests is that the *simulator reproduces the paper's figures*, so they
are deliberately assertions on simulation output, not unit tests.
"""

import numpy as np
import pytest

from repro.core.devices import DEVICE_NAMES, make_device
from repro.core.workloads.membench import run_membench
from repro.core.workloads.stream import run_stream
from repro.core.workloads.viper import ViperConfig, run_viper


@pytest.fixture(scope="module")
def membench_results():
    return {n: run_membench(make_device(n), working_set_bytes=2 << 20,
                            accesses=3000) for n in DEVICE_NAMES}


@pytest.fixture(scope="module")
def stream_results():
    return {n: run_stream(make_device(n), dataset_bytes=4 << 20)
            for n in DEVICE_NAMES}


@pytest.fixture(scope="module")
def viper_216():
    return {n: run_viper(make_device(n), ViperConfig(kv_bytes=216))
            for n in DEVICE_NAMES}


@pytest.fixture(scope="module")
def viper_532():
    return {n: run_viper(make_device(n), ViperConfig(kv_bytes=532))
            for n in DEVICE_NAMES}


def _avg_bw(res):
    return float(np.mean([r.bandwidth_gbps for r in res.values()]))


# ------------------------------------------------------------------ C1: Fig 4
class TestLatencyClaims:
    def test_c1_latency_ordering(self, membench_results):
        lat = {n: r.avg_latency_ns for n, r in membench_results.items()}
        assert lat["dram"] < lat["cxl-dram"] < lat["pmem"] < lat["cxl-ssd"]
        # cached CXL-SSD serves hot data at the CXL-DRAM/PMEM class, far
        # below the uncached device
        assert lat["cxl-ssd-cache"] < lat["cxl-ssd"] / 5

    def test_c9_cxl_adds_about_50ns(self, membench_results):
        delta = (membench_results["cxl-dram"].avg_latency_ns
                 - membench_results["dram"].avg_latency_ns)
        assert 40 <= delta <= 80  # 50 ns network + link serialization

    def test_uncached_ssd_is_microseconds(self, membench_results):
        assert 1_000 <= membench_results["cxl-ssd"].avg_latency_ns <= 50_000


# ------------------------------------------------------------------ C2/C3: Fig 3
class TestBandwidthClaims:
    def test_c2_dram_highest(self, stream_results):
        dram = _avg_bw(stream_results["dram"])
        for other in ("cxl-dram", "pmem", "cxl-ssd", "cxl-ssd-cache"):
            assert dram >= _avg_bw(stream_results[other])

    def test_c2_cached_ssd_close_to_cxl_dram(self, stream_results):
        cached = _avg_bw(stream_results["cxl-ssd-cache"])
        cxl_dram = _avg_bw(stream_results["cxl-dram"])
        assert cached / cxl_dram > 0.85

    def test_c3_pmem_about_65pct_of_dram(self, stream_results):
        ratio = _avg_bw(stream_results["pmem"]) / _avg_bw(stream_results["dram"])
        assert 0.55 <= ratio <= 0.75

    def test_uncached_ssd_lowest(self, stream_results):
        ssd = _avg_bw(stream_results["cxl-ssd"])
        for other in ("dram", "cxl-dram", "pmem", "cxl-ssd-cache"):
            assert ssd <= _avg_bw(stream_results[other])


# ------------------------------------------------------------- C4-C7: Fig 5/6
class TestViperClaims:
    def test_c4_cxl_dram_14pct_loss(self, viper_216):
        ratio = viper_216["cxl-dram"]["avg"] / viper_216["dram"]["avg"]
        assert 0.80 <= ratio <= 0.92  # paper: ~14% loss

    def test_c5_pmem_20_50pct_behind_cxl_dram(self, viper_216):
        ratio = viper_216["pmem"]["avg"] / viper_216["cxl-dram"]["avg"]
        assert 0.50 <= ratio <= 0.80

    def test_c6_cache_7_to_10x(self, viper_216):
        ratio = viper_216["cxl-ssd-cache"]["avg"] / viper_216["cxl-ssd"]["avg"]
        assert 6.0 <= ratio <= 12.0  # paper: 7-10x on average

    def test_c7_532b_cached_20_30pct_below_pmem(self, viper_532):
        ratio = viper_532["cxl-ssd-cache"]["avg"] / viper_532["pmem"]["avg"]
        assert 0.65 <= ratio <= 0.85  # paper: 20-30% degradation

    def test_216b_cached_beats_pmem(self, viper_216):
        assert viper_216["cxl-ssd-cache"]["avg"] > viper_216["pmem"]["avg"]

    def test_qps_drops_with_value_size(self, viper_216, viper_532):
        for dev in ("dram", "cxl-dram", "pmem"):
            assert viper_532[dev]["avg"] <= viper_216[dev]["avg"] * 1.05

    def test_writes_generated_by_insert_update_delete(self):
        dev = make_device("pmem")
        run_viper(dev, ViperConfig(kv_bytes=216, ops_per_phase=500,
                                   keyspace=3000, seed_keys=2000))
        assert dev.stats["writes"] > 0 and dev.stats["reads"] > 0


# ------------------------------------------------------------------ C8: §III-C
@pytest.mark.slow
class TestPolicyClaims:
    @pytest.fixture(scope="class")
    def policy_qps(self):
        from repro.core.cache.dram_cache import DRAMCacheConfig
        from repro.core.devices import CachedCXLSSDDevice
        out = {}
        for pol in ("lru", "fifo", "2q", "lfru", "direct"):
            dev = CachedCXLSSDDevice(cache_cfg=DRAMCacheConfig(policy=pol))
            out[pol] = run_viper(dev, ViperConfig(kv_bytes=532))["avg"]
        return out

    def test_c8_lru_best(self, policy_qps):
        assert policy_qps["lru"] == max(policy_qps.values())

    def test_c8_fifo_below_lru(self, policy_qps):
        assert policy_qps["fifo"] < policy_qps["lru"]


# ----------------------------------------------------------- posted semantics
def test_posted_vs_persistent_writes():
    dev = make_device("pmem")
    t_posted = dev.service(0, 0, 64, write=True, posted=True)
    dev2 = make_device("pmem")
    t_sync = dev2.service(0, 0, 64, write=True, posted=False)
    assert t_posted < t_sync


def test_rmw_on_uncached_write_miss():
    dev = make_device("cxl-ssd")
    # Prime a page on flash, cycle the registers, then write 64B to it again:
    # must pay a read-modify-write fill.
    t = dev.service(0, 0, 64, write=True)
    for pg in range(1, 9):
        t = dev.service(t, pg * 4096, 64, write=True)
    # force the dirty page 0 out and back
    before = dev.stats["rmw_fills"]
    t = dev.service(t, 0, 64, write=True)
    assert dev.stats["rmw_fills"] > before
