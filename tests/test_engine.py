"""Discrete-event engine semantics."""

import pytest

from repro.core.engine import EventEngine, ns, to_ns, us


def test_fifo_order_for_simultaneous_events():
    eng = EventEngine()
    seen = []
    for i in range(5):
        eng.schedule(100, lambda i=i: seen.append(i))
    eng.run()
    assert seen == [0, 1, 2, 3, 4]


def test_tick_ordering():
    eng = EventEngine()
    seen = []
    eng.schedule(ns(30), lambda: seen.append("b"))
    eng.schedule(ns(10), lambda: seen.append("a"))
    eng.schedule(ns(50), lambda: seen.append("c"))
    end = eng.run()
    assert seen == ["a", "b", "c"]
    assert end == ns(50)


def test_nested_scheduling():
    eng = EventEngine()
    seen = []
    def outer():
        seen.append(("outer", eng.now))
        eng.schedule(ns(5), lambda: seen.append(("inner", eng.now)))
    eng.schedule(ns(10), outer)
    eng.run()
    assert seen == [("outer", ns(10)), ("inner", ns(15))]


def test_cancel():
    eng = EventEngine()
    seen = []
    ev = eng.schedule(ns(10), lambda: seen.append(1))
    eng.cancel(ev)
    eng.run()
    assert seen == [] and eng.events_executed == 0


def test_run_until():
    eng = EventEngine()
    seen = []
    eng.schedule(ns(10), lambda: seen.append(1))
    eng.schedule(us(10), lambda: seen.append(2))
    eng.run(until=ns(100))
    assert seen == [1]
    assert eng.now == ns(100)
    eng.run()
    assert seen == [1, 2]


def test_negative_delay_rejected():
    with pytest.raises(ValueError):
        EventEngine().schedule(-1, lambda: None)


def test_schedule_in_past_rejected():
    eng = EventEngine()
    eng.schedule(ns(100), lambda: None)
    eng.run()
    with pytest.raises(ValueError):
        eng.schedule_at(ns(50), lambda: None)


def test_unit_helpers():
    assert ns(1) == 1000
    assert us(1) == 1_000_000
    assert to_ns(ns(123.5)) == pytest.approx(123.5)
