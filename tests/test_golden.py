"""Golden-trace conformance: every engine reproduces pinned per-access
latencies tick-for-tick.

The pairwise property tests (python == scan) can miss *joint* drift — a
timing-model change that moves both engines together.  These tests compare
each engine against a committed fixture (``tests/golden/golden_traces.json``)
covering all five paper devices, directly attached and fabric-mounted, plus
a multi-host QoS+ECMP scenario.  Regenerate intentionally with
``PYTHONPATH=src python tests/golden/regen.py``.
"""

import pytest

from golden import scenarios as sc


@pytest.fixture(scope="module")
def fixture():
    assert sc.FIXTURE.exists(), \
        "missing golden fixture; run: PYTHONPATH=src python tests/golden/regen.py"
    data = sc.load_fixture()
    assert data["format"] == 1
    return data["scenarios"]


@pytest.fixture(scope="module")
def names(fixture):
    got = set(sc.scenario_names())
    pinned = set(fixture)
    assert got == pinned, (
        f"scenario table and fixture disagree (missing={got - pinned}, "
        f"stale={pinned - got}); regenerate the fixture")
    return sorted(got)


def _assert_match(expected, actual, engine, name):
    assert actual["elapsed_ticks"] == expected["elapsed_ticks"], \
        f"{name}/{engine}: elapsed_ticks diverged"
    assert actual["sum_latency_ticks"] == expected["sum_latency_ticks"], \
        f"{name}/{engine}: sum_latency_ticks diverged"
    assert actual["end_tick"] == expected["end_tick"], \
        f"{name}/{engine}: end_tick diverged"
    exp, act = expected["latency_ticks"], actual["latency_ticks"]
    assert len(act) == len(exp), f"{name}/{engine}: access count diverged"
    bad = [i for i, (a, b) in enumerate(zip(exp, act)) if a != b]
    assert not bad, (
        f"{name}/{engine}: {len(bad)} per-access latencies diverged "
        f"(first at access {bad[0]}: pinned {exp[bad[0]]}, got "
        f"{act[bad[0]]})")


@pytest.mark.parametrize("name", sc.scenario_names())
def test_python_engine_matches_golden(fixture, name):
    expected = fixture[name]["python_scan"]
    actual = sc.run_python(name)
    if name == "multihost-qos-ecmp":
        for h, (e, a) in enumerate(zip(expected, actual)):
            _assert_match(e, a, "python", f"{name}[h{h}]")
    else:
        _assert_match(expected, actual, "python", name)


@pytest.mark.parametrize("name", sc.scenario_names())
def test_scan_engine_matches_golden(fixture, name):
    expected = fixture[name]["python_scan"]
    actual = sc.run_scan(name)
    if name == "multihost-qos-ecmp":
        for h, (e, a) in enumerate(zip(expected, actual)):
            _assert_match(e, a, "scan", f"{name}[h{h}]")
    else:
        _assert_match(expected, actual, "scan", name)


@pytest.mark.parametrize("name", sc.scenario_names())
def test_blocked_scan_engine_matches_golden(fixture, name):
    """The blocked scan (B accesses per sequential step) reuses the
    python_scan pins verbatim: block seams must be tick-invisible."""
    expected = fixture[name]["python_scan"]
    actual = sc.run_scan_blocked(name)
    if name == "multihost-qos-ecmp":
        for h, (e, a) in enumerate(zip(expected, actual)):
            _assert_match(e, a, "scan[blocked]", f"{name}[h{h}]")
    else:
        _assert_match(expected, actual, "scan[blocked]", name)


@pytest.mark.parametrize("name",
                         [n for n in sc.scenario_names()
                          if sc.assoc_supported(n)])
def test_assoc_engine_matches_golden(fixture, name):
    """The log-depth associative lane reuses the python_scan pins verbatim
    on every stack it certifies (stateless DRAM/PMEM media)."""
    expected = fixture[name]["python_scan"]
    actual = sc.run_assoc(name)
    _assert_match(expected, actual, "assoc", name)


@pytest.mark.parametrize("name",
                         [n for n in sc.scenario_names()
                          if sc.pallas_supported(n)])
def test_pallas_engine_matches_golden(fixture, name):
    expected = fixture[name]["pallas"]
    actual = sc.run_pallas(name)
    _assert_match(expected, actual, "pallas", name)


def test_fixture_scenarios_in_sync(names):
    """`names` already cross-checks table vs fixture; keep it referenced."""
    assert names
