"""Golden-trace conformance: every engine reproduces pinned per-access
latencies tick-for-tick.

The pairwise property tests (python == scan) can miss *joint* drift — a
timing-model change that moves both engines together.  These tests compare
each engine against a committed fixture (``tests/golden/golden_traces.json``)
covering all five paper devices, directly attached and fabric-mounted, plus
a multi-host QoS+ECMP scenario.  Regenerate intentionally with
``PYTHONPATH=src python tests/golden/regen.py``.
"""

import pytest

from golden import scenarios as sc


@pytest.fixture(scope="module")
def fixture():
    assert sc.FIXTURE.exists(), \
        "missing golden fixture; run: PYTHONPATH=src python tests/golden/regen.py"
    data = sc.load_fixture()
    assert data["format"] == 1
    return data["scenarios"]


@pytest.fixture(scope="module")
def names(fixture):
    got = set(sc.scenario_names())
    pinned = set(fixture)
    assert got == pinned, (
        f"scenario table and fixture disagree (missing={got - pinned}, "
        f"stale={pinned - got}); regenerate the fixture")
    return sorted(got)


def _assert_match(expected, actual, engine, name):
    assert actual["elapsed_ticks"] == expected["elapsed_ticks"], \
        f"{name}/{engine}: elapsed_ticks diverged"
    assert actual["sum_latency_ticks"] == expected["sum_latency_ticks"], \
        f"{name}/{engine}: sum_latency_ticks diverged"
    assert actual["end_tick"] == expected["end_tick"], \
        f"{name}/{engine}: end_tick diverged"
    exp, act = expected["latency_ticks"], actual["latency_ticks"]
    assert len(act) == len(exp), f"{name}/{engine}: access count diverged"
    bad = [i for i, (a, b) in enumerate(zip(exp, act)) if a != b]
    assert not bad, (
        f"{name}/{engine}: {len(bad)} per-access latencies diverged "
        f"(first at access {bad[0]}: pinned {exp[bad[0]]}, got "
        f"{act[bad[0]]})")


@pytest.mark.parametrize("name", sc.scenario_names())
def test_python_engine_matches_golden(fixture, name):
    expected = fixture[name]["python_scan"]
    actual = sc.run_python(name)
    if sc.is_multi(name):
        for h, (e, a) in enumerate(zip(expected, actual)):
            _assert_match(e, a, "python", f"{name}[h{h}]")
    else:
        _assert_match(expected, actual, "python", name)


@pytest.mark.parametrize("name", sc.scenario_names())
def test_scan_engine_matches_golden(fixture, name):
    expected = fixture[name]["python_scan"]
    actual = sc.run_scan(name)
    if sc.is_multi(name):
        for h, (e, a) in enumerate(zip(expected, actual)):
            _assert_match(e, a, "scan", f"{name}[h{h}]")
    else:
        _assert_match(expected, actual, "scan", name)


@pytest.mark.parametrize("name", sc.scenario_names())
def test_blocked_scan_engine_matches_golden(fixture, name):
    """The blocked scan (B accesses per sequential step) reuses the
    python_scan pins verbatim: block seams must be tick-invisible."""
    expected = fixture[name]["python_scan"]
    actual = sc.run_scan_blocked(name)
    if sc.is_multi(name):
        for h, (e, a) in enumerate(zip(expected, actual)):
            _assert_match(e, a, "scan[blocked]", f"{name}[h{h}]")
    else:
        _assert_match(expected, actual, "scan[blocked]", name)


@pytest.mark.parametrize("name",
                         [n for n in sc.scenario_names()
                          if sc.assoc_supported(n)])
def test_assoc_engine_matches_golden(fixture, name):
    """The log-depth associative lane reuses the python_scan pins verbatim
    on every stack it certifies (stateless DRAM/PMEM media)."""
    expected = fixture[name]["python_scan"]
    actual = sc.run_assoc(name)
    _assert_match(expected, actual, "assoc", name)


@pytest.mark.parametrize("name",
                         [n for n in sc.scenario_names()
                          if sc.pallas_supported(n)])
def test_pallas_engine_matches_golden(fixture, name):
    expected = fixture[name]["pallas"]
    actual = sc.run_pallas(name)
    _assert_match(expected, actual, "pallas", name)


@pytest.mark.parametrize("name", sc.scenario_names())
def test_python_metrics_match_golden(fixture, name):
    """The interpreted drivers' stats dicts render to the pinned metrics
    bundle — the schema contract observability consumers rely on."""
    assert sc.run_python_metrics(name) == fixture[name]["metrics"], \
        f"{name}: python metrics bundle diverged from the pin"


@pytest.mark.parametrize("name", sc.scenario_names())
def test_scan_metrics_match_golden(fixture, name):
    """The fused lanes' in-scan accumulators reproduce the pinned metrics
    bundle value-for-value — histograms, windows, component counters,
    port/QoS/ECMP telemetry, flash counters."""
    assert sc.run_scan_metrics(name) == fixture[name]["metrics"], \
        f"{name}: fused metrics bundle diverged from the pin"


def test_fixture_scenarios_in_sync(names):
    """`names` already cross-checks table vs fixture; keep it referenced."""
    assert names


def test_fixture_covers_multihost_cached_and_gc(fixture):
    """The PR-5 scenarios are pinned: multi-host cached CXL-SSD (mounts,
    pool, shared flash) and the GC-pressure single-host trace."""
    for name in ("multihost-ssd-mounts", "multihost-ssd-pool",
                 "multihost-ssd-sharedflash", "ssd-gc@direct"):
        assert name in fixture, f"{name} missing from golden fixture"
    assert len(fixture["multihost-ssd-pool"]["python_scan"]) == 4
    assert len(fixture["multihost-ssd-sharedflash"]["python_scan"]) == 2


def test_fixture_pins_multihost_transport_fault_counters(fixture):
    """The PR-9 multi-host transport-fault scenarios are pinned with live
    degradation counters: the down window degrades accesses and forces
    ECMP failovers at x2 hosts, the CRC schedule charges link retries at
    x4 — so a fused lane that silently stops mirroring fabric faults
    (counters collapsing to zero) fails here, not just in parity."""
    x2 = fixture["faults-portdown@multihost_x2"]
    x4 = fixture["faults-linkretry@spine_leaf_x4"]
    assert len(x2["python_scan"]) == 2 and len(x4["python_scan"]) == 4
    assert x2["metrics"]["faults"]["degraded_accesses"] > 0
    assert x2["metrics"]["faults"]["failovers"] > 0
    assert x4["metrics"]["faults"]["link_retries"] > 0


def test_regen_refuses_dropping_or_rewriting_pins():
    """The fixture is append-only: regen aborts when a pinned scenario
    disappears from the table or regenerates to different values."""
    import importlib.util
    from pathlib import Path

    spec = importlib.util.spec_from_file_location(
        "golden_regen", Path(__file__).parent / "golden" / "regen.py")
    regen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(regen)
    with pytest.raises(SystemExit, match="refusing to drop"):
        regen.check_history({"ghost@direct": {}}, ["dram@direct"])
    pinned = {"dram@direct": {"python_scan": {"elapsed_ticks": 1}}}
    with pytest.raises(SystemExit, match="refusing to rewrite"):
        regen.check_rewrite("dram@direct", pinned,
                            {"python_scan": {"elapsed_ticks": 2}})
    # dropping a pinned contract key is a rewrite too
    with pytest.raises(SystemExit, match="refusing to rewrite"):
        regen.check_rewrite("dram@direct", pinned, {"metrics": {}})
    # unchanged values, new scenarios, and NEW contract keys alongside
    # untouched pins (how "metrics" was added) all pass
    regen.check_rewrite("dram@direct", pinned, pinned["dram@direct"])
    regen.check_rewrite("new@direct", pinned, {"python_scan": {}})
    regen.check_rewrite("dram@direct", pinned,
                        {"python_scan": {"elapsed_ticks": 1},
                         "metrics": {"hist": []}})
