"""The five replacement policies: unit behavior + cross-validation of the
vectorized lax.scan simulator against the Python object model (oracle)."""

import numpy as np
import pytest

# Property tests need hypothesis (a dev extra); everything else below runs
# without it, so only the property tests skip on a bare checkout.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.cache.policies import (
    POLICIES,
    DirectPolicy,
    FIFOPolicy,
    LFRUPolicy,
    LRUPolicy,
    TwoQPolicy,
    make_policy,
)
from repro.core.cache.trace_sim import TraceCacheSim, simulate_trace


class TestLRU:
    def test_eviction_order(self):
        p = LRUPolicy(2)
        p.access(1); p.access(2)
        p.access(1)               # 1 is now MRU
        _, ev = p.access(3)       # evicts 2
        assert ev.page == 2
        assert p.resident_pages() == {1, 3}

    def test_dirty_propagation(self):
        p = LRUPolicy(1)
        p.access(1, write=True)
        _, ev = p.access(2)
        assert ev.page == 1 and ev.dirty


class TestFIFO:
    def test_touch_does_not_promote(self):
        p = FIFOPolicy(2)
        p.access(1); p.access(2)
        p.access(1)               # hit, but FIFO ignores recency
        _, ev = p.access(3)       # evicts 1 (first in)
        assert ev.page == 1

    def test_differs_from_lru_on_temporal_locality(self):
        trace = [1, 2, 1, 3, 1, 4, 1, 5, 1, 6, 1, 7]
        lru, fifo = LRUPolicy(2), FIFOPolicy(2)
        for pg in trace:
            lru.access(pg); fifo.access(pg)
        assert lru.hits > fifo.hits  # the paper's point (§III-C)


class TestDirect:
    def test_conflict_eviction(self):
        p = DirectPolicy(4)
        p.access(0)
        _, ev = p.access(4)       # same frame (4 % 4 == 0)
        assert ev.page == 0
        hit, _ = p.access(1)      # different frame: no conflict
        assert not hit and p.lookup(1) and p.lookup(4)

    def test_no_eviction_on_refill_same_page(self):
        p = DirectPolicy(2)
        p.access(0)
        hit, ev = p.access(0)
        assert hit and ev is None


class Test2Q:
    def test_ghost_promotion(self):
        p = TwoQPolicy(4, kin_frac=0.5, kout_frac=1.0)
        # fill probation, evict 1 into ghost, re-access 1 -> goes to Am
        p.access(1); p.access(2); p.access(3); p.access(4)
        p.access(5)               # evicts 1 from A1in into A1out
        assert not p.lookup(1)
        p.access(1)               # ghost hit -> promote into Am
        assert 1 in p._am

    def test_capacity_respected(self):
        p = TwoQPolicy(4)
        for i in range(20):
            p.access(i)
        assert len(p) <= 4


class TestLFRU:
    def test_frequency_beats_recency(self):
        p = LFRUPolicy(2)
        for _ in range(5):
            p.access(1)           # hot page
        p.access(2)
        _, ev = p.access(3)       # evicts 2 (freq 1) not 1 (freq 5)
        assert ev.page == 2
        assert p.lookup(1)

    def test_aging_halves_frequencies(self):
        p = LFRUPolicy(2, freq_cap=8)
        for _ in range(10):
            p.access(1)
        freq_before = p._pages[1][0]
        p.access(2)
        p.access(3)               # eviction w/ high freq triggers aging sweep
        assert p._pages[1][0] <= freq_before


class TestFactory:
    def test_all_five_constructible(self):
        for name in POLICIES:
            pol = make_policy(name, 8)
            pol.access(1)
            assert pol.lookup(1)

    def test_unknown_rejected(self):
        with pytest.raises(ValueError):
            make_policy("mru", 8)

    def test_hit_rate_math(self):
        p = make_policy("lru", 4)
        p.access(1); p.access(1); p.access(2)
        assert p.hit_rate == pytest.approx(1 / 3)


# --------------------------------------------------------------------------
# Vectorized lax.scan simulator vs the Python object model (oracle).
# Set-associative oracle: partition pages by set, one policy object per set.
def _oracle_set_assoc(pages, writes, num_sets, ways, policy_cls):
    sets = [policy_cls(ways) for _ in range(num_sets)]
    hits, dirty_evicts = [], []
    for pg, wr in zip(pages, writes):
        hit, ev = sets[pg % num_sets].access(pg, write=wr)
        hits.append(hit)
        dirty_evicts.append(bool(ev and ev.dirty))
    return np.array(hits), np.array(dirty_evicts)


@pytest.mark.parametrize("policy,cls", [("lru", LRUPolicy), ("fifo", FIFOPolicy)])
@pytest.mark.parametrize("num_sets,ways", [(1, 4), (4, 2), (8, 1), (16, 4)])
def test_trace_sim_matches_oracle(policy, cls, num_sets, ways):
    rng = np.random.default_rng(42)
    n = 600
    pages = rng.integers(0, num_sets * ways * 3, size=n).astype(np.int32)
    writes = rng.random(n) < 0.3
    res = simulate_trace(pages, writes, num_sets=num_sets, ways=ways, policy=policy)
    oh, oe = _oracle_set_assoc(pages, writes, num_sets, ways, cls)
    np.testing.assert_array_equal(res["hit_flags"], oh)
    np.testing.assert_array_equal(res["dirty_evict_flags"], oe)


def test_trace_sim_direct_matches_oracle():
    rng = np.random.default_rng(1)
    pages = rng.integers(0, 64, size=500).astype(np.int32)
    writes = rng.random(500) < 0.5
    res = simulate_trace(pages, writes, num_sets=16, ways=1, policy="direct")
    oh, oe = _oracle_set_assoc(pages, writes, 16, 1, DirectPolicy)
    np.testing.assert_array_equal(res["hit_flags"], oh)
    np.testing.assert_array_equal(res["dirty_evict_flags"], oe)


if HAVE_HYPOTHESIS:
    @given(
        data=st.data(),
        num_sets=st.sampled_from([1, 2, 4]),
        ways=st.sampled_from([1, 2, 4]),
        policy=st.sampled_from(["lru", "fifo"]),
    )
    @settings(max_examples=30, deadline=None)
    def test_trace_sim_property(data, num_sets, ways, policy):
        n = data.draw(st.integers(min_value=1, max_value=120))
        pages = np.array(
            data.draw(st.lists(st.integers(0, num_sets * ways * 2),
                               min_size=n, max_size=n)), dtype=np.int32)
        writes = np.array(
            data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
        cls = LRUPolicy if policy == "lru" else FIFOPolicy
        res = simulate_trace(pages, writes, num_sets=num_sets, ways=ways, policy=policy)
        oh, oe = _oracle_set_assoc(pages, writes, num_sets, ways, cls)
        np.testing.assert_array_equal(res["hit_flags"], oh)
        np.testing.assert_array_equal(res["dirty_evict_flags"], oe)
else:
    def test_trace_sim_property():
        pytest.importorskip("hypothesis")


def test_trace_sim_rejects_bad_config():
    with pytest.raises(ValueError):
        TraceCacheSim(num_sets=4, ways=2, policy="direct")
    with pytest.raises(ValueError):
        TraceCacheSim(num_sets=4, ways=2, policy="2q")
