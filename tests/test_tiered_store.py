"""TieredStore: the paper's DRAM-cache-over-SSD at the serving layer."""

import numpy as np
import pytest

from repro.core.devices import make_device
from repro.tiered.store import TieredStore, TieredStoreConfig


def _store(policy="lru", hbm=4, pages=16, backing=False):
    return TieredStore(
        TieredStoreConfig(n_logical_pages=pages, page_shape=(8, 16),
                          hbm_pages=hbm, policy=policy),
        backing=make_device("cxl-ssd") if backing else None)


def test_roundtrip_through_tiers():
    st = _store()
    data = np.arange(8 * 16, dtype=np.float32).reshape(8, 16)
    st.write_page(3, data)
    out = st.read_pages([3])
    np.testing.assert_array_equal(np.asarray(out[0]), data)


def test_hits_after_fill():
    st = _store()
    st.write_page(1, np.ones((8, 16), np.float32))
    st.read_pages([1])
    assert st.stats["misses"] == 1
    st.read_pages([1])
    assert st.stats["hits"] == 1
    assert st.hit_rate == 0.5


def test_mshr_coalescing_within_request():
    st = _store()
    st.read_pages([5, 5, 5, 2])
    assert st.stats["coalesced"] == 2
    assert st.stats["fills"] == 2       # pages 5 and 2 fetched once each


def test_eviction_and_writeback():
    st = _store(hbm=2)
    a = np.full((8, 16), 7.0, np.float32)
    st.ensure_resident([0], dirty=False)
    st.update_page(1, a)                 # dirty page in HBM
    st.read_pages([2])                   # evicts LRU (page 0, clean)
    st.read_pages([3])                   # evicts page 1 (dirty) -> writeback
    assert st.stats["writebacks"] >= 1
    np.testing.assert_array_equal(st.capacity_page(1), a)


def test_lru_keeps_hot_page():
    st = _store(hbm=2)
    st.read_pages([0])
    st.read_pages([1])
    st.read_pages([0])                   # 0 is hot
    st.read_pages([2])                   # evicts 1, not 0
    assert st.policy.lookup(0)
    assert not st.policy.lookup(1)


def test_policy_comparison_zipf_traffic():
    """LRU beats FIFO on a zipf-skewed page trace (paper §III-C at the
    serving layer)."""
    rng = np.random.default_rng(0)
    w = 1.0 / np.arange(1, 17) ** 1.2
    trace = rng.choice(16, size=400, p=w / w.sum())
    rates = {}
    for pol in ("lru", "fifo"):
        st = _store(policy=pol, hbm=4)
        for lpn in trace:
            st.read_pages([int(lpn)])
        rates[pol] = st.hit_rate
    assert rates["lru"] >= rates["fifo"]


def test_simulated_cxl_ssd_clock_advances_on_miss_only():
    st = _store(backing=True)
    st.write_page(0, np.zeros((8, 16), np.float32))
    t0 = st.sim_time_us
    st.read_pages([0])                   # miss -> simulated SSD read
    t1 = st.sim_time_us
    assert t1 > t0
    st.read_pages([0])                   # hit -> no capacity-tier access
    assert st.sim_time_us == t1


def test_2q_and_lfru_functional():
    for pol in ("2q", "lfru", "direct"):
        st = _store(policy=pol, hbm=4)
        for lpn in [0, 1, 2, 3, 0, 4, 0, 5]:
            st.read_pages([lpn])
        out = st.read_pages([0])
        assert out.shape == (1, 8, 16)
