"""Sharding rules, FSDP specs, optimizer-state specs, and the dry-run's
HLO-collective parser / roofline analytics (pure logic — no mesh needed
beyond a 1-device stand-in for divisibility checks uses a fake mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import (MeshAxes, fsdp_param_specs,
                                        opt_state_specs, param_specs)
from repro.models.transformer import init_params
from repro.optim.adamw import adamw_init

KEY = jax.random.PRNGKey(0)


class _FakeMesh:
    """Duck-typed mesh: just axis sizes + names (no devices needed)."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


MESH = _FakeMesh({"data": 16, "model": 16})
AX = MeshAxes(dp=("data",), tp="model")


def _specs(arch_id, kind="train"):
    cfg = get_arch(arch_id)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg, jnp.bfloat16), KEY)
    return cfg, shapes, param_specs(shapes, cfg, MESH, AX, kind=kind)


class TestParamSpecs:
    def test_dense_attention_tp(self):
        _, _, sp = _specs("glm4-9b")
        assert sp["blocks"]["wq"] == P(None, None, "model")
        assert sp["blocks"]["wo"] == P(None, "model", None)
        assert sp["blocks"]["w_down"] == P(None, "model", None)
        assert sp["embed"] == P("model", None)
        assert sp["blocks"]["ln1"] == P()

    def test_moe_ep_fsdp_train(self):
        _, _, sp = _specs("kimi-k2-1t-a32b")
        moe = sp["blocks"]["moe"]
        assert moe.w_gate == P(None, "model", ("data",), None)
        assert moe.router == P(None, None, None)

    def test_moe_resident_decode_layout(self):
        _, _, sp = _specs("kimi-k2-1t-a32b", kind="decode")
        moe = sp["blocks"]["moe"]
        assert moe.w_gate == P(None, "model", None, ("data",))
        assert moe.w_down == P(None, "model", ("data",), None)

    def test_mixtral_tp_in_expert(self):
        _, _, sp = _specs("mixtral-8x7b")
        moe = sp["blocks"]["moe"]
        # (L, E, D, F): F over model, D over data (FSDP)
        assert moe.w_gate == P(None, None, ("data",), "model")

    def test_ssm_sharded_for_mamba_replicated_for_hybrid(self):
        _, _, sp = _specs("mamba2-2_7b")
        assert sp["blocks"]["ssm"].in_x == P(None, None, "model")
        assert sp["blocks"]["ssm"].in_B == P()
        _, _, sp = _specs("hymba-1_5b")
        assert sp["blocks"]["ssm"].in_x == P()  # 50 heads % 16 != 0

    def test_indivisible_falls_back_to_replicate(self):
        # hand-built leaf whose rule-assigned axis does not divide 16
        cfg = get_arch("glm4-9b")
        tree = {"blocks": {"wq": jax.ShapeDtypeStruct((2, 30, 30), jnp.float32)}}
        sp = param_specs(tree, cfg, MESH, AX)
        assert sp["blocks"]["wq"] == P()  # 30 % 16 != 0 -> replicate

    def test_vlm_superblock_lead_axes(self):
        _, _, sp = _specs("llama-3_2-vision-90b")
        # blocks stacked (n_cross, cross_every, ...) -> two leading Nones
        assert sp["blocks"]["wq"] == P(None, None, None, "model")
        assert sp["cross"]["wq"] == P(None, None, "model")


class TestFSDPSpecs:
    def test_largest_dim_sharded_over_all_axes(self):
        cfg, shapes, _ = _specs("glm4-9b")
        sp = fsdp_param_specs(shapes, cfg, MESH, AX)
        # (L=40, 4096, 4096): largest divisible dim shards over 256
        assert sp["blocks"]["wq"] == P(None, ("data", "model"), None)
        assert sp["embed"] == P(("data", "model"), None)

    def test_axes_subset(self):
        cfg, shapes, _ = _specs("glm4-9b")
        sp = fsdp_param_specs(shapes, cfg, MESH, AX, axes=("model",))
        assert sp["blocks"]["wq"] == P(None, ("model",), None)


class TestOptStateSpecs:
    def test_zero1_adds_dp_axis(self):
        cfg, shapes, sp = _specs("glm4-9b")
        opt = jax.eval_shape(adamw_init, shapes)
        osp = opt_state_specs(opt, sp, MESH, AX)
        # wq param spec (None,None,model) -> moments add data on a free dim
        assert "data" in str(osp["mu"]["blocks"]["wq"])

    def test_zero1_skips_already_dp_sharded(self):
        cfg, shapes, sp = _specs("kimi-k2-1t-a32b")
        opt = jax.eval_shape(adamw_init, shapes)
        osp = opt_state_specs(opt, sp, MESH, AX)
        assert osp["mu"]["blocks"]["moe"].w_gate == sp["blocks"]["moe"].w_gate

    def test_int8_moment_specs(self):
        cfg, shapes, sp = _specs("glm4-9b")
        opt = jax.eval_shape(lambda p: adamw_init(p, "int8"), shapes)
        osp = opt_state_specs(opt, sp, MESH, AX)
        assert "mu_q" in osp and "mu_s" in osp
        # scale spec = value spec minus the quantized last axis
        vq = tuple(osp["mu_q"]["blocks"]["wq"])
        vs = tuple(osp["mu_s"]["blocks"]["wq"])
        assert len(vs) <= max(len(vq) - 1, 0) or vs == ()


class TestCollectiveParser:
    def test_parse_and_ring_costs(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
  %ar = bf16[16,4096] all-reduce(bf16[16,4096] %x), replica_groups={{0,1,2,3}}
  %ag = f32[1024] all-gather(f32[256] %y), replica_groups=[2,8]<=[16]
  %cp = f32[128] collective-permute(f32[128] %z)
"""
        st = parse_collectives(hlo, default_group=16)
        ar = st["all-reduce"]
        assert ar["count"] == 1
        assert ar["result_bytes"] == 16 * 4096 * 2
        assert ar["wire_bytes"] == pytest.approx(2 * 3 / 4 * 16 * 4096 * 2)
        ag = st["all-gather"]
        assert ag["wire_bytes"] == pytest.approx(7 / 8 * 1024 * 4)
        assert st["collective-permute"]["wire_bytes"] == 128 * 4
        assert st["total_wire_bytes"] > 0

    def test_start_done_not_double_counted(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
  %s = bf16[64] all-reduce-start(bf16[64] %x), replica_groups={{0,1}}
  %d = bf16[64] all-reduce-done(bf16[64] %s)
"""
        st = parse_collectives(hlo, 2)
        assert st["all-reduce"]["count"] == 1


class TestRooflineAnalytics:
    def test_decode_memory_equals_state(self):
        from repro.launch.roofline import analytic_memory_bytes
        rec = {"arch": "glm4-9b", "shape": "decode_32k", "chips": 256,
               "analytic_state_bytes_per_device": 123456}
        assert analytic_memory_bytes(rec) == 123456

    def test_train_memory_exceeds_prefill(self):
        from repro.launch.roofline import analytic_memory_bytes
        tr = analytic_memory_bytes({"arch": "glm4-9b", "shape": "train_4k",
                                    "chips": 256,
                                    "analytic_state_bytes_per_device": 0})
        pf = analytic_memory_bytes({"arch": "glm4-9b", "shape": "prefill_32k",
                                    "chips": 256,
                                    "analytic_state_bytes_per_device": 0})
        assert tr > 0 and pf > 0
        # train re-reads weights (remat) + writes grads/moments; per token it
        # moves far more than inference
        tr_tok = tr / (256 * 4096 / 16)
        pf_tok = pf / (32 * 32768 / 16)
        assert tr_tok > pf_tok

    def test_model_flops_train_vs_decode(self):
        from repro.configs.base import SHAPES
        from repro.launch.dryrun import model_flops
        cfg = get_arch("glm4-9b")
        tr = model_flops(cfg, SHAPES["train_4k"])
        de = model_flops(cfg, SHAPES["decode_32k"])
        assert tr > 1000 * de
        # 6*N*D should dominate the train estimate
        assert tr == pytest.approx(
            6 * cfg.active_param_count() * 256 * 4096, rel=0.2)

    def test_variants_registry(self):
        from repro.launch.dryrun import VARIANTS
        for v in ("baseline", "tri", "fsdp", "kvq8", "repx", "opt8",
                  "compress", "mb4"):
            assert v in VARIANTS
