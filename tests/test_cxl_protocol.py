"""CXL.mem protocol layer: flit codec, MetaValue rules, HomeAgent routing."""

import pytest

# Property tests need hypothesis (a dev extra); everything else below runs
# without it, so only the property tests skip on a bare checkout.
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.cxl.flit import (
    CXL_FLIT_BYTES,
    CXLCommand,
    CXLFlit,
    MemCmd,
    MetaValue,
    Packet,
    SnpType,
    decode_flit,
    encode_flit,
    flit_to_response_packet,
    meta_value_for,
    packet_to_flit,
)
from repro.core.cxl.home_agent import AddressRange, HomeAgent
from repro.core.engine import EventEngine
from repro.core.devices import CXLDRAMDevice, DRAMDevice


class TestFlitCodec:
    def test_wire_size(self):
        flit = CXLFlit(opcode=CXLCommand.M2SReq, addr=0x1000, tag=7)
        assert len(encode_flit(flit)) == CXL_FLIT_BYTES == 64

    def test_roundtrip_basic(self):
        flit = CXLFlit(opcode=CXLCommand.M2SRwD, addr=0x40, tag=123,
                       meta_value=MetaValue.Invalid, snp_type=SnpType.SnpInv,
                       length_blocks=3, poison=True, data=b"hello world")
        out = decode_flit(encode_flit(flit), data=flit.data)
        assert out.opcode == flit.opcode
        assert out.addr == flit.addr
        assert out.tag == flit.tag
        assert out.meta_value == flit.meta_value
        assert out.snp_type == flit.snp_type
        assert out.length_blocks == flit.length_blocks
        assert out.poison and not out.dirty_evict
        assert out.data == b"hello world"

    def test_unaligned_request_rejected(self):
        with pytest.raises(ValueError):
            encode_flit(CXLFlit(opcode=CXLCommand.M2SReq, addr=0x41, tag=0))

    def test_bad_wire_length(self):
        with pytest.raises(ValueError):
            decode_flit(b"\x00" * 63)


if HAVE_HYPOTHESIS:
    @given(
        op=st.sampled_from(list(CXLCommand)),
        addr=st.integers(min_value=0, max_value=2**48 - 1).map(lambda a: a * 64),
        tag=st.integers(min_value=0, max_value=2**16 - 1),
        mv=st.sampled_from(list(MetaValue)),
        nblk=st.integers(min_value=0, max_value=2**16 - 1),
        poison=st.booleans(),
        dirty=st.booleans(),
    )
    @settings(max_examples=200, deadline=None)
    def test_roundtrip_property(op, addr, tag, mv, nblk, poison, dirty):
        flit = CXLFlit(opcode=op, addr=addr, tag=tag, meta_value=mv,
                       length_blocks=nblk, poison=poison, dirty_evict=dirty)
        out = decode_flit(encode_flit(flit))
        assert (out.opcode, out.addr, out.tag, out.meta_value,
                out.length_blocks, out.poison, out.dirty_evict) == \
               (op, addr, tag, mv, nblk, poison, dirty)
else:
    def test_roundtrip_property():
        pytest.importorskip("hypothesis")


class TestMetaValueRules:
    """Paper §II-B-3: MetaValue from invalidate/flush semantics."""

    def test_plain_read_write_is_any(self):
        assert meta_value_for(MemCmd.ReadReq) == MetaValue.Any
        assert meta_value_for(MemCmd.WriteReq) == MetaValue.Any

    def test_invalidate_is_invalid(self):
        assert meta_value_for(MemCmd.InvalidateReq) == MetaValue.Invalid
        assert meta_value_for(MemCmd.CleanEvict) == MetaValue.Invalid

    def test_flush_keeps_shared(self):
        assert meta_value_for(MemCmd.FlushReq) == MetaValue.Shared


class TestPacketConversion:
    """Paper §II-B-2: ReadReq→M2SReq, WriteReq→M2SRwD."""

    def test_read_converts(self):
        flit = packet_to_flit(Packet(cmd=MemCmd.ReadReq, addr=0x80), tag=1)
        assert flit.opcode == CXLCommand.M2SReq
        assert flit.meta_value == MetaValue.Any

    def test_write_converts_with_data(self):
        pkt = Packet(cmd=MemCmd.WriteReq, addr=0x80, data=b"\xab" * 64)
        flit = packet_to_flit(pkt, tag=2)
        assert flit.opcode == CXLCommand.M2SRwD
        assert flit.data == b"\xab" * 64

    def test_multiline_block_count(self):
        flit = packet_to_flit(Packet(cmd=MemCmd.ReadReq, addr=0, size=4096), tag=0)
        assert flit.length_blocks == 64  # 4 KB = 64 x 64 B logical blocks

    def test_address_alignment(self):
        flit = packet_to_flit(Packet(cmd=MemCmd.ReadReq, addr=0x8f), tag=0)
        assert flit.addr == 0x80

    def test_response_conversion(self):
        req = Packet(cmd=MemCmd.ReadReq, addr=0x100, req_id=9)
        drs = CXLFlit(opcode=CXLCommand.S2MDRS, addr=0x100, tag=0, data=b"x" * 64)
        resp = flit_to_response_packet(drs, req)
        assert resp.cmd == MemCmd.ReadResp and resp.req_id == 9
        ndr = CXLFlit(opcode=CXLCommand.S2MNDR, addr=0x100, tag=0)
        resp = flit_to_response_packet(ndr, req)
        assert resp.cmd == MemCmd.WriteResp

    def test_unconvertible_rejected(self):
        with pytest.raises(ValueError):
            packet_to_flit(Packet(cmd=MemCmd.ReadResp, addr=0), tag=0)


class TestHomeAgent:
    def _system(self):
        eng = EventEngine()
        ha = HomeAgent(eng)
        local = DRAMDevice(eng)
        cxl = CXLDRAMDevice(eng)
        ha.attach(AddressRange(0, 1 << 20), local, is_cxl=False)
        ha.attach(AddressRange(1 << 20, 1 << 20), cxl, is_cxl=True)
        return eng, ha

    def test_local_path_no_conversion(self):
        eng, ha = self._system()
        got = []
        ha.send(Packet(cmd=MemCmd.ReadReq, addr=0x100), got.append)
        eng.run()
        assert len(got) == 1 and got[0].cmd == MemCmd.ReadResp
        assert ha.stats["pkts_converted"] == 0

    def test_cxl_path_converts_and_responds(self):
        eng, ha = self._system()
        got = []
        ha.send(Packet(cmd=MemCmd.ReadReq, addr=(1 << 20) + 0x40), got.append)
        t_end = eng.run()
        assert len(got) == 1 and got[0].cmd == MemCmd.ReadResp
        assert ha.stats["pkts_converted"] == 1
        assert ha.stats["flit_bytes_m2s"] >= 64
        # CXL round trip (50 ns) + DRAM access — strictly slower than local
        assert t_end >= 50_000  # >= 50 ns in ticks

    def test_cxl_write_path(self):
        eng, ha = self._system()
        got = []
        ha.send(Packet(cmd=MemCmd.WriteReq, addr=(1 << 20), data=b"z" * 64), got.append)
        eng.run()
        assert got and got[0].cmd == MemCmd.WriteResp

    def test_unmapped_address_raises(self):
        _, ha = self._system()
        with pytest.raises(ValueError):
            ha.send(Packet(cmd=MemCmd.ReadReq, addr=1 << 30), lambda p: None)

    def test_overlapping_range_rejected(self):
        eng, ha = self._system()
        with pytest.raises(ValueError):
            ha.attach(AddressRange(0x1000, 0x1000), DRAMDevice(eng), is_cxl=False)

    def test_unconvertible_command_warns(self):
        eng, ha = self._system()
        ha.send(Packet(cmd=MemCmd.M2SReq, addr=(1 << 20)), lambda p: None)
        eng.run()
        assert ha.stats["warnings"] == 1

    def test_cxl_latency_exceeds_local(self):
        eng, ha = self._system()
        done = {}
        ha.send(Packet(cmd=MemCmd.ReadReq, addr=0x40), lambda p: done.setdefault("local", eng.now))
        eng.run()
        local_t = done["local"]
        eng2, ha2 = self._system()
        ha2.send(Packet(cmd=MemCmd.ReadReq, addr=(1 << 20) + 0x40),
                 lambda p: done.setdefault("cxl", eng2.now))
        eng2.run()
        assert done["cxl"] > local_t
