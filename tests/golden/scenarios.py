"""Golden-trace scenario table + engine runners.

One scenario = one (device stack, attach mode, seeded trace) combination.
The fixture (``golden_traces.json``) pins per-access latencies so that
*silent* divergence — python and scan drifting together, or an engine's
latency model changing without anyone noticing — fails loudly, which the
pairwise python==scan property tests cannot catch.

Contracts pinned per scenario:

* ``python_scan`` — per-access latency ticks that the interpreted
  ``TraceDriver``/``MultiHostDriver`` path, the fused lax.scan replay, the
  **blocked** scan (``block_size=BLOCK_SIZE``), and the **associative**
  log-depth lane (where it certifies the stack — stateless DRAM/PMEM
  media) must ALL reproduce exactly.  One pin, every tick-exact lane.
* ``pallas`` — the Pallas engine's own per-access latencies where the
  engine supports the stack (cached CXL-SSD).  Its analytic latency model
  is *not* tick-identical to python; pinning its output separately catches
  silent regressions in that model too.  The golden runner passes
  ``validate=True`` so every conformance pass also cross-checks the
  in-kernel latency chain against the shared associative reconstruction.

The ``@stream`` scenarios replay with ``outstanding=32`` — the
bandwidth-bound regime the associative lane is built for (it converges in
a couple of sweeps there, vs. crawling through the LFB feedback on the
``outstanding=8`` scenarios).

Regenerate with ``PYTHONPATH=src python tests/golden/regen.py`` after an
intentional timing-model change, and say so in the commit message.  Regen
refuses to alter any previously pinned scenario — history can only be
extended, never silently rewritten.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

FIXTURE = Path(__file__).with_name("golden_traces.json")

CACHE_KW = dict(capacity_bytes=16 * 4096, mshr_entries=4, writeback_buffer=2)
DEVICES = ["dram", "cxl-dram", "pmem", "cxl-ssd", "cxl-ssd-cache"]
N_ACCESSES = 160
OUTSTANDING = 8
STREAM_OUTSTANDING = 32      # @stream scenarios: bandwidth-bound issue depth
BLOCK_SIZE = 8               # blocked-scan lane pinned alongside B=1
ASSOC_SWEEPS = 256           # short traces afford a generous Kleene budget

# multi-host tentpole scenario: QoS weights + ECMP on a spine-leaf pool
MULTI = dict(num_hosts=3, num_leaves=2, num_spines=2,
             qos_weights={"h0": 3.0, "h1": 1.0, "h2": 1.0})

# stacked-state scenarios: multi-host cached CXL-SSD (PR 5 tentpole) —
# private mounts, a shared pool, per-host caches over one shared flash
# (GC-triggering), and a single-host GC-pressure trace
MULTI_SSD_HOSTS = {"multihost-ssd-mounts": 2, "multihost-ssd-pool": 4,
                   "multihost-ssd-sharedflash": 2}

# fault-injection scenarios (PR 7): deterministic FaultPlans pinned
# end-to-end — link CRC-retry bursts under ECMP, a port-down window that
# forces failover reroutes, and NAND read-retry + erase-fail retirement
# (+ read poison) on a GC-pressured cached SSD
FAULT_SCENARIOS = ("faults-linkretry@spine_leaf",
                   "faults-portdown-failover@mesh",
                   "faults-nand-retry@direct")

# multi-host transport-fault scenarios (PR 9): the fused multi-host lanes
# mirror fabric fault plans on per-host mounts; the pins carry each
# host's per-access latencies AND the aggregated fault counters
# (degraded accesses, ECMP failovers, link retries)
MULTI_FAULT_HOSTS = {"faults-portdown@multihost_x2": 2,
                     "faults-linkretry@spine_leaf_x4": 4}

# rack-scale fleet scenario (PR 10): a synthesized Zipfian fleet on a
# 2-pod datacenter fabric (cross-pod host->device paths through the core
# tier), replayed by the SHARDED shard_map lane — the pin holds the
# interpreted MultiHostDriver's latencies, so golden conformance certifies
# sharded == python tick-for-tick at whatever device count the run forces
# (D=1 in the default tier; the CI fleet-smoke job re-runs it on 8 forced
# host-platform devices)
FLEET_SCENARIO = "fleet-zipf@multipod_2x4"
FLEET_GOLDEN_HOSTS = 8


def scenario_names():
    names = [f"{d}@{attach}" for d in DEVICES
             for attach in ("direct", "fabric")]
    names.append("multihost-qos-ecmp")
    names += ["dram@stream", "pmem@stream"]
    names += sorted(MULTI_SSD_HOSTS)
    names.append("ssd-gc@direct")
    names += list(FAULT_SCENARIOS)
    # single-host fabric port with weighted (QoS) arbitration: pins the
    # qos_throttle_events counter python==fused (PR 8 — previously the
    # fused single-host lanes hardcoded 0 and the divergence was
    # deliberately left unpinned)
    names.append("dram-qos@fabric")
    names += sorted(MULTI_FAULT_HOSTS)
    names.append(FLEET_SCENARIO)
    return names


def is_multi(name: str) -> bool:
    """Multi-host scenarios pin one latency list per host."""
    return (name.startswith("multihost") or name in MULTI_FAULT_HOSTS
            or name.startswith("fleet"))


def scenario_outstanding(name: str) -> int:
    return STREAM_OUTSTANDING if name.endswith("@stream") else OUTSTANDING


def make_trace(seed: int, n: int = N_ACCESSES, pages: int = 24,
               write_frac: float = 0.3):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, pages, n) * 4096 + rng.integers(0, 64, n) * 64
    writes = rng.random(n) < write_frac
    return [(int(a), 64, bool(w)) for a, w in zip(addrs, writes)]


def _mk_device(name: str):
    from repro.core.cache.dram_cache import DRAMCacheConfig
    from repro.core.devices import make_device

    if name == "cxl-ssd-cache":
        return make_device(name, cache_cfg=DRAMCacheConfig(policy="lru",
                                                           **CACHE_KW))
    return make_device(name)


def _gc_ssd_cfg(cap_pages: int):
    """Tiny flash geometry so short pinned traces reach the GC watermark."""
    from repro.core.ssd.hil import SSDConfig
    from repro.core.ssd.pal import NANDTiming

    return SSDConfig(capacity_bytes=cap_pages * 4096, page_bytes=4096,
                     channels=2, dies_per_channel=2, pages_per_block=8,
                     timing=NANDTiming.low_latency(), hil_overhead_ns=1000.0)


def _make_fault_target(name: str):
    """Fresh target with its scenario's deterministic FaultPlan installed
    (the plan is a pure function of (seed, config): rebuilding the target
    reproduces the exact same fault schedule)."""
    from repro.core.cache.dram_cache import DRAMCacheConfig
    from repro.core.devices import make_device
    from repro.core.fabric import Fabric
    from repro.core.faults import FaultConfig, FaultPlan, install

    if name == "faults-linkretry@spine_leaf":
        fab = Fabric.build("spine_leaf", num_hosts=2, num_devices=2,
                           num_leaves=2, num_spines=2, ecmp=True)
        tgt = fab.mount("h0", "d0", _mk_device("dram"))
        install(FaultPlan(FaultConfig(link_retry_rate=0.25), seed=7), [tgt])
        return tgt
    if name == "faults-portdown-failover@mesh":
        fab = Fabric.build("mesh", num_hosts=2, num_devices=2)
        tgt = fab.mount("h0", "d0", _mk_device("cxl-dram"))
        install(FaultPlan(FaultConfig(
            down_links=(("s0_0", "s0_1", 10, 70),)), seed=7), [tgt])
        return tgt
    # faults-nand-retry@direct: GC-pressured cached SSD so the pinned
    # trace also exercises erase-fail block retirement and read poison
    dev = make_device("cxl-ssd-cache", ssd_cfg=_gc_ssd_cfg(750),
                      cache_cfg=DRAMCacheConfig(
                          capacity_bytes=8 * 4096, mshr_entries=4,
                          writeback_buffer=2))
    install(FaultPlan(FaultConfig(nand_read_retry_rate=0.3,
                                  erase_fail_rate=0.5,
                                  poison_rate=0.1), seed=0), [dev])
    return dev


def make_target(name: str):
    """Fresh device for ``<device>@<attach>`` scenarios (``@stream`` is
    directly attached, replayed at the streaming issue depth;
    ``ssd-gc`` is a cached CXL-SSD with a near-full tiny flash; the
    ``faults-*`` scenarios carry an installed deterministic fault plan)."""
    from repro.core.cache.dram_cache import DRAMCacheConfig
    from repro.core.devices import make_device
    from repro.core.fabric import Fabric

    if name in FAULT_SCENARIOS:
        return _make_fault_target(name)
    if name == "dram-qos@fabric":
        # weighted-arbitration fabric port: the single-host QoS virtual
        # clock can outrun arrivals, so the throttle counter moves
        fab = Fabric.build("two_level", num_hosts=2, num_devices=2,
                           num_leaves=2, qos_weights={"h0": 3.0, "h1": 1.0})
        return fab.mount("h1", "d1", _mk_device("dram"))
    device, attach = name.split("@")
    if device == "ssd-gc":
        return make_device("cxl-ssd-cache", ssd_cfg=_gc_ssd_cfg(750),
                           cache_cfg=DRAMCacheConfig(
                               capacity_bytes=8 * 4096, mshr_entries=4,
                               writeback_buffer=2))
    dev = _mk_device(device)
    if attach == "fabric":
        fab = Fabric.build("two_level", num_hosts=2, num_devices=2,
                           num_leaves=2)
        return fab.mount("h1", "d1", dev)
    return dev


def _make_multi_fault_targets(name: str):
    """Per-host fabric mounts on one spine-leaf with a deterministic
    transport FaultPlan installed — a down window that forces ECMP
    failover (x2) or CRC link-retry bursts (x4)."""
    from repro.core.devices import make_device
    from repro.core.fabric import Fabric
    from repro.core.faults import FaultConfig, FaultPlan, install

    nh = MULTI_FAULT_HOSTS[name]
    fab = Fabric.build("spine_leaf", num_hosts=nh, num_devices=nh,
                       num_leaves=2, num_spines=2, ecmp=True)
    tgts = [fab.mount(f"h{i}", f"d{i}", make_device("dram"))
            for i in range(nh)]
    if name == "faults-portdown@multihost_x2":
        cfg = FaultConfig(down_links=(("s0", "sp0", 20, 90),))
    else:
        cfg = FaultConfig(link_retry_rate=0.2, link_retry_max=2)
    install(FaultPlan(cfg, seed=11), tgts)
    return tgts


def make_multi_targets(name: str = "multihost-qos-ecmp"):
    """Fresh targets + traces builder inputs for the multi-host scenarios."""
    from repro.core.cache.dram_cache import DRAMCacheConfig
    from repro.core.devices import CachedCXLSSDDevice, DRAMDevice
    from repro.core.fabric import Fabric, MemoryPool
    from repro.core.ssd.hil import HIL

    if name in MULTI_FAULT_HOSTS:
        return _make_multi_fault_targets(name)
    if name == FLEET_SCENARIO:
        from repro.core.devices import make_device

        fab = Fabric.build("multi_pod", ecmp=True, num_pods=2,
                           hosts_per_pod=FLEET_GOLDEN_HOSTS // 2)
        return [fab.mount(f"h{i}", f"d{i}", make_device("dram"))
                for i in range(FLEET_GOLDEN_HOSTS)]
    if name == "multihost-qos-ecmp":
        fab = Fabric.build("spine_leaf", num_hosts=MULTI["num_hosts"],
                           num_devices=2, num_leaves=MULTI["num_leaves"],
                           num_spines=MULTI["num_spines"], ecmp=True,
                           qos_weights=MULTI["qos_weights"])
        pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
        return pool.views([f"h{i}" for i in range(MULTI["num_hosts"])])
    cache_cfg = dict(policy="lru", **CACHE_KW)
    if name == "multihost-ssd-pool":
        fab = Fabric.build("two_level", num_hosts=4, num_devices=2,
                           num_leaves=2)
        pool = MemoryPool(fab, {
            "d0": CachedCXLSSDDevice(
                cache_cfg=DRAMCacheConfig(**cache_cfg)),
            "d1": CachedCXLSSDDevice(
                cache_cfg=DRAMCacheConfig(**cache_cfg))})
        return pool.views([f"h{i}" for i in range(4)])
    nh = MULTI_SSD_HOSTS[name]
    fab = Fabric.build("two_level", num_hosts=nh, num_devices=nh,
                       num_leaves=2)
    hil = (HIL(_gc_ssd_cfg(48))
           if name == "multihost-ssd-sharedflash" else None)
    return [fab.mount(f"h{i}", f"d{i}", CachedCXLSSDDevice(
                cache_cfg=DRAMCacheConfig(**cache_cfg), hil=hil))
            for i in range(nh)]


def multi_traces(name: str = "multihost-qos-ecmp"):
    if name in MULTI_FAULT_HOSTS:
        return [make_trace(400 + h) for h in range(MULTI_FAULT_HOSTS[name])]
    if name == FLEET_SCENARIO:
        # synthesized (hash-seeded) Zipfian fleet traffic — the workload
        # generator twins, pinned end-to-end through the replay engines
        from repro.data import WorkloadSpec, make_traces

        spec = WorkloadSpec("zipfian", num_pages=48, zipf_s=1.1)
        return make_traces(spec, 29, FLEET_GOLDEN_HOSTS, N_ACCESSES)
    if name == "multihost-ssd-sharedflash":
        # write-heavy churn past the 16-page cache: reaches the tiny shared
        # flash's GC watermark (sustained, clean-victim collections)
        return [make_trace(300 + h, n=N_ACCESSES, pages=24, write_frac=0.7)
                for h in range(MULTI_SSD_HOSTS[name])]
    nh = MULTI_SSD_HOSTS.get(name, MULTI["num_hosts"])
    return [make_trace(100 + h) for h in range(nh)]


class ServiceTap:
    """Wrap a MemDevice, recording the latency of every service call —
    the interpreted drivers' per-access latencies, without touching them."""

    def __init__(self, dev):
        self._dev = dev
        self.latencies = []

    def __getattr__(self, name):
        return getattr(self._dev, name)

    def service(self, now, addr, size, write, posted=False):
        done = self._dev.service(now, addr, size, write, posted)
        self.latencies.append(int(done - now))
        return done


def _summ(latencies, result):
    return {
        "latency_ticks": [int(x) for x in latencies],
        "elapsed_ticks": int(result.elapsed_ticks),
        "sum_latency_ticks": int(result.sum_latency_ticks),
        "end_tick": int(result.end_tick),
    }


def scenario_trace(name: str):
    """The pinned trace for a single-host scenario (seeded random; the GC
    scenario uses the deterministic near-full fill + scattered rewrites so
    victim blocks carry valid pages and the migration path is pinned)."""
    if name == "ssd-gc@direct":
        trace = [(p * 4096, 64, True) for p in range(750)]
        trace += [(((k * 9) % 750) * 4096 + (k % 64) * 64, 64, True)
                  for k in range(40)]
        return trace
    if name == "faults-nand-retry@direct":
        # near-full fill + scattered rewrites (GC + erase-fail retirement)
        # + a read tail (NAND read retries through cache misses, and read
        # ordinals the poison schedule can flag)
        trace = [(p * 4096, 64, True) for p in range(750)]
        trace += [(((k * 9) % 750) * 4096 + (k % 64) * 64, 64, True)
                  for k in range(40)]
        trace += [(((k * 131) % 750) * 4096, 64, False) for k in range(24)]
        return trace
    return make_trace(hash_seed(name))


def run_python(name: str):
    """Interpreted reference: per-access latencies + scalar summary."""
    from repro.core.workloads.driver import MultiHostDriver, TraceDriver

    if is_multi(name):
        taps = [ServiceTap(t) for t in make_multi_targets(name)]
        res = MultiHostDriver(taps, outstanding=OUTSTANDING).run(
            multi_traces(name))
        return [_summ(tap.latencies, host)
                for tap, host in zip(taps, res.per_host)]
    tap = ServiceTap(make_target(name))
    res = TraceDriver(tap, outstanding=scenario_outstanding(name)).run(
        scenario_trace(name))
    return _summ(tap.latencies, res)


def run_scan(name: str, block_size: int = 1):
    """Fused lax.scan replay (optionally blocked): per-access latencies +
    scalar summary.  Any ``block_size`` must match the ``python_scan``
    pins exactly.  ``fleet-*`` scenarios replay through the SHARDED
    shard_map lane, so the pins certify it at the run's device count."""
    from repro.core.replay import (MultiHostReplay, ReplayEngine,
                                   ShardedMultiHostReplay)

    if is_multi(name):
        cls = (ShardedMultiHostReplay if name.startswith("fleet")
               else MultiHostReplay)
        eng = cls(make_multi_targets(name),
                  outstanding=OUTSTANDING,
                  block_size=block_size)
        res, lat = eng.run_recorded(multi_traces(name))
        return [_summ(l.tolist(), host)
                for l, host in zip(lat, res.per_host)]
    res = ReplayEngine(make_target(name),
                       outstanding=scenario_outstanding(name),
                       block_size=block_size).run(scenario_trace(name))
    return _summ(res.latency_ticks.tolist(), res)


def run_python_metrics(name: str):
    """Interpreted reference metrics bundle (JSON form): the schema every
    fused lane must reproduce value-for-value."""
    from repro.core.replay.metrics import MetricsSpec
    from repro.core.workloads.driver import MultiHostDriver, TraceDriver

    spec = MetricsSpec()
    if is_multi(name):
        res = MultiHostDriver(make_multi_targets(name),
                              outstanding=OUTSTANDING,
                              metrics=spec).run(multi_traces(name))
    else:
        res = TraceDriver(make_target(name),
                          outstanding=scenario_outstanding(name),
                          engine="python",
                          metrics=spec).run(scenario_trace(name))
    return res.metrics.to_jsonable()


def run_scan_metrics(name: str):
    """Fused-lane metrics bundle (JSON form): in-scan accumulation must
    match the interpreted stats dicts exactly (``fleet-*`` through the
    sharded lane — its psum-folded accumulators included)."""
    from repro.core.replay import (MultiHostReplay, ReplayEngine,
                                   ShardedMultiHostReplay)
    from repro.core.replay.metrics import MetricsSpec

    spec = MetricsSpec()
    if is_multi(name):
        cls = (ShardedMultiHostReplay if name.startswith("fleet")
               else MultiHostReplay)
        res = cls(make_multi_targets(name),
                  outstanding=OUTSTANDING,
                  metrics=spec).run(multi_traces(name))
    else:
        res = ReplayEngine(make_target(name),
                           outstanding=scenario_outstanding(name),
                           metrics=spec).run(scenario_trace(name))
    return res.metrics.to_jsonable()


def run_scan_blocked(name: str):
    """Blocked-scan lane (``block_size=BLOCK_SIZE``): must match the
    ``python_scan`` pins — block seams are tick-invisible."""
    return run_scan(name, block_size=BLOCK_SIZE)


def run_assoc(name: str):
    """Log-depth associative lane: must match the ``python_scan`` pins on
    every stack it certifies (stateless DRAM/PMEM media)."""
    from repro.core.replay import AssocReplayEngine

    res = AssocReplayEngine(make_target(name),
                            outstanding=scenario_outstanding(name),
                            max_sweeps=ASSOC_SWEEPS).run(
        scenario_trace(name))
    return _summ(res.latency_ticks.tolist(), res)


def assoc_supported(name: str) -> bool:
    return name.split("@")[0] in ("dram", "cxl-dram", "pmem") \
        and not is_multi(name)


def run_pallas(name: str):
    """Pallas engine (cached CXL-SSD only): its own pinned latencies, with
    the associative latency reconstruction cross-check enabled."""
    from repro.core.replay.pallas_engine import run_pallas as _run
    from repro.core.replay.spec import trace_to_arrays

    addrs, writes, size = trace_to_arrays(scenario_trace(name))
    res = _run(make_target(name), addrs, writes, size=size,
               outstanding=scenario_outstanding(name), validate=True)
    return _summ(res.latency_ticks.tolist(), res)


def pallas_supported(name: str) -> bool:
    return name.startswith("cxl-ssd-cache@")


def hash_seed(name: str) -> int:
    """Stable small per-scenario trace seed (NOT Python's randomized
    ``hash``)."""
    return sum(ord(c) for c in name) % 997


def load_fixture() -> dict:
    with open(FIXTURE) as fh:
        return json.load(fh)
