"""Golden-trace scenario table + engine runners.

One scenario = one (device stack, attach mode, seeded trace) combination.
The fixture (``golden_traces.json``) pins per-access latencies so that
*silent* divergence — python and scan drifting together, or an engine's
latency model changing without anyone noticing — fails loudly, which the
pairwise python==scan property tests cannot catch.

Contracts pinned per scenario:

* ``python_scan`` — per-access latency ticks that the interpreted
  ``TraceDriver``/``MultiHostDriver`` path, the fused lax.scan replay, the
  **blocked** scan (``block_size=BLOCK_SIZE``), and the **associative**
  log-depth lane (where it certifies the stack — stateless DRAM/PMEM
  media) must ALL reproduce exactly.  One pin, every tick-exact lane.
* ``pallas`` — the Pallas engine's own per-access latencies where the
  engine supports the stack (cached CXL-SSD).  Its analytic latency model
  is *not* tick-identical to python; pinning its output separately catches
  silent regressions in that model too.  The golden runner passes
  ``validate=True`` so every conformance pass also cross-checks the
  in-kernel latency chain against the shared associative reconstruction.

The ``@stream`` scenarios replay with ``outstanding=32`` — the
bandwidth-bound regime the associative lane is built for (it converges in
a couple of sweeps there, vs. crawling through the LFB feedback on the
``outstanding=8`` scenarios).

Regenerate with ``PYTHONPATH=src python tests/golden/regen.py`` after an
intentional timing-model change, and say so in the commit message.  Regen
refuses to alter any previously pinned scenario — history can only be
extended, never silently rewritten.
"""

from __future__ import annotations

import json
from pathlib import Path

import numpy as np

FIXTURE = Path(__file__).with_name("golden_traces.json")

CACHE_KW = dict(capacity_bytes=16 * 4096, mshr_entries=4, writeback_buffer=2)
DEVICES = ["dram", "cxl-dram", "pmem", "cxl-ssd", "cxl-ssd-cache"]
N_ACCESSES = 160
OUTSTANDING = 8
STREAM_OUTSTANDING = 32      # @stream scenarios: bandwidth-bound issue depth
BLOCK_SIZE = 8               # blocked-scan lane pinned alongside B=1
ASSOC_SWEEPS = 256           # short traces afford a generous Kleene budget

# multi-host tentpole scenario: QoS weights + ECMP on a spine-leaf pool
MULTI = dict(num_hosts=3, num_leaves=2, num_spines=2,
             qos_weights={"h0": 3.0, "h1": 1.0, "h2": 1.0})


def scenario_names():
    names = [f"{d}@{attach}" for d in DEVICES
             for attach in ("direct", "fabric")]
    names.append("multihost-qos-ecmp")
    names += ["dram@stream", "pmem@stream"]
    return names


def scenario_outstanding(name: str) -> int:
    return STREAM_OUTSTANDING if name.endswith("@stream") else OUTSTANDING


def make_trace(seed: int, n: int = N_ACCESSES, pages: int = 24,
               write_frac: float = 0.3):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, pages, n) * 4096 + rng.integers(0, 64, n) * 64
    writes = rng.random(n) < write_frac
    return [(int(a), 64, bool(w)) for a, w in zip(addrs, writes)]


def _mk_device(name: str):
    from repro.core.cache.dram_cache import DRAMCacheConfig
    from repro.core.devices import make_device

    if name == "cxl-ssd-cache":
        return make_device(name, cache_cfg=DRAMCacheConfig(policy="lru",
                                                           **CACHE_KW))
    return make_device(name)


def make_target(name: str):
    """Fresh device for ``<device>@<attach>`` scenarios (``@stream`` is
    directly attached, replayed at the streaming issue depth)."""
    from repro.core.fabric import Fabric

    device, attach = name.split("@")
    dev = _mk_device(device)
    if attach == "fabric":
        fab = Fabric.build("two_level", num_hosts=2, num_devices=2,
                           num_leaves=2)
        return fab.mount("h1", "d1", dev)
    return dev


def make_multi_targets():
    """Fresh pool views for the multihost QoS+ECMP scenario."""
    from repro.core.devices import DRAMDevice
    from repro.core.fabric import Fabric, MemoryPool

    fab = Fabric.build("spine_leaf", num_hosts=MULTI["num_hosts"],
                       num_devices=2, num_leaves=MULTI["num_leaves"],
                       num_spines=MULTI["num_spines"], ecmp=True,
                       qos_weights=MULTI["qos_weights"])
    pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
    return pool.views([f"h{i}" for i in range(MULTI["num_hosts"])])


def multi_traces():
    return [make_trace(100 + h) for h in range(MULTI["num_hosts"])]


class ServiceTap:
    """Wrap a MemDevice, recording the latency of every service call —
    the interpreted drivers' per-access latencies, without touching them."""

    def __init__(self, dev):
        self._dev = dev
        self.latencies = []

    def __getattr__(self, name):
        return getattr(self._dev, name)

    def service(self, now, addr, size, write, posted=False):
        done = self._dev.service(now, addr, size, write, posted)
        self.latencies.append(int(done - now))
        return done


def _summ(latencies, result):
    return {
        "latency_ticks": [int(x) for x in latencies],
        "elapsed_ticks": int(result.elapsed_ticks),
        "sum_latency_ticks": int(result.sum_latency_ticks),
        "end_tick": int(result.end_tick),
    }


def run_python(name: str):
    """Interpreted reference: per-access latencies + scalar summary."""
    from repro.core.workloads.driver import MultiHostDriver, TraceDriver

    if name == "multihost-qos-ecmp":
        taps = [ServiceTap(t) for t in make_multi_targets()]
        res = MultiHostDriver(taps, outstanding=OUTSTANDING).run(
            multi_traces())
        return [_summ(tap.latencies, host)
                for tap, host in zip(taps, res.per_host)]
    tap = ServiceTap(make_target(name))
    res = TraceDriver(tap, outstanding=scenario_outstanding(name)).run(
        make_trace(hash_seed(name)))
    return _summ(tap.latencies, res)


def run_scan(name: str, block_size: int = 1):
    """Fused lax.scan replay (optionally blocked): per-access latencies +
    scalar summary.  Any ``block_size`` must match the ``python_scan``
    pins exactly."""
    from repro.core.replay import MultiHostReplay, ReplayEngine

    if name == "multihost-qos-ecmp":
        eng = MultiHostReplay(make_multi_targets(), outstanding=OUTSTANDING,
                              block_size=block_size)
        res, lat = eng.run_recorded(multi_traces())
        return [_summ(l.tolist(), host)
                for l, host in zip(lat, res.per_host)]
    res = ReplayEngine(make_target(name),
                       outstanding=scenario_outstanding(name),
                       block_size=block_size).run(make_trace(hash_seed(name)))
    return _summ(res.latency_ticks.tolist(), res)


def run_scan_blocked(name: str):
    """Blocked-scan lane (``block_size=BLOCK_SIZE``): must match the
    ``python_scan`` pins — block seams are tick-invisible."""
    return run_scan(name, block_size=BLOCK_SIZE)


def run_assoc(name: str):
    """Log-depth associative lane: must match the ``python_scan`` pins on
    every stack it certifies (stateless DRAM/PMEM media)."""
    from repro.core.replay import AssocReplayEngine

    res = AssocReplayEngine(make_target(name),
                            outstanding=scenario_outstanding(name),
                            max_sweeps=ASSOC_SWEEPS).run(
        make_trace(hash_seed(name)))
    return _summ(res.latency_ticks.tolist(), res)


def assoc_supported(name: str) -> bool:
    return name.split("@")[0] in ("dram", "cxl-dram", "pmem") \
        and name != "multihost-qos-ecmp"


def run_pallas(name: str):
    """Pallas engine (cached CXL-SSD only): its own pinned latencies, with
    the associative latency reconstruction cross-check enabled."""
    from repro.core.replay.pallas_engine import run_pallas as _run
    from repro.core.replay.spec import trace_to_arrays

    addrs, writes, size = trace_to_arrays(make_trace(hash_seed(name)))
    res = _run(make_target(name), addrs, writes, size=size,
               outstanding=scenario_outstanding(name), validate=True)
    return _summ(res.latency_ticks.tolist(), res)


def pallas_supported(name: str) -> bool:
    return name.startswith("cxl-ssd-cache@")


def hash_seed(name: str) -> int:
    """Stable small per-scenario trace seed (NOT Python's randomized
    ``hash``)."""
    return sum(ord(c) for c in name) % 997


def load_fixture() -> dict:
    with open(FIXTURE) as fh:
        return json.load(fh)
