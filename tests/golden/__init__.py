"""Golden-trace conformance fixtures for the replay engines."""
