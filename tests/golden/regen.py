"""Regenerate the golden-trace conformance fixture.

    PYTHONPATH=src python tests/golden/regen.py

Two refusal rules protect the pins:

* **No lane divergence** — the interpreted driver, the fused scan, the
  blocked scan, and (where it certifies the stack) the associative lane
  must agree tick-for-tick before anything is written; the pallas runner's
  built-in cross-check (``validate=True``) guards its analytic chain.
* **No silent rewrites** — any contract already pinned in the existing
  fixture must regenerate to *exactly* the same values; a mismatch aborts.
  New scenarios — and new per-scenario contracts (e.g. ``metrics``) — may
  be appended, history is never rewritten.  After an
  intentional timing-model change, delete the stale fixture entries first
  and mention the regeneration in the commit message.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from golden import scenarios as sc  # noqa: E402


def check_history(old: dict, names) -> None:
    """Refuse to *drop* committed history: every pinned scenario must still
    be in the scenario table (append-only fixture)."""
    dropped = sorted(set(old) - set(names))
    if dropped:
        raise SystemExit(
            f"scenario(s) {dropped} are pinned but gone from the scenario "
            "table — refusing to drop committed history (delete the stale "
            "fixture entries first if the removal is intentional)")


def check_rewrite(name: str, old: dict, entry: dict) -> None:
    """Refuse to *rewrite* committed history, key-wise: every contract
    already pinned for the scenario (``python_scan``, ``pallas``,
    ``metrics``, ...) must regenerate byte-for-byte.  *New* keys may be
    appended — growing the pinned surface never requires touching the
    existing pins."""
    if name not in old:
        return
    for key in old[name]:
        if key not in entry:
            raise SystemExit(
                f"{name}: pinned contract {key!r} would be dropped — "
                "refusing to rewrite history (delete the stale entry "
                "first if the removal is intentional)")
        if old[name][key] != entry[key]:
            raise SystemExit(
                f"{name}: regenerated {key!r} differs from the committed "
                "pin — refusing to rewrite history (delete the stale "
                "entry first if the timing-model change is intentional)")


def regen() -> dict:
    old = sc.load_fixture()["scenarios"] if sc.FIXTURE.exists() else {}
    check_history(old, sc.scenario_names())
    fixture = {"format": 1, "scenarios": {}}
    for name in sc.scenario_names():
        py = sc.run_python(name)
        for lane, run in (("scan", sc.run_scan),
                          ("scan[blocked]", sc.run_scan_blocked)):
            got = run(name)
            if py != got:
                raise SystemExit(
                    f"{name}: python and {lane} engines disagree — refusing "
                    "to pin a divergence (fix the engines first)")
        if sc.assoc_supported(name) and py != sc.run_assoc(name):
            raise SystemExit(
                f"{name}: python and assoc engines disagree — refusing to "
                "pin a divergence (fix the engines first)")
        py_metrics = sc.run_python_metrics(name)
        if py_metrics != sc.run_scan_metrics(name):
            raise SystemExit(
                f"{name}: python and scan metrics bundles disagree — "
                "refusing to pin a divergence (fix the engines first)")
        entry = {"python_scan": py, "metrics": py_metrics}
        if sc.pallas_supported(name):
            entry["pallas"] = sc.run_pallas(name)
        check_rewrite(name, old, entry)
        fixture["scenarios"][name] = entry
        print(f"  {name}: ok")
    return fixture


if __name__ == "__main__":
    data = regen()
    with open(sc.FIXTURE, "w") as fh:
        json.dump(data, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {sc.FIXTURE}")
