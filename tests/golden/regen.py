"""Regenerate the golden-trace conformance fixture.

    PYTHONPATH=src python tests/golden/regen.py

Refuses to write if the interpreted and scan engines disagree — a fixture
must never pin a divergence.  Rerun only after an *intentional*
timing-model change, and mention the regeneration in the commit message.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from golden import scenarios as sc  # noqa: E402


def regen() -> dict:
    fixture = {"format": 1, "scenarios": {}}
    for name in sc.scenario_names():
        py = sc.run_python(name)
        scan = sc.run_scan(name)
        if py != scan:
            raise SystemExit(
                f"{name}: python and scan engines disagree — refusing to "
                "pin a divergence (fix the engines first)")
        entry = {"python_scan": py}
        if sc.pallas_supported(name):
            entry["pallas"] = sc.run_pallas(name)
        fixture["scenarios"][name] = entry
        print(f"  {name}: ok")
    return fixture


if __name__ == "__main__":
    data = regen()
    with open(sc.FIXTURE, "w") as fh:
        json.dump(data, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {sc.FIXTURE}")
