"""Optimizer, schedules, compression, data pipeline, checkpointing,
straggler watchdog."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import ShardedLoader
from repro.distributed.straggler import StragglerConfig, StragglerWatchdog
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.compression import compress_init, compressed_gradients
from repro.optim.schedules import cosine_schedule, wsd_schedule


# ------------------------------------------------------------------- adamw
class TestAdamW:
    def _quadratic_converges(self, params):
        state = adamw_init(params)
        cfg = AdamWConfig(weight_decay=0.0)
        target = jax.tree.map(jnp.zeros_like, params)

        def loss(p):
            return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))

        l0 = float(loss(params))
        for i in range(60):
            grads = jax.grad(loss)(params)
            params, state = adamw_update(grads, state, params,
                                         jnp.asarray(0.05), cfg)
        assert float(loss(params)) < l0 * 0.1
        return params

    def test_converges_plain_tree(self):
        self._quadratic_converges({"a": jnp.ones((4, 4)), "b": jnp.ones((3,))})

    def test_converges_namedtuple_tree(self):
        """Regression: NamedTuple subtrees must survive the update unzip."""
        from repro.models.moe import MoEParams
        params = {"moe": MoEParams(router=jnp.ones((2, 2)),
                                   w_gate=jnp.ones((2, 2, 2)),
                                   w_up=jnp.ones((2, 2, 2)),
                                   w_down=jnp.ones((2, 2, 2)))}
        out = self._quadratic_converges(params)
        assert isinstance(out["moe"], MoEParams)

    def test_grad_clip(self):
        params = {"w": jnp.ones((4,))}
        state = adamw_init(params)
        huge = {"w": jnp.full((4,), 1e6)}
        p1, _ = adamw_update(huge, state, params, jnp.asarray(0.1),
                             AdamWConfig(grad_clip=1.0, weight_decay=0.0))
        # with clipping the first step is bounded by ~lr
        assert float(jnp.abs(p1["w"] - params["w"]).max()) < 0.2

    def test_bf16_params_f32_moments(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        state = adamw_init(params)
        assert state["mu"]["w"].dtype == jnp.float32
        g = {"w": jnp.ones((4,), jnp.bfloat16)}
        p1, s1 = adamw_update(g, state, params, jnp.asarray(0.01))
        assert p1["w"].dtype == jnp.bfloat16
        assert s1["count"] == 1


class TestSchedules:
    def test_wsd_phases(self):
        lr = wsd_schedule(1.0, warmup_steps=10, stable_steps=50, decay_steps=20,
                          final_frac=0.1)
        assert float(lr(0)) == 0.0
        assert float(lr(5)) == pytest.approx(0.5)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(40)) == pytest.approx(1.0)      # stable plateau
        assert float(lr(60)) == pytest.approx(1.0)
        assert 0.09 < float(lr(80)) < 0.11              # decayed to final
        assert float(lr(200)) == pytest.approx(0.1)

    def test_cosine(self):
        lr = cosine_schedule(1.0, 10, 100)
        assert float(lr(10)) == pytest.approx(1.0)
        assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)


class TestCompression:
    def test_error_feedback_unbiased(self):
        """Accumulated compressed grads converge to accumulated true grads."""
        g = {"w": jnp.asarray([0.3, -0.7, 0.001, 5.0])}
        st = compress_init(g)
        total = jnp.zeros(4)
        for _ in range(50):
            cg, st = compressed_gradients(g, st)
            total = total + cg["w"]
        np.testing.assert_allclose(np.asarray(total / 50),
                                   np.asarray(g["w"]), rtol=0.02, atol=1e-3)

    def test_quantization_bounded_error(self):
        g = {"w": jnp.linspace(-1, 1, 256)}
        st = compress_init(g)
        cg, st = compressed_gradients(g, st)
        assert float(jnp.abs(cg["w"] - g["w"]).max()) <= 1.0 / 127 + 1e-6


# -------------------------------------------------------------------- data
class TestData:
    def test_deterministic_restart(self):
        cfg = get_arch("minicpm-2b").reduced()
        a = ShardedLoader(cfg, 32, 4, seed=7)
        batches = [a.next() for _ in range(5)]
        st = a.state()
        more = [a.next() for _ in range(3)]
        b = ShardedLoader(cfg, 32, 4, seed=7)
        b.restore(st)
        for want in more:
            got = b.next()
            np.testing.assert_array_equal(got["tokens"], want["tokens"])

    def test_shards_differ(self):
        cfg = get_arch("minicpm-2b").reduced()
        a = ShardedLoader(cfg, 32, 4, shard=0, num_shards=2, seed=7).next()
        b = ShardedLoader(cfg, 32, 4, shard=1, num_shards=2, seed=7).next()
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_learnable_structure(self):
        """Markov stream has non-uniform bigram stats (lower entropy)."""
        cfg = get_arch("minicpm-2b").reduced()
        t = ShardedLoader(cfg, 512, 8, seed=3).next()["tokens"].ravel()
        uniq = len(np.unique(t))
        assert uniq < 300  # 64 states x 8 emissions, not full vocab

    def test_vlm_frontend(self):
        cfg = get_arch("llama-3_2-vision-90b").reduced()
        b = ShardedLoader(cfg, 16, 2, seed=1).next()
        assert "frontend" in b
        assert b["frontend"].shape == (2, cfg.n_frontend_tokens, cfg.d_model)


# -------------------------------------------------------------- checkpoint
class TestCheckpoint:
    def _tree(self):
        return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                           "b": jnp.ones((5,), jnp.bfloat16)},
                "count": jnp.asarray(3)}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = self._tree()
        mgr.save(7, tree, extra={"loader": {"step": 9}})
        out, extra, step = mgr.restore(tree)
        assert step == 7 and extra["loader"]["step"] == 9
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))
        assert out["params"]["b"].dtype == np.dtype("bfloat16") or \
            str(out["params"]["b"].dtype) == "bfloat16"

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, self._tree())
        assert not list(tmp_path.glob("*.tmp"))
        assert (tmp_path / "step_00000001" / "manifest.json").exists()

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = self._tree()
        path = mgr.save(2, tree)
        victim = next(path.glob("params__w.bin"))
        data = bytearray(victim.read_bytes())
        data[-1] ^= 0xFF
        victim.write_bytes(bytes(data))
        with pytest.raises(IOError):
            mgr.restore(tree)

    def test_keep_last(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree())
        assert sorted(mgr.all_steps()) == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save_async(5, self._tree())
        mgr.wait()
        assert mgr.latest_step() == 5

    def test_resume_training_loop(self, tmp_path):
        """End-to-end: train, checkpoint, restart, identical continuation."""
        from repro.distributed.step import make_train_step
        from repro.models.transformer import init_params
        from repro.optim.adamw import adamw_init
        from repro.optim.schedules import wsd_schedule

        cfg = get_arch("minicpm-2b").reduced()
        key = jax.random.PRNGKey(0)
        step_fn = jax.jit(make_train_step(
            cfg, mesh=None, lr_fn=wsd_schedule(1e-3, 2, 10, 5)))
        loader = ShardedLoader(cfg, 16, 2, seed=5)

        params = init_params(key, cfg)
        opt = adamw_init(params)
        mgr = CheckpointManager(tmp_path)
        for step in range(4):
            batch = {k: jnp.asarray(v) for k, v in loader.next().items()}
            params, opt, loss = step_fn(params, opt, batch,
                                        jnp.asarray(step, jnp.int32))
            if step == 1:
                mgr.save(2, {"p": params, "o": opt},
                         extra={"loader": loader.state()})
        want = float(loss)

        # restart from step 2
        tmpl = {"p": init_params(key, cfg), "o": adamw_init(params)}
        state, extra, start = mgr.restore(tmpl)
        loader2 = ShardedLoader(cfg, 16, 2, seed=5)
        loader2.restore(extra["loader"])
        p2, o2 = state["p"], state["o"]
        for step in range(start, 4):
            batch = {k: jnp.asarray(v) for k, v in loader2.next().items()}
            p2, o2, loss2 = step_fn(p2, o2, batch, jnp.asarray(step, jnp.int32))
        assert float(loss2) == pytest.approx(want, rel=1e-5)


# --------------------------------------------------------------- straggler
class TestStraggler:
    def test_flags_slow_steps(self):
        wd = StragglerWatchdog(StragglerConfig(warmup_steps=2, threshold=2.0))
        for _ in range(5):
            wd.end_step(duration_s=1.0)
        rep = wd.end_step(duration_s=3.0)
        assert rep.flagged
        rep = wd.end_step(duration_s=1.0)
        assert not rep.flagged

    def test_evict_advice_after_consecutive(self):
        wd = StragglerWatchdog(StragglerConfig(warmup_steps=1, threshold=1.5,
                                               evict_after=3))
        wd.end_step(duration_s=1.0)
        wd.end_step(duration_s=1.0)
        reps = [wd.end_step(host=4, duration_s=5.0) for _ in range(3)]
        assert reps[-1].evict_advised
        assert wd.worst_hosts() == [4]

    def test_straggler_does_not_poison_ewma(self):
        wd = StragglerWatchdog(StragglerConfig(warmup_steps=1, threshold=2.0))
        wd.end_step(duration_s=1.0)
        wd.end_step(duration_s=1.0)
        before = wd.ewma
        wd.end_step(duration_s=10.0)   # flagged -> must not update ewma
        assert wd.ewma == before


class TestInt8Moments:
    """8-bit Adam moments (the trillion-param capacity lever, §Dry-run)."""

    def test_converges(self):
        params = {"a": jnp.ones((8, 16)), "b": jnp.ones((5,))}
        cfg = AdamWConfig(weight_decay=0.0, moment_dtype="int8")
        state = adamw_init(params, "int8")

        def loss(p):
            return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(p))

        l0 = float(loss(params))
        for _ in range(80):
            g = jax.grad(loss)(params)
            params, state = adamw_update(g, state, params, jnp.asarray(0.05),
                                         cfg)
        assert float(loss(params)) < l0 * 0.05

    def test_optimizes_as_well_as_f32(self):
        """Per the 8-bit-Adam literature: parameter trajectories diverge
        under quantization noise, but the achieved LOSS matches f32."""
        rng = jax.random.PRNGKey(3)
        params = {"w": jax.random.normal(rng, (16, 16))}
        tgt = jax.random.normal(jax.random.fold_in(rng, 1), (16, 16))

        def loss(p):
            return jnp.mean(jnp.square(p["w"] - tgt))

        p32, s32 = dict(params), adamw_init(params)
        p8, s8 = dict(params), adamw_init(params, "int8")
        c32 = AdamWConfig(weight_decay=0.0)
        c8 = AdamWConfig(weight_decay=0.0, moment_dtype="int8")
        for _ in range(30):
            g32 = jax.grad(loss)(p32)
            p32, s32 = adamw_update(g32, s32, p32, jnp.asarray(0.02), c32)
            g8 = jax.grad(loss)(p8)
            p8, s8 = adamw_update(g8, s8, p8, jnp.asarray(0.02), c8)
        l32, l8 = float(loss(p32)), float(loss(p8))
        assert l8 < l32 * 1.1 + 1e-3, (l8, l32)

    def test_state_is_4x_smaller(self):
        params = {"w": jnp.ones((64, 256))}
        s32 = adamw_init(params)
        s8 = adamw_init(params, "int8")
        b32 = sum(l.nbytes for l in jax.tree.leaves(s32))
        b8 = sum(l.nbytes for l in jax.tree.leaves(s8))
        assert b8 < 0.3 * b32
