"""JAX-native seeded workload generators (repro.data.workloads): the
scalar / numpy / jnp twins must be bit-equal per element, deterministic per
(seed, shape), and the distributions must actually have the shape their
names promise (Zipf rank-frequency slope, hotspot mass concentration,
bursty duty cycle, scan periodicity)."""

import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.data import (
    WORKLOAD_KINDS,
    WorkloadSpec,
    access_at,
    host_trace_jnp,
    host_trace_np,
    make_traces,
    traces_np,
    zipf_cdf,
)

SPECS = {
    "zipfian": WorkloadSpec("zipfian", num_pages=512, zipf_s=1.1),
    "hotspot": WorkloadSpec("hotspot", num_pages=256, hot_frac=0.85,
                            hot_pages=16),
    "bursty": WorkloadSpec("bursty", num_pages=384, on_len=32, off_len=96),
    "scan": WorkloadSpec("scan", num_pages=200, stride_pages=3),
}


@pytest.mark.parametrize("kind", WORKLOAD_KINDS)
def test_deterministic_per_seed_and_shape(kind):
    spec = SPECS[kind]
    a1, w1 = host_trace_np(spec, 7, 3, 400)
    a2, w2 = host_trace_np(spec, 7, 3, 400)
    assert np.array_equal(a1, a2) and np.array_equal(w1, w2)
    # a longer trace is a prefix-extension, not a reshuffle
    a3, _ = host_trace_np(spec, 7, 3, 800)
    assert np.array_equal(a3[:400], a1)
    # seed and host both move the stream (scan's pages are index-only,
    # but its line offsets and writes still draw from the hash)
    ds, _ = host_trace_np(spec, 8, 3, 400)
    dh, _ = host_trace_np(spec, 7, 4, 400)
    assert not np.array_equal(ds, a1)
    assert not np.array_equal(dh, a1)


@pytest.mark.parametrize("kind", WORKLOAD_KINDS)
def test_scalar_numpy_jnp_twins_bit_equal(kind):
    spec = SPECS[kind]
    n = 300
    an, wn = host_trace_np(spec, 11, 2, n)
    for i in range(0, n, 37):
        a, w = access_at(spec, 11, 2, i)
        assert (a, w) == (int(an[i]), bool(wn[i]))
    with enable_x64():
        aj, wj = host_trace_jnp(spec, 11, 2, n)
        assert np.array_equal(np.asarray(aj), an)
        assert np.array_equal(np.asarray(wj), wn)


def test_traces_np_and_make_traces_agree():
    spec = SPECS["hotspot"]
    addrs, writes = traces_np(spec, 5, 3, 64)
    assert addrs.shape == (3, 64) and writes.shape == (3, 64)
    tup = make_traces(spec, 5, 3, 64)
    assert len(tup) == 3
    for h in range(3):
        assert [a for a, _, _ in tup[h]] == list(addrs[h])
        assert [w for _, _, w in tup[h]] == list(writes[h])
        assert all(s == 64 for _, s, _ in tup[h])


def test_addresses_stay_inside_the_footprint():
    for kind, spec in SPECS.items():
        addrs, _ = host_trace_np(spec, 3, 0, 2000)
        assert addrs.min() >= 0
        assert addrs.max() < spec.num_pages * spec.page_bytes
        assert (addrs % 64 == 0).all()


def test_write_fraction_tracks_the_coin():
    spec = WorkloadSpec("scan", num_pages=64, write_frac=0.25)
    _, writes = host_trace_np(spec, 9, 0, 20_000)
    assert abs(writes.mean() - 0.25) < 0.02


def test_zipf_rank_frequency_slope():
    """log(freq) vs log(rank) of a Zipf(s) sample must have slope ~ -s."""
    spec = SPECS["zipfian"]
    addrs, _ = host_trace_np(spec, 13, 0, 60_000)
    pages = addrs // spec.page_bytes
    counts = np.bincount(pages, minlength=spec.num_pages)
    top = np.sort(counts)[::-1][:64].astype(float)
    assert (top > 0).all()
    slope = np.polyfit(np.log(np.arange(1, 65)), np.log(top), 1)[0]
    assert -1.35 < slope < -0.85       # s = 1.1
    # page 0 is the hottest rank
    assert counts.argmax() == 0
    cdf = zipf_cdf(spec.num_pages, spec.zipf_s)
    assert cdf[-1] == 1.0 and (np.diff(cdf) > 0).all()


def test_hotspot_mass_concentration():
    spec = SPECS["hotspot"]
    addrs, _ = host_trace_np(spec, 17, 1, 40_000)
    pages = addrs // spec.page_bytes
    hot = (pages < spec.hot_set_pages).mean()
    assert abs(hot - spec.hot_frac) < 0.02
    # the hot set is 16/256 of the footprint but carries ~85% of the mass
    assert hot > 4 * (spec.hot_set_pages / spec.num_pages)


def test_bursty_duty_cycle():
    spec = SPECS["bursty"]
    n = 8 * (spec.on_len + spec.off_len)
    addrs, _ = host_trace_np(spec, 19, 0, n)
    pages = addrs // spec.page_bytes
    idx = np.arange(n)
    on = idx % (spec.on_len + spec.off_len) < spec.on_len
    # ON windows hit the hot set, OFF windows stride the cold footprint
    assert (pages[on] < spec.hot_set_pages).all()
    assert np.array_equal(
        pages[~on], (idx[~on] * spec.cold_stride) % spec.num_pages)
    assert abs(on.mean() - spec.on_len / (spec.on_len + spec.off_len)) < 1e-9


def test_scan_periodicity():
    spec = SPECS["scan"]
    addrs, _ = host_trace_np(spec, 23, 0, 1000)
    pages = addrs // spec.page_bytes
    assert np.array_equal(pages,
                          (np.arange(1000) * spec.stride_pages)
                          % spec.num_pages)


def test_spec_validation():
    with pytest.raises(ValueError):
        WorkloadSpec("nope", num_pages=8)
    with pytest.raises(ValueError):
        WorkloadSpec("zipfian", num_pages=1)
    with pytest.raises(ValueError):
        WorkloadSpec("hotspot", num_pages=8, hot_pages=8)
    with pytest.raises(ValueError):
        WorkloadSpec("bursty", num_pages=8, on_len=0)
    with pytest.raises(ValueError):
        WorkloadSpec("scan", num_pages=8, stride_pages=0)
