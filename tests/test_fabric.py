"""CXL fabric subsystem: topology builders, deterministic routing, CXLLink
equivalence on the direct topology, shared-bottleneck contention, QoS
weighted arbitration, ECMP multipath, pooled address mapping, the
multi-host driver, and the vectorized congestion estimator."""

import numpy as np
import pytest

from repro.core.devices import CXLDRAMDevice, CXLLink, DRAMDevice, NullLink
from repro.core.fabric import (
    Fabric,
    FabricAttachedDevice,
    MemoryPool,
    PoolAddressMapper,
    Topology,
    build_topology,
    direct,
    flow_choices,
    flow_hash,
    mesh,
    single_switch,
    spine_leaf,
    two_level,
)
from repro.core.workloads.driver import MultiHostDriver, TraceDriver

LINE = 64


def stream_trace(n, base=0, write_every=4):
    return [(base + i * LINE, LINE, i % write_every == 0) for i in range(n)]


# ------------------------------------------------------------------ topology
class TestTopology:
    def test_builders_produce_expected_shapes(self):
        t = single_switch(3, 2)
        assert t.hosts == ["h0", "h1", "h2"]
        assert t.devices == ["d0", "d1"]
        assert t.switches == ["s0"]
        t = two_level(4, 2, num_leaves=2)
        assert len(t.switches) == 3
        t = mesh(2, 2, rows=2, cols=2)
        assert len(t.switches) == 4

    def test_factory_rejects_unknown(self):
        with pytest.raises(ValueError):
            build_topology("torus")

    def test_duplicate_node_and_link_rejected(self):
        t = Topology()
        t.add_host("h0")
        with pytest.raises(ValueError):
            t.add_switch("h0")
        t.add_device("d0")
        t.connect("h0", "d0")
        with pytest.raises(ValueError):
            t.connect("d0", "h0")

    def test_disconnected_node_rejected(self):
        t = Topology()
        t.add_host("h0")
        t.add_device("d0")
        with pytest.raises(ValueError):
            t.validate()


# ------------------------------------------------------------------- routing
class TestRouting:
    def test_shortest_and_deterministic(self):
        fab = Fabric(mesh(1, 1, rows=3, cols=3))
        p1 = fab.path("h0", "d0")
        p2 = fab.path("h0", "d0")
        assert p1 is p2  # cached
        # h0 at s0_0, d0 at s2_2: 4 switch hops + 2 edge hops.
        assert len(p1) - 1 == 6
        # Deterministic lexicographic tie-break among equal-cost grid paths.
        assert p1 == ["h0", "s0_0", "s0_1", "s0_2", "s1_2", "s2_2", "d0"]

    def test_hosts_never_relay(self):
        # Two hosts on one switch: route must go h0->s0->d0, never via h1.
        fab = Fabric(single_switch(2, 1))
        assert fab.path("h0", "d0") == ["h0", "s0", "d0"]

    def test_unroutable_raises(self):
        # Two disconnected islands: h0-s0-d0 and h1-s1-d1.
        t = Topology()
        for i in range(2):
            t.add_host(f"h{i}")
            t.add_switch(f"s{i}")
            t.add_device(f"d{i}")
            t.connect(f"h{i}", f"s{i}")
            t.connect(f"s{i}", f"d{i}")
        fab = Fabric(t)
        assert fab.path("h0", "d0") == ["h0", "s0", "d0"]
        with pytest.raises(ValueError):
            fab.path("h0", "d1")


# -------------------------------------------------- equivalence (satellite)
class TestCXLLinkEquivalence:
    """Direct topology + fabric must reproduce bare CXLLink exactly."""

    def test_single_access_matches(self):
        fab = Fabric(direct(1))
        fd = fab.mount("h0", "d0", DRAMDevice())
        bare = CXLDRAMDevice()
        for now, size, write in [(0, 64, False), (10_000, 4096, True),
                                 (10_500, 64, False)]:
            assert fd.service(now, 0x40, size, write) == \
                bare.service(now, 0x40, size, write)

    def test_trace_timing_matches_exactly(self):
        rng = np.random.default_rng(0)
        trace = [(int(a) * LINE, LINE, bool(w))
                 for a, w in zip(rng.integers(0, 1 << 14, 3000),
                                 rng.random(3000) < 0.3)]
        fab = Fabric(direct(1))
        r_fab = TraceDriver(fab.mount("h0", "d0", DRAMDevice())).run(trace)
        r_bare = TraceDriver(CXLDRAMDevice()).run(trace)
        assert r_fab.elapsed_ticks == r_bare.elapsed_ticks
        assert r_fab.sum_latency_ticks == r_bare.sum_latency_ticks
        assert r_fab.end_tick == r_bare.end_tick

    def test_detach_link_prevents_double_count(self):
        fab = Fabric(direct(1))
        inner = CXLDRAMDevice()
        fd = fab.mount("h0", "d0", inner)  # detaches by default
        assert isinstance(inner.link, NullLink)
        # With the private link neutralized, timing equals DRAM-behind-fabric.
        fab2 = Fabric(direct(1))
        fd2 = fab2.mount("h0", "d0", DRAMDevice())
        assert fd.service(0, 0, LINE, False) == fd2.service(0, 0, LINE, False)

    def test_mount_validates_nodes(self):
        fab = Fabric(direct(1))
        with pytest.raises(ValueError):
            fab.mount("h9", "d0", DRAMDevice())


# ------------------------------------------------- contention (satellite)
class TestSharedBottleneck:
    def _run(self, num_hosts, accesses=8000):
        fab = Fabric(single_switch(num_hosts, 1))
        pool = MemoryPool(fab, {"d0": DRAMDevice()})
        drv = MultiHostDriver(pool.views(fab.topology.hosts), outstanding=64)
        res = drv.run([stream_trace(accesses, base=h << 30)
                       for h in range(num_hosts)])
        return fab, res

    def test_two_hosts_split_the_bottleneck_port(self):
        _, r1 = self._run(1)
        fab, r2 = self._run(2)
        bw1 = r1.per_host_bandwidth_gbps[0]
        # Aggregate is capped by the s0->d0 egress port (16 GB/s)...
        assert r2.aggregate_bandwidth_gbps <= 16.0 * 1.01
        # ...so each of two hosts gets measurably less than a lone host.
        for bw in r2.per_host_bandwidth_gbps:
            assert bw < bw1 * 0.75
        # Symmetric traffic splits the port roughly evenly.
        lo, hi = sorted(r2.per_host_bandwidth_gbps)
        assert hi - lo < 0.1 * hi

    def test_port_queueing_visible_in_stats(self):
        fab, res = self._run(2)
        shared = fab.ports[("s0", "d0")]
        assert shared.packets == 2 * 8000
        assert shared.queued_ticks > 0
        assert 0.9 < shared.utilization(res.elapsed_ticks) <= 1.0

    def test_port_report_utilization_and_bytes_by_host(self):
        fab, res = self._run(2)
        rows = {r["port"]: r for r in fab.port_report(res.elapsed_ticks)}
        shared = rows["s0->d0"]
        # both hosts' traffic is attributed on the shared egress port
        assert shared["bytes_by_host"] == {"h0": 8000 * 64, "h1": 8000 * 64}
        assert sum(shared["bytes_by_host"].values()) == shared["bytes"]
        assert 0.9 < shared["utilization"] <= 1.0
        # host->switch ingress ports carry exactly one host each
        assert rows["h0->s0"]["bytes_by_host"] == {"h0": 8000 * 64}
        # reset clears the attribution
        fab.reset()
        assert fab.ports[("s0", "d0")].bytes_by_origin == {}

    def test_private_links_do_not_contend(self):
        fab = Fabric(direct(2))
        views = [fab.mount(f"h{i}", f"d{i}", DRAMDevice()) for i in range(2)]
        res = MultiHostDriver(views, outstanding=64).run(
            [stream_trace(4000, base=h << 30) for h in range(2)])
        lone = Fabric(direct(1))
        r1 = MultiHostDriver([lone.mount("h0", "d0", DRAMDevice())],
                             outstanding=64).run([stream_trace(4000)])
        for bw in res.per_host_bandwidth_gbps:
            assert bw == pytest.approx(r1.per_host_bandwidth_gbps[0], rel=0.02)


# ----------------------------------------------------------------- pooling
class TestPool:
    def test_interleave_mapper_partitions_address_space(self):
        m = PoolAddressMapper(num_devices=3, granularity=4096)
        seen = {}
        for frame in range(30):
            dev, local = m.map(frame * 4096 + 17)
            assert dev == frame % 3
            assert local % 4096 == 17
            # Local frames are dense per device.
            assert local // 4096 == frame // 3
            seen.setdefault(dev, []).append(local)
        assert set(seen) == {0, 1, 2}

    def test_segment_mapper_and_capacity(self):
        m = PoolAddressMapper(num_devices=2, mode="segment",
                              segment_bytes=1 << 20)
        assert m.map(0) == (0, 0)
        assert m.map((1 << 20) + 5) == (1, 5)
        with pytest.raises(ValueError):
            m.map(2 << 20)

    def test_bad_configs_rejected(self):
        with pytest.raises(ValueError):
            PoolAddressMapper(num_devices=0)
        with pytest.raises(ValueError):
            PoolAddressMapper(num_devices=1, mode="hash")
        fab = Fabric(single_switch(1, 2))
        with pytest.raises(ValueError):
            MemoryPool(fab, {"d0": DRAMDevice()},
                       mapper=PoolAddressMapper(num_devices=2))

    def test_per_host_stats_accumulate_on_views(self):
        fab = Fabric(single_switch(2, 2))
        pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
        v0, v1 = pool.views(["h0", "h1"])
        MultiHostDriver([v0, v1]).run([stream_trace(100),
                                      stream_trace(50, base=1 << 30)])
        assert v0.stats["reads"] + v0.stats["writes"] == 100
        assert v1.stats["reads"] + v1.stats["writes"] == 50
        # Interleaved mapping actually spread traffic over both devices.
        assert all(d.stats["bytes"] > 0 for d in pool.devices)


# ------------------------------------------------------- multi-host driver
class TestMultiHostDriver:
    def test_single_host_matches_trace_driver(self):
        trace = stream_trace(2000)
        dev1, dev2 = DRAMDevice(), DRAMDevice()
        r_multi = MultiHostDriver([dev1]).run([trace])
        r_single = TraceDriver(dev2).run(trace)
        host = r_multi.per_host[0]
        assert host.elapsed_ticks == r_single.elapsed_ticks
        assert host.sum_latency_ticks == r_single.sum_latency_ticks

    def test_mismatched_traces_rejected(self):
        with pytest.raises(ValueError):
            MultiHostDriver([DRAMDevice()]).run([[], []])
        with pytest.raises(ValueError):
            MultiHostDriver([])

    def test_deterministic_across_runs(self):
        def go():
            fab = Fabric(single_switch(2, 1))
            pool = MemoryPool(fab, {"d0": DRAMDevice()})
            res = MultiHostDriver(pool.views(["h0", "h1"])).run(
                [stream_trace(500), stream_trace(500, base=1 << 30)])
            return [(r.elapsed_ticks, r.sum_latency_ticks)
                    for r in res.per_host]
        assert go() == go()


# --------------------------------------------------- congestion estimator
class TestLinkCongestionSim:
    def _sim(self):
        pytest.importorskip("jax")
        from repro.core.fabric.link_sim import LinkCongestionSim
        fab = Fabric(two_level(2, 1, num_leaves=2))
        return fab, LinkCongestionSim(fab, fab.topology.hosts,
                                      fab.topology.devices)

    def test_bytes_conserved_and_bottleneck_found(self):
        fab, sim = self._sim()
        n = 10_000
        hi = np.zeros(n, np.int32)          # all traffic from h0
        di = np.zeros(n, np.int32)
        nb = np.full(n, LINE)
        out = sim.estimate(hi, di, nb, window_s=1e-5)
        assert out["pair_bytes"].sum() == n * LINE
        # Every link on the h0->d0 route carries all bytes; others are idle.
        path = fab.path("h0", "d0")
        hot = {f"{u}->{v}" for u, v in zip(path, path[1:])}
        for name, util in zip(out["link_names"], out["link_utilization"]):
            assert (util > 0) == (name in hot)
        assert out["bottleneck_link"] in hot

    def test_slowdown_scales_with_load(self):
        _, sim = self._sim()
        nb = np.full(10_000, LINE)
        zeros = np.zeros(10_000, np.int32)
        light = sim.estimate(zeros, zeros, nb, window_s=1.0)
        heavy = sim.estimate(zeros, zeros, nb, window_s=1e-7)
        assert light["pair_slowdown"].max() == pytest.approx(1.0)
        assert heavy["pair_slowdown"].max() > 1.0

    def test_what_if_sweep_monotone(self):
        _, sim = self._sim()
        n = 50_000
        rng = np.random.default_rng(1)
        hi = rng.integers(0, 2, n)
        di = np.zeros(n, np.int32)
        out = sim.what_if_bandwidth(hi, di, np.full(n, LINE), 1e-5,
                                    [0.5, 1.0, 2.0, 4.0])
        util = out["max_link_utilization"]
        assert np.all(np.diff(util) < 0)  # faster links -> lower utilization


# --------------------------------------------------------- QoS arbitration
def _qos_pool(weights, num_hosts):
    fab = Fabric.build("single_switch", num_hosts=num_hosts, num_devices=1,
                       qos_weights=weights)
    pool = MemoryPool(fab, {"d0": DRAMDevice()})
    return fab, pool.views([f"h{i}" for i in range(num_hosts)])


class TestQoS:
    def test_equal_weights_reproduce_fcfs_exactly(self):
        """The acceptance criterion: all-equal weights on a single path are
        bit-identical to the pre-QoS FCFS discipline."""
        traces = [stream_trace(3000, base=h << 30) for h in range(2)]

        def go(weights):
            _, views = _qos_pool(weights, 2)
            res = MultiHostDriver(views, outstanding=64).run(traces)
            return [(r.elapsed_ticks, r.sum_latency_ticks, r.end_tick)
                    for r in res.per_host]

        assert go(None) == go({"h0": 2.0, "h1": 2.0}) == \
            go({"h0": 1.0, "h1": 1.0})

    def test_weighted_split_orders_by_weight(self):
        """Under contention the heavy host finishes its trace measurably
        faster, and its contended-phase bandwidth approaches its share."""
        traces = [stream_trace(6000, base=h << 30) for h in range(2)]
        _, views = _qos_pool({"h0": 3.0, "h1": 1.0}, 2)
        res = MultiHostDriver(views, outstanding=32).run(traces)
        heavy, light = res.per_host
        assert heavy.end_tick < light.end_tick * 0.7
        # heavy's own-window bandwidth lands near 3/4 of the 16 GB/s port
        assert heavy.bandwidth_gbps > 10.0
        # and the port is never left idling: the light host reclaims the
        # full port after the heavy trace drains, so no aggregate collapse
        assert res.aggregate_bandwidth_gbps > 9.0

    def test_lone_host_on_weighted_fabric_is_fcfs_exact(self):
        """Work conservation: a lone origin is never regulated, even on a
        fabric with unequal weights configured."""
        trace = [stream_trace(2500)]

        def go(weights):
            _, views = _qos_pool(weights, 2)
            res = MultiHostDriver(views[:1]).run(trace)
            return (res.per_host[0].elapsed_ticks,
                    res.per_host[0].sum_latency_ticks)

        assert go({"h0": 5.0, "h1": 1.0}) == go(None)

    def test_weight_validation(self):
        port = Fabric(single_switch(2, 1)).ports[("s0", "d0")]
        with pytest.raises(ValueError):
            port.set_weights({"h0": 0.0})
        with pytest.raises(ValueError):
            port.set_weights({"h0": -1.0})

    def test_partial_weight_map_rejected(self):
        """A map that skips a host would silently disable the implied
        default-1.0 share (the all-equal gate sees configured values only),
        so the fabric requires every host be weighted explicitly."""
        fab = Fabric(single_switch(3, 1))
        with pytest.raises(ValueError, match="h2"):
            fab.set_qos_weights({"h0": 2.0, "h1": 2.0})
        with pytest.raises(ValueError, match="not a host"):
            fab.set_qos_weights({"h0": 1.0, "h1": 1.0, "h2": 1.0,
                                 "d0": 2.0})
        fab.set_qos_weights({"h0": 2.0, "h1": 2.0, "h2": 1.0})

    def test_set_weights_after_traffic_rejected(self):
        fab = Fabric(single_switch(1, 1))
        fab.traverse(0, "h0", "d0", 64)
        with pytest.raises(ValueError):
            fab.set_qos_weights({"h0": 2.0})
        fab.reset()
        fab.set_qos_weights({"h0": 2.0})    # fine on a reset fabric

    def test_port_report_echoes_weights(self):
        fab, views = _qos_pool({"h0": 3.0, "h1": 1.0}, 2)
        MultiHostDriver(views).run(
            [stream_trace(200, base=h << 30) for h in range(2)])
        rows = {r["port"]: r for r in fab.port_report(1)}
        assert rows["s0->d0"]["qos_weights"] == {"h0": 3.0, "h1": 1.0}


# ------------------------------------------------------------ ECMP routing
class TestECMP:
    def test_spine_leaf_enumerates_all_spines(self):
        fab = Fabric(spine_leaf(2, 2, num_leaves=2, num_spines=4), ecmp=True)
        paths = fab.paths("h0", "d0")
        assert len(paths) == 4
        hops = {len(p) for p in paths}
        assert hops == {5}                      # all equal cost
        assert {p[2] for p in paths} == {"sp0", "sp1", "sp2", "sp3"}
        # lexicographic order, primary path unchanged
        assert paths == sorted(paths)
        assert fab.path("h0", "d0") == paths[0]

    def test_ecmp_off_keeps_single_path(self):
        fab = Fabric(spine_leaf(1, 1, num_leaves=2, num_spines=3))
        assert fab.paths("h0", "d0") == [fab.path("h0", "d0")]

    def test_flow_hash_deterministic_and_scalar_vector_agree(self):
        lines = np.arange(4096, dtype=np.int64)
        v1 = flow_choices("h0", "d3", lines, 5)
        v2 = flow_choices("h0", "d3", lines, 5)
        assert (v1 == v2).all()
        scalar = np.array([flow_hash("h0", "d3", int(x)) % 5 for x in lines])
        assert (v1 == scalar).all()
        # different flow pair -> different (salted) spreading
        assert (v1 != flow_choices("h1", "d3", lines, 5)).any()
        # choices actually spread across the path set
        assert set(np.unique(v1)) == set(range(5))

    def test_ecmp_spreads_traffic_across_spines(self):
        fab = Fabric(spine_leaf(1, 1, num_leaves=2, num_spines=3), ecmp=True)
        dev = fab.mount("h0", "d0", DRAMDevice())
        TraceDriver(dev, outstanding=32).run(stream_trace(3000))
        spine_bytes = {s: fab.ports[("s0", s)].bytes
                       for s in ("sp0", "sp1", "sp2")}
        assert all(b > 0 for b in spine_bytes.values())
        # single-path routing would put every byte on one spine
        total = sum(spine_bytes.values())
        assert max(spine_bytes.values()) < 0.6 * total

    def test_ecmp_lifts_aggregate_on_parallel_spines(self):
        """Two hosts with uplink-bound cross-leaf traffic: ECMP across two
        spines must beat the single deterministic path measurably (thin
        uplinks make the spine tier the bottleneck; with full-rate uplinks
        the edge links bound both modes identically)."""
        def agg(ecmp):
            fab = Fabric(spine_leaf(2, 2, num_leaves=2, num_spines=2,
                                    uplink_bw_gbps=6.0), ecmp=ecmp)
            pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
            res = MultiHostDriver(pool.views(["h0", "h1"]),
                                  outstanding=64).run(
                [stream_trace(6000, base=h << 30) for h in range(2)])
            return res.aggregate_bandwidth_gbps

        assert agg(True) > agg(False) * 1.3

    def test_mesh_equal_cost_paths_are_all_shortest(self):
        fab = Fabric(mesh(1, 1, rows=3, cols=3), ecmp=True)
        paths = fab.paths("h0", "d0")
        assert len(paths) > 1
        want = len(fab.path("h0", "d0"))
        for p in paths:
            assert len(p) == want
            for u, v in zip(p, p[1:]):          # every hop is a real link
                assert (u, v) in fab.ports


# --------------------------------------------- QoS/ECMP property tests
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    WEIGHTS = st.lists(st.sampled_from([0.5, 1.0, 2.0, 3.0, 7.0]),
                       min_size=3, max_size=3)
    PAGES = st.lists(st.integers(0, 63), min_size=96, max_size=96)

    @settings(max_examples=12, deadline=None)
    @given(weights=WEIGHTS, pages=PAGES)
    def test_property_qos_bytes_conserved_no_starvation(weights, pages):
        """Bytes conservation per-origin and no starvation under positive
        weights, for arbitrary weight mixes and traffic."""
        wmap = {f"h{i}": w for i, w in enumerate(weights)}
        fab, views = _qos_pool(wmap, 3)
        traces = [[((h << 30) + p * LINE, LINE, p % 3 == 0) for p in pages]
                  for h in range(3)]
        res = MultiHostDriver(views, outstanding=8).run(traces)
        # no starvation: every access of every host completed
        for host in res.per_host:
            assert host.accesses == len(pages)
            assert host.end_tick < 1 << 50
            assert host.sum_latency_ticks >= 0
        # bytes conservation: per-origin attribution sums to the port total
        for port in fab.ports.values():
            if port.packets:
                assert sum(port.bytes_by_origin.values()) == port.bytes

    @settings(max_examples=8, deadline=None)
    @given(w=st.sampled_from([0.5, 1.0, 2.0, 5.0]), pages=PAGES)
    def test_property_equal_weights_degenerate_to_fcfs(w, pages):
        traces = [[((h << 30) + p * LINE, LINE, p % 3 == 0) for p in pages]
                  for h in range(2)]

        def go(weights):
            _, views = _qos_pool(weights, 2)
            res = MultiHostDriver(views, outstanding=8).run(traces)
            return [(r.elapsed_ticks, r.sum_latency_ticks, r.end_tick)
                    for r in res.per_host]

        assert go({"h0": w, "h1": w}) == go(None)

    @settings(max_examples=16, deadline=None)
    @given(lines=st.lists(st.integers(0, 1 << 40), min_size=4, max_size=64),
           spines=st.integers(2, 5))
    def test_property_ecmp_paths_shortest_and_hash_deterministic(
            lines, spines):
        fab = Fabric(spine_leaf(1, 1, num_leaves=2, num_spines=spines),
                     ecmp=True)
        paths = fab.paths("h0", "d0")
        shortest = len(paths[0])
        assert len(paths) == spines
        assert len({tuple(p) for p in paths}) == spines   # all distinct
        arr = np.asarray(lines, np.int64)
        choices = flow_choices("h0", "d0", arr, len(paths))
        again = flow_choices("h0", "d0", arr, len(paths))
        assert (choices == again).all()
        for line, c in zip(lines, choices):
            chosen = fab.select_path("h0", "d0", line)
            assert chosen == paths[c]               # same selection rule
            assert len(chosen) == shortest          # every choice shortest
            for u, v in zip(chosen, chosen[1:]):
                assert (u, v) in fab.ports          # over real links
