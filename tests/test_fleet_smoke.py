"""Fleet smoke: the sharded replay exercised end-to-end on whatever JAX
device mesh the process has.

In the default test tier this runs the degenerate single-shard mesh (the
same SPMD program).  The CI ``fleet-smoke`` job re-runs it with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the winner
election and record broadcast cross real shard boundaries, and checks the
golden ``fleet-zipf@multipod_2x4`` pin through the sharded lane at that
device count."""

import jax

from golden import scenarios as sc
from repro.core.devices import make_device
from repro.core.fabric import Fabric
from repro.core.replay import (
    MultiHostReplay,
    ShardedMultiHostReplay,
    shard_count,
)
from repro.data import WorkloadSpec, traces_np


def _mounts(nh):
    fab = Fabric.build("multi_pod", ecmp=True, num_pods=2,
                       hosts_per_pod=nh // 2)
    return [fab.mount(f"h{i}", f"d{i}", make_device("dram"))
            for i in range(nh)]


def test_fleet_smoke_sharded_equals_unsharded_on_forced_mesh():
    nh = 8
    spec = WorkloadSpec("zipfian", num_pages=128, zipf_s=1.1)
    addrs, writes = traces_np(spec, 31, nh, 100)
    ru = MultiHostReplay(_mounts(nh), outstanding=8).run_arrays(
        addrs, writes)
    eng = ShardedMultiHostReplay(_mounts(nh), outstanding=8)
    rs = eng.run_arrays(addrs, writes)
    assert ru.elapsed_ticks == rs.elapsed_ticks
    for a, b in zip(ru.per_host, rs.per_host):
        assert (a.accesses, a.elapsed_ticks, a.sum_latency_ticks,
                a.end_tick) == (b.accesses, b.elapsed_ticks,
                                b.sum_latency_ticks, b.end_tick)
    # the mesh must use every device the platform offers (up to H): under
    # the CI job's 8 forced devices this asserts a genuinely distributed
    # run, not a silent single-shard fallback
    assert eng.last_mesh["device_count"] == shard_count(nh)
    if jax.device_count() >= nh:
        assert eng.last_mesh["device_count"] == nh


def test_fleet_smoke_golden_pin_through_sharded_lane():
    """The committed fleet-zipf@multipod_2x4 pin (interpreted
    MultiHostDriver latencies) reproduced by the sharded lane at this
    run's device count."""
    fixture = sc.load_fixture()["scenarios"]
    expected = fixture[sc.FLEET_SCENARIO]["python_scan"]
    actual = sc.run_scan(sc.FLEET_SCENARIO)
    assert len(actual) == sc.FLEET_GOLDEN_HOSTS
    for h, (e, a) in enumerate(zip(expected, actual)):
        assert a["latency_ticks"] == e["latency_ticks"], \
            f"host {h}: sharded per-access latencies diverged from the pin"
        assert a["elapsed_ticks"] == e["elapsed_ticks"]
        assert a["sum_latency_ticks"] == e["sum_latency_ticks"]
        assert a["end_tick"] == e["end_tick"]
    assert sc.run_scan_metrics(sc.FLEET_SCENARIO) == \
        fixture[sc.FLEET_SCENARIO]["metrics"]
