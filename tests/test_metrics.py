"""Observability layer: python-vs-scan metrics parity, percentile
correctness, fabric attribution, streaming-mode allocation, and the
Perfetto export.

The contract under test: with ``metrics=MetricsSpec(...)`` the fused
replay lanes emit the SAME bundle — histogram for histogram, counter for
counter — the interpreted drivers build from their live stats dicts, and
the histogram percentiles agree with ``numpy.percentile`` over the raw
latencies.
"""

import json

import numpy as np
import pytest

from repro.core.cache.dram_cache import DRAMCacheConfig
from repro.core.devices import DRAMDevice, make_device
from repro.core.fabric import Fabric, MemoryPool
from repro.core.fabric.routing import flow_hash
from repro.core.fabric.switch import SwitchPort
from repro.core.replay import (MetricsSpec, MultiHostReplay, ReplayEngine,
                               ReplayUnsupported)
from repro.core.replay.metrics import (MAX_HIST_BUCKETS, bucket_bounds,
                                       bucket_index, bucket_index_jnp,
                                       percentile_from_hist)
from repro.core.workloads.driver import MultiHostDriver, TraceDriver
from repro.obs import to_perfetto, write_perfetto

CACHE_KW = dict(capacity_bytes=16 * 4096, mshr_entries=4, writeback_buffer=2)
SPEC = MetricsSpec()


def _mk(name, policy="lru"):
    if name == "cxl-ssd-cache":
        return make_device(name, cache_cfg=DRAMCacheConfig(
            policy=policy, **CACHE_KW))
    return make_device(name)


def _trace(seed, n=600, pages=48, write_frac=0.3):
    rng = np.random.default_rng(seed)
    addrs = rng.integers(0, pages, n) * 4096 + rng.integers(0, 64, n) * 64
    writes = rng.random(n) < write_frac
    return [(int(a), 64, bool(w)) for a, w in zip(addrs, writes)]


def _gc_device():
    from repro.core.ssd.hil import SSDConfig
    from repro.core.ssd.pal import NANDTiming

    cfg = SSDConfig(capacity_bytes=750 * 4096, page_bytes=4096, channels=2,
                    dies_per_channel=2, pages_per_block=8,
                    timing=NANDTiming.low_latency(), hil_overhead_ns=1000.0)
    return make_device("cxl-ssd-cache", ssd_cfg=cfg,
                       cache_cfg=DRAMCacheConfig(capacity_bytes=8 * 4096,
                                                 mshr_entries=4,
                                                 writeback_buffer=2))


def _qos_ecmp_views(num_hosts=3):
    fab = Fabric.build("spine_leaf", num_hosts=num_hosts, num_devices=2,
                       num_leaves=2, num_spines=2, ecmp=True,
                       qos_weights={"h0": 3.0, "h1": 1.0, "h2": 1.0})
    pool = MemoryPool(fab, {"d0": DRAMDevice(), "d1": DRAMDevice()})
    return pool.views([f"h{i}" for i in range(num_hosts)])


def _parity(py_res, scan_res):
    jp, js = py_res.metrics.to_jsonable(), scan_res.metrics.to_jsonable()
    assert jp == js, "python and scan metrics bundles diverged"
    return jp


# --------------------------------------------------------- direct parity
@pytest.mark.parametrize("name", ["dram", "cxl-dram", "pmem", "cxl-ssd",
                                  "cxl-ssd-cache"])
def test_metrics_parity_all_devices(name):
    trace = _trace(11)
    py = TraceDriver(_mk(name), outstanding=8, engine="python",
                     metrics=SPEC).run(trace)
    rp = ReplayEngine(_mk(name), outstanding=8, metrics=SPEC).run(trace)
    j = _parity(py, rp)
    assert j["media"][0]["accesses"] == len(trace)
    assert sum(j["hist"][0].values()) == len(trace)


def test_metrics_parity_gc_pressure():
    """Write churn past a near-full tiny flash: GC runs/erases/migrations
    and write amplification must agree counter-for-counter."""
    trace = [(p * 4096, 64, True) for p in range(750)]
    trace += _trace(13, n=60, pages=750, write_frac=1.0)
    py = TraceDriver(_gc_device(), outstanding=8, engine="python",
                     metrics=SPEC).run(trace)
    rp = ReplayEngine(_gc_device(), outstanding=8, metrics=SPEC).run(trace)
    j = _parity(py, rp)
    assert j["flash"][0]["gc_runs"] > 0
    assert py.write_amplification == rp.write_amplification > 1.0


def test_metrics_parity_multihost_qos_ecmp():
    traces = [_trace(20 + h, n=300) for h in range(3)]
    py = MultiHostDriver(_qos_ecmp_views(), outstanding=8,
                         metrics=SPEC).run(traces)
    rp = MultiHostReplay(_qos_ecmp_views(), outstanding=8,
                         metrics=SPEC).run(traces)
    j = _parity(py, rp)
    assert j["ecmp"], "spine-leaf ECMP pairs must register path choices"
    assert any(r["qos_throttle_events"] for r in j["ports"].values()), \
        "3:1:1 weights under contention must floor someone"


# ------------------------------------------------ result-surface properties
def test_result_properties_and_empty_trace_guards():
    res = TraceDriver(_mk("cxl-ssd-cache"), engine="python",
                      metrics=SPEC).run(_trace(31))
    assert res.p99_ns is not None and res.p99_ns > 0
    assert 0.0 < res.hit_rate < 1.0
    assert res.write_amplification >= 1.0
    empty = TraceDriver(_mk("dram"), engine="python", metrics=SPEC).run([])
    assert empty.avg_latency_ns == 0.0
    assert empty.p99_ns is None
    assert empty.hit_rate == 0.0
    assert empty.write_amplification == 1.0
    bare = TraceDriver(_mk("dram"), engine="python").run([])
    assert bare.avg_latency_ns == 0.0
    assert bare.p99_ns is None


def test_lane_refusal_for_metricless_engines():
    """Lanes that cannot carry the telemetry accumulators refuse loudly —
    metrics are never silently omitted."""
    for engine in ("assoc", "pallas"):
        with pytest.raises(ReplayUnsupported, match="metrics"):
            TraceDriver(_mk("dram"), engine=engine, metrics=SPEC)


# -------------------------------------------------- streaming allocation
def test_streaming_mode_allocates_buckets_not_trace():
    """``return_latencies=False`` on a cached CXL-SSD: no per-access
    arrays, O(hist_buckets + num_windows) telemetry only, scalar summary
    identical to the full run."""
    trace = _trace(41, n=2000)
    full = ReplayEngine(_mk("cxl-ssd-cache"), metrics=SPEC).run(trace)
    slim = ReplayEngine(_mk("cxl-ssd-cache"), metrics=SPEC).run(
        trace, return_latencies=False)
    assert slim.latency_ticks is None
    assert slim.hit_flags is None and slim.evict_flags is None
    mb = slim.metrics
    assert mb.hist.shape == (1, SPEC.hist_buckets)
    assert mb.windows.shape == (1, SPEC.num_windows, 4)
    assert full.metrics.to_jsonable() == mb.to_jsonable()
    for attr in ("elapsed_ticks", "sum_latency_ticks", "end_tick",
                 "accesses"):
        assert getattr(full, attr) == getattr(slim, attr)


def test_streaming_mode_multihost():
    traces = [_trace(50 + h, n=200) for h in range(3)]
    full = MultiHostReplay(_qos_ecmp_views(), metrics=SPEC).run(traces)
    slim = MultiHostReplay(_qos_ecmp_views(), metrics=SPEC).run(
        traces, return_latencies=False)
    assert full.metrics.to_jsonable() == slim.metrics.to_jsonable()
    assert full.elapsed_ticks == slim.elapsed_ticks
    for a, b in zip(full.per_host, slim.per_host):
        assert (a.elapsed_ticks, a.sum_latency_ticks, a.end_tick) == \
            (b.elapsed_ticks, b.sum_latency_ticks, b.end_tick)


# -------------------------------------------------------- fabric counters
def test_ecmp_bytes_by_host_attribution_exact():
    """Under ECMP multipath, each port's ``bytes_by_host`` must attribute
    exactly the bytes of the flows whose hash chose a path through it —
    computed here independently from the flow hashes."""
    fab = Fabric.build("spine_leaf", num_hosts=2, num_devices=2,
                      num_leaves=2, num_spines=2, ecmp=True)
    rng = np.random.default_rng(7)
    size = 64
    expected = {}
    for _ in range(200):
        host = f"h{rng.integers(0, 2)}"
        dev = f"d{rng.integers(0, 2)}"
        addr = int(rng.integers(0, 1 << 20)) * size
        fab.traverse(0, host, dev, size, line_addr=addr // 64)
        paths = fab.routing.paths(host, dev)
        path = paths[flow_hash(host, dev, addr // 64) % len(paths)] \
            if len(paths) > 1 else paths[0]
        for u, v in zip(path, path[1:]):
            key = expected.setdefault((u, v), {})
            key[host] = key.get(host, 0) + size
    for (u, v), by_host in expected.items():
        assert fab.ports[(u, v)].bytes_by_origin == by_host, f"{u}->{v}"
    # port_report surfaces the same attribution (plus the new counter)
    for row in fab.port_report(1):
        u, v = row["port"].split("->")
        assert row["bytes_by_host"] == expected[(u, v)]
        assert row["qos_throttle_events"] == 0  # no QoS weights configured
    # and the selection counts cover every multipath pair that carried flow
    assert fab.ecmp_counts
    for key, counts in fab.ecmp_counts.items():
        assert sum(counts) > 0 and len(counts) > 1


def test_qos_throttle_event_counter():
    port = SwitchPort("a", "b", bw_gbps=64.0)
    port.set_weights({"h0": 3.0, "h1": 1.0})
    assert port.qos_update(0, 64, "h1") == 0      # first arrival: no floor
    floored = 0
    for t in range(1, 20):
        floored += port.qos_update(t, 64, "h1") > 0
    assert port.qos_throttle_events == floored > 0
    port.reset()
    assert port.qos_throttle_events == 0


# ------------------------------------------------------------ percentiles
def test_percentiles_match_numpy_inverted_cdf():
    rng = np.random.default_rng(3)
    for n in (1, 2, 17, 1000):
        lat = rng.integers(0, 1 << 30, n)
        hist = np.bincount(bucket_index(lat, SPEC.hist_buckets),
                           minlength=SPEC.hist_buckets)
        for q in (50, 95, 99, 100):
            p = percentile_from_hist(hist, q)
            want = int(np.percentile(lat, q, method="inverted_cdf"))
            assert p["lo"] <= want <= p["hi"], (n, q)
            assert p["bucket"] == int(bucket_index(want, SPEC.hist_buckets))
    assert percentile_from_hist(np.zeros(16, np.int64), 99) is None


def test_bucket_index_numpy_jnp_twins_agree():
    from jax.experimental import enable_x64

    vals = np.concatenate([
        np.arange(0, 64),
        2 ** np.arange(3, 52, dtype=np.int64),
        2 ** np.arange(3, 52, dtype=np.int64) - 1,
        np.random.default_rng(5).integers(0, 1 << 52, 500)])
    with enable_x64():
        jidx = np.asarray(bucket_index_jnp(vals, MAX_HIST_BUCKETS))
    nidx = bucket_index(vals, MAX_HIST_BUCKETS)
    assert (jidx == nidx).all()
    # bounds invert the index: every value lies inside its bucket
    for v in vals[vals < (1 << 40)]:
        lo, hi = bucket_bounds(int(nidx[list(vals).index(v)]))
        assert lo <= int(v) <= hi


def test_metrics_spec_validation():
    with pytest.raises(ValueError):
        MetricsSpec(hist_buckets=4)
    with pytest.raises(ValueError):
        MetricsSpec(hist_buckets=MAX_HIST_BUCKETS + 1)
    with pytest.raises(ValueError):
        MetricsSpec(num_windows=0)


# -------------------------------------------------------- perfetto export
def test_perfetto_export_smoke(tmp_path):
    traces = [_trace(60 + h, n=150) for h in range(3)]
    res = MultiHostDriver(_qos_ecmp_views(), outstanding=8,
                          metrics=SPEC).run(traces)
    path = write_perfetto(res, str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ns"
    phases = {e["ph"] for e in events}
    assert {"M", "C", "X"} <= phases
    procs = {e["args"]["name"] for e in events if e["name"] == "process_name"}
    assert {"host h0", "host h1", "host h2", "fabric", "devices"} <= procs
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert {"bandwidth_gbps", "occupancy", "hit_rate"} == counters
    assert any(e["name"].startswith("port ") for e in events)
    assert any(e["name"].startswith("ecmp ") for e in events)
    with pytest.raises(TypeError):
        to_perfetto(object())


# --------------------------------------------------- property tests (sat.)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    # Fixed length + bounded page pool keeps one compiled program per
    # device kind across all examples (same shape discipline as
    # test_replay's property tests).
    PAGES = st.lists(st.integers(0, 31), min_size=192, max_size=192)
    WRITES = st.lists(st.booleans(), min_size=192, max_size=192)
    OFFSETS = st.lists(st.integers(0, 63), min_size=192, max_size=192)

    @settings(max_examples=6, deadline=None)
    @given(pages=PAGES, writes=WRITES, offs=OFFSETS,
           name=st.sampled_from(["dram", "cxl-dram", "pmem", "cxl-ssd",
                                 "cxl-ssd-cache"]))
    def test_property_metrics_parity_all_devices(pages, writes, offs, name):
        trace = [(p * 4096 + o * 64, 64, w)
                 for p, o, w in zip(pages, offs, writes)]
        py = TraceDriver(_mk(name), outstanding=4, engine="python",
                         metrics=SPEC).run(trace)
        rp = ReplayEngine(_mk(name), outstanding=4, metrics=SPEC).run(trace)
        _parity(py, rp)

    @settings(max_examples=4, deadline=None)
    @given(pages=PAGES, writes=WRITES)
    def test_property_metrics_parity_multihost_qos_ecmp(pages, writes):
        traces = [[(p * 4096 + ((h * 7 + i) % 64) * 64, 64, w)
                   for i, (p, w) in enumerate(zip(pages, writes))]
                  for h in range(3)]
        py = MultiHostDriver(_qos_ecmp_views(), outstanding=4,
                             metrics=SPEC).run(traces)
        rp = MultiHostReplay(_qos_ecmp_views(), outstanding=4,
                             metrics=SPEC).run(traces)
        _parity(py, rp)

    # 600 of 750 pages pre-filled: close enough to the watermark that the
    # rewrite tail collects, far enough that greedy GC keeps up with any
    # 192-rewrite distribution (uniform spread is the worst case; tested)
    GC_PAGES = st.lists(st.integers(0, 599), min_size=192, max_size=192)

    @settings(max_examples=4, deadline=None)
    @given(pages=GC_PAGES, offs=OFFSETS)
    def test_property_metrics_parity_gc_pressure(pages, offs):
        trace = [(p * 4096, 64, True) for p in range(600)]
        trace += [(p * 4096 + o * 64, 64, True)
                  for p, o in zip(pages, offs)]
        py = TraceDriver(_gc_device(), outstanding=8, engine="python",
                         metrics=SPEC).run(trace)
        rp = ReplayEngine(_gc_device(), outstanding=8,
                          metrics=SPEC).run(trace)
        _parity(py, rp)

    LATS = st.lists(st.integers(0, (1 << 48) - 1), min_size=1, max_size=400)

    @settings(max_examples=50, deadline=None)
    @given(lat=LATS, q=st.sampled_from([50.0, 90.0, 95.0, 99.0, 99.9]))
    def test_property_percentile_contains_numpy(lat, q):
        arr = np.asarray(lat, np.int64)
        hist = np.bincount(bucket_index(arr, MAX_HIST_BUCKETS),
                           minlength=MAX_HIST_BUCKETS)
        p = percentile_from_hist(hist, q)
        want = int(np.percentile(arr, q, method="inverted_cdf"))
        assert p["lo"] <= want <= p["hi"]
        assert p["n"] == arr.size
